"""Granite-3.0-8B [hf:ibm-granite]: 40L, d=4096, 32H GQA(kv=8), ff=12800, v=49155."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12800, vocab_size=49155,
)
