"""RecurrentGemma-9B [arXiv:2402.19427]: 38L, d=4096, 16H MQA(kv=1), ff=12288,
v=256000.  RG-LRU + local attention, pattern (rec, rec, attn) = 1 attn : 2 rec,
window 2048.  Sub-quadratic -> serves long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000, mlp_act="gelu",
    block_pattern=("rglru", "rglru", "attn"), window=2048,
)
