"""Qwen2-1.5B [arXiv:2407.10671]: 28L, d=1536, 12H GQA(kv=2), ff=8960, v=151936.

GQA with QKV bias, SwiGLU, tied embeddings (Qwen2-1.5B ties lm_head).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
    rope_theta=1e6,
)
