"""InternVL2-1B [arXiv:2404.16821]: Qwen2-0.5B LM backbone (24L, d=896, 14H
GQA(kv=2), ff=4864, v=151655) + InternViT frontend (STUB: input_specs provides
256 precomputed patch embeddings per image).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151655, qkv_bias=True, tie_embeddings=True,
    frontend="vit_stub", n_frontend_tokens=256, rope_theta=1e6,
)
