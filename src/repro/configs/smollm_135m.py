"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M]: 30L, d=576, 9H GQA(kv=3), ff=1536, v=49152."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, head_dim=64,
    d_ff=1536, vocab_size=49152, tie_embeddings=True,
)
