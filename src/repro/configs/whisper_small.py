"""Whisper-small [arXiv:2212.04356]: enc-dec, 12L+12L, d=768, 12H MHA(kv=12),
ff=3072, v=51865.  Conv audio frontend is a STUB (precomputed frame embeddings,
1500 frames = 30 s).  GELU MLPs, pre-LN.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865, mlp_act="gelu",
    is_encdec=True, n_enc_layers=12, n_enc_tokens=1500,
    frontend="audio_stub", tie_embeddings=True,
)
