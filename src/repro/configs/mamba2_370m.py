"""Mamba2-370M [arXiv:2405.21060]: 48L, d=1024, attn-free SSD, state=128.

d_inner = 2*d = 2048, head_dim 64 -> 32 SSD heads. Sub-quadratic -> long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280, tie_embeddings=True,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
    block_pattern=("ssm",),
)
