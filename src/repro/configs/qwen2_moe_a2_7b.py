"""Qwen1.5-MoE-A2.7B [hf:Qwen]: 24L, d=2048, 16H MHA(kv=16), 60 routed experts
top-4 + 4 shared (shared ff = 4x1408 = 5632), expert ff=1408, v=151936."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=151936, qkv_bias=True,
    n_experts=60, n_experts_active=4, shared_d_ff=5632,
)
