"""Granite-3.0-1B-A400M [hf:ibm-granite]: 24L, d=1024, 16H GQA(kv=8),
32 experts top-8, expert ff=512, v=49155."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    n_experts=32, n_experts_active=8,
)
