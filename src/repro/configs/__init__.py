"""Assigned-architecture registry: one module per arch, exact public configs."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = [
    "qwen2_1_5b",
    "smollm_135m",
    "granite_3_8b",
    "minicpm_2b",
    "recurrentgemma_9b",
    "internvl2_1b",
    "granite_moe_1b_a400m",
    "qwen2_moe_a2_7b",
    "mamba2_370m",
    "whisper_small",
]

# CLI ids (dashes) → module names
_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
