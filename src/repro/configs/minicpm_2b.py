"""MiniCPM-2B [arXiv:2404.06395]: 40L, d=2304, 36H MHA(kv=36), ff=5760, v=122753.

Llama-like arch; trained with the WSD schedule (optim/schedules.py provides it).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
    d_ff=5760, vocab_size=122753, tie_embeddings=True,
)
