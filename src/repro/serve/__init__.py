"""repro.serve"""
