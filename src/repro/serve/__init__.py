"""repro.serve — two-phase batched-prefill/decode serving over a ring or
paged-block-pool KV cache (DESIGN.md §6)."""

from repro.serve.engine import (Engine, Request, make_chunked_prefill,
                                make_decode_and_sample, make_fused_decode,
                                make_paged_prefill, make_serve_fns)
from repro.serve.kvpool import KVPool
from repro.serve.metrics import (Histogram, JsonlSink, Metrics, NullSink,
                                 StdoutSink, make_sink)
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.scheduler import Scheduler
from repro.serve.trace import Tracer, format_explain

__all__ = ["Engine", "Request", "make_serve_fns", "make_decode_and_sample",
           "make_fused_decode", "make_chunked_prefill", "make_paged_prefill",
           "KVPool", "SamplingParams", "sample_tokens",
           "Scheduler", "Metrics", "Histogram", "NullSink", "StdoutSink",
           "JsonlSink", "make_sink", "Tracer", "format_explain"]
