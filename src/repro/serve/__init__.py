"""repro.serve — two-phase batched-prefill/decode serving (DESIGN.md §6)."""

from repro.serve.engine import Engine, Request, make_serve_fns
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.scheduler import Scheduler

__all__ = ["Engine", "Request", "make_serve_fns", "SamplingParams",
           "sample_tokens", "Scheduler"]
