"""Serving: two-phase (batched prefill → batched decode) engine.

``make_serve_fns`` builds the two jit-able entry points — ``prefill_step``
and ``decode_step`` — and ``Engine`` is the host-side loop that drives them
(DESIGN.md §6): a :class:`~repro.serve.scheduler.Scheduler` admits queued
requests into free decode slots; admitted prompts run through the *batched*
``prefill_step`` (right-padded prompt batch, one forward pass, KV written
per-slot into the shared ring cache, prefill logits seeding the first
sampled token); the steady state is one ``decode_step`` per tick over every
active slot.  Per-request :class:`~repro.serve.sampling.SamplingParams`
drive greedy/temperature/top-k sampling, EOS/stop handling and the
per-request dither-counter offsets; slots are preempted at ``max_len`` and
recycled; streaming callbacks fire per emitted token.

The numerics policy — and therefore the fused kernel backend — applies to
prefill and decode alike, so weight-quantised serving exercises the same
dispatcher path as training.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.models.config import ModelConfig
from repro.numerics.policy import QuantPolicy
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.scheduler import Scheduler

__all__ = ["make_serve_fns", "make_decode_and_sample", "Engine", "Request",
           "SamplingParams", "Scheduler"]


def make_serve_fns(cfg: ModelConfig, policy: Optional[QuantPolicy] = None, *,
                   max_len: int, kv_quant: bool = False, frames=None):
    """Build the two jit-able serving entry points (DESIGN.md §6).

    ``prefill_step(params, tokens, lengths, kv_offset, counter)`` maps a
    right-padded (B, S) prompt batch + (B,) true lengths to the last-prompt-
    token logits (B, vocab) and a full decode cache whose per-slot positions
    equal ``lengths`` — attention-only architectures do this in one batched
    forward (``transformer.prefill_with_cache``); recurrent/enc-dec
    architectures fall back to a scanned on-device prefill
    (``registry.apply_prefill``).  ``decode_step(params, token, cache,
    kv_offset, counter)`` is one token for every slot.  The engine jits the
    prefill step directly and drives decode through the fused
    ``make_decode_and_sample`` tick below; ``decode_step`` remains the
    standalone two-call building block (launch/dryrun.py rooflines the same
    prefill-forward and decode-step compute at pod scale, and the parity
    tests replay it against the fused path).  ``policy`` is resolved here so
    the traced steps embed a concrete kernel-dispatcher backend.
    """
    policy = policy.resolved() if policy is not None else None
    batched = registry.supports_batched_prefill(cfg)

    def prefill_step(params, tokens, lengths, kv_offset=None, counter=0):
        cache0 = None
        if not batched:
            cache0 = registry.make_cache(
                params, cfg, tokens.shape[0], max_len, frames=frames,
                policy=policy, kv_quant=kv_quant)
        return registry.apply_prefill(
            params, cfg, tokens, lengths, max_len, policy=policy,
            counter=counter, kv_quant=kv_quant, kv_offset=kv_offset,
            cache0=cache0)

    def decode_step(params, token, cache, kv_offset=None, counter=0):
        return registry.apply_decode(params, cfg, token, cache, policy=policy,
                                     counter=counter, kv_offset=kv_offset)

    return prefill_step, decode_step


def make_decode_and_sample(cfg: ModelConfig,
                           policy: Optional[QuantPolicy] = None):
    """Build the fused single-dispatch decode tick (DESIGN.md §6).

    One jitted call per generated token: ``decode_and_sample(params, token,
    cache, kv_offset, counter, temps, topks, seeds, counters)`` runs the
    model decode step *and* the per-slot sampler on device and returns
    ``(tokens (B,) int32, counters + 1, new cache)`` — the PR-2 engine's
    ``decode_step`` + ``sample_tokens`` pair collapsed into one device
    dispatch, so the steady-state tick costs one host→device launch instead
    of two.  The sampling counters advance on device (one emitted token per
    tick per slot); the engine refreshes its device-resident copies only
    when slot state actually changes.  Token-stream equivalence with the
    two-call path is pinned by tests/test_decode_attention.py.
    """
    policy = policy.resolved() if policy is not None else None

    def decode_and_sample(params, token, cache, kv_offset, counter,
                          temps, topks, seeds, counters):
        logits, new_cache = registry.apply_decode(
            params, cfg, token, cache, policy=policy, counter=counter,
            kv_offset=kv_offset)
        toks = sample_tokens(logits, temps, topks, seeds, counters)
        return toks, counters + 1, new_cache

    return decode_and_sample


@dataclass
class Request:
    """One generation request.

    Lifecycle (DESIGN.md §6): ``queued`` → (scheduler admits) → ``active``
    → ``done`` with ``finish_reason`` ∈ {"eos", "stop", "length",
    "preempted", "rejected"}.  ``sampling`` carries the per-request decode
    controls; ``max_new`` is a convenience override of
    ``sampling.max_new`` kept from the original API.  ``stream`` (if set)
    is called as ``stream(request, token)`` for every emitted token.
    Timing fields are host-clock seconds: ``ttft`` = time-to-first-token
    from submission, ``itl`` = inter-token latencies.
    """

    rid: int
    prompt: List[int]
    sampling: SamplingParams = field(default_factory=SamplingParams)
    priority: int = 0
    max_new: Optional[int] = None
    stream: Optional[Callable[["Request", int], None]] = None
    out: List[int] = field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None
    state: str = "new"
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_last: Optional[float] = None
    itl: List[float] = field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        if self.t_first is None or self.t_submit is None:
            return None
        return self.t_first - self.t_submit

    def effective_max_new(self) -> int:
        return self.max_new if self.max_new is not None else self.sampling.max_new


def _bucket(n: int) -> int:
    """Round a prompt length up to a power of two (≥ 8) so the jitted
    prefill compiles once per bucket, not once per prompt length."""
    b = 8
    while b < n:
        b *= 2
    return b


class Engine:
    """Host-side continuous-batching loop over the two jitted serve fns.

    Fixed decode batch B (the slot count) over a shared per-slot ring-buffer
    KV cache.  Each :meth:`step`:

    1. asks the scheduler for requests to fill free slots; admitted prompts
       are right-padded into a (B, S_bucket) batch and run through the
       batched ``prefill_step`` — the prompt costs one forward pass, its KV
       lands in the admitted slots, and the prefill logits seed each
       request's first sampled token;
    2. runs one fused ``decode_and_sample`` call for every active slot —
       model decode step *and* per-request sampling
       (:class:`SamplingParams`) in a single device dispatch per tick;
    3. retires slots on EOS/stop tokens, ``max_new``, or ``max_len``
       preemption, freeing them for the next admission wave.

    Steady-state host↔device traffic is minimal: the per-slot sampling
    state (temperature / top-k / seed / counter-offset arrays) and the last
    sampled token live **device-resident** and are re-uploaded only when
    slot membership changes (admission), with the sampling counters and
    last tokens advancing on device inside the fused step; and the ring
    cache argument is **donated** to the jitted decode and prefill-merge
    steps, so the B×cap×layers KV updates in place instead of
    double-buffering every tick.

    The policy dither counter advances once per engine tick ("rounding in
    time", §VII); per-request ``counter_offset`` shifts the int8-KV and
    sampling counters so concurrent requests walk independent pulse
    sequences and restarts replay identically (DESIGN.md §6).
    """

    def __init__(self, params, cfg: ModelConfig, batch: int, max_len: int,
                 policy: Optional[QuantPolicy] = None, frames=None,
                 kv_quant: bool = False,
                 scheduler: Union[str, Scheduler] = "fcfs"):
        self.params, self.cfg, self.batch, self.max_len = params, cfg, batch, max_len
        policy = policy.resolved() if policy is not None else None
        self.policy = policy
        self.kv_quant = kv_quant
        self.cache = registry.make_cache(params, cfg, batch, max_len,
                                         frames=frames, policy=policy,
                                         kv_quant=kv_quant)
        prefill_step, decode_step = make_serve_fns(
            cfg, policy, max_len=max_len, kv_quant=kv_quant, frames=frames)
        self._prefill = jax.jit(prefill_step)
        self._sample = jax.jit(sample_tokens)
        # one fused device dispatch per decode tick; the cache argument is
        # donated so the ring buffer updates in place (no double-buffered
        # B×cap×layers KV copy per token)
        self._decode_and_sample = jax.jit(
            make_decode_and_sample(cfg, policy), donate_argnums=(2,))
        self._merge = jax.jit(
            lambda old, new, act: registry.merge_prefill(cfg, old, new, act),
            donate_argnums=(0,))

        self.scheduler = (Scheduler(scheduler) if isinstance(scheduler, str)
                          else scheduler)
        self.slots: List[Optional[Request]] = [None] * batch
        self.finished: List[Request] = []
        self.tick = 0
        # per-slot state: host mirrors for bookkeeping, plus device-resident
        # copies refreshed only when slot membership changes (admission);
        # steady-state decode ticks advance the device copies in place
        self._last_token = np.zeros((batch,), np.int32)
        self._slot_pos = np.zeros((batch,), np.int64)
        self._temps = np.zeros((batch,), np.float32)
        self._topks = np.zeros((batch,), np.int32)
        self._seeds = np.zeros((batch,), np.int32)
        self._offsets = np.zeros((batch,), np.int32)
        self._counters = np.zeros((batch,), np.int32)
        self._dev = {}
        self._dev_dirty = True
        self.stats = {"prefill_s": 0.0, "prefill_tokens": 0, "prefill_calls": 0,
                      "decode_s": 0.0, "decode_tokens": 0, "decode_calls": 0}

    # ------------------------------------------------------------------ API

    def reset_stats(self):
        """Zero the throughput counters (benchmarks call this after a
        warm-up wave so compile time stays out of the measured rates)."""
        self.stats = {k: type(v)() for k, v in self.stats.items()}

    def submit(self, req: Request):
        req.state = "queued"
        if req.t_submit is None:
            req.t_submit = time.time()
        self.scheduler.submit(req)

    def step(self) -> List[Request]:
        """One engine tick: admit + batched-prefill, then decode every
        active slot.  Returns the requests still active after the tick."""
        self._admit_and_prefill()
        if any(s is not None for s in self.slots):
            self._decode_tick()
        return [s for s in self.slots if s is not None]

    def run(self, ticks: int) -> List[Request]:
        """Drive :meth:`step` until the queue and slots drain (or ``ticks``
        elapse); returns every request finished so far."""
        for _ in range(ticks):
            self.step()
            if not len(self.scheduler) and all(s is None for s in self.slots):
                break
        return self.finished

    # ------------------------------------------------------------ internals

    def _refresh_device_state(self):
        """Re-upload the per-slot sampling state and last tokens if any slot
        changed since the previous tick (admission marks the state dirty);
        in steady state this is a no-op and decode ticks touch the host only
        to read the sampled tokens back."""
        if self._dev_dirty:
            self._dev = {
                "temps": jnp.asarray(self._temps),
                "topks": jnp.asarray(self._topks),
                "seeds": jnp.asarray(self._seeds),
                "offsets": jnp.asarray(self._offsets),
                "counters": jnp.asarray(self._counters),
                "last_token": jnp.asarray(self._last_token),
            }
            self._dev_dirty = False

    def _admit_and_prefill(self):
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return
        admitted = []
        for req in self.scheduler.admit(len(free)):
            if len(req.prompt) > self.max_len:
                req.done, req.finish_reason, req.state = True, "rejected", "done"
                self.finished.append(req)
                continue
            admitted.append(req)
        if not admitted:
            return

        now = time.time()
        lens = np.zeros((self.batch,), np.int32)
        prompts = {}
        for req in admitted:
            i = free.pop(0)
            sp = req.sampling
            self.slots[i] = req
            req.state, req.t_admit = "active", now
            prompts[i] = list(req.prompt) or [1]          # empty prompt → BOS
            lens[i] = len(prompts[i])
            self._temps[i] = sp.temperature
            self._topks[i] = sp.top_k
            self._seeds[i] = sp.seed
            self._offsets[i] = sp.counter_offset
            self._counters[i] = sp.counter_offset
            self._slot_pos[i] = lens[i]

        s_bucket = _bucket(int(lens.max()))
        toks = np.zeros((self.batch, s_bucket), np.int32)
        for i, p in prompts.items():
            toks[i, : len(p)] = p

        self._dev_dirty = True            # admission changed per-slot state
        self._refresh_device_state()
        t0 = time.time()
        last_logits, pf_cache = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(lens),
            self._dev["offsets"], self.tick)
        self.cache = self._merge(self.cache, pf_cache,
                                 jnp.asarray(lens > 0))
        first = np.asarray(self._sample(
            last_logits, self._dev["temps"], self._dev["topks"],
            self._dev["seeds"], self._dev["counters"]))
        dt = time.time() - t0
        self.stats["prefill_s"] += dt
        self.stats["prefill_tokens"] += int(lens.sum())
        self.stats["prefill_calls"] += 1

        now = time.time()
        for i, req in list(prompts.items()):
            self._emit(i, self.slots[i], int(first[i]), now)
        # _emit advanced host counters / last tokens for the admitted slots;
        # re-sync the device copies before the first decode tick reads them
        self._dev_dirty = True

    def _decode_tick(self):
        active = [(i, s) for i, s in enumerate(self.slots) if s is not None]
        self._refresh_device_state()
        t0 = time.time()
        toks_dev, counters_dev, self.cache = self._decode_and_sample(
            self.params, self._dev["last_token"], self.cache,
            self._dev["offsets"], self.tick,
            self._dev["temps"], self._dev["topks"], self._dev["seeds"],
            self._dev["counters"])
        toks = np.asarray(toks_dev)
        dt = time.time() - t0
        # the fused step advanced counters and produced the next input token
        # on device — keep those copies resident (no re-upload next tick)
        self._dev["counters"] = counters_dev
        self._dev["last_token"] = toks_dev
        self.tick += 1
        self.stats["decode_s"] += dt
        self.stats["decode_tokens"] += len(active)
        self.stats["decode_calls"] += 1

        now = time.time()
        for i, req in active:
            self._slot_pos[i] += 1
            self._emit(i, req, int(toks[i]), now)

    def _emit(self, i: int, req: Request, tok: int, now: float):
        req.out.append(tok)
        if req.t_first is None:
            req.t_first = now
        else:
            req.itl.append(now - req.t_last)
        req.t_last = now
        self._counters[i] += 1
        self._last_token[i] = tok
        if req.stream is not None:
            req.stream(req, tok)

        sp = req.sampling
        if sp.eos_id is not None and tok == sp.eos_id:
            self._finish(i, req, "eos")
        elif tok in sp.stop_set():
            self._finish(i, req, "stop")
        elif len(req.out) >= req.effective_max_new():
            self._finish(i, req, "length")
        elif self._slot_pos[i] >= self.max_len:
            # the slot's ring cache is full: preempt so the next admission
            # wave can recycle it (the request keeps what it generated)
            self._finish(i, req, "preempted")

    def _finish(self, i: int, req: Request, reason: str):
        req.done, req.finish_reason, req.state = True, reason, "done"
        self.finished.append(req)
        self.slots[i] = None
