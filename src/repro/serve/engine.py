"""Serving: prefill + batched decode engine.

``make_serve_fns`` builds the two pjit-able entry points the dry-run lowers
(``prefill_step`` and ``decode_step``); ``Engine`` is the host-side loop used
by the examples — continuous batching over a request queue with a shared
ring-buffer KV cache (slots freed on EOS / max-len).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.models.config import ModelConfig
from repro.numerics.policy import QuantPolicy

__all__ = ["make_serve_fns", "Engine"]


def make_serve_fns(cfg: ModelConfig, policy: Optional[QuantPolicy] = None):
    # pin backend aliases to a concrete kernel-dispatcher backend at build
    # time, so the lowered prefill/decode route through kernels/dispatch.py
    policy = policy.resolved() if policy is not None else None

    def prefill_step(params, batch):
        return registry.apply_model(params, cfg, batch, policy=policy, remat=False)

    def decode_step(params, token, cache):
        return registry.apply_decode(params, cfg, token, cache, policy=policy)

    return prefill_step, decode_step


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class Engine:
    """Minimal continuous-batching decode engine (example/serving driver).

    Fixed decode batch B; requests are admitted into free slots, prompts are
    prefilled token-by-token into the slot's cache region (CPU-scale demo —
    a production deployment would use the prefill_step path), then decoded
    greedily until EOS/max_new.
    """

    def __init__(self, params, cfg: ModelConfig, batch: int, max_len: int,
                 policy: Optional[QuantPolicy] = None, frames=None,
                 kv_quant: bool = False):
        self.params, self.cfg, self.batch, self.max_len = params, cfg, batch, max_len
        policy = policy.resolved() if policy is not None else None
        self.policy = policy
        self.cache = registry.make_cache(params, cfg, batch, max_len, frames=frames,
                                         policy=policy, kv_quant=kv_quant)
        self._decode = jax.jit(
            lambda p, t, c: registry.apply_decode(p, cfg, t, c, policy=policy)
        )
        self.slots: List[Optional[Request]] = [None] * batch
        self.queue: List[Request] = []
        self.token = jnp.zeros((batch,), jnp.int32)

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                self.slots[i] = self.queue.pop(0)

    def step(self):
        """One engine tick: admit, decode one token for every active slot."""
        self._admit()
        feed = []
        for i, req in enumerate(self.slots):
            if req is None:
                feed.append(0)
            elif req.prompt:
                feed.append(req.prompt.pop(0))       # prefill phase (teacher-forced)
            elif req.out:
                feed.append(req.out[-1])
            else:
                feed.append(1)                        # BOS
        token = jnp.asarray(feed, jnp.int32)
        logits, self.cache = self._decode(self.params, token, self.cache)
        nxt = jnp.argmax(logits, axis=-1)
        for i, req in enumerate(self.slots):
            if req is None or req.prompt:
                continue
            req.out.append(int(nxt[i]))
            if len(req.out) >= req.max_new:
                req.done = True
                self.slots[i] = None
        return [r for r in [s for s in self.slots] if r is not None]

    def run(self, ticks: int):
        done: List[Request] = []
        seen = set()
        all_reqs = list(self.queue)
        for _ in range(ticks):
            self.step()
            for r in all_reqs:
                if r.done and r.rid not in seen:
                    seen.add(r.rid)
                    done.append(r)
            if not self.queue and all(s is None for s in self.slots):
                break
        return done
