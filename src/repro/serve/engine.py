"""Serving: two-phase (batched prefill → batched decode) engine.

``make_serve_fns`` builds the two jit-able entry points — ``prefill_step``
and ``decode_step`` — and ``Engine`` is the host-side loop that drives them
(DESIGN.md §6): a :class:`~repro.serve.scheduler.Scheduler` admits queued
requests into free decode slots; admitted prompts run through the *batched*
``prefill_step`` (right-padded prompt batch, one forward pass, KV written
per-slot into the shared ring cache, prefill logits seeding the first
sampled token); the steady state is one ``decode_step`` per tick over every
active slot.  Per-request :class:`~repro.serve.sampling.SamplingParams`
drive greedy/temperature/top-k sampling, EOS/stop handling and the
per-request dither-counter offsets; slots are preempted at ``max_len`` and
recycled; streaming callbacks fire per emitted token.

The numerics policy — and therefore the fused kernel backend — applies to
prefill and decode alike, so weight-quantised serving exercises the same
dispatcher path as training.

``Engine(..., mesh=...)`` runs the same loop sharded over a
('data', 'model') mesh (DESIGN.md §9): decode slots and the paged block
pools partition on 'data' (one shard-local ``KVPool`` per data shard), KV
heads on 'model' (replicated fallback when the GQA head count does not
divide), and every jitted step executes per-shard under ``shard_map``.
The layout is reduction-preserving — QKV column-parallel, heads
all-gathered before a replicated W_O, no psums — so for policy-free bf16
and int8-KV serving the sharded token stream is *bitwise* the
single-device stream (tests/test_sharded_serve.py).
"""

from __future__ import annotations

import functools
import json
import os
import time
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Callable, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import ctx as dist_ctx
from repro.dist import sharding as dist_sharding
from repro.dist.fault_tolerance import StragglerWatchdog
from repro.models import registry
from repro.models.config import ModelConfig
from repro.numerics.policy import QuantPolicy
from repro.serve.draft import Drafter, PromptLookupDrafter
from repro.serve.kvpool import KVPool
from repro.serve.metrics import Metrics
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.scheduler import Scheduler
from repro.serve.trace import Tracer

__all__ = ["make_serve_fns", "make_decode_and_sample", "make_fused_decode",
           "make_paged_prefill", "make_chunked_prefill", "make_spec_verify",
           "Engine", "Request", "SamplingParams", "Scheduler", "KVPool",
           "Metrics", "Drafter", "PromptLookupDrafter"]


def make_serve_fns(cfg: ModelConfig, policy: Optional[QuantPolicy] = None, *,
                   max_len: int, kv_quant: bool = False, frames=None):
    """Build the two jit-able serving entry points (DESIGN.md §6).

    ``prefill_step(params, tokens, lengths, kv_offset, counter)`` maps a
    right-padded (B, S) prompt batch + (B,) true lengths to the last-prompt-
    token logits (B, vocab) and a full decode cache whose per-slot positions
    equal ``lengths`` — attention-only architectures do this in one batched
    forward (``transformer.prefill_with_cache``); recurrent/enc-dec
    architectures fall back to a scanned on-device prefill
    (``registry.apply_prefill``).  ``decode_step(params, token, cache,
    kv_offset, counter)`` is one token for every slot.  The engine jits the
    prefill step directly and drives decode through the fused
    ``make_decode_and_sample`` tick below; ``decode_step`` remains the
    standalone two-call building block (launch/dryrun.py rooflines the same
    prefill-forward and decode-step compute at pod scale, and the parity
    tests replay it against the fused path).  ``policy`` is resolved here so
    the traced steps embed a concrete kernel-dispatcher backend.
    """
    policy = policy.resolved() if policy is not None else None
    batched = registry.supports_batched_prefill(cfg)

    def prefill_step(params, tokens, lengths, kv_offset=None, counter=0):
        cache0 = None
        if not batched:
            cache0 = registry.make_cache(
                params, cfg, tokens.shape[0], max_len, frames=frames,
                policy=policy, kv_quant=kv_quant)
        return registry.apply_prefill(
            params, cfg, tokens, lengths, max_len, policy=policy,
            counter=counter, kv_quant=kv_quant, kv_offset=kv_offset,
            cache0=cache0)

    def decode_step(params, token, cache, kv_offset=None, counter=0):
        return registry.apply_decode(params, cfg, token, cache, policy=policy,
                                     counter=counter, kv_offset=kv_offset)

    return prefill_step, decode_step


def make_decode_and_sample(cfg: ModelConfig,
                           policy: Optional[QuantPolicy] = None):
    """Build the fused single-dispatch decode tick (DESIGN.md §6).

    One jitted call per generated token: ``decode_and_sample(params, token,
    cache, kv_offset, counter, temps, topks, seeds, counters)`` runs the
    model decode step *and* the per-slot sampler on device and returns
    ``(tokens (B,) int32, counters + 1, new cache)`` — the PR-2 engine's
    ``decode_step`` + ``sample_tokens`` pair collapsed into one device
    dispatch, so the steady-state tick costs one host→device launch instead
    of two.  The sampling counters advance on device (one emitted token per
    tick per slot); the engine refreshes its device-resident copies only
    when slot state actually changes.  Token-stream equivalence with the
    two-call path is pinned by tests/test_decode_attention.py.
    """
    policy = policy.resolved() if policy is not None else None

    def decode_and_sample(params, token, cache, kv_offset, counter,
                          temps, topks, seeds, counters):
        logits, new_cache = registry.apply_decode(
            params, cfg, token, cache, policy=policy, counter=counter,
            kv_offset=kv_offset)
        toks = sample_tokens(logits, temps, topks, seeds, counters)
        return toks, counters + 1, new_cache

    return decode_and_sample


def make_fused_decode(cfg: ModelConfig, policy: Optional[QuantPolicy] = None,
                      *, n_ticks: int = 1):
    """Build the windowed multi-tick decode dispatch (DESIGN.md §11).

    ``fused_decode(params, token, cache, kv_offset, counter, temps, topks,
    seeds, counters, alive, budgets, stops)`` runs ``n_ticks`` fused
    decode-and-sample ticks in one jitted call via ``lax.scan`` and returns
    ``(tokens (n_ticks, B), last_token (B,), counters, cache')`` — the host
    drains one (n_ticks, B) token matrix per window instead of syncing every
    tick.  Finish detection moves on-device as an ``alive`` bitmask: a slot
    dies when its sampled token lands in its ``stops`` row ((B, W) int32,
    -1-padded — EOS is folded in) or when it has emitted ``budgets[b]``
    tokens this window (max_new / max_len / paged-block coverage, computed
    host-side).  Dead and idle rows keep decoding but are *inert*: their
    sampled token, sampling counter and cache position freeze, and (paged)
    their block-table row is masked to the trash block so a finished slot
    can never scribble over blocks headed for the prefix cache.  Because a
    live slot's ops are bitwise those of the n_ticks=1 scan, an N-tick
    window reproduces N single ticks exactly (tests/test_overlap.py).
    """
    policy = policy.resolved() if policy is not None else None

    def fused_decode(params, token, cache, kv_offset, counter,
                     temps, topks, seeds, counters, alive, budgets, stops):
        paged = "block_tables" in cache
        if paged:
            leaf = (jax.tree.leaves(cache["layers"][0])[0] if cache["layers"]
                    else jax.tree.leaves(cache["remainder"][0])[0])
            # shard-local pool leading dim is blocks + 1; last id is trash
            nbp = leaf.shape[1] if cache["layers"] else leaf.shape[0]
            trash = jnp.int32(nbp - 1)

        def body(carry, j):
            token, cache, counters, alive, emitted = carry
            pos0 = cache["pos"]
            step_cache = cache
            if paged:
                step_cache = dict(cache)
                step_cache["block_tables"] = jnp.where(
                    alive[:, None], cache["block_tables"], trash)
            logits, new_cache = registry.apply_decode(
                params, cfg, token, step_cache, policy=policy,
                counter=counter + j, kv_offset=kv_offset)
            toks = sample_tokens(logits, temps, topks, seeds, counters)
            toks = jnp.where(alive, toks, token)
            new_cache["pos"] = jnp.where(alive, new_cache["pos"], pos0)
            if paged:
                new_cache["block_tables"] = cache["block_tables"]
            counters = jnp.where(alive, counters + 1, counters)
            emitted = emitted + alive.astype(jnp.int32)
            hit = jnp.any(toks[:, None] == stops, axis=1)
            alive = alive & ~hit & (emitted < budgets)
            return (toks, new_cache, counters, alive, emitted), toks

        carry0 = (token, cache, counters, alive, jnp.zeros_like(counters))
        (token, cache, counters, _, _), toks_all = jax.lax.scan(
            body, carry0, jnp.arange(n_ticks, dtype=jnp.int32))
        return toks_all, token, counters, cache

    return fused_decode


def make_spec_verify(cfg: ModelConfig, policy: Optional[QuantPolicy] = None,
                     *, draft_k: int):
    """Build the speculative verify dispatch (DESIGN.md §14).

    ``spec_verify(params, drafts, cache, kv_offset, counter, temps, topks,
    seeds, counters, alive, wcap)`` scores ``draft_k`` positions per slot in
    one jitted call and returns ``(sampled (B, K) int32, cache')``.
    ``drafts[:, 0]`` is each slot's last committed token (the pending decode
    input) and ``drafts[:, 1:]`` the drafter's proposals; ``sampled[:, t]``
    is what the engine's sampler — stateless in (seed, counter + t) — draws
    from row t's logits, which are bitwise the sequential decode logits at
    position ``pos + t`` whenever rows 1..t matched (the accept condition
    the host walk checks).  All K positions are written to the (donated)
    cache up to each row's ``wcap`` budget; ``pos`` does not advance — the
    host follows up with one ``spec_commit`` dispatch once accept lengths
    are known.  Dead rows (``alive`` false) write nothing: ring writes
    route out of bounds and paged writes (plus their block-table reads)
    route to the trash block, mirroring the fused decode window's masking.
    """
    policy = policy.resolved() if policy is not None else None

    def spec_verify(params, drafts, cache, kv_offset, counter,
                    temps, topks, seeds, counters, alive, wcap):
        paged = "block_tables" in cache
        step_cache = cache
        if paged:
            leaf = (jax.tree.leaves(cache["layers"][0])[0] if cache["layers"]
                    else jax.tree.leaves(cache["remainder"][0])[0])
            nbp = leaf.shape[1] if cache["layers"] else leaf.shape[0]
            step_cache = dict(cache)
            step_cache["block_tables"] = jnp.where(
                alive[:, None], cache["block_tables"], jnp.int32(nbp - 1))
        logits, new_cache = registry.apply_verify(
            params, cfg, drafts, step_cache, policy=policy, counter=counter,
            kv_offset=kv_offset, alive=alive, wcap=wcap)
        if paged:
            new_cache["block_tables"] = cache["block_tables"]
        # row t samples with the counter sequential decode would have used
        # at position pos + t — with bitwise-equal logits the draw is
        # bitwise the sequential draw, for greedy and temperature alike
        sampled = jnp.stack(
            [sample_tokens(logits[:, t], temps, topks, seeds, counters + t)
             for t in range(draft_k)], axis=1)
        return sampled, new_cache

    return spec_verify


def make_chunked_prefill(cfg: ModelConfig,
                         policy: Optional[QuantPolicy] = None, *,
                         kv_quant: bool = False):
    """Build the jit-able chunked ring prefill step (DESIGN.md §11).

    ``chunked_prefill(params, tokens, lengths, starts, cache, kv_offset,
    counter)`` runs one batched forward over per-slot prompt *chunks* at
    absolute positions ``starts + t``, joins each slot's already-written
    ring history inside attention, merges the chunk K/V into the (donated)
    live ring cache and returns ``(last_chunk_logits, cache')``.  The paged
    engine needs no analogue — ``make_paged_prefill`` already takes
    block-aligned ``starts``, so a paged chunk is just a suffix call."""
    policy = policy.resolved() if policy is not None else None

    def chunked_prefill(params, tokens, lengths, starts, cache, kv_offset,
                        counter):
        return registry.apply_prefill_chunked(
            params, cfg, tokens, lengths, starts, cache, policy=policy,
            counter=counter, kv_quant=kv_quant, kv_offset=kv_offset)

    return chunked_prefill


def make_paged_prefill(cfg: ModelConfig, policy: Optional[QuantPolicy] = None,
                       *, kv_quant: bool = False):
    """Build the jit-able paged prefill step (DESIGN.md §6).

    ``paged_prefill(params, tokens, lengths, starts, block_tables, cache,
    kv_offset, counter, prefix_blocks=...)`` runs one batched forward over
    the prompt *suffixes*, scatters their K/V into the pool blocks named by
    ``block_tables`` and returns ``(last_logits, cache')`` — the live cache
    is donated by the engine, so the pool updates in place.
    ``prefix_blocks`` is static (0 on cold waves — exactly the cold batched
    prefill — or the table width when any admitted request hit the prefix
    cache), so the engine compiles at most two variants.
    """
    policy = policy.resolved() if policy is not None else None

    def paged_prefill(params, tokens, lengths, starts, block_tables, cache,
                      kv_offset, counter, *, prefix_blocks: int = 0):
        return registry.apply_prefill_paged(
            params, cfg, tokens, lengths, starts, block_tables, cache,
            policy=policy, counter=counter, kv_quant=kv_quant,
            kv_offset=kv_offset, prefix_blocks=prefix_blocks)

    return paged_prefill


@dataclass
class Request:
    """One generation request.

    Lifecycle (DESIGN.md §6): ``queued`` → (scheduler admits) → ``active``
    → ``done`` with ``finish_reason`` ∈ {"eos", "stop", "length",
    "preempted", "rejected", "deadline", "shed"}.  ``sampling`` carries the
    per-request decode controls; ``max_new`` is a convenience override of
    ``sampling.max_new`` kept from the original API.  ``stream`` (if set)
    is called as ``stream(request, token)`` for every emitted token.
    Timing fields are host-clock seconds: ``ttft`` = time-to-first-token
    from submission, ``itl`` = inter-token latencies.  ``deadline_s`` is a
    wall-clock budget from submission (DESIGN.md §12): the engine expires
    the request — queued or running — once the budget elapses, checked
    once per window drain with zero extra device dispatches.
    """

    rid: int
    prompt: List[int]
    sampling: SamplingParams = field(default_factory=SamplingParams)
    priority: int = 0
    max_new: Optional[int] = None
    deadline_s: Optional[float] = None
    stream: Optional[Callable[["Request", int], None]] = None
    out: List[int] = field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None
    state: str = "new"
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_last: Optional[float] = None
    itl: List[float] = field(default_factory=list)
    # paged-pool lifecycle state (engine-internal): a preempted request's
    # frozen decode position / pending input token (blocks stay in the
    # pool, so re-admission resumes instead of re-prefilling), and the
    # count of its pool blocks sealed into the prefix cache so far
    _resume: Optional[dict] = None
    _sealed: int = 0
    # chunked-prefill progress (engine-internal): tokens of the prompt
    # already written to cache while state == "prefilling" (DESIGN.md §11)
    _pf_pos: int = 0

    @property
    def ttft(self) -> Optional[float]:
        if self.t_first is None or self.t_submit is None:
            return None
        return self.t_first - self.t_submit

    def effective_max_new(self) -> int:
        return self.max_new if self.max_new is not None else self.sampling.max_new


def _bucket(n: int) -> int:
    """Round a prompt length up to a power of two (≥ 8) so the jitted
    prefill compiles once per bucket, not once per prompt length."""
    b = 8
    while b < n:
        b *= 2
    return b


class Engine:
    """Host-side continuous-batching loop over the two jitted serve fns.

    Fixed decode batch B (the slot count) over a shared per-slot ring-buffer
    KV cache.  Each :meth:`step`:

    1. asks the scheduler for requests to fill free slots; admitted prompts
       are right-padded into a (B, S_bucket) batch and run through the
       batched ``prefill_step`` — the prompt costs one forward pass, its KV
       lands in the admitted slots, and the prefill logits seed each
       request's first sampled token;
    2. runs one fused ``decode_and_sample`` call for every active slot —
       model decode step *and* per-request sampling
       (:class:`SamplingParams`) in a single device dispatch per tick;
    3. retires slots on EOS/stop tokens, ``max_new``, or ``max_len``
       preemption, freeing them for the next admission wave.

    Steady-state host↔device traffic is minimal: the per-slot sampling
    state (temperature / top-k / seed / counter-offset arrays) and the last
    sampled token live **device-resident** and are re-uploaded only when
    slot membership changes (admission), with the sampling counters and
    last tokens advancing on device inside the fused step; and the ring
    cache argument is **donated** to the jitted decode and prefill-merge
    steps, so the B×cap×layers KV updates in place instead of
    double-buffering every tick.

    The policy dither counter advances once per engine tick ("rounding in
    time", §VII); per-request ``counter_offset`` shifts the int8-KV and
    sampling counters so concurrent requests walk independent pulse
    sequences and restarts replay identically (DESIGN.md §6).

    Fault tolerance (DESIGN.md §12): per-request deadlines and a queue TTL
    expire stale work once per window drain; ``queue_cap`` bounds the queue
    with a shed policy ('reject-new' / 'evict-lowest-priority'); pool
    pressure past ``degrade_high`` steps the decode window down to single
    ticks and pauses prefix-cache insertion until pressure clears past
    ``degrade_low``; :meth:`snapshot`/:meth:`restore` give bitwise crash
    recovery (host truth serialized, device KV re-materialized by replay).
    """

    SHED_POLICIES = ("reject-new", "evict-lowest-priority")

    def __init__(self, params, cfg: ModelConfig, batch: int, max_len: int,
                 policy: Optional[QuantPolicy] = None, frames=None,
                 kv_quant: bool = False,
                 scheduler: Union[str, Scheduler] = "fcfs",
                 kv_layout: str = "ring",
                 block_size: Optional[int] = None,
                 num_blocks: Optional[int] = None,
                 prefix_cache: bool = True,
                 mesh=None,
                 metrics: Union[None, str, Metrics] = None,
                 trace: Union[None, str, Tracer] = None,
                 decode_ticks: int = 1,
                 prefill_chunk: Optional[int] = None,
                 queue_cap: Optional[int] = None,
                 shed_policy: str = "reject-new",
                 queue_ttl_s: Optional[float] = None,
                 injector=None,
                 watchdog: Union[None, bool, StragglerWatchdog] = True,
                 snapshot_path: Optional[str] = None,
                 snapshot_every: int = 1,
                 degrade_high: float = 0.90,
                 degrade_low: float = 0.70,
                 spec_decode: bool = False,
                 draft_k: int = 4,
                 drafter: Optional[Drafter] = None):
        self.params, self.cfg, self.batch, self.max_len = params, cfg, batch, max_len
        policy = policy.resolved() if policy is not None else None
        self.policy = policy
        self.kv_quant = kv_quant
        self._frames = frames
        if kv_layout not in ("ring", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if kv_layout == "paged" and not registry.supports_paged_kv(cfg):
            raise ValueError("kv_layout='paged' requires an attention-only "
                             f"decoder; {cfg.name!r} is not one")
        self.kv_layout = kv_layout
        self.decode_ticks = int(decode_ticks)
        if self.decode_ticks < 1:
            raise ValueError(f"decode_ticks must be >= 1, got {decode_ticks}")
        if prefill_chunk is not None:
            prefill_chunk = int(prefill_chunk)
            if prefill_chunk < 1:
                raise ValueError("prefill_chunk must be >= 1 (or None)")
            if not registry.supports_chunked_prefill(cfg):
                raise ValueError("chunked prefill requires an attention-only "
                                 f"decoder; {cfg.name!r} is not one")
        self.prefill_chunk = prefill_chunk

        # ---- speculative decoding (DESIGN.md §14): draft-and-verify decode
        # windows; every gate protects the bitwise stream contract
        self.spec_decode = bool(spec_decode)
        self.draft_k = int(draft_k)
        self.drafter = drafter if drafter is not None else PromptLookupDrafter()
        if self.spec_decode:
            if self.draft_k < 2:
                raise ValueError(f"draft_k must be >= 2, got {draft_k}")
            if not registry.supports_spec_decode(cfg):
                raise ValueError(
                    "spec_decode requires an attention-only decoder without "
                    f"MoE; {cfg.name!r} is not one (SSM/RG-LRU recurrences "
                    "have no multi-token verify form, and MoE capacity ranks "
                    "couple a verify row to its own future draft positions)")
            if policy is not None and policy.enabled:
                raise ValueError(
                    "spec_decode requires policy=None: the activation "
                    "quantiser's tensor-global absmax couples verify rows, "
                    "so they would not be bitwise the sequential steps")
            if kv_layout == "ring" and cfg.window and cfg.window < max_len:
                raise ValueError(
                    "spec_decode over the ring layout needs ring capacity "
                    f"= max_len; window={cfg.window} < max_len={max_len} "
                    "would let the verify forward overwrite positions its "
                    "own earlier rows still attend (use kv_layout='paged')")

        # ---- fault-tolerance / overload knobs (DESIGN.md §12)
        if shed_policy not in self.SHED_POLICIES:
            raise ValueError(f"unknown shed_policy {shed_policy!r}; expected "
                             f"one of {self.SHED_POLICIES}")
        if queue_cap is not None and int(queue_cap) < 1:
            raise ValueError(f"queue_cap must be >= 1 (or None), got {queue_cap}")
        if not (0.0 < degrade_low <= degrade_high <= 1.0):
            raise ValueError("need 0 < degrade_low <= degrade_high <= 1, got "
                             f"({degrade_low}, {degrade_high})")
        self.queue_cap = None if queue_cap is None else int(queue_cap)
        self.shed_policy = shed_policy
        self.queue_ttl_s = queue_ttl_s
        self.injector = injector
        self.watchdog = (StragglerWatchdog() if watchdog is True
                         else (watchdog or None))
        self.snapshot_path = snapshot_path
        self.snapshot_every = max(1, int(snapshot_every))
        self.degrade_high, self.degrade_low = degrade_high, degrade_low
        self._degraded = False
        self._now = time.time          # injectable clock (deadline tests)
        self._steps_since_snap = 0
        self._step_tick = 0            # tick at window start (injector key)
        self._last_window_s = 0.0

        # ---- mesh layout (DESIGN.md §9): decode slots partition on 'data',
        # KV heads on 'model' (replicated fallback when the GQA head count
        # does not divide — mirroring dist.sharding._TP_RULES' guards)
        self.mesh = mesh
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names,
                             (int(mesh.shape[a]) for a in mesh.axis_names)))
            self.dp = int(sizes.get("data", 1))
            self.tp = int(sizes.get("model", 1))
            if not registry.supports_batched_prefill(cfg):
                raise ValueError(
                    "mesh serving requires an attention-only decoder "
                    f"(arch {cfg.name!r} has recurrent state / an encoder)")
            if batch % self.dp:
                raise ValueError(f"batch {batch} must be a multiple of the "
                                 f"mesh's data axis ({self.dp})")
        else:
            self.dp = self.tp = 1
        self.heads_sharded = (mesh is not None
                               and dist_sharding.serve_heads_shardable(
                                   cfg, self.tp))
        # inside shard_map the model code sees local shapes: scale the head
        # counts down (head_dim pinned so cfg.hd() is unchanged)
        self._cfg_local = (_dc_replace(cfg,
                                       n_heads=cfg.n_heads // self.tp,
                                       n_kv_heads=cfg.n_kv_heads // self.tp,
                                       head_dim=cfg.hd())
                           if self.heads_sharded else cfg)

        if kv_layout == "ring":
            # the ring-chunk scatter covers each slot's ring at most once per
            # chunk only while the chunk fits the ring capacity
            ring_cap = (min(cfg.window, max_len) if cfg.window else max_len)
            if self.prefill_chunk is not None:
                self.prefill_chunk = min(self.prefill_chunk, ring_cap)

        if kv_layout == "paged":
            from repro.kernels import autotune as _autotune
            from repro.kernels import dispatch as _dispatch

            if block_size is None:
                nkv = max(1, cfg.n_kv_heads)
                shape = (batch, max_len, nkv,
                         max(1, cfg.n_heads // nkv), cfg.hd())
                dtype = "int8" if kv_quant else "bfloat16"
                block_size = _autotune.best_block(
                    "paged_attention", shape, dtype, 8 if kv_quant else 16,
                    "flash", _dispatch.resolve_backend(None).name)[0]
            self.block_size = bs = int(block_size)
            self.nbmax = -(-max_len // bs)
            if self.prefill_chunk is not None:
                # paged chunks stay block-aligned so every continuation chunk
                # starts at a block boundary (the paged prefill's contract)
                self.prefill_chunk = max(bs, self.prefill_chunk // bs * bs)
            # default capacity matches the dense ring's token count; callers
            # under-provision it to exercise continuous batching / eviction.
            # Under a mesh the pool partitions on 'data': each data shard
            # owns num_blocks/dp blocks (its admission budget) plus its own
            # trash block, and block tables carry shard-local physical ids.
            total = (int(num_blocks) if num_blocks is not None
                     else batch * self.nbmax)
            total = -(-total // self.dp) * self.dp     # round up to dp
            self.num_blocks = total
            self._nb_local = total // self.dp
            # prefix reuse requires prefill numerics that depend only on
            # token identity + absolute position: policy off, or the
            # counter-independent deterministic rounding scheme.  (The int8
            # KV quantiser is always position-keyed; its per-request offset
            # seeds the prefix-hash chain instead.)
            self._prefix_enabled = bool(prefix_cache) and (
                policy is None or policy.scheme == "deterministic")
            self.pools = [KVPool(self._nb_local, bs,
                                 prefix_cache=self._prefix_enabled)
                          for _ in range(self.dp)]
            self._trash = self._nb_local          # shard-local trash id
            self._rid_shard: dict = {}            # rid → data shard holding it
            self.cache = registry.make_cache(
                params, cfg, batch, max_len, frames=frames, policy=policy,
                kv_quant=kv_quant, kv_layout="paged", block_size=bs,
                num_blocks=self._nb_local, data_shards=self.dp)
            self._bt = np.full((batch, self.nbmax), self._trash, np.int32)
            self._bt_dirty = True
        else:
            self.pools = []
            self.cache = registry.make_cache(params, cfg, batch, max_len,
                                             frames=frames, policy=policy,
                                             kv_quant=kv_quant)

        cfg_l = self._cfg_local
        prefill_step, decode_step = make_serve_fns(
            cfg_l, policy, max_len=max_len, kv_quant=kv_quant, frames=frames)
        self._sample = jax.jit(sample_tokens)
        self._merge = jax.jit(
            lambda old, new, act: registry.merge_prefill(cfg, old, new, act),
            donate_argnums=(0,))
        self._paged_variants: dict = {}
        # windowed decode dispatches compile once per distinct window length
        # (decode_ticks plus any shorter drain tails) — see _fused_for
        self._fused_variants: dict = {}
        # speculative verify/commit dispatches, one pair per draft_k
        self._spec_variants: dict = {}
        self._commit_variants: dict = {}
        if mesh is None:
            self._prefill = jax.jit(prefill_step)
            if kv_layout == "paged":
                self._prefill_paged = jax.jit(
                    make_paged_prefill(cfg_l, policy, kv_quant=kv_quant),
                    static_argnames=("prefix_blocks",), donate_argnums=(5,))
            elif self.prefill_chunk is not None:
                self._prefill_chunked = jax.jit(
                    make_chunked_prefill(cfg_l, policy, kv_quant=kv_quant),
                    donate_argnums=(4,))
        else:
            # the same jitted steps, run per-shard under shard_map: every
            # in/out leaf carries an explicit PartitionSpec, and the body is
            # wrapped in a serve shard scope so the KV quantiser hashes
            # global element indices and attention heads all-gather before
            # the replicated W_O (the bitwise-parity contract, DESIGN.md §9)
            P = jax.sharding.PartitionSpec
            row, tok2, sc = P("data"), P("data", None), P()
            self._pspec = dist_sharding.serve_param_specs(params, cfg, mesh)
            self._cspec = dist_sharding.cache_specs(self.cache, cfg, mesh)
            # the ring prefill's output cache mirrors the ring engine cache;
            # the paged engine prefills through _paged_prefill_call instead
            self._prefill = (jax.jit(self._mesh_wrap(
                prefill_step,
                (self._pspec, tok2, row, row, sc),
                (tok2, self._cspec))) if kv_layout == "ring" else None)
            # fused decode: the (n_ticks, B) token matrix shards its slot
            # axis (axis 1) on 'data'; everything per-slot rides 'data' rows
            self._in_specs_fused = (self._pspec, row, self._cspec, row, sc,
                                    row, row, row, row, row, row, tok2)
            self._out_specs_fused = (P(None, "data"), row, row, self._cspec)
            # speculative verify: drafts/sampled (B, K) shard rows on 'data'
            self._in_specs_spec = (self._pspec, tok2, self._cspec, row, sc,
                                   row, row, row, row, row, row)
            self._out_specs_spec = (tok2, self._cspec)
            self._in_specs_commit = (self._cspec, row, row)
            if kv_layout == "paged":
                self._in_specs_paged = (self._pspec, tok2, row, row, tok2,
                                        self._cspec, row, sc)
                self._out_specs_paged = (tok2, self._cspec)
            elif self.prefill_chunk is not None:
                self._prefill_chunked = jax.jit(self._mesh_wrap(
                    make_chunked_prefill(cfg_l, policy, kv_quant=kv_quant),
                    (self._pspec, tok2, row, row, self._cspec, row, sc),
                    (tok2, self._cspec)), donate_argnums=(4,))

        self.scheduler = (Scheduler(scheduler) if isinstance(scheduler, str)
                          else scheduler)
        self.slots: List[Optional[Request]] = [None] * batch
        self.finished: List[Request] = []
        self.tick = 0
        # per-slot state: host mirrors for bookkeeping, plus device-resident
        # copies refreshed only when slot membership changes (admission);
        # steady-state decode ticks advance the device copies in place
        self._last_token = np.zeros((batch,), np.int32)
        self._slot_pos = np.zeros((batch,), np.int64)
        self._temps = np.zeros((batch,), np.float32)
        self._topks = np.zeros((batch,), np.int32)
        self._seeds = np.zeros((batch,), np.int32)
        self._offsets = np.zeros((batch,), np.int32)
        self._counters = np.zeros((batch,), np.int32)
        self._dev = {}
        self._dev_dirty = True
        # per-window paged write budget: slot → positions covered by already-
        # allocated blocks (set by _pre_decode_paged, read by _decode_tick)
        self._paged_cap: dict = {}
        self.stats = {"prefill_s": 0.0, "prefill_tokens": 0, "prefill_calls": 0,
                      "decode_s": 0.0, "decode_tokens": 0, "decode_calls": 0,
                      "prefix_hit_tokens": 0, "preemptions": 0}
        # observability surface (DESIGN.md §10): host-side counters, per-tick
        # gauges and TTFT/ITL histograms behind a buffered crash-isolated
        # sink.  Accepts a Metrics instance, a sink spec ('stdout',
        # 'jsonl:<path>', a sink object) or None (collect, don't stream).
        self.metrics = (metrics if isinstance(metrics, Metrics)
                        else Metrics(sink=metrics))
        # per-request tracing (DESIGN.md §13): span timelines + latency
        # attribution, host-timestamped only where the engine already syncs
        # — zero extra device dispatches, disabled entirely by default.
        # Accepts a Tracer, a spec string ('mem', 'perfetto:<path>',
        # 'jsonl:<path>', comma-combinable), a sink object, or None (off).
        self.trace = Tracer.from_spec(trace)
        if self.trace.enabled:
            # queue/block provenance rides the tracer's event feed; the
            # hooks stay None (and cost nothing) on an untraced engine
            self.scheduler.on_event = self.trace.event
            for pool in self.pools:
                pool.on_event = self.trace.event

    # ------------------------------------------------------------- mesh glue

    def _mesh_wrap(self, fn, in_specs, out_specs):
        """Run ``fn`` per-shard under ``shard_map`` on the engine mesh, with
        the serve shard scope installed so model code maps its local batch
        rows / KV heads back to global coordinates (DESIGN.md §9)."""
        from jax.experimental.shard_map import shard_map

        nkv_local = self._cfg_local.n_kv_heads
        heads_sharded = self.heads_sharded

        def body(*args):
            head0 = (jax.lax.axis_index("model") * nkv_local
                     if heads_sharded else 0)
            with dist_ctx.serve_shard_scope(head0=head0,
                                            heads_sharded=heads_sharded):
                return fn(*args)

        return shard_map(body, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)

    def _paged_prefill_call(self, *args, prefix_blocks: int):
        """Dispatch the paged prefill: the single-device engine keeps one
        jitted fn with a static ``prefix_blocks``; the mesh engine builds
        (at most two — 0 and nbmax) shard_map variants instead, since
        shard_map bodies take positional args only."""
        if self.mesh is None:
            return self._prefill_paged(*args, prefix_blocks=prefix_blocks)
        fn = self._paged_variants.get(prefix_blocks)
        if fn is None:
            base = make_paged_prefill(self._cfg_local, self.policy,
                                      kv_quant=self.kv_quant)
            fn = jax.jit(self._mesh_wrap(
                functools.partial(base, prefix_blocks=prefix_blocks),
                self._in_specs_paged, self._out_specs_paged),
                donate_argnums=(5,))
            self._paged_variants[prefix_blocks] = fn
        return fn(*args)

    def _fused_for(self, n: int):
        """The windowed decode dispatch for an ``n``-tick window, compiled on
        first use and cached — steady state uses ``decode_ticks`` only, so
        this compiles once (plus once per distinct stop-set bucket width via
        the (B, W) ``stops`` argument shape)."""
        fn = self._fused_variants.get(n)
        if fn is None:
            base = make_fused_decode(self._cfg_local, self.policy, n_ticks=n)
            if self.mesh is None:
                fn = jax.jit(base, donate_argnums=(2,))
            else:
                fn = jax.jit(self._mesh_wrap(base, self._in_specs_fused,
                                             self._out_specs_fused),
                             donate_argnums=(2,))
            self._fused_variants[n] = fn
        return fn

    def _spec_for(self, k: int):
        """The speculative verify dispatch for a ``k``-row window, compiled
        on first use (steady state uses ``draft_k`` only)."""
        fn = self._spec_variants.get(k)
        if fn is None:
            base = make_spec_verify(self._cfg_local, self.policy, draft_k=k)
            if self.mesh is None:
                fn = jax.jit(base, donate_argnums=(2,))
            else:
                fn = jax.jit(self._mesh_wrap(base, self._in_specs_spec,
                                             self._out_specs_spec),
                             donate_argnums=(2,))
            self._spec_variants[k] = fn
        return fn

    def _spec_commit_for(self, k: int):
        """The bulk-commit + rejected-suffix-scrub dispatch for ``k``-row
        windows: ``fn(cache, new_pos, written) -> cache`` (cache donated)."""
        fn = self._commit_variants.get(k)
        if fn is None:
            base = functools.partial(registry.spec_commit, draft_k=k)
            if self.mesh is None:
                fn = jax.jit(base, donate_argnums=(0,))
            else:
                fn = jax.jit(self._mesh_wrap(base, self._in_specs_commit,
                                             self._cspec),
                             donate_argnums=(0,))
            self._commit_variants[k] = fn
        return fn

    # ------------------------------------------------------ pool aggregates

    @property
    def pool(self) -> Optional[KVPool]:
        """The shard-local block pool (data shard 0) — the *whole* pool on a
        single-shard engine, which is what pre-mesh callers expect; use
        :attr:`pools` / :meth:`pool_stats` for per-shard views under a mesh
        (DESIGN.md §9)."""
        return self.pools[0] if self.pools else None

    def pool_stats(self) -> dict:
        """Allocator stats summed across the per-data-shard pools, plus the
        aggregate ``live``/``cached`` block counts."""
        agg = {"live": 0, "cached": 0}
        for p in self.pools:
            for k, v in p.stats.items():
                agg[k] = agg.get(k, 0) + v
            agg["live"] += p.live_blocks
            agg["cached"] += p.cached_blocks
        return agg

    def _slot_shard(self, i: int) -> int:
        return i // (self.batch // self.dp)

    def _pool_of(self, rid: int) -> KVPool:
        return self.pools[self._rid_shard[rid]]

    # ------------------------------------------------------------------ API

    def reset_stats(self):
        """Zero the throughput counters *and* the metrics surface
        (benchmarks call this after a warm-up wave so compile time stays
        out of the measured rates and histograms)."""
        self.stats = {k: type(v)() for k, v in self.stats.items()}
        self.metrics.reset()

    def submit(self, req: Request):
        """Enqueue a request, applying overload admission control
        (DESIGN.md §12) when ``queue_cap`` is set: a full queue either
        sheds the newcomer ('reject-new') or evicts the queued request
        with the lowest priority — latest arrival among ties — when the
        newcomer outranks it ('evict-lowest-priority').  Preempted
        requests re-enter through the scheduler's ``requeue`` and are
        never shed: they hold pool blocks and their place in line."""
        req.state = "queued"
        if req.t_submit is None:
            req.t_submit = time.time()
        self.trace.begin(req.rid, req.t_submit, priority=req.priority)
        if self.queue_cap is not None and \
                len(self.scheduler) >= self.queue_cap:
            victim = req
            if self.shed_policy == "evict-lowest-priority":
                lowest = min(self.scheduler.queued(),
                             key=lambda r: (r.priority, -r._arrival))
                if lowest.priority < req.priority:
                    self.scheduler.pop(lowest)
                    victim = lowest
            self._finish_queued(victim, "shed")
            if victim is req:
                return
        self.scheduler.submit(req)

    def explain(self, rid: int) -> dict:
        """Latency-attribution report for a traced request (DESIGN.md §13):
        wall time decomposed into queue / prefill / decode / preempt_stall /
        degraded / recovery shares that sum to 100%, with the dominant term
        named.  Requires the engine to have been constructed with
        ``trace=...``; raises ``KeyError`` for an unknown rid."""
        if not self.trace.enabled:
            raise RuntimeError("tracing is disabled; construct the Engine "
                               "with trace='mem' (or a sink spec) to explain "
                               "requests")
        return self.trace.explain(rid, now=self._now())

    def step(self) -> List[Request]:
        """One engine window: expire deadlines, admit + batched-prefill,
        decode every active slot, observe the window wall time, persist a
        snapshot.  Returns the requests still active after the window.
        The five ``injector`` crash points fire in this order (keyed on
        the tick at window start); a crashed engine's host state is
        mid-mutation — discard it and restore a fresh engine from the
        snapshot (``run_serve_with_restarts``)."""
        self._step_tick = self.tick
        t0 = self._now()
        self._maybe_fail("pre_admit")
        self._expire_deadlines()
        self._update_pressure()
        self._admit_and_prefill()
        if any(s is not None for s in self.slots):
            if self.spec_decode:
                self._spec_decode_tick()
            else:
                self._decode_tick()
        self._observe_window(self._now() - t0)
        self._maybe_fail("sink_write")
        self._record_tick_metrics()
        self._maybe_fail("post_drain")
        self._maybe_snapshot()
        return [s for s in self.slots if s is not None]

    def run(self, ticks: int) -> List[Request]:
        """Drive :meth:`step` until the queue and slots drain (or ``ticks``
        elapse); returns every request finished so far."""
        for _ in range(ticks):
            self.step()
            if not len(self.scheduler) and all(s is None for s in self.slots):
                break
        self.metrics.flush()          # drain the tail of the gauge buffer
        self.trace.flush()
        if self.snapshot_path is not None:
            self.write_snapshot(self.snapshot_path)
        return self.finished

    # ----------------------------------------- fault tolerance (DESIGN.md §12)

    def _maybe_fail(self, phase: str):
        """One injector crash point, keyed on the tick at window start so a
        chaos test can name any phase of a specific window."""
        if self.injector is not None:
            self.injector.maybe_fail(self._step_tick, phase)

    def _finish_queued(self, req: Request, reason: str):
        """Retire a request that never reaches a slot this time (shed at
        submission, or expired while queued).  A preempted block-holder
        releases its blocks — expiry must not leak pool capacity."""
        if self.pools and req.rid in self._rid_shard:
            self._pool_of(req.rid).release(req.rid)
            self._rid_shard.pop(req.rid, None)
        req._resume = None
        req.done, req.finish_reason, req.state = True, reason, "done"
        self.finished.append(req)
        self.metrics.inc("finished_requests")
        self.metrics.inc(f"finish_{reason}")
        self.trace.finish(req.rid, self._now(), reason)

    def _expire_deadlines(self):
        """Expire overdue requests, once per window drain, *before*
        admission — a pure host-side scan over the queue and the slots
        (zero device dispatches; a cancelled running slot reuses the
        normal finish path, whose block release the engine already pays
        on every finish).  Queued requests expire on their own
        ``deadline_s`` or the engine-wide ``queue_ttl_s``; running ones
        only on their ``deadline_s`` (TTL is a queue-staleness bound, not
        an execution cap)."""
        ttl = self.queue_ttl_s
        now = self._now()

        def age(r):
            return now - (r.t_submit if r.t_submit is not None else now)

        for req in self.scheduler.queued():
            if (req.deadline_s is not None and age(req) > req.deadline_s) or \
                    (ttl is not None and age(req) > ttl):
                self.scheduler.pop(req)
                self._finish_queued(req, "deadline")
        for i, req in enumerate(self.slots):
            if req is not None and req.deadline_s is not None \
                    and age(req) > req.deadline_s:
                self._finish(i, req, "deadline")

    def _window_ticks(self) -> int:
        """The decode window length this step: ``decode_ticks``, stepped
        down to 1 while degraded (shorter windows = more frequent
        admission/preemption decisions under block scarcity).  Safe to vary
        freely — window length is bitwise stream-preserving (§11)."""
        return 1 if self._degraded else self.decode_ticks

    def _update_pressure(self):
        """Graceful degradation under pool pressure (DESIGN.md §12), with
        hysteresis so the engine does not flap at the watermark: when live
        blocks cross ``degrade_high`` of capacity, decode windows drop to
        single ticks and prefix-cache *insertion* pauses (finished blocks
        return to the free list instead of lingering as cached copies —
        sealing resumes where it left off once pressure clears below
        ``degrade_low``).  Both effects are stream-preserving: window
        length is bitwise-invariant (§11) and prefix hit vs cold is
        stream-pinned (§6), so degradation never changes emitted tokens."""
        if not self.pools:
            return
        share = sum(p.live_blocks for p in self.pools) / self.num_blocks
        if not self._degraded and share >= self.degrade_high:
            self._degraded = True
            self.metrics.inc("degrade_events")
            now = self._now()
            self.trace.event("degraded", t=now, tick=self.tick,
                             live_share=share)
            self.trace.set_degraded(True, now)
        elif self._degraded and share <= self.degrade_low:
            self._degraded = False
            now = self._now()
            self.trace.event("restored", t=now, tick=self.tick,
                             live_share=share)
            self.trace.set_degraded(False, now)

    def _observe_window(self, seconds: float):
        """Feed the straggler watchdog one window wall time; flagged
        windows bump the ``slow_windows`` counter and log an event on the
        tracer's feed (DESIGN.md §13 — lifecycle events unified there)."""
        self._last_window_s = seconds
        if self.watchdog is not None and \
                self.watchdog.observe(self._step_tick, seconds):
            self.metrics.inc("slow_windows")
            self.trace.event("slow_window", tick=self._step_tick,
                             window_s=seconds)

    def _maybe_snapshot(self):
        if self.snapshot_path is None:
            return
        self._steps_since_snap += 1
        if self._steps_since_snap >= self.snapshot_every:
            self._steps_since_snap = 0
            self.write_snapshot(self.snapshot_path)

    def write_snapshot(self, path: str):
        """Atomically persist :meth:`snapshot` as JSON (tmp + ``os.replace``
        — a crash mid-write can never corrupt the previous snapshot)."""
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump(self.snapshot(), fh)
        os.replace(tmp, path)

    # ------------------------------------------------------------ internals

    def _record_tick_metrics(self):
        """One per-tick gauge snapshot (DESIGN.md §10).  Every value is a
        host-side int/float the engine already tracks — scheduler depth,
        slot occupancy, the cumulative ``stats`` counters and the pool
        allocator's host bookkeeping — so this adds **no device dispatch**
        (and no device→host sync) to the tick."""
        active = sum(1 for s in self.slots if s is not None)
        gauges = dict(
            queue_depth=len(self.scheduler),
            active_slots=active,
            batch_occupancy=active / self.batch,
            finished_total=len(self.finished),
            prefill_tokens=self.stats["prefill_tokens"],
            decode_tokens=self.stats["decode_tokens"],
            prefix_hit_tokens=self.stats["prefix_hit_tokens"],
            preemptions=self.stats["preemptions"],
            window_s=self._last_window_s,
            degraded=int(self._degraded),
        )
        if self.pools:
            ps = self.pool_stats()
            gauges.update(
                live_blocks=ps["live"], cached_blocks=ps["cached"],
                free_blocks=sum(p.free_blocks for p in self.pools))
        self.metrics.tick(**gauges)
        self.trace.counters(t=self._now(), **gauges)

    def _refresh_device_state(self):
        """Re-upload the per-slot sampling state and last tokens if any slot
        changed since the previous tick (admission marks the state dirty);
        in steady state this is a no-op and decode ticks touch the host only
        to read the sampled tokens back."""
        if self._dev_dirty:
            self._dev = {
                "temps": jnp.asarray(self._temps),
                "topks": jnp.asarray(self._topks),
                "seeds": jnp.asarray(self._seeds),
                "offsets": jnp.asarray(self._offsets),
                "counters": jnp.asarray(self._counters),
                "last_token": jnp.asarray(self._last_token),
            }
            self._dev_dirty = False

    def _admit_and_prefill(self):
        if self.kv_layout == "paged":
            return self._admit_and_prefill_paged()
        if self.prefill_chunk is not None:
            return self._admit_and_prefill_ring_chunked()
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return
        admitted = []
        for req in self.scheduler.admit(len(free)):
            if len(req.prompt) > self.max_len:
                req.done, req.finish_reason, req.state = True, "rejected", "done"
                self.finished.append(req)
                self.metrics.inc("finished_requests")
                self.metrics.inc("finish_rejected")
                self.trace.finish(req.rid, self._now(), "rejected")
                continue
            admitted.append(req)
        if not admitted:
            return

        now = time.time()
        lens = np.zeros((self.batch,), np.int32)
        prompts = {}
        for req in admitted:
            i = free.pop(0)
            sp = req.sampling
            self.slots[i] = req
            req.state, req.t_admit = "active", now
            self.trace.phase(req.rid, "prefill", now, slot=i)
            prompts[i] = list(req.prompt) or [1]          # empty prompt → BOS
            lens[i] = len(prompts[i])
            self._temps[i] = sp.temperature
            self._topks[i] = sp.top_k
            self._seeds[i] = sp.seed
            self._offsets[i] = sp.counter_offset
            self._counters[i] = sp.counter_offset
            self._slot_pos[i] = lens[i]

        s_bucket = _bucket(int(lens.max()))
        toks = np.zeros((self.batch, s_bucket), np.int32)
        for i, p in prompts.items():
            toks[i, : len(p)] = p

        self._dev_dirty = True            # admission changed per-slot state
        self._refresh_device_state()
        t0 = time.time()
        last_logits, pf_cache = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(lens),
            self._dev["offsets"], self.tick)
        self.cache = self._merge(self.cache, pf_cache,
                                 jnp.asarray(lens > 0))
        first = np.asarray(self._sample(
            last_logits, self._dev["temps"], self._dev["topks"],
            self._dev["seeds"], self._dev["counters"]))
        dt = time.time() - t0
        self.stats["prefill_s"] += dt
        self.stats["prefill_tokens"] += int(lens.sum())
        self.stats["prefill_calls"] += 1
        self.trace.wave(
            "prefill_wave", t0, t0 + dt,
            [(self.slots[i].rid, "prefill[0]",
              {"slot": i, "tokens": int(lens[i])}) for i in prompts],
            tick=self._step_tick)

        now = time.time()
        for i, req in list(prompts.items()):
            self._emit(i, self.slots[i], int(first[i]), now)
        # _emit advanced host counters / last tokens for the admitted slots;
        # re-sync the device copies before the first decode tick reads them
        self._dev_dirty = True

    def _admit_and_prefill_ring_chunked(self):
        """Sarathi-style piggyback prefill on the ring engine (DESIGN.md
        §11): admitted prompts enter in ``prefill_chunk``-token chunks, one
        chunk wave per engine step, so a long prompt never stalls running
        decodes for its full length.  Slots sit in state ``prefilling`` —
        excluded from the decode window's alive mask — until their last
        chunk lands, which also samples their first token.  Because the
        dither KV codes key on absolute position (``starts + t``) and the
        first sampled token on the prefill-final logits, the chunked stream
        is the whole-prompt stream (tests/test_overlap.py)."""
        chunk = self.prefill_chunk
        free = [i for i, s in enumerate(self.slots) if s is None]
        admitted_now = time.time()
        for req in self.scheduler.admit(len(free)):
            if len(req.prompt) > self.max_len:
                req.done, req.finish_reason, req.state = True, "rejected", "done"
                self.finished.append(req)
                self.metrics.inc("finished_requests")
                self.metrics.inc("finish_rejected")
                self.trace.finish(req.rid, admitted_now, "rejected")
                continue
            i = free.pop(0)
            self.slots[i] = req
            req.state, req.t_admit = "prefilling", admitted_now
            self.trace.phase(req.rid, "prefill", admitted_now, slot=i)
            req._pf_pos = 0
            self._set_slot_sampling(i, req)
            self._slot_pos[i] = 0
            self._dev_dirty = True

        waving = [(i, s) for i, s in enumerate(self.slots)
                  if s is not None and s.state == "prefilling"]
        if not waving:
            return
        lens = np.zeros((self.batch,), np.int32)
        starts = np.zeros((self.batch,), np.int32)
        pieces = {}
        for i, req in waving:
            prompt = list(req.prompt) or [1]          # empty prompt → BOS
            pieces[i] = prompt[req._pf_pos:req._pf_pos + chunk]
            lens[i] = len(pieces[i])
            starts[i] = req._pf_pos

        s_bucket = _bucket(int(lens.max()))
        toks = np.zeros((self.batch, s_bucket), np.int32)
        for i, p in pieces.items():
            toks[i, : len(p)] = p

        self._dev_dirty = True
        self._refresh_device_state()
        t0 = time.time()
        last_logits, self.cache = self._prefill_chunked(
            self.params, jnp.asarray(toks), jnp.asarray(lens),
            jnp.asarray(starts), self.cache, self._dev["offsets"], self.tick)
        first = np.asarray(self._sample(
            last_logits, self._dev["temps"], self._dev["topks"],
            self._dev["seeds"], self._dev["counters"]))
        dt = time.time() - t0
        self.stats["prefill_s"] += dt
        self.stats["prefill_tokens"] += int(lens.sum())
        self.stats["prefill_calls"] += 1
        self.trace.wave(
            "prefill_wave", t0, t0 + dt,
            [(req.rid, f"prefill[{int(starts[i]) // chunk}]",
              {"slot": i, "tokens": int(lens[i])}) for i, req in waving],
            tick=self._step_tick)

        now = time.time()
        for i, req in waving:
            req._pf_pos += len(pieces[i])
            self._slot_pos[i] = req._pf_pos
            if req._pf_pos >= len(list(req.prompt) or [1]):
                req.state = "active"
                self._emit(i, req, int(first[i]), now)
        self._dev_dirty = True

    # ----------------------------------------------------- paged internals

    def _tokens_written(self, req: Request) -> List[int]:
        """Every token with (or about to get) a cache position: the prompt
        (BOS-substituted if empty) followed by the generated stream —
        position p holds ``seq[p]``, which is what block sealing and
        resume-by-reprefill both rely on."""
        return (list(req.prompt) or [1]) + list(req.out)

    def _set_bt_row(self, i: int, table: List[int]):
        self._bt[i, :] = self._trash
        if table:
            self._bt[i, : len(table)] = table
        self._bt_dirty = True

    def _sync_block_tables(self):
        if self._bt_dirty:
            self.cache["block_tables"] = jnp.asarray(self._bt)
            self._bt_dirty = False

    def _set_slot_sampling(self, i: int, req: Request):
        sp = req.sampling
        self._temps[i] = sp.temperature
        self._topks[i] = sp.top_k
        self._seeds[i] = sp.seed
        self._offsets[i] = sp.counter_offset
        self._counters[i] = sp.counter_offset + len(req.out)

    def _release_slot_blocks(self, i: int, req: Request):
        self._pool_of(req.rid).release(req.rid)
        self._rid_shard.pop(req.rid, None)
        self._set_bt_row(i, [])
        self.cache["pos"] = self.cache["pos"].at[i].set(0)
        self._slot_pos[i] = 0

    def _preempt_requeue(self, i: int, req: Request):
        """Out-of-blocks preemption: freeze the slot's host state and send
        the request back through the scheduler *with its blocks intact* —
        re-admission resumes decode from the frozen position instead of
        re-prefilling (the PR-4 replacement for the ring engine's hard
        'preempted' finish)."""
        req._resume = {"pos": int(self._slot_pos[i]),
                       "last_token": int(self._last_token[i]),
                       "t": time.time(), "reprefill": False,
                       "prefilling": req.state == "prefilling"}
        self.trace.phase(
            req.rid, "preempt_stall", req._resume["t"], slot=i,
            blocks=len(self._pool_of(req.rid).table(req.rid)))
        req.state = "queued"
        self.slots[i] = None
        self._set_bt_row(i, [])
        self.cache["pos"] = self.cache["pos"].at[i].set(0)
        self.scheduler.requeue(req)
        self.stats["preemptions"] += 1

    def _release_for_reprefill(self, req: Request):
        """Deadlock breaker (last resort): a *queued* preempted request
        gives its blocks back to the pool; on re-admission it re-prefills
        its full history (prompt + generated so far).  Counters replay
        exactly — KV quantiser = absolute position + offset, sampling =
        offset + emitted count — so the first layer's int8 codes are
        bit-identical; deeper layers re-enter through the batched prefill
        and agree with the decode-written cache to rounding only (the same
        prefill≡decode divergence tests/test_serve.py has always pinned),
        so a greedy near-tie after resume may break differently.  The
        primary preemption path (blocks intact) has no such divergence."""
        self._pool_of(req.rid).forget(req.rid)
        self._rid_shard.pop(req.rid, None)
        req._sealed = 0
        if req._resume is None:
            req._resume = {"pos": 0, "last_token": 0, "t": time.time()}
        req._resume["reprefill"] = True
        self.trace.event("reprefill", rid=req.rid, t=self._now(),
                         pos=req._resume["pos"])
        # 'preemptions' counts preemption *events* — a requeue-with-blocks
        # and a later block reclamation are two events for one request
        self.stats["preemptions"] += 1

    def _resume_slot(self, i: int, req: Request):
        # invariant: slot i is on the data shard holding req's blocks
        # (admission only resumes onto the home shard, DESIGN.md §9)
        st = req._resume
        req._resume = None
        self.slots[i] = req
        # a request preempted mid-prefill rejoins the chunk waves where it
        # stopped (its _pf_pos and blocks survived the round trip)
        req.state = "prefilling" if st.get("prefilling") else "active"
        self.trace.phase(
            req.rid, "prefill" if st.get("prefilling") else "decode",
            self._now(), slot=i, resumed=1,
            shard=self._rid_shard[req.rid],
            blocks=len(self._pool_of(req.rid).table(req.rid)))
        self._set_slot_sampling(i, req)
        self._last_token[i] = st["last_token"]
        self._slot_pos[i] = st["pos"]
        self._set_bt_row(i, self._pool_of(req.rid).table(req.rid))
        self.cache["pos"] = self.cache["pos"].at[i].set(st["pos"])
        self._dev_dirty = True

    def _seal_full_blocks(self, req: Request, n_tokens: int):
        """Publish every full block below ``n_tokens`` into the prefix
        cache (chained-hash order).  Callers only invoke this after the
        device writes for those blocks were dispatched — a same-wave hit
        would race the scatter.  Paused while degraded (DESIGN.md §12):
        ``req._sealed`` does not advance, so sealing resumes from the same
        block once pressure clears."""
        if not self._prefix_enabled or self._degraded:
            return
        bs = self.block_size
        pool = self._pool_of(req.rid)
        seq = self._tokens_written(req)
        while req._sealed < n_tokens // bs:
            j = req._sealed
            pool.seal_block(req.rid, j, seq[j * bs:(j + 1) * bs])
            req._sealed += 1

    def _admit_and_prefill_paged(self):
        """Continuous-batching admission (DESIGN.md §6/§9): admit while a
        slot *and* that slot's data-shard pool allow — prefix-hit requests
        only need blocks (and prefill compute) for their unshared suffix;
        preempted requests resume in place *on their home shard* (their
        blocks live in that shard's pool).  New requests pick the shard
        with the longest cached prefix, then the most free blocks.
        Head-of-line order is preserved: the first request no eligible
        shard can serve stops admission (after the deadlock breaker below
        has had its chance)."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            # no admission this step, but half-prefilled slots still push
            # their next chunk (DESIGN.md §11)
            self._prefill_wave_paged()
            return
        bs = self.block_size
        free_by_shard: dict = {}
        for i in free:                       # slot order ⇒ shard-local order
            free_by_shard.setdefault(self._slot_shard(i), []).append(i)

        def take_slot(shard: int) -> int:
            slots_d = free_by_shard[shard]
            i = slots_d.pop(0)
            if not slots_d:
                del free_by_shard[shard]
            return i

        admitted = []                       # (slot, req, suffix, start)
        while free_by_shard:
            req = self.scheduler.peek()
            if req is None:
                break
            if req._resume is not None and not req._resume.get("reprefill"):
                # resume with blocks intact; may need one block to continue
                shard = self._rid_shard[req.rid]
                if shard not in free_by_shard:
                    break        # HOL: the home shard has no free slot yet
                pool = self.pools[shard]
                pos = req._resume["pos"]
                needs_block = (pos % bs == 0
                               and pos // bs >= len(pool.table(req.rid)))
                if needs_block and pool.free_blocks < 1:
                    if self._break_deadlock(req, 1, shard):
                        continue
                    break
                self.scheduler.pop(req)
                if needs_block:
                    phys = pool.append_block(req.rid)
                    assert phys is not None
                self._resume_slot(take_slot(shard), req)
                continue

            seq = self._tokens_written(req)      # prompt (+ out on reprefill)
            if len(req.prompt) > self.max_len or \
                    self.pools[0].blocks_needed(min(len(seq) + 1,
                                                    self.max_len)) \
                    > self._nb_local:
                self.scheduler.pop(req)
                # a reprefill-resumed request whose grown history no longer
                # fits was *served* up to the pool's capacity — that is a
                # 'length' stop, not a rejection of an unserved request
                reason = "length" if req.out else "rejected"
                req.done, req.finish_reason, req.state = True, reason, "done"
                self.finished.append(req)
                self.metrics.inc("finished_requests")
                self.metrics.inc(f"finish_{reason}")
                self.trace.finish(req.rid, self._now(), reason)
                continue
            seed = req.sampling.counter_offset if self.kv_quant else 0
            # rank eligible shards: longest cached prefix first, then most
            # free blocks (ties keep the lowest shard — deterministic)
            ranked = sorted(
                ((pool.match_prefix(seq, seed), shard)
                 for shard, pool in ((s, self.pools[s])
                                     for s in free_by_shard)),
                key=lambda t: (-len(t[0][0]),
                               -self.pools[t[1]].free_blocks, t[1]))
            table = shard = None
            for (shared, chain), cand in ranked:
                table = self.pools[cand].allocate(req.rid, len(seq),
                                                  shared, chain)
                if table is not None:
                    shard = cand
                    break
            if table is None:
                (shared, _), cand = ranked[0]
                if self._break_deadlock(
                        req,
                        self.pools[cand].blocks_needed(len(seq))
                        - len(shared), cand):
                    continue
                break
            self._rid_shard[req.rid] = shard
            self.scheduler.pop(req)
            req._sealed = len(shared)
            req._resume = None
            start = len(shared) * bs
            i = take_slot(shard)
            admitted.append((i, req, start))

        # place admitted requests into their slots in ``prefilling`` state —
        # their full-history blocks are already allocated (held across
        # windows), so the chunk waves below only *write* into them
        now = time.time()
        for i, req, start in admitted:
            self.slots[i] = req
            req.state = "prefilling"
            req._pf_pos = start
            if req.t_admit is None:
                req.t_admit = now
            self.trace.phase(
                req.rid, "prefill", now, slot=i,
                shard=self._rid_shard[req.rid],
                blocks=len(self._pool_of(req.rid).table(req.rid)),
                prefix_tokens=start)
            self._set_slot_sampling(i, req)
            self._slot_pos[i] = start
            self._set_bt_row(i, self._pool_of(req.rid).table(req.rid))
            self.stats["prefix_hit_tokens"] += start

        self._prefill_wave_paged()

    def _prefill_wave_paged(self):
        """One chunked-prefill wave over every ``prefilling`` paged slot
        (DESIGN.md §11).  ``prefill_chunk`` is block-aligned, so every
        continuation chunk starts at a block boundary and rides the
        prefix-hit path of the paged prefill — earlier chunks' K/V is
        gathered from the slot's own pool blocks inside attention.  With
        ``prefill_chunk=None`` the whole suffix lands in one wave (the
        pre-overlap behaviour).  A slot's last chunk samples its first
        token and flips it ``active``."""
        chunk = self.prefill_chunk or (self.max_len + 1)
        waving = [(i, s) for i, s in enumerate(self.slots)
                  if s is not None and s.state == "prefilling"]
        if not waving:
            return
        lens = np.zeros((self.batch,), np.int32)
        starts = np.zeros((self.batch,), np.int32)
        pieces = {}
        any_prefix = False
        for i, req in waving:
            seq = self._tokens_written(req)
            pf = req._pf_pos
            pieces[i] = seq[pf:pf + chunk]
            lens[i] = len(pieces[i])
            starts[i] = pf
            any_prefix = any_prefix or pf > 0

        s_bucket = _bucket(int(lens.max()))
        toks = np.zeros((self.batch, s_bucket), np.int32)
        for i, p in pieces.items():
            toks[i, : len(p)] = p

        self._dev_dirty = True
        self._refresh_device_state()
        bt_dev = jnp.asarray(self._bt)
        self._bt_dirty = False
        t0 = time.time()
        last_logits, self.cache = self._paged_prefill_call(
            self.params, jnp.asarray(toks), jnp.asarray(lens),
            jnp.asarray(starts), bt_dev, self.cache,
            self._dev["offsets"], self.tick,
            prefix_blocks=self.nbmax if any_prefix else 0)
        first = np.asarray(self._sample(
            last_logits, self._dev["temps"], self._dev["topks"],
            self._dev["seeds"], self._dev["counters"]))
        dt = time.time() - t0
        self.stats["prefill_s"] += dt
        self.stats["prefill_tokens"] += int(lens.sum())
        self.stats["prefill_calls"] += 1
        self.trace.wave(
            "prefill_wave", t0, t0 + dt,
            [(req.rid, f"prefill[{int(starts[i]) // chunk}]",
              {"slot": i, "tokens": int(lens[i])}) for i, req in waving],
            tick=self._step_tick)

        # the prefill dispatch is ordered before any later gather, so the
        # chunk's full blocks are now safely publishable for prefix hits
        now = time.time()
        for i, req in waving:
            req._pf_pos += len(pieces[i])
            self._slot_pos[i] = req._pf_pos
            self._seal_full_blocks(req, req._pf_pos)
            if req._pf_pos >= len(self._tokens_written(req)):
                req.state = "active"
                self._emit(i, req, int(first[i]), now)
        self._dev_dirty = True

    def _break_deadlock(self, head: Request, blocks_short: int,
                        shard: int = 0) -> bool:
        """Admission stalled on the queue head with every slot of ``shard``
        idle: make room in that shard's pool by taking blocks back from
        *queued* preempted requests holding blocks there (youngest
        preemption first — the least progress to re-prefill), or, if the
        head itself holds everything, flip it to reprefill mode so its own
        blocks free up.  Returns True when the caller should retry
        admission."""
        per = self.batch // self.dp
        if any(self.slots[i] is not None
               for i in range(shard * per, (shard + 1) * per)):
            return False     # active slots will finish/preempt and free blocks
        pool = self.pools[shard]
        holders = [r for r in self.scheduler.queued()
                   if r is not head and r._resume is not None
                   and self._rid_shard.get(r.rid) == shard
                   and pool.table(r.rid)]
        holders.sort(key=lambda r: -r._resume["t"])
        made_room = False
        for victim in holders:
            self._release_for_reprefill(victim)
            made_room = True
            if pool.free_blocks >= blocks_short:
                return True
        if (not made_room and head._resume is not None
                and self._rid_shard.get(head.rid) == shard
                and pool.table(head.rid)):
            self._release_for_reprefill(head)
            return True
        return made_room

    def _pre_decode_paged(self, window: Optional[int] = None):
        """Before each decode window: the window writes this slot's next
        ``w = min(decode_ticks, budget)`` positions, so blocks covering
        ``[p, p + w)`` must exist *now* — the host cannot allocate
        mid-window.  Sealing of filled blocks happens here (their device
        writes are complete); when the pool can only cover part of the
        window, the slot's per-window budget is capped instead of finishing
        early (``_paged_cap``, read by _decode_tick) so tight pools behave
        exactly like decode_ticks=1; zero coverage preempts-and-requeues,
        and ``max_len`` is a hard stop ('length' — the paged pool has no
        ring wrap to overwrite).  Slots still mid-prefill are skipped: they
        decode nothing and their blocks are already allocated.  ``window``
        overrides the window length (the speculative tick passes
        ``draft_k``; rollback gives surplus coverage back, so partial
        acceptance never strands blocks)."""
        self._maybe_fail("pool_alloc")
        bs = self.block_size
        for i, req in [(i, s) for i, s in enumerate(self.slots)
                       if s is not None and s.state == "active"]:
            pool = self.pools[self._slot_shard(i)]
            p = int(self._slot_pos[i])
            if p >= self.max_len:
                self._finish(i, req, "length")
                continue
            self._seal_full_blocks(req, p)
            w = min(self._window_ticks() if window is None else window,
                    self.max_len - p,
                    max(1, req.effective_max_new() - len(req.out)))
            pre = len(pool.table(req.rid))
            need = (p + w - 1) // bs + 1
            while len(pool.table(req.rid)) < need:
                phys = pool.append_block(req.rid)
                if phys is None:
                    break
                self._bt[i, len(pool.table(req.rid)) - 1] = phys
                self._bt_dirty = True
            covered = len(pool.table(req.rid)) * bs - p
            if covered <= 0:
                if pool.holders == 1:
                    # nothing to evict or preempt — this shard's pool itself
                    # is the capacity limit for its lone request
                    self._finish(i, req, "length")
                else:
                    self._preempt_requeue(i, req)
                continue
            self._paged_cap[i] = covered
            if p // bs < pre:
                # the window starts inside a pre-existing block (partial
                # tail or a resume) — copy-on-write guard before writing
                self._ensure_tail_writable(i, req, p // bs)

    def _ensure_tail_writable(self, i: int, req: Request, logical: int):
        """Copy-on-write guard before this tick's decode write: the tail
        block is uniquely owned by construction (only full blocks are ever
        sealed/shared), so this is normally a refcount check and nothing
        more — but if a future sharing path ever hands out a partial
        block, the write copies it private instead of corrupting every
        other holder.  Pool exhaustion during the copy preempts like any
        other allocation failure."""
        shard = self._slot_shard(i)
        old = int(self._bt[i, logical])
        try:
            phys, copied = self.pools[shard].ensure_writable(req.rid, logical)
        except MemoryError:
            self._preempt_requeue(i, req)
            return
        if copied:
            self._copy_pool_block(shard, old, int(phys))
            self._bt[i, logical] = phys
            self._bt_dirty = True

    def _copy_pool_block(self, shard: int, src: int, dst: int):
        """Duplicate one physical block's contents across every layer's
        pool arrays (stacked pattern entries carry a leading repeat axis).
        ``src``/``dst`` are shard-local ids; the device pool lays the
        shards' sub-pools back to back (DESIGN.md §9), so the global index
        offsets by shard·(blocks-per-shard + 1)."""
        off = shard * (self._nb_local + 1)
        src, dst = off + src, off + dst
        self.cache["layers"] = [
            jax.tree.map(lambda a: a.at[:, dst].set(a[:, src]), e)
            for e in self.cache["layers"]]
        self.cache["remainder"] = [
            jax.tree.map(lambda a: a.at[dst].set(a[src]), e)
            for e in self.cache["remainder"]]

    def _decode_tick(self):
        """One decode *window*: ``decode_ticks`` fused scan ticks in a
        single device dispatch, then one host drain of the (n, B) token
        matrix (DESIGN.md §11).  Per-slot window budgets (max_new /
        max_len / paged block coverage) and stop sets ride down as device
        arrays so finish detection never syncs mid-window; the drain walks
        each slot's column up to its first stop hit and re-runs the exact
        per-token finish logic of the one-tick engine (``_emit``)."""
        n = self._window_ticks()
        self._paged_cap = {}
        if self.kv_layout == "paged":
            self._pre_decode_paged()
            self._sync_block_tables()
        active = [(i, s) for i, s in enumerate(self.slots)
                  if s is not None and s.state == "active"]
        if not active:
            return
        alive = np.zeros((self.batch,), bool)
        budgets = np.zeros((self.batch,), np.int32)
        stop_sets = {}
        wmax = 1
        for i, req in active:
            b = min(n, req.effective_max_new() - len(req.out),
                    self.max_len - int(self._slot_pos[i]))
            if self.kv_layout == "paged":
                b = min(b, self._paged_cap[i])
            alive[i] = True
            budgets[i] = b
            ss = set(req.sampling.stop_set())
            if req.sampling.eos_id is not None:
                ss.add(req.sampling.eos_id)
            stop_sets[i] = ss
            wmax = max(wmax, len(ss))
        # bucket the stop-set width so the (B, W) stops array compiles per
        # power-of-two width, not per distinct stop-set size
        W = 1
        while W < wmax:
            W *= 2
        stops = np.full((self.batch, W), -1, np.int32)   # -1 never sampled
        for i, ss in stop_sets.items():
            for j, t in enumerate(sorted(ss)):
                stops[i, j] = t

        self._refresh_device_state()
        t0 = time.time()
        toks_dev, last_dev, counters_dev, self.cache = self._fused_for(n)(
            self.params, self._dev["last_token"], self.cache,
            self._dev["offsets"], self.tick,
            self._dev["temps"], self._dev["topks"], self._dev["seeds"],
            self._dev["counters"], jnp.asarray(alive),
            jnp.asarray(budgets), jnp.asarray(stops))
        toks = np.asarray(toks_dev)           # (n, B) — the window drain
        # crash point between the device window and the host drain: the
        # window's tokens are lost with the process, never half-emitted
        self._maybe_fail("mid_window")
        dt = time.time() - t0
        # the fused window advanced counters and produced the next input
        # token on device — keep those copies resident (no re-upload next
        # window; dead rows froze, matching the host mirrors below)
        self._dev["counters"] = counters_dev
        self._dev["last_token"] = last_dev
        self.tick += n
        self.stats["decode_s"] += dt
        self.stats["decode_calls"] += 1

        now = time.time()
        kept = {}
        for i, req in active:
            col = toks[:, i]
            ss = stop_sets[i]
            m = int(budgets[i])               # tokens this slot really kept
            for j in range(m):
                if int(col[j]) in ss:
                    m = j + 1
                    break
            kept[i] = m
            # windowed-drain ITL attribution: m tokens arrived over one
            # host drain interval — attribute the per-token inter-arrival
            # as interval/m instead of one m-sized observation per drain
            t_prev = req.t_last if req.t_last is not None else now
            share = (now - t_prev) / m
            for j in range(m):
                self._slot_pos[i] += 1
                self._emit(i, req, int(col[j]), t_prev + share * (j + 1))
            self.stats["decode_tokens"] += m
        self.trace.wave(
            "decode_window", t0, t0 + dt,
            [(req.rid, f"decode[w{self._step_tick}]",
              {"slot": i, "tokens": kept[i]}) for i, req in active],
            tick=self._step_tick, n_ticks=n)

    def _spec_decode_tick(self):
        """One speculative window (DESIGN.md §14): draft ``draft_k - 1``
        tokens per slot host-side, score all ``draft_k`` positions in one
        verify dispatch, then commit the longest prefix each slot's own
        sampler agrees with.  Acceptance is *exact token match* — row t's
        logits are bitwise the sequential decode logits whenever rows 1..t
        matched, and the sampler is stateless in (seed, counter) — so the
        emitted stream is bitwise the plain-decode stream for greedy and
        temperature alike; a window always commits at least row 0's sampled
        token (plain decode's tick), so wrong drafts cost latency, never
        progress.  Supersedes ``decode_ticks`` while spec_decode is on: the
        verify window *is* the engine window.  Rejected suffixes roll back
        in the same commit dispatch (scrub to never-written bytes); paged
        slots then return surplus draft-coverage blocks via
        ``KVPool.truncate``, leaving pool state as if never drafted."""
        K = self.draft_k
        self._paged_cap = {}
        if self.kv_layout == "paged":
            self._pre_decode_paged(window=K)
            self._sync_block_tables()
        active = [(i, s) for i, s in enumerate(self.slots)
                  if s is not None and s.state == "active"]
        if not active:
            return
        td0 = time.time()
        alive = np.zeros((self.batch,), bool)
        budgets = np.zeros((self.batch,), np.int32)
        drafts = np.zeros((self.batch, K), np.int32)
        n_drafted = {}
        for i, req in active:
            b = min(K, req.effective_max_new() - len(req.out),
                    self.max_len - int(self._slot_pos[i]))
            if self.kv_layout == "paged":
                b = min(b, self._paged_cap[i])
            alive[i] = True
            budgets[i] = b
            drafts[i, 0] = self._last_token[i]
            prop = self.drafter.propose(list(req.prompt) + req.out, K - 1)
            nd = min(len(prop), K - 1)
            if nd:
                drafts[i, 1:1 + nd] = prop[:nd]
            n_drafted[i] = nd
        td1 = time.time()

        self._refresh_device_state()
        t0 = time.time()
        toks_dev, self.cache = self._spec_for(K)(
            self.params, jnp.asarray(drafts), self.cache,
            self._dev["offsets"], self.tick,
            self._dev["temps"], self._dev["topks"], self._dev["seeds"],
            self._dev["counters"], jnp.asarray(alive), jnp.asarray(budgets))
        toks = np.asarray(toks_dev)               # (B, K) sampled per row
        self._maybe_fail("mid_window")
        dt = time.time() - t0
        self.tick += 1
        self.stats["decode_s"] += dt
        self.stats["decode_calls"] += 1

        # host accept walk: row t committed iff its *input* draft matched
        # row t-1's sampled token (= what sequential decode would have fed),
        # cut at the first stop/EOS hit like the plain window drain
        tc0 = time.time()
        accept = {}
        for i, req in active:
            b = int(budgets[i])
            m = 1
            while m < b and drafts[i, m] == toks[i, m - 1]:
                m += 1
            ss = set(req.sampling.stop_set())
            if req.sampling.eos_id is not None:
                ss.add(req.sampling.eos_id)
            for j in range(m):
                if int(toks[i, j]) in ss:
                    m = j + 1
                    break
            accept[i] = m
        # bulk commit + rejected-suffix scrub in one dispatch, against the
        # *pre-truncation* block tables (the scrub needs the draft mapping)
        new_pos = np.asarray(self._slot_pos, np.int32)
        for i, _ in active:
            new_pos[i] += accept[i]
        self.cache = self._spec_commit_for(K)(
            self.cache, jnp.asarray(new_pos), jnp.asarray(budgets))
        if self.kv_layout == "paged":
            bs = self.block_size
            # reverse slot order, truncate returning newest-first: the
            # appends to each pool's free list exactly mirror the pop order
            # of this window's draft-coverage allocation across *all* slots,
            # so the free list (order included) is restored to its
            # never-drafted state — the pool-state parity the rollback
            # tests pin (DESIGN.md §14)
            for i, req in reversed(active):
                pool = self.pools[self._slot_shard(i)]
                keep = max(1, -(-int(new_pos[i]) // bs))
                have = len(pool.table(req.rid))
                if have > keep:
                    pool.truncate(req.rid, keep)
                    self._bt[i, keep:have] = self._trash
                    self._bt_dirty = True
        tc1 = time.time()

        now = time.time()
        for i, req in active:
            m = accept[i]
            t_prev = req.t_last if req.t_last is not None else now
            share = (now - t_prev) / m
            for j in range(m):
                self._slot_pos[i] += 1
                self._emit(i, req, int(toks[i, j]), t_prev + share * (j + 1))
            self.stats["decode_tokens"] += m
            self.metrics.inc("spec_draft_tokens", int(budgets[i]) - 1)
            self.metrics.inc("spec_accepted_tokens", m - 1)
            self.metrics.inc("spec_emitted_tokens", m)
        self.metrics.inc("spec_windows")
        # the device sampling counters ran ahead (all K rows drew); the
        # host mirrors advanced by the accept length in _emit — re-upload
        self._dev_dirty = True
        self.trace.wave(
            "spec_draft", td0, td1,
            [(req.rid, f"draft[w{self._step_tick}]",
              {"slot": i, "drafted": n_drafted[i]}) for i, req in active],
            tick=self._step_tick)
        self.trace.wave(
            "spec_verify", t0, t0 + dt,
            [(req.rid, f"verify[w{self._step_tick}]",
              {"slot": i, "k": K, "budget": int(budgets[i])})
             for i, req in active],
            tick=self._step_tick, n_ticks=K)
        self.trace.wave(
            "spec_commit", tc0, tc1,
            [(req.rid, f"commit[w{self._step_tick}]",
              {"slot": i, "accepted": accept[i]}) for i, req in active],
            tick=self._step_tick)

    def _emit(self, i: int, req: Request, tok: int, now: float):
        req.out.append(tok)
        if req.t_first is None:
            req.t_first = now
            self.trace.phase(req.rid, "decode", now, slot=i)
            if req.ttft is not None:
                self.metrics.observe_ttft(req.ttft)
        else:
            req.itl.append(now - req.t_last)
            self.metrics.observe_itl(now - req.t_last)
        req.t_last = now
        self._counters[i] += 1
        self._last_token[i] = tok
        if req.stream is not None:
            req.stream(req, tok)

        sp = req.sampling
        if sp.eos_id is not None and tok == sp.eos_id:
            self._finish(i, req, "eos")
        elif tok in sp.stop_set():
            self._finish(i, req, "stop")
        elif len(req.out) >= req.effective_max_new():
            self._finish(i, req, "length")
        elif self.kv_layout == "ring" and self._slot_pos[i] >= self.max_len:
            # the slot's ring cache is full: preempt so the next admission
            # wave can recycle it (the request keeps what it generated).
            # The paged engine has no ring wrap — it requeues-with-blocks on
            # pool pressure instead (_preempt_requeue) and treats max_len as
            # a hard 'length' stop in _pre_decode_paged.
            self._finish(i, req, "preempted")

    def _finish(self, i: int, req: Request, reason: str):
        req.done, req.finish_reason, req.state = True, reason, "done"
        self.finished.append(req)
        self.metrics.inc("finished_requests")
        self.metrics.inc(f"finish_{reason}")
        self.trace.finish(req.rid, req.t_last if req.t_last is not None
                          else self._now(), reason, slot=i)
        self.slots[i] = None
        if self.kv_layout == "paged":
            # seal what the prompt + generation filled (future prefix hits),
            # then drop the references — sealed blocks linger in the pool's
            # LRU prefix cache until allocation pressure evicts them
            self._seal_full_blocks(req, int(self._slot_pos[i]))
            self._release_slot_blocks(i, req)

    # --------------------------------- snapshot / restore (DESIGN.md §12)

    @staticmethod
    def _req_to_state(req: Request) -> dict:
        sp = req.sampling
        return {
            "rid": req.rid, "prompt": list(req.prompt),
            "out": list(req.out), "priority": req.priority,
            "max_new": req.max_new, "deadline_s": req.deadline_s,
            "done": req.done, "finish_reason": req.finish_reason,
            "state": req.state,
            "t_submit": req.t_submit, "t_admit": req.t_admit,
            "t_first": req.t_first, "t_last": req.t_last,
            "itl": list(req.itl),
            "arrival": getattr(req, "_arrival", None),
            "resume": req._resume, "sealed": req._sealed,
            "pf_pos": req._pf_pos,
            "sampling": {"temperature": sp.temperature, "top_k": sp.top_k,
                         "seed": sp.seed, "max_new": sp.max_new,
                         "eos_id": sp.eos_id,
                         "stop_ids": list(sp.stop_ids),
                         "counter_offset": sp.counter_offset},
        }

    @staticmethod
    def _req_from_state(st: dict,
                        streams: Optional[dict] = None) -> Request:
        sps = st["sampling"]
        req = Request(
            rid=st["rid"], prompt=list(st["prompt"]),
            sampling=SamplingParams(
                temperature=sps["temperature"], top_k=sps["top_k"],
                seed=sps["seed"], max_new=sps["max_new"],
                eos_id=sps["eos_id"], stop_ids=tuple(sps["stop_ids"]),
                counter_offset=sps["counter_offset"]),
            priority=st["priority"], max_new=st["max_new"],
            deadline_s=st["deadline_s"])
        req.out = list(st["out"])
        req.done, req.finish_reason = st["done"], st["finish_reason"]
        req.state = st["state"]
        req.t_submit, req.t_admit = st["t_submit"], st["t_admit"]
        req.t_first, req.t_last = st["t_first"], st["t_last"]
        req.itl = list(st["itl"])
        if st["arrival"] is not None:
            req._arrival = st["arrival"]
        req._resume = st["resume"]
        req._sealed, req._pf_pos = st["sealed"], st["pf_pos"]
        if streams is not None:
            req.stream = streams.get(req.rid)
        return req

    def snapshot(self) -> dict:
        """Serialize **all host-side truth** as one JSON-able dict
        (DESIGN.md §12): queue + per-slot request states (tokens emitted,
        ``_pf_pos`` prefill progress, preempt-resume records), pool block
        tables / refcounts / prefix index, the per-slot sampler mirrors
        (seed / offset / counter / last token), stats and metrics.  Device
        state is deliberately absent — it is a pure function of this host
        truth (dither KV codes are position-pure, the sampler is a
        stateless hash), which is exactly what :meth:`restore` exploits.
        Streaming callbacks cannot be serialized; ``restore(...,
        streams={rid: cb})`` re-attaches them."""
        return {
            "version": 1,
            "layout": {
                "kv_layout": self.kv_layout, "batch": self.batch,
                "max_len": self.max_len, "kv_quant": bool(self.kv_quant),
                "decode_ticks": self.decode_ticks,
                "prefill_chunk": self.prefill_chunk,
                "block_size": getattr(self, "block_size", None),
                "num_blocks": getattr(self, "num_blocks", None),
                "dp": self.dp, "tp": self.tp,
            },
            "tick": self.tick,
            "degraded": self._degraded,
            "scheduler": self.scheduler.snapshot(),
            "queue": [self._req_to_state(r) for r in self.scheduler.queued()],
            "slots": [None if s is None else self._req_to_state(s)
                      for s in self.slots],
            "finished": [self._req_to_state(r) for r in self.finished],
            "slot_state": {
                "last_token": [int(x) for x in self._last_token],
                "slot_pos": [int(x) for x in self._slot_pos],
                "temps": [float(x) for x in self._temps],
                "topks": [int(x) for x in self._topks],
                "seeds": [int(x) for x in self._seeds],
                "offsets": [int(x) for x in self._offsets],
                "counters": [int(x) for x in self._counters],
            },
            "pools": [p.snapshot() for p in self.pools],
            "rid_shard": {str(r): s for r, s in self._rid_shard.items()}
                         if self.pools else {},
            "stats": dict(self.stats),
            "metrics": self.metrics.snapshot(),
            "trace": self.trace.snapshot(self._now()),
        }

    def restore(self, snap: dict, streams: Optional[dict] = None) -> "Engine":
        """Adopt a :meth:`snapshot` and re-materialize the device KV so the
        engine continues **bitwise** where the snapshot was taken
        (policy-free / deterministic-scheme serving — the §12 contract,
        tests/test_serve_fault.py).  Works on a freshly constructed engine
        of the same layout *or* in place on a crashed one (every mutable
        field is overwritten; the device cache is rebuilt from scratch).

        Host truth is copied back verbatim; then :meth:`_replay_device_state`
        rebuilds each occupied slot's KV: the written prompt region through
        the engine's own prefill path (position-pure codes ⇒ the original
        prefill's bits) and the generated region by **teacher-forced decode
        replay** — each committed token re-runs the fused decode-step math
        with sampling discarded, so the decode-written KV is bit-identical
        too.  Unheld prefix-cache blocks are dropped (see
        ``KVPool.restore``); free capacity is unchanged."""
        lay = snap["layout"]
        mine = {
            "kv_layout": self.kv_layout, "batch": self.batch,
            "max_len": self.max_len, "kv_quant": bool(self.kv_quant),
            "decode_ticks": self.decode_ticks,
            "prefill_chunk": self.prefill_chunk,
            "block_size": getattr(self, "block_size", None),
            "num_blocks": getattr(self, "num_blocks", None),
            "dp": self.dp, "tp": self.tp,
        }
        diff = {k for k in mine if lay.get(k) != mine[k]}
        if diff:
            raise ValueError("snapshot layout does not match this engine: "
                             + ", ".join(f"{k}={lay.get(k)!r}!={mine[k]!r}"
                                         for k in sorted(diff)))
        self.tick = int(snap["tick"])
        self._degraded = bool(snap["degraded"])
        queue = [self._req_from_state(st, streams) for st in snap["queue"]]
        self.scheduler.restore(snap["scheduler"], queue)
        self.slots = [None if st is None else self._req_from_state(st, streams)
                      for st in snap["slots"]]
        self.finished = [self._req_from_state(st, streams)
                         for st in snap["finished"]]
        ss = snap["slot_state"]
        self._last_token = np.asarray(ss["last_token"], np.int32)
        self._slot_pos = np.asarray(ss["slot_pos"], np.int64)
        self._temps = np.asarray(ss["temps"], np.float32)
        self._topks = np.asarray(ss["topks"], np.int32)
        self._seeds = np.asarray(ss["seeds"], np.int32)
        self._offsets = np.asarray(ss["offsets"], np.int32)
        self._counters = np.asarray(ss["counters"], np.int32)
        self.stats = dict(snap["stats"])
        self.metrics.restore(snap["metrics"])
        # resume the request timelines (spans open at crash close with a
        # recovery marker; absent in pre-v9 snapshots → no-op)
        self.trace.restore(snap.get("trace"), t=self._now())
        self._paged_cap = {}
        self._steps_since_snap = 0
        if self.pools:
            for pool, ps in zip(self.pools, snap["pools"]):
                pool.restore(ps)
            self._rid_shard = {int(r): int(s)
                               for r, s in snap["rid_shard"].items()}
        # fresh device cache, then deterministic re-materialization of
        # every occupied slot's KV (and of the block-table mirror)
        if self.kv_layout == "paged":
            self.cache = registry.make_cache(
                self.params, self.cfg, self.batch, self.max_len,
                frames=self._frames, policy=self.policy,
                kv_quant=self.kv_quant, kv_layout="paged",
                block_size=self.block_size, num_blocks=self._nb_local,
                data_shards=self.dp)
            self._bt = np.full((self.batch, self.nbmax), self._trash,
                               np.int32)
            for i, req in enumerate(self.slots):
                if req is not None:
                    self._bt[i, :len(self._pool_of(req.rid).table(req.rid))] \
                        = self._pool_of(req.rid).table(req.rid)
            self._bt_dirty = True
        else:
            self.cache = registry.make_cache(
                self.params, self.cfg, self.batch, self.max_len,
                frames=self._frames, policy=self.policy,
                kv_quant=self.kv_quant)
        self._dev_dirty = True
        self._replay_device_state()
        self._dev_dirty = True
        self.metrics.inc("recoveries")
        return self

    def _replay_fn_for(self):
        """The jitted teacher-forced replay step (compiled on first use):
        the fused decode tick's model math with sampling stripped — the
        input token is *given*, not sampled — and the same inert-row
        freezing (position pinned, paged rows masked to the trash block)."""
        fn = getattr(self, "_replay_fn", None)
        if fn is not None:
            return fn
        cfg_l, policy = self._cfg_local, self.policy
        paged = self.kv_layout == "paged"

        def replay_step(params, token, cache, kv_offset, counter, alive):
            pos0 = cache["pos"]
            step_cache = cache
            if paged:
                leaf = (jax.tree.leaves(cache["layers"][0])[0]
                        if cache["layers"]
                        else jax.tree.leaves(cache["remainder"][0])[0])
                nbp = leaf.shape[1] if cache["layers"] else leaf.shape[0]
                step_cache = dict(cache)
                step_cache["block_tables"] = jnp.where(
                    alive[:, None], cache["block_tables"],
                    jnp.int32(nbp - 1))
            _, new_cache = registry.apply_decode(
                params, cfg_l, token, step_cache, policy=policy,
                counter=counter, kv_offset=kv_offset)
            new_cache["pos"] = jnp.where(alive, new_cache["pos"], pos0)
            if paged:
                new_cache["block_tables"] = cache["block_tables"]
            return new_cache

        if self.mesh is None:
            fn = jax.jit(replay_step, donate_argnums=(2,))
        else:
            P = jax.sharding.PartitionSpec
            row, sc = P("data"), P()
            fn = jax.jit(self._mesh_wrap(
                replay_step,
                (self._pspec, row, self._cspec, row, sc, row),
                self._cspec), donate_argnums=(2,))
        self._replay_fn = fn
        return fn

    def _replay_device_state(self):
        """Re-materialize the device KV for every occupied slot, bitwise.

        Two regions per slot, split at the prompt boundary: positions the
        original run wrote via *prefill* are re-prefilled through the same
        batched prefill path (dither codes are position-pure, so the bits
        match); positions written via *decode* are replayed teacher-forced
        — one decode step per committed token, inert rows frozen — which
        reproduces the decode-written bits exactly (re-prefilling them
        instead would only agree to rounding: the prefill≡decode
        first-layer-only divergence tests/test_serve.py pins).  Slots
        restored mid-reprefill (``_resume['reprefill']`` histories) treat
        prompt + generated as one prefill region, matching what the
        original engine would write on re-admission."""
        occupied = [(i, s) for i, s in enumerate(self.slots)
                    if s is not None]
        if self.kv_layout == "paged":
            self._sync_block_tables()
        if not occupied:
            return
        prompt_part, gen_tokens = {}, {}
        for i, req in occupied:
            written = int(self._slot_pos[i])
            seq = self._tokens_written(req)        # prompt (+ out: reprefill)
            prompt_len = len(list(req.prompt) or [1])
            if req.state == "prefilling":
                # mid-prefill: everything written so far came via prefill
                prompt_part[i], gen_tokens[i] = seq[:written], []
            else:
                p = min(written, prompt_len)
                prompt_part[i] = seq[:p]
                gen_tokens[i] = list(req.out)[:written - p]

        lens = np.zeros((self.batch,), np.int32)
        for i, _ in occupied:
            lens[i] = len(prompt_part[i])
        if lens.max() > 0:
            s_bucket = _bucket(int(lens.max()))
            toks = np.zeros((self.batch, s_bucket), np.int32)
            for i, _ in occupied:
                toks[i, :lens[i]] = prompt_part[i]
            self._dev_dirty = True
            self._refresh_device_state()
            if self.kv_layout == "paged":
                starts = np.zeros((self.batch,), np.int32)
                bt_dev = jnp.asarray(self._bt)
                self._bt_dirty = False
                _, self.cache = self._paged_prefill_call(
                    self.params, jnp.asarray(toks), jnp.asarray(lens),
                    jnp.asarray(starts), bt_dev, self.cache,
                    self._dev["offsets"], self.tick, prefix_blocks=0)
            else:
                _, pf_cache = self._prefill(
                    self.params, jnp.asarray(toks), jnp.asarray(lens),
                    self._dev["offsets"], self.tick)
                self.cache = self._merge(self.cache, pf_cache,
                                         jnp.asarray(lens > 0))

        depth = max(len(g) for g in gen_tokens.values()) \
            if gen_tokens else 0
        if depth:
            replay = self._replay_fn_for()
            for t in range(depth):
                token = np.zeros((self.batch,), np.int32)
                alive = np.zeros((self.batch,), bool)
                for i, _ in occupied:
                    g = gen_tokens[i]
                    if t < len(g):
                        token[i], alive[i] = g[t], True
                self.cache = replay(
                    self.params, jnp.asarray(token), self.cache,
                    jnp.asarray(self._offsets), self.tick,
                    jnp.asarray(alive))
