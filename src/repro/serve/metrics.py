"""Engine observability: host-side counters, per-tick gauges and latency
histograms behind a buffered, crash-isolated, pluggable sink (DESIGN.md §10).

The collection contract mirrors the paper's serving claims being *numeric*:
the engine's behaviour (throughput trajectory, prefix-hit rate, preemption
pressure, TTFT/ITL distribution) must be observable per tick so the perf
gate (benchmarks/perf_gate.py) and operators (docs/serving_ops.md) see
regressions instead of reading raw JSON by hand.  Three design rules, all
load-bearing:

* **host-side only** — every value recorded here is a Python int/float the
  engine already holds on the host (scheduler depth, slot occupancy, pool
  allocator counts, wall-clock deltas).  Nothing reads a device array, so
  metrics add **zero dispatches** to the fused decode tick; the acceptance
  criterion "smoke decode tok/s within gate tolerance" rides on this.
* **buffered** — per-tick records accumulate in a list and reach the sink
  in batches of ``flush_every``, so a slow sink (file, socket) amortises
  instead of stalling every tick.
* **crash-isolated** — a sink raising must never kill serving (the
  HomebrewNLP ``wandblog`` idiom: observability is best-effort).  The first
  sink exception is reported once on stderr, the sink is replaced by
  :class:`NullSink`, and the engine never sees the error; buffered records
  held at that moment are dropped (counted in ``sink_errors``).

Histograms are log-spaced-bucket histograms: ``record`` is O(1), counts are
exact, percentiles are geometric interpolation inside the landing bucket
(≈ one bucket ratio of relative error — see :class:`Histogram`).  The
exact per-request TTFT/ITL lists on :class:`~repro.serve.engine.Request`
remain the precise record; the histograms are the streaming aggregate.
"""

from __future__ import annotations

import json
import math
import os
import sys
import time
from typing import Dict, List, Optional, Union

__all__ = ["Histogram", "Metrics", "NullSink", "StdoutSink", "JsonlSink",
           "SinkBuffer", "make_sink"]


class Histogram:
    """Fixed log-spaced-bucket latency histogram (values in seconds).

    ``n_buckets`` geometric buckets span [lo, hi); values below ``lo`` land
    in an underflow bucket, values ≥ ``hi`` in an overflow bucket.  With
    the defaults (10 µs … 1000 s over 96 buckets) each bucket spans a
    ratio of ``(1e8)**(1/96) ≈ 1.21``, so percentiles carry ≤ ~21%
    relative error — plenty for trajectory tracking; exact values stay on
    the Request objects.  ``count``/``sum``/``max`` are exact.
    """

    def __init__(self, lo: float = 1e-5, hi: float = 1e3,
                 n_buckets: int = 96):
        if not (0 < lo < hi) or n_buckets <= 0:
            raise ValueError("need 0 < lo < hi and n_buckets > 0")
        self.lo, self.hi, self.n_buckets = float(lo), float(hi), n_buckets
        self._log_lo = math.log(lo)
        self._log_span = math.log(hi) - math.log(lo)
        # counts[0] = underflow, counts[1..n] = buckets, counts[n+1] = overflow
        self.counts = [0] * (n_buckets + 2)
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def _bucket(self, v: float) -> int:
        if v < self.lo:
            return 0
        if v >= self.hi:
            return self.n_buckets + 1
        frac = (math.log(v) - self._log_lo) / self._log_span
        return 1 + min(self.n_buckets - 1, int(frac * self.n_buckets))

    def _edge(self, i: int) -> float:
        """Upper edge of counts-index ``i`` (underflow edge = lo)."""
        if i <= 0:
            return self.lo
        if i > self.n_buckets:
            return self.max
        return math.exp(self._log_lo + self._log_span * i / self.n_buckets)

    def record(self, v: float) -> None:
        v = float(v)
        self.counts[self._bucket(v)] += 1
        self.count += 1
        self.sum += v
        if v > self.max:
            self.max = v

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (q in [0, 100]): geometric
        interpolation inside the bucket where the cumulative count crosses
        the target rank.  0.0 on an empty histogram."""
        if self.count == 0:
            return 0.0
        target = q / 100.0 * self.count
        seen = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= target:
                lo_edge = self._edge(i - 1) if i else 0.0
                hi_edge = self._edge(i)
                frac = (target - seen) / c
                if lo_edge <= 0.0:
                    return hi_edge * frac
                return math.exp(math.log(lo_edge)
                                + frac * (math.log(hi_edge)
                                          - math.log(lo_edge)))
            seen += c
        return self.max

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.sum,
                "mean": self.sum / self.count if self.count else 0.0,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99), "max": self.max}

    def state(self) -> dict:
        """JSON-able full state (exact counts, not the percentile summary) —
        engine snapshots carry this so a restored engine's histograms keep
        accumulating where the crashed one stopped (DESIGN.md §12)."""
        return {"lo": self.lo, "hi": self.hi, "n_buckets": self.n_buckets,
                "counts": list(self.counts), "count": self.count,
                "sum": self.sum, "max": self.max}

    @classmethod
    def from_state(cls, state: dict) -> "Histogram":
        h = cls(state["lo"], state["hi"], state["n_buckets"])
        h.counts = [int(c) for c in state["counts"]]
        h.count = int(state["count"])
        h.sum = float(state["sum"])
        h.max = float(state["max"])
        return h


# --------------------------------------------------------------------- sinks


class NullSink:
    """Swallows everything — the default: collection without streaming."""

    def write(self, records: List[dict]) -> None:
        pass

    def close(self) -> None:
        pass


class StdoutSink:
    """One compact JSON line per record to a stream (default stdout)."""

    def __init__(self, stream=None):
        self.stream = stream

    def write(self, records: List[dict]) -> None:
        stream = self.stream or sys.stdout
        for rec in records:
            stream.write(json.dumps(rec, separators=(",", ":")) + "\n")

    def close(self) -> None:
        pass


class JsonlSink:
    """Appends records to a JSONL file, one object per line.  The file is
    opened lazily on first flush and kept open across flushes.

    Durability contract: ``close()`` flushes **and fsyncs** so a clean
    shutdown leaves every record on disk, and the lazy open repairs a torn
    final line (a crash mid-``write`` can leave a partial JSON object with
    no trailing newline) by truncating back to the last complete line —
    downstream jsonl readers never see a corrupt tail after a reopen.
    """

    def __init__(self, path: str):
        self.path = path
        self._fh = None

    def _repair_torn_tail(self) -> None:
        try:
            size = os.path.getsize(self.path)
        except OSError:
            return
        if size == 0:
            return
        with open(self.path, "rb+") as fh:
            # Scan backwards in chunks for the last newline; everything
            # after it is a torn partial record from a crashed writer.
            pos, chunk = size, 4096
            last_nl = -1
            while pos > 0 and last_nl < 0:
                start = max(0, pos - chunk)
                fh.seek(start)
                buf = fh.read(pos - start)
                nl = buf.rfind(b"\n")
                if nl >= 0:
                    last_nl = start + nl
                pos = start
            fh.truncate(last_nl + 1 if last_nl >= 0 else 0)

    def write(self, records: List[dict]) -> None:
        if self._fh is None:
            self._repair_torn_tail()
            self._fh = open(self.path, "a")
        for rec in records:
            self._fh.write(json.dumps(rec, separators=(",", ":")) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._fh.close()
            self._fh = None


def make_sink(spec: Union[None, str, object]):
    """Resolve a sink spec: ``None``/``"null"`` → :class:`NullSink`,
    ``"stdout"`` → :class:`StdoutSink`, ``"jsonl:<path>"`` (or a bare
    ``*.jsonl`` path) → :class:`JsonlSink`, and any object with a
    ``write`` method passes through unchanged."""
    if spec is None or spec == "null":
        return NullSink()
    if isinstance(spec, str):
        if spec == "stdout":
            return StdoutSink()
        if spec.startswith("jsonl:"):
            return JsonlSink(spec[len("jsonl:"):])
        if spec.endswith(".jsonl"):
            return JsonlSink(spec)
        raise ValueError(f"unknown metrics sink spec {spec!r}; expected "
                         "'null', 'stdout', 'jsonl:<path>' or a sink object")
    if hasattr(spec, "write"):
        return spec
    raise TypeError(f"not a metrics sink: {spec!r}")


class SinkBuffer:
    """Buffered, crash-isolated front end shared by every record stream
    (:class:`Metrics` and :class:`repro.serve.trace.Tracer`).

    Records accumulate in a list and reach the sink in batches of
    ``flush_every``.  A sink exception is counted in ``sink_errors``,
    reported once on stderr, and the sink is swapped for a
    :class:`NullSink` — the producer never sees the error (the records of
    the failing flush are dropped: best-effort observability).
    """

    def __init__(self, sink, flush_every: int = 64):
        self.sink = sink if hasattr(sink, "write") else make_sink(sink)
        self.flush_every = max(1, int(flush_every))
        self.sink_errors = 0
        self._warned = False
        self._buffer: List[dict] = []

    def add(self, rec: dict) -> None:
        self._buffer.append(rec)
        if len(self._buffer) >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        if not self._buffer:
            return
        records, self._buffer = self._buffer, []
        if isinstance(self.sink, NullSink):
            return
        try:
            self.sink.write(records)
        except Exception as e:                       # noqa: BLE001
            self.sink_errors += 1
            if not self._warned:
                self._warned = True
                print(f"metrics sink failed ({type(e).__name__}: {e}); "
                      "disabling sink — serving continues without streaming",
                      file=sys.stderr)
            self.sink = NullSink()

    def close(self) -> None:
        self.flush()
        try:
            self.sink.close()
        except Exception:                            # noqa: BLE001
            self.sink_errors += 1

    def clear(self) -> None:
        self._buffer = []


# ----------------------------------------------------------------- collector


class Metrics:
    """The engine's metrics surface: monotonic counters, per-tick gauge
    records, TTFT/ITL histograms, and the buffered sink.

    The engine calls :meth:`tick` once per :meth:`~repro.serve.engine.
    Engine.step` with the host-side gauges of that tick; counters and
    histogram observations arrive from the emit/finish paths.  ``reset``
    zeroes everything (``Engine.reset_stats`` round-trips through it so
    benchmark warm-up waves never leak into measured histograms).
    """

    def __init__(self, sink: Union[None, str, object] = None,
                 flush_every: int = 64):
        self._sb = SinkBuffer(make_sink(sink), flush_every=flush_every)
        self.reset()

    # The sink plumbing lives in the shared SinkBuffer; these properties
    # keep the original public surface (tests read metrics.sink /
    # metrics.sink_errors directly).
    @property
    def sink(self):
        return self._sb.sink

    @property
    def sink_errors(self) -> int:
        return self._sb.sink_errors

    @property
    def flush_every(self) -> int:
        return self._sb.flush_every

    # -- lifecycle

    def reset(self) -> None:
        self.counters: Dict[str, float] = {}
        self.ttft_s = Histogram()
        self.itl_s = Histogram()
        self.ticks = 0
        self._sb.clear()
        self._gauge_sum: Dict[str, float] = {}
        self._gauge_last: Dict[str, float] = {}
        self._gauge_n: Dict[str, int] = {}

    def close(self) -> None:
        self._sb.close()

    # -- recording (all host-side; never touches a device array)

    def inc(self, name: str, v: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + v

    def observe_ttft(self, seconds: float) -> None:
        self.ttft_s.record(seconds)

    def observe_itl(self, seconds: float) -> None:
        """One observation **per completed token**, not per host drain: a
        windowed-decode engine draining m tokens at once must attribute
        drain_interval / m to each (the engine's _decode_tick does exactly
        that), so the itl histogram's count matches the token count at any
        decode_ticks setting (tests/test_metrics.py)."""
        self.itl_s.record(seconds)

    def tick(self, **gauges) -> None:
        """Record one per-tick gauge snapshot and buffer it for the sink."""
        rec = {"t": time.time(), "tick": self.ticks}
        for k, v in gauges.items():
            rec[k] = v
            if isinstance(v, (int, float)):
                self._gauge_sum[k] = self._gauge_sum.get(k, 0.0) + v
                self._gauge_n[k] = self._gauge_n.get(k, 0) + 1
                self._gauge_last[k] = v
        self.ticks += 1
        self._sb.add(rec)

    def event(self, kind: str, **fields) -> None:
        """Buffer one out-of-band event record for the sink (same stream as
        the tick records, distinguished by an ``event`` key).  Since PR 9
        the engine's lifecycle events (degraded/restored/slow_window)
        travel on the tracer's feed instead (DESIGN.md §13); this remains
        for ad-hoc callers that want events interleaved with gauges."""
        rec = {"t": time.time(), "event": kind}
        rec.update(fields)
        self._sb.add(rec)

    # -- sink plumbing

    def flush(self) -> None:
        """Hand the buffered records to the sink (crash-isolated — see
        :class:`SinkBuffer`)."""
        self._sb.flush()

    # -- snapshot / restore (crash recovery, DESIGN.md §12)

    def snapshot(self) -> dict:
        """JSON-able aggregate state: counters, tick count, gauge
        aggregates and full histogram states.  The sink buffer is *not*
        captured — buffered-but-unflushed records are exactly the
        observability loss the crash-isolation contract already permits."""
        return {
            "counters": dict(self.counters),
            "ticks": self.ticks,
            "gauge_sum": dict(self._gauge_sum),
            "gauge_last": dict(self._gauge_last),
            "gauge_n": dict(self._gauge_n),
            "ttft_s": self.ttft_s.state(),
            "itl_s": self.itl_s.state(),
        }

    def restore(self, snap: dict) -> None:
        """Resume accumulation from a :meth:`snapshot` (sink and
        ``flush_every`` keep this instance's configuration)."""
        self.counters = {k: v for k, v in snap["counters"].items()}
        self.ticks = int(snap["ticks"])
        self._gauge_sum = {k: float(v) for k, v in snap["gauge_sum"].items()}
        self._gauge_last = dict(snap["gauge_last"])
        self._gauge_n = {k: int(v) for k, v in snap["gauge_n"].items()}
        self.ttft_s = Histogram.from_state(snap["ttft_s"])
        self.itl_s = Histogram.from_state(snap["itl_s"])
        self._sb.clear()

    # -- reading

    def gauge_mean(self, name: str) -> float:
        n = self._gauge_n.get(name, 0)
        return self._gauge_sum.get(name, 0.0) / n if n else 0.0

    def gauge_last(self, name: str) -> Optional[float]:
        return self._gauge_last.get(name)

    def summary(self) -> dict:
        """One JSON-able snapshot: counters, tick count, per-gauge
        mean/last, and the TTFT/ITL histogram summaries."""
        return {
            "ticks": self.ticks,
            "counters": dict(self.counters),
            "gauges": {k: {"mean": self.gauge_mean(k),
                           "last": self._gauge_last[k]}
                       for k in sorted(self._gauge_last)},
            "ttft_s": self.ttft_s.summary(),
            "itl_s": self.itl_s.summary(),
            "sink_errors": self.sink_errors,
        }
