"""Per-request sampling for the serving engine (DESIGN.md §6).

``SamplingParams`` rides on each ``Request``; the engine packs the per-slot
fields into arrays and samples every active slot in one jitted
``sample_tokens`` call.  Randomness is the repo's stateless hash of
``(seed, vocab_index, counter)`` (core/rounding.hash_uniform): the counter
is the request's dither-counter offset plus its emitted-token count, so
concurrent requests walk independent sampling sequences and a restarted
engine replaying the same requests reproduces them exactly.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import FrozenSet, Optional, Tuple

import jax.numpy as jnp

from repro.core import rounding

__all__ = ["SamplingParams", "sample_tokens"]


@dataclass(frozen=True)
class SamplingParams:
    """Decode-time controls carried by one request.

    * ``temperature <= 0`` — greedy (argmax); otherwise softmax sampling at
      that temperature via Gumbel-max over hash uniforms.
    * ``top_k`` — restrict sampling to the k highest logits (0 = full vocab).
    * ``seed`` — per-request sampling stream seed.
    * ``eos_id`` / ``stop_ids`` — generation stops when the sampled token
      matches (finish_reason "eos" / "stop"; the token is kept in ``out``).
    * ``max_new`` — generated-token budget (finish_reason "length").
    * ``counter_offset`` — per-request dither-counter offset: added to the
      sampling counter *and* to the int8-KV quantiser counter for this
      request's slot, so concurrent requests walk independent pulse
      sequences (DESIGN.md §6).
    """

    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    max_new: int = 16
    eos_id: Optional[int] = None
    stop_ids: Tuple[int, ...] = ()
    counter_offset: int = 0

    def stop_set(self) -> FrozenSet[int]:
        stops = set(self.stop_ids)
        if self.eos_id is not None:
            stops.add(self.eos_id)
        return frozenset(stops)


def sample_tokens(logits, temperature, top_k, seed, counter):
    """Sample one token per row under per-row controls (jit-able).

    logits (B, V) f32; temperature (B,) f32; top_k / seed / counter (B,)
    int32.  Rows with ``temperature <= 0`` take the argmax; the rest draw
    from the top-k-masked, temperature-scaled distribution by Gumbel-max,
    with the Gumbel noise a stateless hash of (seed, vocab index, counter)
    — no PRNG state, bit-identical across backends and engine restarts.
    """
    v = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    k = jnp.where(top_k > 0, jnp.clip(top_k, 1, v), v)
    sorted_desc = -jnp.sort(-logits, axis=-1)
    thresh = jnp.take_along_axis(sorted_desc, (k - 1)[:, None], axis=-1)
    masked = jnp.where(logits >= thresh, logits, -jnp.inf)

    idx = jnp.arange(v, dtype=jnp.uint32)[None, :]
    u = rounding.hash_uniform(seed[:, None], idx, counter[:, None])
    gumbel = -jnp.log(-jnp.log(u + 1e-12) + 1e-12)
    scaled = masked / jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jnp.argmax(scaled + gumbel, axis=-1).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)
