"""Per-request tracing and latency attribution (DESIGN.md §13).

The tracer is a host-only span/event recorder threaded through the serving
engine's request lifecycle.  It takes ``time.time()`` stamps exclusively at
points where the engine already synchronises with the device (submit,
admission, prefill waves, window drains, preempt/resume, finish), so enabling
it adds **zero device dispatches** and cannot perturb the token stream.

Three export surfaces share one record stream:

- a streaming jsonl event feed through the crash-isolated sink machinery
  from :mod:`repro.serve.metrics` (``SinkBuffer``),
- a Chrome-trace/Perfetto JSON export (``perfetto()`` / ``write_perfetto()``)
  with per-request tracks (pid 1, one thread per rid) and engine tracks
  (pid 0: waves, counters, degradation instants),
- ``explain(rid)`` — a latency-attribution report decomposing a request's
  wall time into queue / prefill / decode / preempt_stall / degraded /
  recovery shares that sum to 100% by construction.

Attribution-by-construction invariant: each request owns a list of *phase
segments* that exactly partition ``[t_submit, t_finish]`` — every lifecycle
transition closes the open segment at time ``t`` and opens the next one at
the same ``t``.  Spans open at crash time are closed by ``restore()`` with a
``recovery`` marker and a ``recovery`` segment bridges the gap to resume, so
timelines stay continuous (and still sum to 100%) across snapshot/restore.
"""

from __future__ import annotations

import json
import os
import time

from .metrics import SinkBuffer, make_sink

__all__ = ["Tracer", "format_explain"]

# Lifecycle phases a request moves through.  ``queued`` covers both initial
# queue wait and requeued wait after a preempt-stall; ``recovery`` only
# appears on timelines that crossed a snapshot/restore.
PHASES = ("queued", "prefill", "decode", "preempt_stall", "recovery")

# explain() buckets.  ``queued`` reports as ``queue``; prefill/decode
# segments overlapping a degradation interval report as ``degraded``.
CATEGORIES = ("queue", "prefill", "decode", "preempt_stall", "degraded", "recovery")

_PHASE_TO_CATEGORY = {"queued": "queue"}


class _ReqTrace:
    """Per-request span state: closed segments + at most one open segment."""

    __slots__ = ("rid", "t0", "segments", "open", "done", "reason", "tags")

    def __init__(self, rid, t0):
        self.rid = rid
        self.t0 = float(t0)
        self.segments = []  # [phase, t_start, t_end, degraded(0/1)]
        self.open = None  # [phase, t_start, degraded(0/1), tags dict]
        self.done = False
        self.reason = None
        self.tags = {}  # latest request metadata (slot, shard, ...)

    def state(self):
        return {
            "rid": self.rid,
            "t0": self.t0,
            "segments": [list(s) for s in self.segments],
            "open": list(self.open[:3]) + [dict(self.open[3])] if self.open else None,
            "done": self.done,
            "reason": self.reason,
            "tags": dict(self.tags),
        }

    @classmethod
    def from_state(cls, st):
        tr = cls(st["rid"], st["t0"])
        tr.segments = [list(s) for s in st["segments"]]
        op = st.get("open")
        tr.open = [op[0], op[1], op[2], dict(op[3])] if op else None
        tr.done = bool(st.get("done"))
        tr.reason = st.get("reason")
        tr.tags = dict(st.get("tags") or {})
        return tr


class Tracer:
    """Span/event tracer for the serving engine (DESIGN.md §13).

    Construct via :meth:`from_spec` (what ``Engine(trace=...)`` and the
    ``--trace`` flag do).  A disabled tracer (``enabled=False``) turns every
    method into an early-return no-op so the untraced hot path stays free.
    """

    def __init__(self, sink=None, perfetto_path=None, *, enabled=True,
                 retain=None, flush_every=64):
        self.enabled = bool(enabled)
        self.perfetto_path = perfetto_path
        # Retain records in memory when a Perfetto export (or explicit "mem"
        # mode) needs them; a pure jsonl feed streams without retention.
        if retain is None:
            retain = perfetto_path is not None or sink is None
        self._retain = bool(retain)
        self._retained = []
        self._sb = SinkBuffer(make_sink(sink), flush_every=flush_every)
        self._reqs = {}
        self._degraded = False
        self._autotune_registered = False
        if self.enabled:
            self._register_autotune()

    # ------------------------------------------------------------- spec --
    @classmethod
    def from_spec(cls, spec):
        """Build a tracer from a ``--trace`` spec.

        ``None`` → disabled.  Strings are comma-combinable parts:
        ``mem`` (retain records in memory), ``perfetto:<path>`` (write a
        Chrome-trace JSON on close), ``jsonl:<path>`` / ``<path>.jsonl``
        (stream records through a JsonlSink), ``stdout``, ``null``.  An
        object with a ``write`` method is used as the sink directly, and an
        existing :class:`Tracer` passes through.
        """
        if spec is None:
            return cls(enabled=False)
        if isinstance(spec, Tracer):
            return spec
        if isinstance(spec, str):
            sink_spec, perfetto, mem = None, None, False
            for part in spec.split(","):
                part = part.strip()
                if not part:
                    continue
                if part == "mem":
                    mem = True
                elif part.startswith("perfetto:"):
                    perfetto = part[len("perfetto:"):]
                elif part in ("null", "stdout") or part.startswith("jsonl:") \
                        or part.endswith(".jsonl"):
                    sink_spec = part
                else:
                    raise ValueError(f"unknown trace spec part: {part!r}")
            return cls(sink=sink_spec, perfetto_path=perfetto,
                       retain=True if (mem or perfetto) else None)
        if hasattr(spec, "write"):
            return cls(sink=spec)
        raise TypeError(f"cannot build a Tracer from {type(spec).__name__}")

    # -------------------------------------------------------- internals --
    @property
    def sink(self):
        return self._sb.sink

    @property
    def sink_errors(self):
        return self._sb.sink_errors

    def records(self):
        """Retained records (requires "mem" or perfetto mode)."""
        return list(self._retained)

    def _rec(self, rec):
        if self._retain:
            self._retained.append(rec)
        self._sb.add(rec)

    def _req(self, rid, t):
        tr = self._reqs.get(rid)
        if tr is None or tr.done:
            # Unknown rid (e.g. instrumentation reached before begin(), or a
            # finished rid being reused): start a fresh timeline rather than
            # corrupting the old one.
            tr = _ReqTrace(rid, t)
            self._reqs[rid] = tr
        return tr

    def _open(self, tr, phase, t, tags):
        deg = 1 if (self._degraded and phase in ("prefill", "decode")) else 0
        tr.open = [phase, float(t), deg, dict(tags)]

    def _close_open(self, tr, t, **marks):
        if tr.open is None:
            return
        phase, t_start, deg, tags = tr.open
        t_end = max(float(t), t_start)  # clock skew guard: keep segments monotone
        tr.segments.append([phase, t_start, t_end, deg])
        tr.open = None
        rec = {"kind": "span", "cat": "phase", "name": phase, "rid": tr.rid,
               "t0": t_start, "t1": t_end}
        if deg:
            rec["degraded"] = 1
        rec.update(tags)
        rec.update(marks)
        self._rec(rec)

    # ------------------------------------------------- lifecycle methods --
    def begin(self, rid, t, **tags):
        """Request submitted: open its ``queued`` span at ``t``."""
        if not self.enabled:
            return
        tr = self._reqs.get(rid)
        if tr is not None and not tr.done:
            return  # already live (e.g. restored timeline); keep it
        tr = _ReqTrace(rid, t)
        self._reqs[rid] = tr
        tr.tags.update(tags)
        self._open(tr, "queued", t, {})
        self._rec({"kind": "event", "name": "submit", "rid": rid,
                   "t": float(t), **tags})

    def phase(self, rid, name, t, **tags):
        """Transition ``rid`` to phase ``name`` at ``t`` (closes open span)."""
        if not self.enabled:
            return
        tr = self._req(rid, t)
        tr.tags.update(tags)
        self._close_open(tr, t)
        self._open(tr, name, t, tags)

    def finish(self, rid, t, reason, **tags):
        """Request retired (eos/stop/length/shed/deadline/...): seal timeline."""
        if not self.enabled:
            return
        tr = self._req(rid, t)
        tr.tags.update(tags)
        self._close_open(tr, t, finish_reason=reason)
        tr.done = True
        tr.reason = reason
        self._rec({"kind": "event", "name": "finish", "rid": rid,
                   "t": float(t), "reason": reason, **tags})

    def set_degraded(self, flag, t):
        """Degradation watermark flipped: rotate open prefill/decode spans so
        time under degradation is attributed to the ``degraded`` bucket."""
        if not self.enabled:
            return
        flag = bool(flag)
        if flag == self._degraded:
            return
        self._degraded = flag
        want = 1 if flag else 0
        for tr in self._reqs.values():
            if tr.done or tr.open is None:
                continue
            phase = tr.open[0]
            if phase in ("prefill", "decode") and tr.open[2] != want:
                tags = tr.open[3]
                self._close_open(tr, t)
                self._open(tr, phase, t, tags)

    # ------------------------------------------------- engine-side feeds --
    def event(self, name, t=None, rid=None, **fields):
        """Instant event (degraded/restored/slow_window/shed/pool provenance/...)."""
        if not self.enabled:
            return
        rec = {"kind": "event", "name": name,
               "t": float(t) if t is not None else time.time()}
        if rid is not None:
            rec["rid"] = rid
        rec.update(fields)
        self._rec(rec)

    def wave(self, name, t0, t1, parts=(), **tags):
        """Engine-track span for a batched dispatch (prefill wave / decode
        window), plus fine-grained detail spans on each participating
        request's track.  ``parts`` is ``[(rid, span_name, tags), ...]``.
        Timestamps are the ones the engine already took around the dispatch.
        """
        if not self.enabled:
            return
        t0, t1 = float(t0), max(float(t1), float(t0))
        self._rec({"kind": "span", "cat": "wave", "name": name, "rid": None,
                   "t0": t0, "t1": t1, "n": len(parts), **tags})
        for rid, sname, stags in parts:
            self._rec({"kind": "span", "cat": "wave", "name": sname,
                       "rid": rid, "t0": t0, "t1": t1, **(stags or {})})

    def counters(self, t=None, **gauges):
        """Engine counter sample (queue depth, live blocks, degraded, ...)."""
        if not self.enabled or not gauges:
            return
        self._rec({"kind": "counter",
                   "t": float(t) if t is not None else time.time(), **gauges})

    # ------------------------------------------------ autotune observer --
    def _register_autotune(self):
        if self._autotune_registered:
            return
        try:
            from ..kernels import autotune
            autotune.register_observer(self)
            self._autotune_registered = True
        except Exception:  # pragma: no cover - autotune import must not gate tracing
            pass

    def autotune_event(self, kind, **fields):
        """Observer hook for kernels.autotune winner-cache hit/miss/recompute,
        so cold-start compile stalls show up in the timeline."""
        self.event(kind, **fields)

    # ------------------------------------------------------ attribution --
    def explain(self, rid, now=None):
        """Latency-attribution report for ``rid``.

        Returns a dict with ``wall_s``, per-category ``seconds`` and
        ``shares`` (fractions of wall; sum to 1.0 for any wall > 0), the
        ``dominant`` category, ``finish_reason``, and the raw ``segments``.
        Live requests are attributed up to ``now``.
        """
        tr = self._reqs[rid]
        segs = [list(s) for s in tr.segments]
        if tr.open is not None:
            t = float(now) if now is not None else time.time()
            phase, t_start, deg, _tags = tr.open
            segs.append([phase, t_start, max(t, t_start), deg])
        t_end = segs[-1][2] if segs else tr.t0
        wall = t_end - tr.t0
        seconds = {c: 0.0 for c in CATEGORIES}
        for phase, a, b, deg in segs:
            cat = "degraded" if deg else _PHASE_TO_CATEGORY.get(phase, phase)
            seconds[cat] += b - a
        shares = {c: (v / wall if wall > 0 else 0.0) for c, v in seconds.items()}
        dominant = max(CATEGORIES, key=lambda c: seconds[c]) if wall > 0 else "queue"
        return {
            "rid": rid,
            "done": tr.done,
            "finish_reason": tr.reason,
            "wall_s": wall,
            "seconds": seconds,
            "shares": shares,
            "dominant": dominant,
            "tags": dict(tr.tags),
            "segments": [
                {"phase": p, "t0": a, "t1": b, "degraded": bool(d)}
                for p, a, b, d in segs
            ],
        }

    def request_ids(self):
        return list(self._reqs)

    # ------------------------------------------------ snapshot / restore --
    def snapshot(self, t=None):
        """JSON-able trace state, carried inside the engine snapshot."""
        if not self.enabled:
            return None
        return {
            "t": float(t) if t is not None else time.time(),
            "degraded": 1 if self._degraded else 0,
            "requests": [tr.state() for tr in self._reqs.values()],
        }

    def restore(self, snap, t=None):
        """Resume the timelines carried by an engine snapshot.

        Spans open at crash time are closed at the snapshot stamp with a
        ``recovery`` marker, a ``recovery`` segment bridges crash → resume,
        and the original phase reopens at ``t`` — so restored requests keep
        one continuous, fully-attributed timeline.
        """
        if not self.enabled or not snap:
            return
        t_resume = float(t) if t is not None else time.time()
        t_snap = min(float(snap["t"]), t_resume)
        self._degraded = bool(snap.get("degraded"))
        self._reqs = {}
        reopened = 0
        for st in snap.get("requests", []):
            tr = _ReqTrace.from_state(st)
            self._reqs[tr.rid] = tr
            if self._retain:
                # Re-inject carried segments so a post-restore Perfetto
                # export shows the full pre-crash timeline.  These are NOT
                # re-sent to the jsonl sink: the pre-crash process already
                # streamed them.
                for phase, a, b, deg in tr.segments:
                    rec = {"kind": "span", "cat": "phase", "name": phase,
                           "rid": tr.rid, "t0": a, "t1": b, "carried": 1}
                    if deg:
                        rec["degraded"] = 1
                    self._retained.append(rec)
            if tr.open is not None and not tr.done:
                phase, _t_start, _deg, tags = tr.open
                self._close_open(tr, t_snap, recovery=1)
                tr.segments.append(["recovery", t_snap, t_resume, 0])
                self._rec({"kind": "span", "cat": "phase", "name": "recovery",
                           "rid": tr.rid, "t0": t_snap, "t1": t_resume})
                self._open(tr, phase, t_resume, tags)
                reopened += 1
        self.event("recovery", t=t_resume, t_snap=t_snap, reopened=reopened)

    # ---------------------------------------------------------- exports --
    def perfetto(self):
        """Chrome-trace JSON (``{"traceEvents": [...]}``) from retained
        records.  pid 0 = engine tracks (waves, counters, instants),
        pid 1 = per-request tracks (tid = rid).  Load in ui.perfetto.dev.
        """
        times = [r["t0"] for r in self._retained if "t0" in r]
        times += [r["t"] for r in self._retained if "t" in r]
        times += [tr.t0 for tr in self._reqs.values()]
        base = min(times) if times else 0.0

        def us(t):
            return (t - base) * 1e6

        events = [
            {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
             "args": {"name": "engine"}},
            {"ph": "M", "pid": 0, "tid": 0, "name": "thread_name",
             "args": {"name": "engine"}},
            {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
             "args": {"name": "requests"}},
        ]
        named = set()
        for rec in self._retained:
            rid = rec.get("rid")
            if rid is not None and rid not in named:
                named.add(rid)
                events.append({"ph": "M", "pid": 1, "tid": rid,
                               "name": "thread_name",
                               "args": {"name": f"req {rid}"}})
            if rec["kind"] == "span":
                pid, tid = (1, rid) if rid is not None else (0, 0)
                args = {k: v for k, v in rec.items()
                        if k not in ("kind", "cat", "name", "rid", "t0", "t1")}
                events.append({
                    "ph": "X", "pid": pid, "tid": tid, "name": rec["name"],
                    "cat": rec.get("cat", "span"), "ts": us(rec["t0"]),
                    "dur": max(0.0, (rec["t1"] - rec["t0"]) * 1e6),
                    "args": args,
                })
            elif rec["kind"] == "event":
                pid, tid = (1, rid) if rid is not None else (0, 0)
                args = {k: v for k, v in rec.items()
                        if k not in ("kind", "name", "rid", "t")}
                events.append({
                    "ph": "i", "pid": pid, "tid": tid, "name": rec["name"],
                    "ts": us(rec["t"]), "s": "t" if rid is not None else "p",
                    "args": args,
                })
            elif rec["kind"] == "counter":
                for k, v in rec.items():
                    if k in ("kind", "t"):
                        continue
                    events.append({
                        "ph": "C", "pid": 0, "tid": 0, "name": k,
                        "ts": us(rec["t"]), "args": {"value": v},
                    })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_perfetto(self, path=None):
        path = path or self.perfetto_path
        if path is None:
            raise ValueError("no perfetto path configured")
        tmp = f"{path}.tmp"
        with open(tmp, "w") as fh:
            json.dump(self.perfetto(), fh)
        os.replace(tmp, path)
        return path

    # -------------------------------------------------------- plumbing --
    def flush(self):
        if self.enabled:
            self._sb.flush()

    def close(self):
        """Flush the jsonl feed and write the Perfetto export, if any."""
        if not self.enabled:
            return
        self._sb.close()
        if self.perfetto_path:
            self.write_perfetto(self.perfetto_path)


def format_explain(report):
    """One-line human rendering of an ``explain()`` report."""
    shares = " ".join(
        f"{cat}={100.0 * report['shares'][cat]:.1f}%"
        for cat in CATEGORIES
        if report["seconds"][cat] > 0.0
    )
    reason = report["finish_reason"] or ("live" if not report["done"] else "?")
    return (f"req {report['rid']}: wall={report['wall_s'] * 1e3:.1f}ms "
            f"dominant={report['dominant']} [{reason}] {shares}")
