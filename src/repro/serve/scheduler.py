"""Admission scheduling for the serving engine (DESIGN.md §6).

The scheduler owns the QUEUED stage of the request lifecycle; the engine
asks it for up to ``n`` requests whenever decode slots free up and routes
the admitted batch through the prefill step.

* ``fcfs``     — strict submission order.
* ``priority`` — highest ``Request.priority`` first; submission order
  breaks ties (stable), so equal-priority traffic degrades to FCFS.
"""

from __future__ import annotations

from typing import Any, List

__all__ = ["Scheduler"]


class Scheduler:
    POLICIES = ("fcfs", "priority")

    def __init__(self, policy: str = "fcfs"):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown scheduling policy {policy!r}; "
                             f"expected one of {self.POLICIES}")
        self.policy = policy
        self._queue: List[Any] = []
        self._arrivals = 0

    def submit(self, req) -> None:
        req._arrival = self._arrivals
        self._arrivals += 1
        self._queue.append(req)

    def __len__(self) -> int:
        return len(self._queue)

    def admit(self, n: int) -> List[Any]:
        """Pop up to ``n`` requests in policy order."""
        if n <= 0 or not self._queue:
            return []
        if self.policy == "priority":
            self._queue.sort(
                key=lambda r: (-getattr(r, "priority", 0), r._arrival))
        picked, self._queue = self._queue[:n], self._queue[n:]
        return picked
