"""Admission scheduling for the serving engine (DESIGN.md §6).

The scheduler owns the QUEUED stage of the request lifecycle; the engine
asks it for up to ``n`` requests whenever decode slots free up and routes
the admitted batch through the prefill step.  Under the paged KV pool the
engine admits *conditionally* — it peeks the head, checks the pool can
supply the blocks, and either pops or stops — and preempted requests
re-enter through :meth:`requeue` with their original arrival order, so a
victim resumes ahead of traffic that arrived after it.

Sharded serving (DESIGN.md §9) keeps this queue *global*: one head-of-line
order across every data shard.  The engine, not the scheduler, picks which
shard serves the head (longest cached prefix, then most free blocks), and
a preempted request can only resume on the shard holding its blocks — the
head then waits for a slot there rather than losing its place in line.

* ``fcfs``     — strict submission order.
* ``priority`` — highest ``Request.priority`` first; submission order
  breaks ties (stable), so equal-priority traffic degrades to FCFS.
"""

from __future__ import annotations

from typing import Any, List, Optional

__all__ = ["Scheduler"]


class Scheduler:
    POLICIES = ("fcfs", "priority")

    def __init__(self, policy: str = "fcfs"):
        if policy not in self.POLICIES:
            raise ValueError(f"unknown scheduling policy {policy!r}; "
                             f"expected one of {self.POLICIES}")
        self.policy = policy
        self._queue: List[Any] = []
        self._arrivals = 0
        self._unsorted = False
        # queue-provenance hook (DESIGN.md §13): when set by a tracing
        # engine, called as on_event(kind, **fields) on enter/requeue so
        # queue churn shows up on the trace timeline; None costs nothing.
        self.on_event = None

    def submit(self, req) -> None:
        req._arrival = self._arrivals
        self._arrivals += 1
        self._queue.append(req)
        self._unsorted = True
        if self.on_event is not None:
            self.on_event("queue_enter", rid=getattr(req, "rid", None),
                          arrival=req._arrival, depth=len(self._queue))

    def requeue(self, req) -> None:
        """Put a preempted request back, keeping its original ``_arrival``
        stamp: within its priority class it sorts *before* anything
        submitted after it, so preemption never costs a request its place
        in line (resume-ordering contract, tests/test_kvpool.py)."""
        assert hasattr(req, "_arrival"), "requeue is for admitted requests"
        self._queue.append(req)
        self._unsorted = True
        if self.on_event is not None:
            self.on_event("queue_requeue", rid=getattr(req, "rid", None),
                          arrival=req._arrival, depth=len(self._queue))

    def __len__(self) -> int:
        return len(self._queue)

    def _sort(self) -> None:
        # FCFS keeps arrival order too — requeued victims must slot back in
        # front of later arrivals, not at the tail.  Sorting is deferred to
        # the next read and skipped while nothing was inserted, so the
        # admission loop's peek-per-request stays O(1) in steady state.
        if self._unsorted:
            self._queue.sort(
                key=lambda r: (-getattr(r, "priority", 0), r._arrival)
                if self.policy == "priority" else r._arrival)
            self._unsorted = False

    def queued(self) -> List[Any]:
        """Snapshot of the queue in policy order (read-only view — the
        engine's deadlock breaker scans it for preempted block-holders)."""
        self._sort()
        return list(self._queue)

    def peek(self) -> Optional[Any]:
        """The request :meth:`admit` would hand out next (None if empty) —
        the paged engine's token-budget gate inspects it before popping."""
        if not self._queue:
            return None
        self._sort()
        return self._queue[0]

    def pop(self, req) -> None:
        """Remove a specific request (the engine admits what it peeked)."""
        self._queue.remove(req)

    def admit(self, n: int) -> List[Any]:
        """Pop up to ``n`` requests in policy order."""
        if n <= 0 or not self._queue:
            return []
        self._sort()
        picked, self._queue = self._queue[:n], self._queue[n:]
        return picked

    # --------------------------------------------------- snapshot / restore

    def snapshot(self) -> dict:
        """The scheduler's own serializable state (DESIGN.md §12).  The
        queued requests themselves are engine objects — the engine
        serializes them (with their ``_arrival`` stamps) and hands them
        back through :meth:`restore`."""
        return {"policy": self.policy, "arrivals": self._arrivals}

    def restore(self, snap: dict, queue: List[Any]) -> None:
        """Adopt a snapshot: the arrival counter continues where it
        stopped (post-restore submissions sort after everything restored)
        and ``queue`` — requests carrying their original ``_arrival``
        stamps — becomes the queue, re-sorted lazily as usual."""
        if snap["policy"] != self.policy:
            raise ValueError(f"snapshot policy {snap['policy']!r} does not "
                             f"match this scheduler ({self.policy!r})")
        self._arrivals = int(snap["arrivals"])
        self._queue = list(queue)
        self._unsorted = True
