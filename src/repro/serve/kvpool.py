"""Paged KV-cache block pool: host-side allocator for the serving engine
(DESIGN.md §6).

The device side of the paged cache is a flat pool of fixed-size token
blocks per attention layer plus one shared logical→physical ``block_table``
per slot (models/transformer.init_cache(kv_layout="paged")); this module is
the host-side bookkeeping that decides *which* physical block backs which
logical block:

* **free-list allocation** — capacity scales with live tokens, not
  slots × max_len: a request holds ceil(tokens/bs) blocks, growing one
  block at a time as it decodes.
* **refcounted sharing + copy-on-write** — a full (sealed) block can back
  the same token prefix of many requests at once; writes only ever target
  a request's unsealed tail block, and ``ensure_writable`` copies a block
  out of sharing if a write would land on one with other holders.
* **prefix cache** — sealed blocks are content-addressed by a chained hash
  of (previous-block hash, block tokens): on admission the engine walks a
  new prompt's full blocks through ``match_prefix`` and skips prefilling
  the matched span.  This is sound *because* the dither-quantised codes in
  a block are a pure function of (values, absolute position + offset,
  element index) — the paper's deterministic-in-position Θ(1/N²)
  construction — never of which request or engine tick wrote them;
  stochastic-rounded caches could not be shared this way.  The chain seed
  carries the per-request counter offset for the int8 layout, so hits only
  occur between requests whose codes would be bit-identical.
* **LRU eviction** — blocks released by finished requests stay in the
  prefix cache at refcount 0 until the allocator needs them; allocation
  prefers truly-free blocks and evicts the least-recently-used cached
  block otherwise ("preempt-to-evict").

The pool knows nothing about jax: the engine mirrors its tables into the
device ``block_tables`` array when they change.  Physical ids run
0..num_blocks-1; id ``num_blocks`` is the device-side *trash block* that
absorbs writes through unallocated table entries — the pool never hands it
out.

The pool also knows nothing about meshes: under sharded serving
(DESIGN.md §9) the engine instantiates one ``KVPool`` *per data shard*
(capacity = admission budget of that shard) and keeps every request's
blocks, prefix hits, copy-on-write copies and deadlock-breaking inside its
home shard's pool.  Physical ids are then shard-local — the device lays
the shards' sub-pools (each with its own trash block) back to back, and
each shard's kernels see only their local slice, so the id space above
never changes shape.  Prefix sharing is consequently per data shard.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["KVPool"]


class KVPool:
    def __init__(self, num_blocks: int, block_size: int, *,
                 prefix_cache: bool = True):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError("num_blocks and block_size must be positive")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        self.trash = num_blocks                     # device-side dump block
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._ref = [0] * num_blocks
        self._hash: List[Optional[int]] = [None] * num_blocks
        # refcount-0 sealed blocks, insertion order = LRU order (oldest first)
        self._cached: "OrderedDict[int, None]" = OrderedDict()
        self._lookup: Dict[int, int] = {}           # chain hash → physical id
        self._tables: Dict[int, List[int]] = {}     # rid → logical order
        self._chain: Dict[int, int] = {}            # rid → sealed-chain hash
        self.stats = {"allocated": 0, "evicted": 0, "prefix_hit_blocks": 0,
                      "cow_copies": 0}
        # block-provenance hook (DESIGN.md §13): when set by a tracing
        # engine, called as on_event(kind, **fields) at eviction / prefix
        # hit / CoW / exhaustion; None (the default) costs nothing.
        self.on_event = None

    # ------------------------------------------------------------- inspection

    @property
    def free_blocks(self) -> int:
        """Blocks an allocation could obtain right now (free + evictable)."""
        return len(self._free) + len(self._cached)

    @property
    def live_blocks(self) -> int:
        """Blocks referenced by at least one request."""
        return self.num_blocks - self.free_blocks

    @property
    def cached_blocks(self) -> int:
        return len(self._cached)

    @property
    def holders(self) -> int:
        """Requests currently holding blocks (active or preempted-queued)."""
        return len(self._tables)

    def table(self, rid: int) -> List[int]:
        return list(self._tables.get(rid, ()))

    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    # ------------------------------------------------------------ prefix hash

    @staticmethod
    def chain_hash(prev: int, tokens: Sequence[int]) -> int:
        return hash((prev, tuple(tokens)))

    def match_prefix(self, tokens: Sequence[int],
                     seed: int = 0) -> Tuple[List[int], int]:
        """Longest cached chain of *full* blocks covering a proper prefix of
        ``tokens`` → (physical blocks, chain hash after them).

        The walk is capped at ``len(tokens) - 1`` tokens so at least one
        real token remains to prefill (the engine needs its logits to seed
        sampling); ``seed`` namespaces the chain (the int8 layout passes
        the request's counter offset — codes quantised under different
        offsets are different bits and must never alias).
        """
        hits: List[int] = []
        h = seed
        if not self.prefix_cache:
            return hits, h
        bs = self.block_size
        max_blocks = max(0, (len(tokens) - 1) // bs)
        for j in range(max_blocks):
            h2 = self.chain_hash(h, tokens[j * bs:(j + 1) * bs])
            phys = self._lookup.get(h2)
            if phys is None:
                return hits, h
            hits.append(phys)
            h = h2
        return hits, h

    # ------------------------------------------------------------- allocation

    def _pop_block(self) -> Optional[int]:
        if self._free:
            self.stats["allocated"] += 1
            return self._free.pop()
        if self._cached:
            phys, _ = self._cached.popitem(last=False)   # LRU victim
            h = self._hash[phys]
            if h is not None and self._lookup.get(h) == phys:
                del self._lookup[h]
            self._hash[phys] = None
            self.stats["allocated"] += 1
            self.stats["evicted"] += 1
            if self.on_event is not None:
                self.on_event("block_evict", phys=phys,
                              cached=len(self._cached))
            return phys
        return None

    def _acquire(self, phys: int) -> None:
        if self._ref[phys] == 0:
            self._cached.pop(phys, None)
        self._ref[phys] += 1

    def allocate(self, rid: int, n_tokens: int,
                 shared: Sequence[int] = (),
                 chain: int = 0) -> Optional[List[int]]:
        """Build ``rid``'s block table for an ``n_tokens``-token prompt:
        take references on the ``shared`` prefix blocks (from
        ``match_prefix``) and allocate fresh blocks for the rest.  Returns
        the full table, or None (state unchanged) if the pool cannot supply
        the fresh blocks — the admission gate of continuous batching."""
        assert rid not in self._tables, f"request {rid} already allocated"
        total = self.blocks_needed(max(1, n_tokens))
        fresh_needed = total - len(shared)
        assert fresh_needed >= 0
        # shared blocks sitting in the LRU cache (refcount 0) are about to
        # be re-acquired — they stop being evictable, so they must not be
        # counted as capacity for the fresh blocks
        shared_cached = sum(1 for phys in set(shared) if self._ref[phys] == 0)
        if fresh_needed > self.free_blocks - shared_cached:
            return None
        for phys in shared:
            self._acquire(phys)
        fresh = []
        for _ in range(fresh_needed):
            phys = self._pop_block()
            assert phys is not None   # guarded by free_blocks above
            self._ref[phys] = 1
            fresh.append(phys)
        self._tables[rid] = list(shared) + fresh
        self._chain[rid] = chain
        self.stats["prefix_hit_blocks"] += len(shared)
        if self.on_event is not None and shared:
            self.on_event("prefix_hit", rid=rid, blocks=len(shared),
                          fresh=len(fresh))
        return list(self._tables[rid])

    def append_block(self, rid: int) -> Optional[int]:
        """Grow ``rid`` by one block (decode crossed a block boundary).
        Returns the physical id, or None if the pool is exhausted — the
        caller preempts-and-requeues the request with its blocks intact."""
        phys = self._pop_block()
        if phys is None:
            if self.on_event is not None:
                self.on_event("pool_exhausted", rid=rid,
                              live=self.live_blocks)
            return None
        self._ref[phys] = 1
        self._tables[rid].append(phys)
        return phys

    def ensure_writable(self, rid: int, logical: int) -> Tuple[int, bool]:
        """Copy-on-write guard: the engine calls this before any write to
        ``rid``'s logical block.  If the backing block is shared (refcount
        > 1) a fresh private block is allocated and installed in the table;
        the caller must copy the device contents across and refresh the
        device block table.  Returns (physical id, copied?)."""
        phys = self._tables[rid][logical]
        if self._ref[phys] <= 1:
            return phys, False
        fresh = self._pop_block()
        if fresh is None:
            raise MemoryError("pool exhausted during copy-on-write")
        self._ref[phys] -= 1
        self._ref[fresh] = 1
        self._tables[rid][logical] = fresh
        self.stats["cow_copies"] += 1
        if self.on_event is not None:
            self.on_event("cow_copy", rid=rid, logical=logical)
        return fresh, True

    # ---------------------------------------------------------------- sealing

    def seal_block(self, rid: int, logical: int,
                   tokens: Sequence[int]) -> None:
        """Register ``rid``'s full logical block in the prefix cache under
        the chained content hash.  Only sealed blocks are shareable; the
        engine seals prompt blocks *after* their prefill dispatch returns
        (a same-wave hit would race the device scatter) and decode blocks
        when they fill."""
        if not self.prefix_cache:
            return
        assert len(tokens) == self.block_size
        phys = self._tables[rid][logical]
        h = self.chain_hash(self._chain[rid], tokens)
        self._chain[rid] = h
        if self._ref[phys] == 1 and self._hash[phys] is None \
                and h not in self._lookup:
            self._hash[phys] = h
            self._lookup[h] = phys

    def truncate(self, rid: int, n_blocks: int) -> List[int]:
        """Give back ``rid``'s tail blocks beyond the first ``n_blocks`` —
        the speculative-decode rollback path (DESIGN.md §14): draft coverage
        allocated ahead of a verify forward can outrun the committed
        position when a suffix is rejected.  Only unsealed, uniquely-owned
        tail blocks are ever truncated (the engine seals nothing until
        tokens commit), so popping reverses ``append_block`` exactly — the
        ids return to the free-list end they were taken from, leaving the
        allocator byte-identical to one that never over-allocated.  Returns
        the popped ids (newest first) so the engine can reset their
        block-table entries."""
        table = self._tables[rid]
        assert n_blocks >= 1
        popped = []
        while len(table) > n_blocks:
            phys = table[-1]
            assert self._ref[phys] == 1 and self._hash[phys] is None, \
                "spec rollback must only drop unsealed private tail blocks"
            table.pop()
            self._ref[phys] = 0
            self._free.append(phys)
            popped.append(phys)
        return popped

    # ---------------------------------------------------------------- release

    def release(self, rid: int) -> None:
        """Drop ``rid``'s references.  Sealed blocks at refcount 0 stay in
        the prefix cache (LRU-evictable); unsealed ones return to the free
        list immediately."""
        for phys in self._tables.pop(rid, ()):
            self._ref[phys] -= 1
            if self._ref[phys] == 0:
                if self._hash[phys] is not None:
                    self._cached[phys] = None          # newest = MRU end
                else:
                    self._free.append(phys)
        self._chain.pop(rid, None)

    def forget(self, rid: int) -> None:
        """Release without retaining anything in the prefix cache — the
        deadlock-breaking path (a preempted request giving up its blocks
        for re-prefill later)."""
        for phys in self._tables.pop(rid, ()):
            self._ref[phys] -= 1
            if self._ref[phys] == 0:
                h = self._hash[phys]
                if h is not None and self._lookup.get(h) == phys:
                    del self._lookup[h]
                self._hash[phys] = None
                self._cached.pop(phys, None)
                self._free.append(phys)
        self._chain.pop(rid, None)

    # --------------------------------------------------------- snapshot/restore

    def snapshot(self) -> dict:
        """JSON-able copy of the whole allocator state (DESIGN.md §12):
        free list, refcounts, per-block hashes, the LRU cache order, the
        prefix-lookup index, per-request tables/chains and the stats
        counters.  Chain hashes are hashes of int tuples, which Python
        computes deterministically (PYTHONHASHSEED only perturbs str/bytes),
        so a snapshot restored in a *new process* still matches prefixes."""
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "prefix_cache": self.prefix_cache,
            "free": list(self._free),
            "ref": list(self._ref),
            "hash": list(self._hash),
            "cached": list(self._cached),          # LRU order, oldest first
            "lookup": [[h, phys] for h, phys in self._lookup.items()],
            "tables": {str(rid): list(t) for rid, t in self._tables.items()},
            "chain": {str(rid): h for rid, h in self._chain.items()},
            "stats": dict(self.stats),
        }

    def restore(self, snap: dict, *, drop_unheld: bool = True) -> None:
        """Rebuild allocator state from :meth:`snapshot`.

        ``drop_unheld=True`` (the crash-recovery default) releases every
        refcount-0 prefix-cached block to the free list and forgets its
        hash: the engine's replay re-materialises device contents only for
        blocks *held by live requests* (their holders rewrite bit-identical
        KV), while an unheld cached block's tokens are not recorded
        anywhere, so its device bits cannot be rebuilt and it must not be
        matchable.  Held blocks keep their hash/index entries — sharing
        them stays sound because every holder's replay writes the same
        position-pure bits.  ``free_blocks`` is unchanged either way
        (cached blocks were already evictable), so admission capacity —
        and therefore scheduling — is unaffected."""
        if (snap["num_blocks"] != self.num_blocks
                or snap["block_size"] != self.block_size):
            raise ValueError(
                f"pool snapshot shape ({snap['num_blocks']}×"
                f"{snap['block_size']}) does not match this pool "
                f"({self.num_blocks}×{self.block_size})")
        self._free = [int(x) for x in snap["free"]]
        self._ref = [int(x) for x in snap["ref"]]
        self._hash = [None if h is None else int(h) for h in snap["hash"]]
        self._cached = OrderedDict((int(p), None) for p in snap["cached"])
        self._lookup = {int(h): int(p) for h, p in snap["lookup"]}
        self._tables = {int(r): [int(b) for b in t]
                        for r, t in snap["tables"].items()}
        self._chain = {int(r): int(h) for r, h in snap["chain"].items()}
        self.stats = {k: int(v) for k, v in snap["stats"].items()}
        if drop_unheld:
            for phys in list(self._cached):
                h = self._hash[phys]
                if h is not None and self._lookup.get(h) == phys:
                    del self._lookup[h]
                self._hash[phys] = None
                self._free.append(phys)
            self._cached.clear()
