"""Token drafters for speculative decoding (DESIGN.md §14).

A :class:`Drafter` proposes up to ``k`` draft tokens per request per decode
window; the engine feeds them through one multi-token verify forward and
commits the longest prefix that matches what sequential sampling would have
produced.  Drafting is pure host-side guesswork — a wrong draft costs only
the rejected verify rows, never correctness, because acceptance is exact
token match against the engine's own sampler (the bitwise stream contract).

:class:`PromptLookupDrafter` is the model-free default: repeated spans are
common in serving workloads (code, templated prose, retrieval contexts), so
the continuation of the latest earlier occurrence of the current suffix
n-gram is a cheap, surprisingly strong draft (assisted-generation prompt
lookup).  The interface stays pluggable for a small zoo draft model later.
"""

from __future__ import annotations

import abc
from typing import List, Sequence

__all__ = ["Drafter", "PromptLookupDrafter", "FixedDrafter", "ReplayDrafter"]


class Drafter(abc.ABC):
    """Proposes draft tokens for one request.

    ``propose`` may return fewer than ``k`` tokens (the engine pads the
    verify window; padding rows are scored but their sampled tokens only
    commit if they happen to match — which is still exact).  It must be
    host-side-cheap: it runs per active slot per decode window.
    """

    @abc.abstractmethod
    def propose(self, context: Sequence[int], k: int) -> List[int]:
        """Up to ``k`` draft tokens continuing ``context`` (prompt + output
        so far)."""


class PromptLookupDrafter(Drafter):
    """Prompt-lookup n-gram drafting: find the latest earlier occurrence of
    the current ``max_ngram``-token suffix in the context and propose the
    tokens that followed it, backing off to shorter n-grams.  O(len·n) scan
    per call — fine at serving context lengths; swap in a suffix automaton
    if contexts grow past ~100k."""

    def __init__(self, max_ngram: int = 3):
        if max_ngram < 1:
            raise ValueError(f"max_ngram must be >= 1, got {max_ngram}")
        self.max_ngram = max_ngram

    def propose(self, context: Sequence[int], k: int) -> List[int]:
        ctx = list(context)
        for n in range(min(self.max_ngram, len(ctx) - 1), 0, -1):
            suffix = ctx[-n:]
            # latest earlier occurrence wins: recent repeats predict best
            for start in range(len(ctx) - n - 1, -1, -1):
                if ctx[start:start + n] == suffix:
                    cont = ctx[start + n:start + n + k]
                    if cont:
                        return cont
        return []


class ReplayDrafter(Drafter):
    """Replays known per-request streams, keyed by prompt prefix: a request
    whose context starts with a registered prompt — and whose output so far
    has followed that prompt's recorded stream — is proposed the next ``k``
    recorded tokens.  An oracle drafter: against deterministic sampling its
    accept rate is 1 by construction, which makes it the harness for the
    bulk-commit speedup *ceiling* (serve_bench's spec workload records one
    plain wave, then replays it through the spec engine) and the accept-all
    edge in parity tests."""

    def __init__(self, streams):
        # streams: {prompt token tuple -> recorded output token list}
        self.streams = {tuple(p): list(out) for p, out in streams.items()}

    def propose(self, context: Sequence[int], k: int) -> List[int]:
        ctx = list(context)
        for prompt, out in self.streams.items():
            n = len(prompt)
            if (len(ctx) >= n and tuple(ctx[:n]) == prompt
                    and ctx[n:] == out[:len(ctx) - n]):
                done = len(ctx) - n
                return out[done:done + k]
        return []


class FixedDrafter(Drafter):
    """Always proposes the same token sequence (cycled to length ``k``) —
    the accept-all / reject-all edge-case harness for parity tests, and a
    stand-in for workloads with a known continuation."""

    def __init__(self, tokens: Sequence[int]):
        if not tokens:
            raise ValueError("FixedDrafter needs at least one token")
        self.tokens = list(tokens)

    def propose(self, context: Sequence[int], k: int) -> List[int]:
        reps = -(-k // len(self.tokens))
        return (self.tokens * reps)[:k]
