"""Fault tolerance for long runs: failure injection (tests/chaos), a
straggler watchdog, and restart-from-checkpoint driver loops.

Two consumers share these primitives:

* **training** — launch/train.py calls ``injector.maybe_fail(step, phase)``
  at its failure points ('before_save' / 'after_save'); ``run_with_restarts``
  re-enters the loop after a crash and the loop resumes from the latest
  checkpoint — the recovery contract tests/test_fault_tolerance.py pins.
* **serving** (DESIGN.md §12) — the engine calls the same injector at its
  five serve crash points ('pre_admit', 'pool_alloc', 'mid_window',
  'post_drain', 'sink_write'), keyed on the engine tick at the start of the
  window; ``run_serve_with_restarts`` rebuilds a fresh engine after each
  crash and restores it from the latest ``Engine.snapshot`` file.  Because
  dither KV codes are position-pure and the sampler is a stateless hash of
  (seed, counter), the restored engine's streams are *bitwise* those of an
  uninterrupted run — tests/test_serve_fault.py.
"""

from __future__ import annotations

import json
import os
from typing import Callable, Dict, Optional, Set, Tuple

__all__ = [
    "InjectedFailure", "FailureInjector", "StragglerWatchdog",
    "run_with_restarts", "run_serve_with_restarts", "SERVE_PHASES",
]

# the engine's injection points, in within-step order (DESIGN.md §12)
SERVE_PHASES = ("pre_admit", "pool_alloc", "mid_window", "post_drain",
                "sink_write")


class InjectedFailure(RuntimeError):
    """A deliberately injected crash (never raised in production runs)."""


class FailureInjector:
    """Crashes the run at configured (step, phase) points, once per point.

    ``crash_at`` maps step → phase name ('before_save' / 'after_save').
    ``fired`` records points that already crashed so a resumed run sails
    past them — the restart-converges contract.
    """

    def __init__(self, crash_at: Optional[Dict[int, str]] = None):
        self.crash_at: Dict[int, str] = dict(crash_at or {})
        self.fired: Set[Tuple[int, str]] = set()

    def maybe_fail(self, step: int, phase: str) -> None:
        if self.crash_at.get(step) == phase and (step, phase) not in self.fired:
            self.fired.add((step, phase))
            raise InjectedFailure(f"injected failure at step {step} ({phase})")


class StragglerWatchdog:
    """Flags steps whose wall time exceeds ``threshold`` × the running mean.

    Flagged steps are excluded from the baseline so one straggler does not
    mask the next.  The first ``warmup`` observations only build the baseline.
    """

    def __init__(self, threshold: float = 3.0, warmup: int = 3):
        self.threshold = float(threshold)
        self.warmup = int(warmup)
        self._times: list = []
        self.flagged: Set[int] = set()

    def observe(self, step: int, seconds: float) -> bool:
        baseline_ready = len(self._times) >= self.warmup
        if baseline_ready:
            mean = sum(self._times) / len(self._times)
            if seconds > self.threshold * mean:
                self.flagged.add(step)
                return True
        self._times.append(seconds)
        return False


def run_with_restarts(loop: Callable[[int], object], max_restarts: int = 3):
    """Run ``loop(restart_idx)`` to completion, restarting after crashes.

    Returns the loop's result.  After ``max_restarts`` failed restarts the
    last exception is chained into a RuntimeError (unrecoverable job).
    """
    last_exc: Optional[BaseException] = None
    for restart_idx in range(max_restarts + 1):
        try:
            return loop(restart_idx)
        except Exception as exc:  # noqa: BLE001 — any crash triggers a restart
            last_exc = exc
    raise RuntimeError(
        f"job failed after {max_restarts} restarts"
    ) from last_exc


def run_serve_with_restarts(make_engine: Callable[[], object],
                            submit: Callable[[object], None], *,
                            snapshot_path: str, ticks: int,
                            max_restarts: int = 3,
                            streams: Optional[dict] = None):
    """Crash-tolerant serve driver (DESIGN.md §12): the serving analogue of
    the training restart loop above.

    Each (re)start builds a **fresh** engine via ``make_engine`` — a crashed
    engine died mid-mutation and must be discarded, never re-driven.  If
    ``snapshot_path`` exists the engine restores from it (``submit`` is NOT
    called again: the snapshot already carries the queue and every
    in-flight request); on a cold start ``submit(engine)`` enqueues the
    workload.  ``streams`` optionally re-attaches per-rid streaming
    callbacks, which snapshots cannot carry.  Returns the engine that ran
    to completion.

    ``make_engine`` should pass the same ``snapshot_path`` to the Engine so
    each window persists a recovery point; it should also share one
    ``FailureInjector`` across restarts — its ``fired`` set is what lets a
    resumed run sail past an already-fired crash point.

    Trace handoff (DESIGN.md §13): when ``make_engine`` enables tracing
    (``Engine(trace=...)``), the snapshot carries every request's span
    timeline, so the restored engine resumes the *same* timelines — spans
    open at crash time are closed with a recovery marker and a ``recovery``
    segment bridges crash → resume.  Nothing extra is needed here beyond
    constructing each restart's engine with the same trace spec.
    """

    def loop(_restart_idx: int):
        engine = make_engine()
        if os.path.exists(snapshot_path):
            with open(snapshot_path) as fh:
                engine.restore(json.load(fh), streams=streams)
        else:
            submit(engine)
        engine.run(ticks)
        return engine

    return run_with_restarts(loop, max_restarts=max_restarts)
