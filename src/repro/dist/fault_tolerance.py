"""Fault tolerance for long training runs: failure injection (tests/chaos),
a straggler watchdog, and the restart-from-checkpoint driver loop.

The training loop (launch/train.py) calls ``injector.maybe_fail(step, phase)``
at its failure points; ``run_with_restarts`` re-enters the loop after a crash
and the loop resumes from the latest checkpoint — the recovery contract
tests/test_fault_tolerance.py pins down.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional, Set, Tuple

__all__ = [
    "InjectedFailure", "FailureInjector", "StragglerWatchdog",
    "run_with_restarts",
]


class InjectedFailure(RuntimeError):
    """A deliberately injected crash (never raised in production runs)."""


class FailureInjector:
    """Crashes the run at configured (step, phase) points, once per point.

    ``crash_at`` maps step → phase name ('before_save' / 'after_save').
    ``fired`` records points that already crashed so a resumed run sails
    past them — the restart-converges contract.
    """

    def __init__(self, crash_at: Optional[Dict[int, str]] = None):
        self.crash_at: Dict[int, str] = dict(crash_at or {})
        self.fired: Set[Tuple[int, str]] = set()

    def maybe_fail(self, step: int, phase: str) -> None:
        if self.crash_at.get(step) == phase and (step, phase) not in self.fired:
            self.fired.add((step, phase))
            raise InjectedFailure(f"injected failure at step {step} ({phase})")


class StragglerWatchdog:
    """Flags steps whose wall time exceeds ``threshold`` × the running mean.

    Flagged steps are excluded from the baseline so one straggler does not
    mask the next.  The first ``warmup`` observations only build the baseline.
    """

    def __init__(self, threshold: float = 3.0, warmup: int = 3):
        self.threshold = float(threshold)
        self.warmup = int(warmup)
        self._times: list = []
        self.flagged: Set[int] = set()

    def observe(self, step: int, seconds: float) -> bool:
        baseline_ready = len(self._times) >= self.warmup
        if baseline_ready:
            mean = sum(self._times) / len(self._times)
            if seconds > self.threshold * mean:
                self.flagged.add(step)
                return True
        self._times.append(seconds)
        return False


def run_with_restarts(loop: Callable[[int], object], max_restarts: int = 3):
    """Run ``loop(restart_idx)`` to completion, restarting after crashes.

    Returns the loop's result.  After ``max_restarts`` failed restarts the
    last exception is chained into a RuntimeError (unrecoverable job).
    """
    last_exc: Optional[BaseException] = None
    for restart_idx in range(max_restarts + 1):
        try:
            return loop(restart_idx)
        except Exception as exc:  # noqa: BLE001 — any crash triggers a restart
            last_exc = exc
    raise RuntimeError(
        f"job failed after {max_restarts} restarts"
    ) from last_exc
