"""Ambient mesh context for mesh-agnostic model code.

Layers call ``ctx.constrain(x, ...)`` unconditionally; the call resolves to a
``with_sharding_constraint`` only when a mesh has been installed with
``mesh_context`` (launch/train, launch/serve, dry-run), and to identity
otherwise — so the same model code runs on a single CPU device and on a
(16, 16) v5e pod without branches at the call sites.

Every constraint entry is validated against the live mesh: axes the mesh
does not have, and dims the axis size does not divide, degrade to ``None``
(replicated) instead of erroring.  That is what makes reduced CPU configs
and ragged head counts safe on any topology.
"""

from __future__ import annotations

import contextlib
import math
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "mesh_context", "current_mesh", "axis_size", "tp_size", "dp_axes",
    "dp_shards", "seq_shard_attention", "constrain",
    "serve_shard_scope", "kv_shard_info", "gather_heads",
]

_MESH_STACK: list = []

# DP axes in outer-to-inner order; "model" is the TP axis (launch/mesh.py).
_DP_AXIS_NAMES = ("pod", "data")


@contextlib.contextmanager
def mesh_context(mesh):
    """Install ``mesh`` as the ambient mesh for ``constrain`` / size queries."""
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def current_mesh():
    return _MESH_STACK[-1] if _MESH_STACK else None


def _mesh_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, getattr(mesh, "axis_sizes", None)
                    or tuple(mesh.shape[a] for a in mesh.axis_names)))


def axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    return int(_mesh_sizes(mesh).get(name, 1))


def tp_size() -> int:
    """Tensor-parallel width (the 'model' mesh axis; 1 outside a mesh)."""
    return axis_size("model")


def dp_axes():
    """The data-parallel spec entry: ('pod', 'data') on multi-pod meshes,
    plain 'data' otherwise.  Usable directly as one PartitionSpec entry."""
    mesh = current_mesh()
    if mesh is None:
        return "data"
    present = tuple(a for a in _DP_AXIS_NAMES if a in _mesh_sizes(mesh))
    if not present:
        return "data"
    return present if len(present) > 1 else present[0]


def dp_shards() -> int:
    """Total number of data-parallel shards under the current mesh."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    sizes = _mesh_sizes(mesh)
    return int(math.prod(sizes.get(a, 1) for a in _DP_AXIS_NAMES))


def seq_shard_attention(n_heads: int) -> bool:
    """Sequence-parallel attention: used when TP is on but the (GQA) head
    count cannot split across the 'model' axis — tokens shard instead and
    QKV/O weights stay replicated (dist/sharding.py emits the matching
    replicated specs)."""
    tp = tp_size()
    return tp > 1 and n_heads % tp != 0


# ---------------------------------------------------------------------------
# serve-time shard scope (inside shard_map, DESIGN.md §9)
# ---------------------------------------------------------------------------
#
# The sharded serving engine runs its jitted steps under ``shard_map``: model
# code sees *local* shapes (batch rows of this data shard, KV heads of this
# model shard), but two things must stay functions of the GLOBAL coordinates
# for sharded and single-device streams to be bitwise-equal:
#
# 1. the int8 KV quantiser's element indices (its dither hash is keyed on the
#    global (row, head, element) index — DESIGN.md §6's bit-reusability
#    contract), and
# 2. the all-gather of attention heads before the (replicated) W_O matmul —
#    the serve TP layout keeps every f32 contraction un-split, so sharding
#    never reassociates a reduction (DESIGN.md §9).
#
# The engine installs this scope around the shard_map body; outside it (no
# mesh, or code paths like training that shard via GSPMD instead) both
# helpers degrade to identity / None.

_SERVE_SHARD: list = []


@contextlib.contextmanager
def serve_shard_scope(*, head0, heads_sharded: bool,
                      model_axis: str = "model"):
    """Install the per-shard → global coordinate map for one traced serve
    step.  ``head0`` is the shard's global KV-head offset (a traced scalar,
    ``lax.axis_index`` times the local head count; 0 under the fallback);
    ``heads_sharded`` records whether the 'model' axis actually splits the
    heads (False = GQA replicated fallback, DESIGN.md §9).  Batch rows need
    no offset on purpose: everything the model hashes is row-independent
    (see ``transformer._kv_elem_idx``)."""
    _SERVE_SHARD.append({
        "head0": head0, "heads_sharded": bool(heads_sharded),
        "model_axis": model_axis,
    })
    try:
        yield
    finally:
        _SERVE_SHARD.pop()


def kv_shard_info() -> Optional[dict]:
    """The active serve shard scope (None outside sharded serving) — the KV
    quantiser reads global element-index offsets from it."""
    return _SERVE_SHARD[-1] if _SERVE_SHARD else None


def gather_heads(x: jax.Array) -> jax.Array:
    """All-gather the (sharded) attention-head dim of ``x`` (last axis)
    across the 'model' axis — identity outside sharded serving or under the
    GQA replicated fallback.  Concatenation order equals the global head
    order, so the gathered activation is bitwise the single-device one; the
    consuming W_O matmul then contracts the full head dim on every shard
    instead of psum-ing partial products (DESIGN.md §9)."""
    info = kv_shard_info()
    if info is None or not info["heads_sharded"]:
        return x
    return jax.lax.all_gather(x, info["model_axis"], axis=x.ndim - 1,
                              tiled=True)


def _validated_entry(entry, dim: int, sizes: dict):
    """Keep a spec entry only if all its axes exist and their product divides
    the dim; otherwise replicate that dim."""
    if entry is None:
        return None
    axes = entry if isinstance(entry, tuple) else (entry,)
    if any(a not in sizes for a in axes):
        return None
    size = math.prod(int(sizes[a]) for a in axes)
    if size <= 1 or dim % size != 0:
        return None
    return entry


def constrain(x: jax.Array, *entries) -> jax.Array:
    """``with_sharding_constraint(x, P(*entries))`` under the ambient mesh;
    identity when no mesh is installed (or the mesh is a single device).

    One entry per dim of ``x``; each entry is an axis name, a tuple of axis
    names, or None.  Invalid entries (absent axis / non-dividing size)
    degrade to None per dim rather than erroring.
    """
    mesh = current_mesh()
    if mesh is None or mesh.size <= 1:
        return x
    sizes = _mesh_sizes(mesh)
    spec = tuple(
        _validated_entry(e, d, sizes) for e, d in zip(entries, x.shape)
    )
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
