"""Ambient mesh context for mesh-agnostic model code.

Layers call ``ctx.constrain(x, ...)`` unconditionally; the call resolves to a
``with_sharding_constraint`` only when a mesh has been installed with
``mesh_context`` (launch/train, launch/serve, dry-run), and to identity
otherwise — so the same model code runs on a single CPU device and on a
(16, 16) v5e pod without branches at the call sites.

Every constraint entry is validated against the live mesh: axes the mesh
does not have, and dims the axis size does not divide, degrade to ``None``
(replicated) instead of erroring.  That is what makes reduced CPU configs
and ragged head counts safe on any topology.
"""

from __future__ import annotations

import contextlib
import math
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "mesh_context", "current_mesh", "axis_size", "tp_size", "dp_axes",
    "dp_shards", "seq_shard_attention", "constrain",
]

_MESH_STACK: list = []

# DP axes in outer-to-inner order; "model" is the TP axis (launch/mesh.py).
_DP_AXIS_NAMES = ("pod", "data")


@contextlib.contextmanager
def mesh_context(mesh):
    """Install ``mesh`` as the ambient mesh for ``constrain`` / size queries."""
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def current_mesh():
    return _MESH_STACK[-1] if _MESH_STACK else None


def _mesh_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, getattr(mesh, "axis_sizes", None)
                    or tuple(mesh.shape[a] for a in mesh.axis_names)))


def axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    return int(_mesh_sizes(mesh).get(name, 1))


def tp_size() -> int:
    """Tensor-parallel width (the 'model' mesh axis; 1 outside a mesh)."""
    return axis_size("model")


def dp_axes():
    """The data-parallel spec entry: ('pod', 'data') on multi-pod meshes,
    plain 'data' otherwise.  Usable directly as one PartitionSpec entry."""
    mesh = current_mesh()
    if mesh is None:
        return "data"
    present = tuple(a for a in _DP_AXIS_NAMES if a in _mesh_sizes(mesh))
    if not present:
        return "data"
    return present if len(present) > 1 else present[0]


def dp_shards() -> int:
    """Total number of data-parallel shards under the current mesh."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    sizes = _mesh_sizes(mesh)
    return int(math.prod(sizes.get(a, 1) for a in _DP_AXIS_NAMES))


def seq_shard_attention(n_heads: int) -> bool:
    """Sequence-parallel attention: used when TP is on but the (GQA) head
    count cannot split across the 'model' axis — tokens shard instead and
    QKV/O weights stay replicated (dist/sharding.py emits the matching
    replicated specs)."""
    tp = tp_size()
    return tp > 1 and n_heads % tp != 0


def _validated_entry(entry, dim: int, sizes: dict):
    """Keep a spec entry only if all its axes exist and their product divides
    the dim; otherwise replicate that dim."""
    if entry is None:
        return None
    axes = entry if isinstance(entry, tuple) else (entry,)
    if any(a not in sizes for a in axes):
        return None
    size = math.prod(int(sizes[a]) for a in axes)
    if size <= 1 or dim % size != 0:
        return None
    return entry


def constrain(x: jax.Array, *entries) -> jax.Array:
    """``with_sharding_constraint(x, P(*entries))`` under the ambient mesh;
    identity when no mesh is installed (or the mesh is a single device).

    One entry per dim of ``x``; each entry is an axis name, a tuple of axis
    names, or None.  Invalid entries (absent axis / non-dividing size)
    degrade to None per dim rather than erroring.
    """
    mesh = current_mesh()
    if mesh is None or mesh.size <= 1:
        return x
    sizes = _mesh_sizes(mesh)
    spec = tuple(
        _validated_entry(e, d, sizes) for e, d in zip(entries, x.shape)
    )
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
