"""Distributed-execution layer: mesh context, sharding rules, fault tolerance.

* ``ctx``             — ambient mesh context; ``constrain`` applies sharding
  constraints inside a ``mesh_context`` and is a no-op outside it, so model
  code is mesh-agnostic (CPU tests and TPU production share one code path).
* ``sharding``        — PartitionSpec rules for param / cache / batch trees
  (megatron-style TP + DP, guarded by divisibility so any mesh is legal).
* ``fault_tolerance`` — failure injection, straggler watchdog, restart loop.
"""
