"""PartitionSpec rules for param / cache / batch pytrees.

Megatron-style layout on a ('data', 'model') mesh (optionally with a leading
'pod' DP axis):

* attention — QKV column-parallel, O row-parallel, keyed on *head counts*:
  ``wq``/``wo`` shard only when ``n_heads % tp == 0`` and ``wk``/``wv`` only
  when ``n_kv_heads % tp == 0`` (GQA head counts often don't divide the TP
  axis; the attention layer then falls back to sequence parallelism —
  ``ctx.seq_shard_attention``).
* MLP / MoE experts — up/gate column-parallel (last dim), down row-parallel
  (second-to-last dim).
* embeddings — vocab-parallel (the vocab dim is padded to the TP axis by
  ``ModelConfig.vocab_padded``).
* everything else (norms, biases on d_model, routers, SSM scan params) —
  replicated: small, or accuracy-critical (DESIGN.md §5).

Every emitted entry is divisibility-guarded against the concrete mesh, so
any (arch × mesh) combination yields a legal spec tree: a dim that does not
divide simply stays replicated.  Specs are emitted at the leaf's full rank
(explicit ``None`` per dim) and mirror the param tree structurally.
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["param_specs", "cache_specs", "batch_specs", "data_axes",
           "serve_param_specs", "serve_heads_shardable"]

_DP_AXIS_NAMES = ("pod", "data")

# leaf-name → (which dim shards on 'model' counted from the END, head-count
# attribute guarding it or None for plain dim divisibility)
_LAST, _SECOND_LAST = 1, 2
_TP_RULES = {
    # attention projections (head-count guarded)
    "wq": (_LAST, "n_heads"),
    "bq": (_LAST, "n_heads"),
    "wk": (_LAST, "n_kv_heads"),
    "wv": (_LAST, "n_kv_heads"),
    "bk": (_LAST, "n_kv_heads"),
    "bv": (_LAST, "n_kv_heads"),
    "wo": (_SECOND_LAST, "n_heads"),
    # MLP / MoE expert FFNs: column-parallel up/gate, row-parallel down
    "wg": (_LAST, None),
    "wu": (_LAST, None),
    "bu": (_LAST, None),
    "wd": (_SECOND_LAST, None),
    # SSM fused input projection is column-parallel; output row-parallel
    "in_proj": (_LAST, None),
    "out_proj": (_SECOND_LAST, None),
    # vocab-parallel embedding / head: embed is (vocab, d), head is (d, vocab)
    "embed": (_SECOND_LAST, None),
    "lm_head": (_LAST, None),
}


def _mesh_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, getattr(mesh, "axis_sizes", None)
                    or tuple(mesh.shape[a] for a in mesh.axis_names)))


def data_axes(mesh):
    """The DP spec entry for this mesh: ('pod', 'data'), 'data', or None."""
    present = tuple(a for a in _DP_AXIS_NAMES if a in _mesh_sizes(mesh))
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def _validated(spec: P, shape, mesh) -> P:
    """Clamp a spec to a concrete leaf shape on a concrete mesh: entries past
    the rank are dropped; absent axes and non-dividing sizes become None."""
    sizes = _mesh_sizes(mesh)
    out = []
    for i, entry in enumerate(tuple(spec)[: len(shape)]):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        if any(a not in sizes for a in axes):
            out.append(None)
            continue
        size = math.prod(int(sizes[a]) for a in axes)
        out.append(entry if size > 1 and shape[i] % size == 0 else None)
    return P(*out)


def _leaf_name(path) -> str:
    for key in reversed(path):
        if isinstance(key, jax.tree_util.DictKey):
            return str(key.key)
    return ""


def _replicated(ndim: int) -> P:
    return P(*((None,) * ndim))


def param_specs(params: Any, cfg, mesh) -> Any:
    """Spec tree mirroring ``params`` (leaves may be arrays or ShapeDtypeStructs)."""
    tp = int(_mesh_sizes(mesh).get("model", 1))

    def rule(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        name = _leaf_name(path)
        if name in _TP_RULES:
            from_end, head_attr = _TP_RULES[name]
            if from_end <= len(shape):
                dim = len(shape) - from_end
                guard = (getattr(cfg, head_attr) if head_attr else shape[dim])
                if tp > 1 and guard % tp == 0 and shape[dim] % tp == 0:
                    spec = [None] * len(shape)
                    spec[dim] = "model"
                    return P(*spec)
            return _replicated(len(shape))
        return _replicated(len(shape))

    return jax.tree_util.tree_map_with_path(rule, params)


def serve_heads_shardable(cfg, tp: int) -> bool:
    """Can the serving engine split attention heads across a ``tp``-way
    'model' axis?  Requires the *KV* head count to divide (GQA head counts
    often don't — the engine then falls back to fully replicated TP compute,
    mirroring ``_TP_RULES``'s head-count guards; DESIGN.md §9).  ``n_heads``
    divides whenever ``n_kv_heads`` does (``n_heads = group · n_kv_heads``)."""
    return tp > 1 and cfg.n_kv_heads > 0 and cfg.n_kv_heads % tp == 0 \
        and cfg.n_heads % tp == 0


def serve_param_specs(params: Any, cfg, mesh) -> Any:
    """Spec tree for the *serving* path (DESIGN.md §9): only the QKV
    projections shard (column-parallel on 'model', head-count guarded);
    everything else — W_O, MLP, embeddings, norms — stays replicated.

    This is deliberately a subset of :func:`param_specs`: the training
    layout's row-parallel W_O / W_down produce partial products that a psum
    reassociates, which breaks the engine's bitwise sharded ≡ single-device
    stream contract (the same fixed-reduction-layout argument as the scaled
    unary dot-products of arXiv:2307.03204).  The serve layout instead
    all-gathers the (small) attention-head activations before a replicated
    W_O — every f32 contraction stays whole, and the KV cache (the serving
    memory bottleneck) still shards ``tp``-way on its head dim.
    """
    tp = int(_mesh_sizes(mesh).get("model", 1))
    shardable = serve_heads_shardable(cfg, tp)
    qkv = {"wq", "bq", "wk", "wv", "bk", "bv"}

    def rule(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        name = _leaf_name(path)
        if shardable and name in qkv and shape[-1] % tp == 0:
            spec = [None] * len(shape)
            spec[-1] = "model"
            return P(*spec)
        return _replicated(len(shape))

    return jax.tree_util.tree_map_with_path(rule, params)


# cache leaves whose *entry-local* dim 2 is a (KV) head dim: (B, S, H, hd)
# ring KV, quantised KV scales, cross-attention caches — and, in the paged
# layout, (n_blocks+1, bs, H, hd) pool arrays; SSM state "h" carries heads at
# entry-local dim 1: (B, nh, hd, n).
_HEADS_AT_2 = {"k", "v", "k_scale", "v_scale", "cross_k", "cross_v"}
_HEADS_AT_1 = {"h"}
# tree keys under which cache entries carry a leading stack axis (scanned
# layer repeats / the enc-dec per-layer stacks); "remainder" entries do not
_STACKED_KEYS = {"layers", "self", "cross_k", "cross_v"}


def cache_specs(cache: Any, cfg, mesh) -> Any:
    """Spec tree for decode caches (ring and paged): the per-slot batch dim
    (and the paged layout's pool-block axis) → DP axes, KV/SSM head dims →
    'model'; every entry divisibility-guarded (DESIGN.md §9).

    Stacked entries (under ``layers`` / the enc-dec per-layer stacks) carry
    a leading repeat axis which is *never* sharded — the batch/head rules
    shift right by one.  A cache carrying ``block_tables`` is the paged
    layout: per-layer pool arrays are ``(n_shards·(n_blocks+1), bs, ...)``
    and shard on their leading block axis (each data shard owns its blocks
    plus its own trash block); ``block_tables`` / ``pos`` shard on the slot
    dim.
    """
    sizes = _mesh_sizes(mesh)
    dp = data_axes(mesh)
    dp_axes_tuple = dp if isinstance(dp, tuple) else ((dp,) if dp else ())
    dp_size = math.prod(int(sizes[a]) for a in dp_axes_tuple) if dp_axes_tuple else 1
    tp = int(sizes.get("model", 1))
    # paged caches (identified by a "block_tables" key) need no special
    # branch: dim 0 of an entry is the batch dim on ring layouts and the
    # pool-block axis on paged ones, and both shard on the DP axes; the
    # entry-local head dim is 2 in both layouts.

    def rule(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        name = _leaf_name(path)
        stacked = any(isinstance(k, jax.tree_util.DictKey)
                      and str(k.key) in _STACKED_KEYS for k in path)
        off = 1 if stacked else 0
        spec = [None] * len(shape)
        # dim 0 of every entry (after the stack axis): per-slot batch rows on
        # the ring layouts, the pool-block axis on paged k/v/scale leaves
        if (dp and dp_size > 1 and off < len(shape)
                and shape[off] % dp_size == 0):
            spec[off] = dp
        head_dim = (2 if name in _HEADS_AT_2 else
                    1 if name in _HEADS_AT_1 else None)
        if head_dim is not None:
            head_dim += off
            if (head_dim < len(shape) and tp > 1
                    and shape[head_dim] % tp == 0):
                spec[head_dim] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache)


def batch_specs(cfg, mesh) -> dict:
    """Specs for training/prefill batches: batch dim on the DP axes."""
    dp = data_axes(mesh)
    return {
        "tokens": P(dp, None),
        "labels": P(dp, None),
        "embeds": P(dp, None, None),
        "frames": P(dp, None, None),
    }
