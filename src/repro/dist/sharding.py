"""PartitionSpec rules for param / cache / batch pytrees.

Megatron-style layout on a ('data', 'model') mesh (optionally with a leading
'pod' DP axis):

* attention — QKV column-parallel, O row-parallel, keyed on *head counts*:
  ``wq``/``wo`` shard only when ``n_heads % tp == 0`` and ``wk``/``wv`` only
  when ``n_kv_heads % tp == 0`` (GQA head counts often don't divide the TP
  axis; the attention layer then falls back to sequence parallelism —
  ``ctx.seq_shard_attention``).
* MLP / MoE experts — up/gate column-parallel (last dim), down row-parallel
  (second-to-last dim).
* embeddings — vocab-parallel (the vocab dim is padded to the TP axis by
  ``ModelConfig.vocab_padded``).
* everything else (norms, biases on d_model, routers, SSM scan params) —
  replicated: small, or accuracy-critical (DESIGN.md §5).

Every emitted entry is divisibility-guarded against the concrete mesh, so
any (arch × mesh) combination yields a legal spec tree: a dim that does not
divide simply stays replicated.  Specs are emitted at the leaf's full rank
(explicit ``None`` per dim) and mirror the param tree structurally.
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["param_specs", "cache_specs", "batch_specs", "data_axes"]

_DP_AXIS_NAMES = ("pod", "data")

# leaf-name → (which dim shards on 'model' counted from the END, head-count
# attribute guarding it or None for plain dim divisibility)
_LAST, _SECOND_LAST = 1, 2
_TP_RULES = {
    # attention projections (head-count guarded)
    "wq": (_LAST, "n_heads"),
    "bq": (_LAST, "n_heads"),
    "wk": (_LAST, "n_kv_heads"),
    "wv": (_LAST, "n_kv_heads"),
    "bk": (_LAST, "n_kv_heads"),
    "bv": (_LAST, "n_kv_heads"),
    "wo": (_SECOND_LAST, "n_heads"),
    # MLP / MoE expert FFNs: column-parallel up/gate, row-parallel down
    "wg": (_LAST, None),
    "wu": (_LAST, None),
    "bu": (_LAST, None),
    "wd": (_SECOND_LAST, None),
    # SSM fused input projection is column-parallel; output row-parallel
    "in_proj": (_LAST, None),
    "out_proj": (_SECOND_LAST, None),
    # vocab-parallel embedding / head: embed is (vocab, d), head is (d, vocab)
    "embed": (_SECOND_LAST, None),
    "lm_head": (_LAST, None),
}


def _mesh_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, getattr(mesh, "axis_sizes", None)
                    or tuple(mesh.shape[a] for a in mesh.axis_names)))


def data_axes(mesh):
    """The DP spec entry for this mesh: ('pod', 'data'), 'data', or None."""
    present = tuple(a for a in _DP_AXIS_NAMES if a in _mesh_sizes(mesh))
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def _validated(spec: P, shape, mesh) -> P:
    """Clamp a spec to a concrete leaf shape on a concrete mesh: entries past
    the rank are dropped; absent axes and non-dividing sizes become None."""
    sizes = _mesh_sizes(mesh)
    out = []
    for i, entry in enumerate(tuple(spec)[: len(shape)]):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        if any(a not in sizes for a in axes):
            out.append(None)
            continue
        size = math.prod(int(sizes[a]) for a in axes)
        out.append(entry if size > 1 and shape[i] % size == 0 else None)
    return P(*out)


def _leaf_name(path) -> str:
    for key in reversed(path):
        if isinstance(key, jax.tree_util.DictKey):
            return str(key.key)
    return ""


def _replicated(ndim: int) -> P:
    return P(*((None,) * ndim))


def param_specs(params: Any, cfg, mesh) -> Any:
    """Spec tree mirroring ``params`` (leaves may be arrays or ShapeDtypeStructs)."""
    tp = int(_mesh_sizes(mesh).get("model", 1))

    def rule(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        name = _leaf_name(path)
        if name in _TP_RULES:
            from_end, head_attr = _TP_RULES[name]
            if from_end <= len(shape):
                dim = len(shape) - from_end
                guard = (getattr(cfg, head_attr) if head_attr else shape[dim])
                if tp > 1 and guard % tp == 0 and shape[dim] % tp == 0:
                    spec = [None] * len(shape)
                    spec[dim] = "model"
                    return P(*spec)
            return _replicated(len(shape))
        return _replicated(len(shape))

    return jax.tree_util.tree_map_with_path(rule, params)


def cache_specs(cache: Any, cfg, mesh) -> Any:
    """Spec tree for decode caches: batch dim → DP axes, KV/SSM head dim →
    'model' (both divisibility-guarded)."""
    sizes = _mesh_sizes(mesh)
    dp = data_axes(mesh)
    dp_axes_tuple = dp if isinstance(dp, tuple) else ((dp,) if dp else ())
    dp_size = math.prod(int(sizes[a]) for a in dp_axes_tuple) if dp_axes_tuple else 1
    tp = int(sizes.get("model", 1))

    # cache leaves whose dim 2 is a (KV or state) head dim: (B, S, H, hd) KV,
    # quantised KV scales, and cross-attention caches; SSM state "h" carries
    # heads at dim 1: (B, nh, hd, n).
    heads_at_2 = {"k", "v", "k_scale", "v_scale", "cross_k", "cross_v"}
    heads_at_1 = {"h"}

    def rule(path, leaf):
        shape = leaf.shape
        if len(shape) == 0:
            return P()
        name = _leaf_name(path)
        spec = [None] * len(shape)
        if dp and dp_size > 1 and shape[0] % dp_size == 0:
            spec[0] = dp
        head_dim = (2 if name in heads_at_2 else 1 if name in heads_at_1 else None)
        if (head_dim is not None and head_dim < len(shape) and tp > 1
                and shape[head_dim] % tp == 0):
            spec[head_dim] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache)


def batch_specs(cfg, mesh) -> dict:
    """Specs for training/prefill batches: batch dim on the DP axes."""
    dp = data_axes(mesh)
    return {
        "tokens": P(dp, None),
        "labels": P(dp, None),
        "embeds": P(dp, None, None),
        "frames": P(dp, None, None),
    }
