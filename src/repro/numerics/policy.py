"""Quantisation policy: dither rounding as a first-class numerics feature.

``QuantPolicy`` decides how every dense matmul in the model zoo executes:

* ``scheme='none'``      — plain bf16/f32 matmul (the dry-run / roofline path).
* ``scheme='dither'``    — §VIII 'separate' variant: activations and weights
  are dither-rounded onto a k-bit grid (dynamic absmax range), multiplied,
  and dequantised.  Weights use the paper's Format-2 role (per-step counter,
  "precoded"); activations the Format-1 role (per-call counter) — §VI.
* ``scheme='stochastic'|'deterministic'`` — baselines for comparison.

Gradients flow with a straight-through estimator (custom_vjp): backward uses
the full-precision operands, which is the standard QAT treatment and keeps
the forward-rounding unbiasedness argument (§VII / [9]) intact.

The counter i_s is a *traced* int32 scalar threaded from the train step, so
advancing it never retraces. Counter-advancement is "rounding in time": the
same weight re-rounded across steps walks the dither pulse sequence, giving
the O(1/N) time-averaged SEM of §VII instead of stochastic rounding's
Ω(1/√N).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, replace

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import rounding

__all__ = ["QuantPolicy", "qmatmul", "dense", "fake_quant"]


@dataclass(frozen=True)
class QuantPolicy:
    scheme: str = "none"          # none | dither | stochastic | deterministic
    bits: int = 8
    n_pulses: int = 16            # dither pulse count N (jnp backend)
    seed: int = 0
    # 'jnp' — unfused fake-quant matmul (XLA, default).  Anything else is a
    # kernel-dispatcher backend ('auto', 'pallas', 'pallas-tpu',
    # 'pallas-interpret', 'xla-ref'): the forward matmul runs the fused
    # §VIII 'separate' kernel via kernels/dispatch.py (DESIGN.md §3).
    backend: str = "jnp"
    quantize_weights: bool = True
    quantize_acts: bool = True

    @property
    def enabled(self) -> bool:
        return self.scheme != "none"

    def with_seed(self, seed: int) -> "QuantPolicy":
        return replace(self, seed=seed)

    def resolved(self) -> "QuantPolicy":
        """Pin aliases ('auto', 'pallas') to a concrete dispatcher backend.

        The trainer and serve engine call this once at build time so the
        traced step function embeds a stable backend choice (platform
        detection / $REPRO_KERNEL_BACKEND are read here, not per call).
        """
        from repro.kernels import dispatch  # late: kernels import this module

        return replace(self, backend=dispatch.resolve_policy_backend(self.backend))


def _absmax_scale(x: jax.Array, bits: int) -> jax.Array:
    """Symmetric dynamic range: scale mapping [-absmax, absmax] → [0, 2^k−1]."""
    half = (1 << bits) - 1
    absmax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-6)
    return (half / 2.0) / absmax, absmax


def _fake_quant(x: jax.Array, policy: QuantPolicy, counter, seed: int) -> jax.Array:
    """Round x onto the symmetric k-bit grid with the policy's scheme."""
    scale, _ = _absmax_scale(x, policy.bits)
    half_levels = float((1 << policy.bits) - 1) / 2.0
    scaled = x.astype(jnp.float32) * scale + half_levels  # → [0, 2^k−1]
    if policy.scheme == "deterministic":
        codes = rounding.deterministic_round(scaled)
    elif policy.scheme == "stochastic":
        idx = jnp.arange(x.size, dtype=jnp.uint32).reshape(x.shape)
        u = rounding.hash_uniform(seed, idx, counter)
        fl = jnp.floor(scaled)
        codes = fl + (u < scaled - fl).astype(jnp.float32)
    elif policy.scheme == "dither":
        idx = jnp.arange(x.size, dtype=jnp.uint32).reshape(x.shape)
        slot = rounding.lcg_slot(counter, idx, policy.n_pulses, seed=seed)
        u = rounding.hash_uniform(rounding._u32(seed) ^ np.uint32(0xD1CE), idx, counter)
        fl = jnp.floor(scaled)
        codes = fl + rounding.dither_bit(scaled - fl, slot, u, policy.n_pulses)
    else:
        raise ValueError(policy.scheme)
    codes = jnp.clip(codes, 0.0, 2.0 * half_levels)
    return ((codes - half_levels) / scale).astype(x.dtype)


def _fused_matmul(x, w, policy: QuantPolicy, seed: int, counter) -> jax.Array:
    """Forward via the fused kernel-dispatcher matmul (§VIII 'separate').

    The dispatcher kernels take a *static* operand range, while the policy
    uses dynamic absmax scaling — so both operands are normalised to
    [-1, 1] first (the quantisation grid is identical to ``_fake_quant``'s:
    scaled = (x/absmax + 1)·(2^k−1)/2 either way) and the product is scaled
    back.  Dither pulse counts follow §VII (N_A = N, N_B = M) on this path
    rather than ``policy.n_pulses``.
    """
    from repro.kernels import dispatch  # late: kernels import this module

    ax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-6)
    aw = jnp.maximum(jnp.max(jnp.abs(w)), 1e-6)
    out = dispatch.matmul(
        (x / ax).astype(jnp.float32), (w / aw).astype(jnp.float32),
        bits=policy.bits, scheme=policy.scheme,
        counter=jnp.asarray(counter, jnp.int32), seed=seed,
        a_range=(-1.0, 1.0), b_range=(-1.0, 1.0),
        backend=policy.backend)
    return (out * (ax * aw)).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def qmatmul(x, w, policy: QuantPolicy, seed: int, counter=jnp.float32(0)):
    """Quantised x @ w with straight-through gradients.

    ``counter`` is a float32 scalar (exact for i_s < 2²⁴) so it has a
    well-defined (zero) cotangent under custom_vjp.

    ``policy.backend == 'jnp'`` fake-quantises both operands and multiplies
    in XLA; any other backend routes the forward product through the kernel
    dispatcher's fused quantised matmul (same grid, same STE backward).
    """
    if (policy.backend != "jnp" and x.ndim == 2
            and policy.quantize_acts and policy.quantize_weights):
        return _fused_matmul(x, w, policy, seed, counter)
    xq = _fake_quant(x, policy, counter, seed) if policy.quantize_acts else x
    wq = _fake_quant(w, policy, counter, seed + 1) if policy.quantize_weights else w
    return jnp.matmul(xq, wq)


def _qmatmul_fwd(x, w, policy, seed, counter):
    return qmatmul(x, w, policy, seed, counter), (x, w, counter)


def _qmatmul_bwd(policy, seed, res, g):
    x, w, counter = res
    # STE: full-precision backward (unbiased forward rounding already removed
    # the systematic error the paper worries about; see [9]/§VII).
    gx = jnp.matmul(g, w.T)
    gw = jnp.matmul(x.reshape(-1, x.shape[-1]).T, g.reshape(-1, g.shape[-1]))
    return gx, gw.astype(w.dtype), jnp.zeros_like(counter)


qmatmul.defvjp(_qmatmul_fwd, _qmatmul_bwd)


def fake_quant(x: jax.Array, policy: QuantPolicy | None, counter=0, seed: int = 0) -> jax.Array:
    """Public round-to-grid helper (stop-grad STE) for non-matmul call sites
    (stacked expert einsums, gradient compression)."""
    if policy is None or not policy.enabled:
        return x
    counter = jnp.asarray(counter, jnp.float32)
    xq = _fake_quant(x, policy, counter, policy.seed + seed)
    return x + jax.lax.stop_gradient(xq - x)


def dense(x: jax.Array, w: jax.Array, policy: QuantPolicy | None = None,
          counter=0, seed: int = 0) -> jax.Array:
    """The single matmul entry point used by every model layer.

    x: (..., d_in), w: (d_in, d_out).  policy None / 'none' → plain matmul
    (this is the path the dry-run rooflines); otherwise the §VIII 'separate'
    quantised path with dither/stochastic/deterministic rounding.
    """
    if policy is None or not policy.enabled:
        return jnp.matmul(x, w)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    counter = jnp.asarray(counter, jnp.float32)
    out = qmatmul(x2, w, policy, policy.seed + seed, counter)
    return out.reshape(*lead, w.shape[-1])
