"""Numerics policies: dither/stochastic/deterministic rounding for matmuls."""
from repro.numerics.policy import QuantPolicy, dense, fake_quant, qmatmul
__all__ = ["QuantPolicy", "dense", "fake_quant", "qmatmul"]
