"""Sharded, atomic, async-capable checkpointing (pure numpy — no orbax).

Layout:  <dir>/step_<N>/arrays.npz + manifest.json, written to a tmp dir and
atomically renamed so a crash mid-save never corrupts the latest checkpoint.
``save_async`` runs serialisation on a writer thread (the train loop keeps
stepping).  Restore is *elastic*: arrays load as numpy and are device_put
with whatever sharding the (possibly different-shape) restore mesh needs —
tested by the fault-tolerance suite (kill mid-run, resume on fewer devices).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["Checkpointer"]


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, blocking: bool = True):
        leaves, treedef = _flatten(tree)
        host_leaves = []
        for x in leaves:
            a = np.asarray(jax.device_get(x))
            # widen non-native dtypes (bfloat16) for npz portability; the
            # restore path casts back to the reference dtype.
            if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
                a = a.astype(np.float32)
            host_leaves.append(a)
        if blocking:
            self._write(step, host_leaves)
        else:
            self.wait()  # one outstanding async save at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, host_leaves), daemon=True
            )
            self._thread.start()

    def save_async(self, step: int, tree: Any):
        self.save(step, tree, blocking=False)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + f".tmp.{os.getpid()}.{int(time.time() * 1e6)}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(host_leaves),
                       "time": time.time()}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and not name.endswith(tuple([".tmp"])) \
               and "tmp" not in name:
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; reshard onto ``shardings``
        (pytree of jax.sharding.Sharding or None → default placement)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves, treedef = _flatten(like)
        assert manifest["n_leaves"] == len(leaves), "checkpoint/model mismatch"
        restored = []
        flat_sh = (treedef.flatten_up_to(shardings) if shardings is not None
                   else [None] * len(leaves))
        for i, (ref, sh) in enumerate(zip(leaves, flat_sh)):
            arr = data[f"leaf_{i}"]
            x = jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)
            if hasattr(ref, "dtype") and x.dtype != ref.dtype:
                x = x.astype(ref.dtype)
            restored.append(x)
        return treedef.unflatten(restored)

    def restore_latest(self, like: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like, shardings)
