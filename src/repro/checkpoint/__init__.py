"""repro.checkpoint"""
