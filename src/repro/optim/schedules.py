"""LR schedules: cosine and WSD (warmup-stable-decay, MiniCPM arXiv:2404.06395)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_schedule", "wsd_schedule", "linear_warmup"]


def linear_warmup(step, warmup: int, peak: float):
    return peak * jnp.minimum(1.0, step.astype(jnp.float32) / max(warmup, 1))


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return peak * jnp.where(s < warmup, warm, cos)
    return lr


def wsd_schedule(peak: float, warmup: int, stable: int, decay: int, floor: float = 0.01):
    """Warmup-Stable-Decay: linear warmup → constant plateau → exp-ish decay.

    MiniCPM's schedule; the decay phase uses the paper's exponential form
    f(s) = floor^(s/decay)."""
    def lr(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        in_decay = s > (warmup + stable)
        d = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = jnp.power(jnp.float32(floor), d)
        val = jnp.where(s < warmup, warm, jnp.where(in_decay, dec, 1.0))
        return peak * val
    return lr
