"""AdamW optimizer as pure pytree transforms (no external deps).

Moments are stored in f32 regardless of param dtype (mixed-precision master
statistics); the optimizer state shards exactly like the parameters (the
launcher reuses param PartitionSpecs), i.e. a ZeRO-free fully-sharded-on-TP /
replicated-on-DP layout that matches the dry-run mesh.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "init_opt_state", "apply_updates"]


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def lr_at(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)


def init_opt_state(params: Any) -> Any:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def apply_updates(opt: AdamW, params: Any, grads: Any, state: Any):
    """One AdamW step → (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, opt.grad_clip / (gnorm + 1e-9)) if opt.grad_clip else 1.0

    b1, b2 = opt.b1, opt.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = opt.lr_at(step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1.0 - b1) * g
        v_new = b2 * v + (1.0 - b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + opt.eps)
        if opt.weight_decay and p.ndim >= 2:  # decay matrices only
            delta = delta + opt.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}
