"""repro.optim"""
