"""Unbiased gradient compression with dither rounding (beyond-paper feature).

The paper's estimator is exactly what gradient compression needs: an
*unbiased* low-bit representation with O(1/N²) EMSE.  We compress gradients
to k-bit codes with dither rounding before the cross-replica reduction and
decompress after; because the rounding is unbiased, SGD convergence
guarantees survive (same argument as stochastic-rounding compression, but
with the §VII lower-variance estimator — the step counter walks the pulse
sequence so quantisation error time-averages at O(1/N) instead of Ω(1/√N)).

Under pjit the DP all-reduce is implicit, so this module exposes the
transform applied at the gradient boundary: grads → fake-quantised grads.
On a bf16 wire this halves (8-bit) or quarters (4-bit) DP collective bytes —
the dry-run's collective-term measurements quantify it (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.numerics.policy import QuantPolicy, fake_quant

__all__ = ["compress_grads"]


def compress_grads(grads: Any, policy: QuantPolicy, counter) -> Any:
    """Apply per-tensor dither-rounded quantisation to every gradient leaf."""
    if policy is None or not policy.enabled:
        return grads

    def comp(path, g):
        if g.ndim < 2:  # tiny vectors: not worth compressing
            return g
        seed = abs(hash("/".join(str(k) for k in path))) % (1 << 30)
        return fake_quant(g, policy, counter, seed=seed).astype(g.dtype)

    return jax.tree_util.tree_map_with_path(comp, grads)
