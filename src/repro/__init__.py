"""repro: dither computing (Wu, ARITH 2021) as a production JAX numerics substrate."""

__version__ = "0.1.0"
