"""Arithmetic on pulse sequences (paper §III multiplication, §IV scaled addition).

Multiplication of two pulse sequences is a bitwise AND (Z_i = X_i · Y_i);
scaled addition (averaging) multiplexes the two sequences with a control
sequence W_i: U_i = W_i X_i + (1−W_i) Y_i.  The three schemes differ only in
how the operand sequences / control sequence are generated:

* stochastic:   both operands iid Bernoulli; W_i iid Bernoulli(1/2).
* deterministic: x unary (Format 1), y spread (Format 2); W_i alternating.
* dither:       x dither/unary, y dither/spread with random phase T (§III-C);
                W is one of the two alternating phases chosen with prob 1/2
                (§IV-C) — W_i correlated across i, E(W_i)=1/2.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import representations as rep

__all__ = [
    "multiply_pulses",
    "scaled_add_pulses",
    "encode_pair_for_multiply",
    "encode_pair_for_add",
    "control_sequence",
]


def multiply_pulses(x_pulses: jax.Array, y_pulses: jax.Array) -> jax.Array:
    """Z_i = X_i · Y_i (bitwise AND for {0,1} pulses), §III."""
    return x_pulses * y_pulses


def control_sequence(key: jax.Array, batch_shape: tuple, n_pulses: int, scheme: str) -> jax.Array:
    """The §IV control sequence W for scaled addition, per scheme."""
    if scheme == "stochastic":
        return jax.random.bernoulli(key, 0.5, batch_shape + (n_pulses,)).astype(jnp.float32)
    s = (jnp.arange(n_pulses) % 2).astype(jnp.float32)  # s_i = 1 for i odd (0-based even)
    if scheme == "deterministic":
        return jnp.broadcast_to(s, batch_shape + (n_pulses,))
    if scheme == "dither":
        # With prob 1/2 use {s_i}, else {1-s_i}: W_i correlated, E(W_i)=1/2 (§IV-C).
        flip = jax.random.bernoulli(key, 0.5, batch_shape)[..., None].astype(jnp.float32)
        return flip * (1.0 - s) + (1.0 - flip) * s
    raise ValueError(f"unknown scheme {scheme!r}")


def encode_pair_for_multiply(
    key: jax.Array, x: jax.Array, y: jax.Array, n_pulses: int, scheme: str
):
    """Encode operands with the §III/§VI operand-asymmetric formats."""
    kx, ky, kt = jax.random.split(key, 3)
    if scheme == "stochastic":
        return (
            rep.stochastic_encode(kx, x, n_pulses),
            rep.stochastic_encode(ky, y, n_pulses),
        )
    if scheme == "deterministic":
        return (
            rep.deterministic_encode(x, n_pulses, fmt="unary"),
            rep.deterministic_encode(y, n_pulses, fmt="spread"),
        )
    if scheme == "dither":
        phase = jax.random.uniform(kt, jnp.shape(y))  # the §III-C random offset T
        return (
            rep.dither_encode(kx, x, n_pulses, fmt="unary"),
            rep.dither_encode(ky, y, n_pulses, fmt="spread", phase=phase),
        )
    raise ValueError(f"unknown scheme {scheme!r}")


def encode_pair_for_add(key: jax.Array, x: jax.Array, y: jax.Array, n_pulses: int, scheme: str):
    """Encode operands for §IV scaled addition (both Format 1)."""
    kx, ky = jax.random.split(key)
    if scheme == "stochastic":
        return (
            rep.stochastic_encode(kx, x, n_pulses),
            rep.stochastic_encode(ky, y, n_pulses),
        )
    if scheme == "deterministic":
        return (
            rep.deterministic_encode(x, n_pulses, fmt="unary"),
            rep.deterministic_encode(y, n_pulses, fmt="unary"),
        )
    if scheme == "dither":
        return (
            rep.dither_encode(kx, x, n_pulses, fmt="unary"),
            rep.dither_encode(ky, y, n_pulses, fmt="unary"),
        )
    raise ValueError(f"unknown scheme {scheme!r}")


@functools.partial(jax.jit, static_argnames=("n_pulses", "scheme"))
def scaled_add_pulses(
    key: jax.Array, x: jax.Array, y: jax.Array, n_pulses: int, scheme: str
) -> jax.Array:
    """Full §IV pipeline: encode, multiplex, decode → estimate of (x+y)/2."""
    kenc, kw = jax.random.split(key)
    xp, yp = encode_pair_for_add(kenc, x, y, n_pulses, scheme)
    w = control_sequence(kw, jnp.shape(jnp.asarray(x)), n_pulses, scheme)
    u = w * xp + (1.0 - w) * yp
    return rep.decode(u)


@functools.partial(jax.jit, static_argnames=("n_pulses", "scheme"))
def multiply_estimate(
    key: jax.Array, x: jax.Array, y: jax.Array, n_pulses: int, scheme: str
) -> jax.Array:
    """Full §III pipeline: encode, AND, decode → estimate of x·y."""
    xp, yp = encode_pair_for_multiply(key, x, y, n_pulses, scheme)
    return rep.decode(multiply_pulses(xp, yp))


__all__.append("multiply_estimate")
