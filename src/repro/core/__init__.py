"""Core dither-computing library: the paper's contribution, faithfully.

Modules:
  representations - §II pulse encodings (stochastic / deterministic / dither)
  ops             - §III multiply (AND), §IV scaled addition (mux)
  rounding        - §II-C/§VII rounding schemes incl. counter-based dither
  quantizers      - §VII k-bit fixed-point quantiser
  matmul          - §VII-§VIII quantised matmul, 3 rounding-placement variants
  theory          - closed-form bias/variance/EMSE oracles (Table I)
"""

from repro.core import matmul, ops, quantizers, representations, rounding, theory

__all__ = ["matmul", "ops", "quantizers", "representations", "rounding", "theory"]
