"""Closed-form bias/variance/EMSE expressions from the paper (§II–§IV, Table I).

These are the oracles the tests and Table-I benchmark validate sample
estimates against.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "emse_lower_bound",
    "emse_repr_stochastic",
    "emse_repr_deterministic",
    "var_repr_stochastic",
    "var_repr_dither_bound",
    "emse_repr_dither_bound",
    "emse_rounding_deterministic",
    "emse_rounding_stochastic",
    "TABLE_I",
]


def emse_lower_bound(n: int) -> float:
    """Thm 2.1 with uniform X: L ≥ 1/(12 N²)."""
    return 1.0 / (12.0 * n * n)


def emse_repr_stochastic(n: int) -> float:
    """§II-A, uniform X: L = ∫ x(1−x)/N dx = 1/(6N)."""
    return 1.0 / (6.0 * n)


def var_repr_stochastic(x: np.ndarray, n: int) -> np.ndarray:
    """§II-A: Var(X_s) = x(1−x)/N (pointwise)."""
    return x * (1.0 - x) / n


def emse_repr_deterministic(n: int) -> float:
    """§II-B, uniform X: L = 2N ∫_0^{1/2N} x² dx = 1/(12N²) (bias²-only)."""
    return 1.0 / (12.0 * n * n)


def var_repr_dither_bound(n: int) -> float:
    """§II-D: Var(X_s) ≤ 2/N² for either branch."""
    return 2.0 / (n * n)


def emse_repr_dither_bound(n: int) -> float:
    """§II-D: zero bias ⇒ L = E[Var] ≤ 2/N²."""
    return 2.0 / (n * n)


def emse_rounding_deterministic() -> float:
    """§II-C: 1-bit deterministic rounding of uniform x: L̃ = 1/12."""
    return 1.0 / 12.0


def emse_rounding_stochastic() -> float:
    """§II-C: 1-bit stochastic rounding of uniform x: L = ∫ x(1−x) = 1/6."""
    return 1.0 / 6.0


# Table I: (bias_order, var_order, emse_order) exponents of 1/N per scheme/op.
# exponent 0 ⇒ exactly zero (not O(1)).
TABLE_I = {
    ("stochastic", "repr"): dict(bias=None, var=1, emse=1),
    ("deterministic", "repr"): dict(bias=1, var=None, emse=2),
    ("dither", "repr"): dict(bias=None, var=2, emse=2),
    ("stochastic", "mult"): dict(bias=None, var=1, emse=1),
    ("deterministic", "mult"): dict(bias=1, var=None, emse=2),
    ("dither", "mult"): dict(bias=None, var=2, emse=2),
    ("stochastic", "avg"): dict(bias=None, var=1, emse=1),
    ("deterministic", "avg"): dict(bias=1, var=None, emse=2),
    ("dither", "avg"): dict(bias=None, var=2, emse=2),
}
