"""Pulse-sequence representations of reals in [0, 1] (paper §II).

Three schemes, each mapping x ∈ [0,1] to an N-bit pulse sequence whose mean
estimates x:

* ``stochastic_encode``    — §II-A: iid Bernoulli(x) pulses.  Unbiased,
  Var = x(1-x)/N = Ω(1/N).
* ``deterministic_encode`` — §II-B: unary counting (Format 1) or evenly-spread
  (Format 2).  Var = 0, |bias| ≤ 1/(2N).
* ``dither_encode``        — §II-D: n = ⌊Nx⌋ deterministic 1-pulses under a
  permutation σ plus Bernoulli(δ) residual pulses.  Unbiased,
  Var ≤ 2/N² = Θ(1/N²).

All functions are vectorised over arbitrary leading batch dims and jittable
with static ``n_pulses``.  The pulse axis is appended last.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Format = Literal["unary", "spread"]

__all__ = [
    "stochastic_encode",
    "deterministic_encode",
    "dither_encode",
    "decode",
    "lcg_permutation",
    "spread_ones",
]


def decode(pulses: jax.Array) -> jax.Array:
    """Estimate x from its pulse sequence: X_s = (1/N) Σ X_i (paper §II)."""
    return jnp.mean(pulses.astype(jnp.float32), axis=-1)


# ---------------------------------------------------------------------------
# §II-A stochastic computing
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_pulses",))
def stochastic_encode(key: jax.Array, x: jax.Array, n_pulses: int) -> jax.Array:
    """iid Bernoulli(x) pulses: P(X_i = 1) = x.  Shape: x.shape + (N,)."""
    x = jnp.asarray(x, jnp.float32)
    u = jax.random.uniform(key, x.shape + (n_pulses,))
    return (u < x[..., None]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# §II-B deterministic variant (Jenson & Riedel)
# ---------------------------------------------------------------------------


def spread_ones(n_ones: jax.Array, n_pulses: int, phase: jax.Array | None = None) -> jax.Array:
    """Evenly-spread placement of ``n_ones`` 1-bits among N slots (Format 2).

    Slot i carries a 1 iff ⌊(i+1)·m/N⌋ ≠ ⌊i·m/N⌋ (a Bresenham spread placing
    exactly m ones as uniformly as possible) — the paper's §III-B rule
    "P(Y_i)=1 if ⌊iy⌋ ≠ ⌊(i+1)y⌋" with y = m/N.  ``phase`` (∈[0,1), optional)
    rotates the pattern — the paper's random offset T.
    """
    i = jnp.arange(n_pulses, dtype=jnp.float32)
    if phase is not None:
        i = jnp.mod(i + phase[..., None] * n_pulses, n_pulses)
    m = jnp.asarray(n_ones, jnp.float32)[..., None]
    return (jnp.floor((i + 1.0) * m / n_pulses) != jnp.floor(i * m / n_pulses)).astype(
        jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("n_pulses", "fmt"))
def deterministic_encode(x: jax.Array, n_pulses: int, fmt: Format = "unary") -> jax.Array:
    """Deterministic variant of SC (§II-B, §III-B).

    Format 1 ("unary"):  first R = round(Nx) slots are 1.
    Format 2 ("spread"): R ones spread as evenly as possible (for the right
    operand of a multiply).
    """
    x = jnp.asarray(x, jnp.float32)
    r = jnp.round(n_pulses * x)
    if fmt == "unary":
        i = jnp.arange(n_pulses, dtype=jnp.float32)
        return (i < r[..., None]).astype(jnp.float32)
    return spread_ones(r, n_pulses)


# ---------------------------------------------------------------------------
# §II-D dither computing
# ---------------------------------------------------------------------------


def _coprime_multiplier(n: int) -> int:
    """Smallest multiplier ≥ ~0.618·n coprime to n (good spectral spread)."""
    a = max(1, int(round(0.6180339887 * n))) | 1  # odd start
    while _gcd(a, n) != 1:
        a += 2
    return a


def _gcd(a: int, b: int) -> int:
    while b:
        a, b = b, a % b
    return a


def lcg_permutation(n_pulses: int, offset: int = 0) -> jax.Array:
    """A fixed permutation σ of {0..N-1}: σ(i) = (a·i + offset) mod N, gcd(a,N)=1.

    Used as the paper's σ; linear-congruential so both σ and σ⁻¹ are O(1)
    integer math (the production kernels never materialise this array).
    """
    a = _coprime_multiplier(n_pulses)
    i = jnp.arange(n_pulses, dtype=jnp.int32)
    return (a * i + offset) % n_pulses


@functools.partial(jax.jit, static_argnames=("n_pulses", "fmt"))
def dither_encode(
    key: jax.Array,
    x: jax.Array,
    n_pulses: int,
    fmt: Format = "unary",
    phase: jax.Array | None = None,
) -> jax.Array:
    """Dither-computing encoding (paper §II-D), vectorised.

    For x ∈ [0, 1/2]:  n = ⌊Nx⌋, r = x − n/N, δ = Nr/(N−n):
        P(X_{σ(i)}=1) = 1 for i ≤ n,   δ for i > n.
    For x ∈ (1/2, 1]:  n = ⌈Nx⌉, r = n/N − x, δ = rN/n:
        P(X_{σ(i)}=1) = 1−δ for i ≤ n, 0 for i > n.

    Both branches are unbiased with Var(X_s) ≤ 2/N².

    ``fmt='unary'`` uses the identity permutation (Format 1, left operand);
    ``fmt='spread'`` spreads the deterministic slots evenly (Format 2, right
    operand of a multiply, §III-C) with optional random phase T.
    """
    x = jnp.asarray(x, jnp.float32)
    N = n_pulses

    lo = x <= 0.5
    # -- low branch ---------------------------------------------------------
    n_lo = jnp.floor(N * x)
    r_lo = x - n_lo / N
    delta_lo = jnp.where(N - n_lo > 0, N * r_lo / jnp.maximum(N - n_lo, 1), 0.0)
    # -- high branch --------------------------------------------------------
    n_hi = jnp.ceil(N * x)
    r_hi = n_hi / N - x
    delta_hi = jnp.where(n_hi > 0, r_hi * N / jnp.maximum(n_hi, 1), 0.0)

    n = jnp.where(lo, n_lo, n_hi)[..., None]
    # P(pulse at deterministic-slot positions), P(pulse at residual positions)
    p_head = jnp.where(lo, 1.0, 1.0 - delta_hi)[..., None]
    p_tail = jnp.where(lo, delta_lo, 0.0)[..., None]

    # Slot occupancy: position j is a "head" slot iff σ⁻¹(j) < n.  With the
    # spread format we place head slots evenly (Bresenham) instead.
    j = jnp.arange(N, dtype=jnp.float32)
    if fmt == "unary":
        is_head = j < n
    else:
        if phase is None:
            phase = jnp.zeros(x.shape, jnp.float32)
        is_head = spread_ones(jnp.squeeze(n, -1), N, phase=phase) > 0.5

    p = jnp.where(is_head, p_head, p_tail)
    u = jax.random.uniform(key, x.shape + (N,))
    return (u < p).astype(jnp.float32)
