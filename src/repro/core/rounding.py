"""Rounding schemes (paper §II-C, §VII): deterministic, stochastic, dither.

Dither rounding (§VII): ``d(α, i) = ⌊α⌋ + X_i`` where {X_i} is the dither-
computing representation (§II-D) of ``frac(α)`` and ``i = σ(i_s mod N)`` is
driven by a counter i_s.  This module implements the *lazy, counter-indexed*
TPU-native reduction (DESIGN.md §2): pulse i is a threshold test on the
permuted slot index plus a hashed Bernoulli tail — O(1) integer math per
element, no pulse tensors.  The same bit-exact semantics are shared by the
Pallas kernels (kernels/ref.py delegates here).

All randomness is a stateless xorshift/murmur hash of
(seed, element_index, counter) so results are reproducible and identical
across jnp / Pallas-interpret / Pallas-TPU paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "deterministic_round",
    "stochastic_round",
    "dither_round",
    "dither_bit",
    "hash_uniform",
    "lcg_slot",
    "slot_index",
    "DitherState",
]

# numpy scalars (not jnp) so Pallas kernel bodies see literals, not captures
_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)


def _mix(h: jax.Array) -> jax.Array:
    """murmur3 fmix32 — a high-quality 32-bit finaliser (mod-2³² wraparound
    is intended; numpy warns on scalar uint32 overflow, so silence locally)."""
    with np.errstate(over="ignore"):
        h = h ^ (h >> 16)
        h = h * _M1
        h = h ^ (h >> 13)
        h = h * _M2
        h = h ^ (h >> 16)
        return h


def _u32(v):
    """Coerce to uint32, keeping Python ints as numpy literals (Pallas-safe)."""
    if isinstance(v, jax.Array):
        return v.astype(jnp.uint32)
    if isinstance(v, np.ndarray):
        return v.astype(np.uint32)
    return np.uint32(int(v) & 0xFFFFFFFF)


def hash_uniform(seed, idx, counter) -> jax.Array:
    """Stateless uniform in [0,1) from (seed, element index, counter).

    Pure uint32 ops — portable to Pallas kernel bodies unchanged.
    """
    seed, idx, counter = _u32(seed), _u32(idx), _u32(counter)
    with np.errstate(over="ignore"):
        h = _mix(seed ^ _GOLDEN)
        h = _mix(h ^ idx * _M1)
        h = _mix(h ^ counter * _M2)
    # 24-bit mantissa → exact float32 uniform on [0,1)
    return (h >> 8).astype(jnp.float32) * (1.0 / (1 << 24))


def _coprime_multiplier(n: int) -> int:
    a = max(1, int(round(0.6180339887 * n))) | 1
    while _gcd(a, n) != 1:
        a += 2
    return a


def _gcd(a: int, b: int) -> int:
    while b:
        a, b = b, a % b
    return a


def lcg_slot(counter, idx, n_pulses: int, seed: int = 0) -> jax.Array:
    """σ(i_s mod N) with a linear-congruential permutation σ (per-element phase).

    ``idx`` decorrelates elements of a tensor: each element walks the same
    permutation with its own phase offset (equivalent to an element-specific
    σ, which the paper allows — "σ is either a deterministic or a random
    permutation").
    """
    a = _coprime_multiplier(n_pulses)
    counter, idx = _u32(counter), _u32(idx)
    n = np.uint32(n_pulses)
    phase = _mix(idx ^ _u32(seed) ^ _GOLDEN)
    q = (counter + phase) % n
    return (np.uint32(a) * q + (phase >> 8)) % n


def slot_index(counter, idx, n_pulses: int, seed: int = 0, fmt: str = "spread") -> jax.Array:
    """σ(i_s mod N) for either paper pulse format (§II-B / §VI roles).

    ``fmt='spread'`` (Format 2, the default): the LCG permutation σ of
    ``lcg_slot`` — successive counters jump through the pulse sequence.
    ``fmt='unary'`` (Format 1): identity σ with a per-element phase —
    successive counters walk the slots in order.  Both visit every slot
    exactly once per N counters, so the O(1/N) time-averaged SEM of §VII
    holds for either; the choice matters when two dithered operands meet
    (left operand Format 1, right operand Format 2 decorrelates products).
    """
    if fmt == "spread":
        return lcg_slot(counter, idx, n_pulses, seed=seed)
    if fmt == "unary":
        counter, idx = _u32(counter), _u32(idx)
        phase = _mix(idx ^ _u32(seed) ^ _GOLDEN)
        return (counter + phase) % np.uint32(n_pulses)
    raise ValueError(f"unknown pulse format {fmt!r}")


# ---------------------------------------------------------------------------
# rounding schemes
# ---------------------------------------------------------------------------


def deterministic_round(x: jax.Array) -> jax.Array:
    """round(x) = ⌊x + 0.5⌋ (the paper's definition — half-up, not banker's)."""
    return jnp.floor(x + 0.5)


def stochastic_round(x: jax.Array, seed, counter=0) -> jax.Array:
    """⌊x⌋ + Bernoulli(frac(x)), hash-PRNG driven (§II-C / [8])."""
    x = jnp.asarray(x, jnp.float32)
    flat_idx = jnp.arange(x.size, dtype=jnp.uint32).reshape(x.shape)
    u = hash_uniform(seed, flat_idx, counter)
    f = x - jnp.floor(x)
    return jnp.floor(x) + (u < f).astype(x.dtype)


def dither_bit(frac: jax.Array, slot: jax.Array, u: jax.Array, n_pulses: int) -> jax.Array:
    """Pulse value X_{σ(i)} of the §II-D dither representation, lazily.

    ``frac`` ∈ [0,1], ``slot`` = σ(i_s mod N) ∈ {0..N-1}, ``u`` ~ U[0,1).

    x ≤ 1/2: n = ⌊Nx⌋, δ = (Nx − n)/(N − n):   bit = [slot < n] or Bern(δ)
    x > 1/2: n = ⌈Nx⌉, δ = (n − Nx)/n:          bit = [slot < n]·Bern(1−δ)
    """
    N = float(n_pulses)
    f = jnp.asarray(frac, jnp.float32)
    slot = slot.astype(jnp.float32)

    lo = f <= 0.5
    n_lo = jnp.floor(N * f)
    delta_lo = jnp.where(N - n_lo > 0, (N * f - n_lo) / jnp.maximum(N - n_lo, 1.0), 0.0)
    n_hi = jnp.ceil(N * f)
    delta_hi = jnp.where(n_hi > 0, (n_hi - N * f) / jnp.maximum(n_hi, 1.0), 0.0)

    n = jnp.where(lo, n_lo, n_hi)
    head = slot < n
    p = jnp.where(
        lo,
        jnp.where(head, 1.0, delta_lo),
        jnp.where(head, 1.0 - delta_hi, 0.0),
    )
    return (u < p).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_pulses",))
def dither_round(x: jax.Array, counter, seed, n_pulses: int) -> jax.Array:
    """Dither rounding d(α, i_s) = ⌊α⌋ + X_{σ(i_s mod N)} (paper §VII).

    ``counter`` is the global use-counter i_s (scalar int, or an array
    broadcastable to x for per-use indices, e.g. the k column index in the
    per-partial-product matmul variant).  Negative α handled by reflecting
    through ⌊α⌋ (the paper: "the case α<0 can be handled similarly").
    """
    x = jnp.asarray(x, jnp.float32)
    fl = jnp.floor(x)
    f = x - fl  # ∈ [0,1) for any sign of x
    flat_idx = jnp.arange(x.size, dtype=jnp.uint32).reshape(x.shape)
    counter = jnp.asarray(counter)
    slot = lcg_slot(counter, flat_idx, n_pulses, seed=seed)
    u = hash_uniform(_u32(seed) ^ np.uint32(0xD1CE), flat_idx, counter)
    return fl + dither_bit(f, slot, u, n_pulses)


class DitherState:
    """Tiny counter registry so call sites can thread i_s functionally.

    Usage::

        st = DitherState(seed=0)
        y, st = st.round(x, n_pulses=64)
    """

    def __init__(self, seed: int = 0, counter: int = 0):
        self.seed = int(seed)
        self.counter = int(counter)

    def round(self, x: jax.Array, n_pulses: int):
        y = dither_round(x, self.counter, self.seed, n_pulses)
        return y, DitherState(self.seed, self.counter + 1)
