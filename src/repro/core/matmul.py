"""k-bit quantised matrix multiplication with rounding-scheme variants
(paper §VII Fig. 7 and §VIII).

Three placements of the rounding operation for C = A·B, A: p×q, B: q×r:

* ``per_partial``  — every partial product A_ij·B_jk rounds both operands
  (2·pqr roundings, Fig. 7 / Fig. 9).  For dither rounding, N_A = r and
  N_B = p: element A_ij is used r times (once per output column k, the
  counter), B_jk p times (once per output row i) — exactly the paper's
  prescription "each element of A is used r times … set N = N_A = r".
* ``round_a_once`` — A rounded once per element, B per partial product
  (pq(r+1) roundings, Figs. 11–12: "the input is only quantised once").
* ``separate``     — both matrices rounded once, then a plain matmul
  ((p+r)q roundings, Figs. 13–14).  This is the variant that scales to deep
  learning; it routes through the kernel dispatcher (kernels/dispatch.py), so
  the same call lowers to the fused Pallas kernel on TPU, Pallas interpret
  mode under CI, or the pure-XLA reference — selected by platform detection,
  ``backend=``, or $REPRO_KERNEL_BACKEND (DESIGN.md §3).

All math is done on the k-bit integer grid (codes in {0..2^k−1} after affine
rescale of [lo,hi]) and mapped back, mirroring the paper's "k-bit fixed point
multiplier" setup.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import rounding
from repro.core.quantizers import QuantSpec, dequantize, quantize
from repro.kernels import dispatch

Variant = Literal["per_partial", "round_a_once", "separate"]
Scheme = Literal["deterministic", "stochastic", "dither"]

__all__ = ["quantized_matmul", "matmul_error"]


def _codes_expanded(
    x: jax.Array,
    spec: QuantSpec,
    scheme: str,
    counter_axis_len: int,
    counter_on: str,  # 'new_last' (A: counter = output col) | 'new_first' (B: counter = output row)
    n_pulses: int,
    seed: int,
    counter0=0,
) -> jax.Array:
    """Round every *use* of x: expand with a new counter axis of given length.

    Returns codes with shape x.shape + (L,) for 'new_last' or (L,) + x.shape
    for 'new_first', where use index along the new axis is the dither/hash
    counter, phase-shifted by the global step counter ``counter0`` ("rounding
    in time" across calls).  Deterministic rounding collapses to a broadcast
    (no use-dep).
    """
    scaled = (jnp.asarray(x, jnp.float32) - spec.lo) * spec.scale
    fl = jnp.floor(scaled)
    f = scaled - fl
    L = counter_axis_len
    uses = jnp.arange(L, dtype=jnp.uint32) + rounding._u32(counter0)

    if counter_on == "new_last":
        fl_e, f_e = fl[..., None], f[..., None]
        counter = uses  # broadcasts against trailing axis
        idx = jnp.arange(x.size, dtype=jnp.uint32).reshape(x.shape)[..., None]
    else:
        fl_e, f_e = fl[None, ...], f[None, ...]
        counter = uses.reshape((L,) + (1,) * x.ndim)
        idx = jnp.arange(x.size, dtype=jnp.uint32).reshape(x.shape)[None, ...]

    if scheme == "deterministic":
        codes = jnp.broadcast_to(
            rounding.deterministic_round(scaled)[..., None]
            if counter_on == "new_last"
            else rounding.deterministic_round(scaled)[None, ...],
            fl_e.shape[:-1] + (L,) if counter_on == "new_last" else (L,) + x.shape,
        )
    elif scheme == "stochastic":
        u = rounding.hash_uniform(seed, idx, counter)
        codes = fl_e + (u < f_e).astype(jnp.float32)
    elif scheme == "dither":
        slot = rounding.lcg_slot(counter, idx, n_pulses, seed=seed)
        u = rounding.hash_uniform(rounding._u32(seed) ^ np.uint32(0xD1CE), idx, counter)
        codes = fl_e + rounding.dither_bit(f_e, slot, u, n_pulses)
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    return jnp.clip(codes, 0, spec.levels)


def quantized_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bits: int,
    scheme: Scheme = "dither",
    variant: Variant = "separate",
    seed: int = 0,
    lo: float = 0.0,
    hi: float = 1.0,
    counter=0,
    fmt: str = "spread",
    backend: str | None = None,
) -> jax.Array:
    """Compute A·B through a k-bit fixed-point multiplier (paper §VII–§VIII).

    Returns Ĉ in the real domain (rescaled back from the code grid).
    Entries of A and B are assumed in [lo, hi].

    The production ``separate`` variant executes on the kernel dispatcher
    backend selected by ``backend`` / $REPRO_KERNEL_BACKEND / platform
    detection; the research variants (``per_partial``, ``round_a_once``) are
    pure-XLA only.  The backend is resolved *outside* the jit cache so an
    environment override always takes effect.
    """
    if variant == "separate":
        # Dispatch directly: the backends jit themselves (nesting a second
        # jit here would only force a static seed and per-seed recompiles).
        return dispatch.matmul(
            a, b, bits=bits, scheme=scheme,
            counter=jnp.asarray(counter, jnp.int32), seed=seed,
            a_range=(lo, hi), b_range=(lo, hi), fmt=fmt, backend=backend)
    return _quantized_matmul_jit(
        a, b, jnp.asarray(counter, jnp.int32), jnp.asarray(seed, jnp.int32),
        bits=bits, scheme=scheme, variant=variant, lo=lo, hi=hi)


@functools.partial(
    jax.jit,
    static_argnames=("bits", "scheme", "variant", "lo", "hi"),
)
def _quantized_matmul_jit(
    a: jax.Array,
    b: jax.Array,
    counter: jax.Array,
    seed: jax.Array,
    *,
    bits: int,
    scheme: Scheme,
    variant: Variant,
    lo: float,
    hi: float,
) -> jax.Array:
    """The research variants (per_partial / round_a_once); seed and the
    global counter i_s are traced, so sweeping either never retraces."""
    p, q = a.shape
    q2, r = b.shape
    assert q == q2, (a.shape, b.shape)
    spec = QuantSpec(bits, lo, hi)

    if variant == "round_a_once":
        ca = quantize(a, spec, scheme, counter=counter, seed=seed,
                      n_pulses=max(r, 2), out_dtype=jnp.float32)
        # B_jk rounded per partial product: counter = output row i, N_B = p.
        cb = _codes_expanded(b, spec, scheme, p, "new_first", max(p, 2),
                             seed + 1, counter0=counter)
        cc = jnp.einsum("ij,ijk->ik", ca, cb)
    elif variant == "per_partial":
        # A_ij rounded per use: counter = output column k, N_A = r.
        ca = _codes_expanded(a, spec, scheme, r, "new_last", max(r, 2), seed,
                             counter0=counter)
        cb = _codes_expanded(b, spec, scheme, p, "new_first", max(p, 2),
                             seed + 1, counter0=counter)
        cc = jnp.einsum("ijk,ijk->ik", ca, cb)
    else:
        raise ValueError(f"unknown variant {variant!r}")

    # Map the code-grid product back to the real domain:
    # x ≈ lo + code/s  ⇒  C[i,k] = cc/s² + (lo/s)·(Σ_j ca + Σ_j cb) + q·lo².
    c = cc / (spec.scale * spec.scale)
    if lo != 0.0:
        if variant == "round_a_once":
            sum_a = ca.sum(axis=1)[:, None]  # (p,1)
            sum_b = cb.sum(axis=1)           # (p,r): Σ_j cb[i,j,k]
        else:  # per_partial
            sum_a = ca.sum(axis=1)           # (p,r): Σ_j ca[i,j,k]
            sum_b = cb.sum(axis=1)           # (p,r)
        c = c + lo * (sum_a + sum_b) / spec.scale + q * lo * lo
    return c


def matmul_error(a: jax.Array, b: jax.Array, c_hat: jax.Array) -> jax.Array:
    """Frobenius error e_f = ‖AB − Ĉ‖_F (the paper's §VII metric)."""
    return jnp.linalg.norm(a @ b - c_hat)
