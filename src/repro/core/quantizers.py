"""k-bit fixed-point quantisation (paper §VII).

The paper's quantiser: q(x) = round(x) clipped to [0, 2^k − 1]; real inputs in
[lo, hi] are affinely rescaled to the code range first, rounded with one of
the three schemes, and (for analysis / dequantised arithmetic) mapped back.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import rounding

__all__ = ["QuantSpec", "quantize", "dequantize", "quantize_dequantize"]


@dataclass(frozen=True)
class QuantSpec:
    """A k-bit affine quantiser over the real interval [lo, hi]."""

    bits: int
    lo: float = 0.0
    hi: float = 1.0

    @property
    def levels(self) -> int:
        return (1 << self.bits) - 1  # 2^k − 1 (top code)

    @property
    def scale(self) -> float:
        return self.levels / (self.hi - self.lo)


def _round(scaled: jax.Array, scheme: str, *, counter, seed, n_pulses: int) -> jax.Array:
    if scheme == "deterministic":
        return rounding.deterministic_round(scaled)
    if scheme == "stochastic":
        return rounding.stochastic_round(scaled, seed, counter)
    if scheme == "dither":
        return rounding.dither_round(scaled, counter, seed, n_pulses)
    raise ValueError(f"unknown rounding scheme {scheme!r}")


@functools.partial(
    jax.jit, static_argnames=("spec", "scheme", "n_pulses", "out_dtype")
)
def quantize(
    x: jax.Array,
    spec: QuantSpec,
    scheme: str = "deterministic",
    *,
    counter=0,
    seed: int = 0,
    n_pulses: int = 16,
    out_dtype=jnp.int32,
) -> jax.Array:
    """Real → integer codes in {0..2^k−1}, with under/overflow clipping."""
    scaled = (jnp.asarray(x, jnp.float32) - spec.lo) * spec.scale
    codes = _round(scaled, scheme, counter=counter, seed=seed, n_pulses=n_pulses)
    return jnp.clip(codes, 0, spec.levels).astype(out_dtype)


def dequantize(codes: jax.Array, spec: QuantSpec) -> jax.Array:
    return codes.astype(jnp.float32) / spec.scale + spec.lo


def quantize_dequantize(
    x: jax.Array,
    spec: QuantSpec,
    scheme: str = "deterministic",
    *,
    counter=0,
    seed: int = 0,
    n_pulses: int = 16,
) -> jax.Array:
    """The fake-quant round trip used for EMSE measurement and QAT."""
    return dequantize(
        quantize(x, spec, scheme, counter=counter, seed=seed, n_pulses=n_pulses), spec
    )
