"""Synthetic MNIST / Fashion-MNIST stand-ins (paper §VII–§VIII experiments).

Offline container → the real datasets are unavailable; we generate a
deterministic 10-class image problem with the same tensor interface:
28×28 grayscale in [0, 1].  Each class has a smooth random template;
samples are template + pixel noise, clipped to [0, 1].  This preserves
everything the paper's rounding experiments measure (relative accuracy of
deterministic vs stochastic vs dither rounding at k bits, variance across
trials) while being reproducible.  DESIGN.md §7 records the substitution.
"""

from __future__ import annotations

import numpy as np

__all__ = ["make_dataset", "IMG", "N_CLASSES"]

IMG = 28 * 28
N_CLASSES = 10


def _templates(rng: np.random.RandomState, sharp: float) -> np.ndarray:
    """Smooth per-class templates: low-frequency random fields in [0,1]."""
    t = []
    xs, ys = np.meshgrid(np.linspace(0, 1, 28), np.linspace(0, 1, 28))
    for _ in range(N_CLASSES):
        field = np.zeros((28, 28))
        for _ in range(6):
            fx, fy = rng.uniform(1, 4, 2)
            px, py = rng.uniform(0, 2 * np.pi, 2)
            field += rng.uniform(0.3, 1.0) * np.sin(2 * np.pi * fx * xs + px) * np.sin(
                2 * np.pi * fy * ys + py)
        field = (field - field.min()) / (np.ptp(field) + 1e-9)
        t.append(field.reshape(-1) * sharp)
    return np.stack(t)


def make_dataset(n_train: int = 6000, n_test: int = 1000, seed: int = 0,
                 noise: float = 0.15, sharp: float = 0.9, hard: bool = False):
    """→ (x_train, y_train, x_test, y_test); x in [0,1]^(N,784), y int in [0,10).

    ``hard=True`` lowers template separation (Fashion-MNIST-like difficulty).
    """
    rng = np.random.RandomState(seed)
    temps = _templates(rng, sharp * (0.6 if hard else 1.0))

    def sample(n, rs):
        y = rs.randint(0, N_CLASSES, n)
        x = temps[y] + rs.normal(0, noise * (1.5 if hard else 1.0), (n, IMG))
        return np.clip(x, 0.0, 1.0).astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = sample(n_train, np.random.RandomState(seed + 1))
    x_te, y_te = sample(n_test, np.random.RandomState(seed + 2))
    return x_tr, y_tr, x_te, y_te
