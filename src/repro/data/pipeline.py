"""Deterministic synthetic data pipeline (offline container → no downloads).

Token streams are a stateless hash of (seed, step, position): every host can
generate exactly its shard without coordination, restarts are reproducible
from the step counter alone (checkpoint stores only ``step``), and skew/
straggler behaviour is testable by construction.  The stream has real
next-token structure (a noisy Markov chain over the vocab) so losses move.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rounding import hash_uniform
from repro.models.config import ModelConfig

__all__ = ["DataConfig", "synthetic_batch", "data_iterator"]


@dataclass(frozen=True)
class DataConfig:
    batch: int
    seq: int
    seed: int = 0
    markov_order: int = 1


def _hash_tokens(seed: int, step: int, batch: int, seq: int, vocab: int) -> jax.Array:
    """Base stream: u = hash(seed, flat index, step) → token ids."""
    idx = jnp.arange(batch * seq, dtype=jnp.uint32).reshape(batch, seq)
    u = hash_uniform(seed, idx, step)
    return (u * vocab).astype(jnp.int32) % vocab


def synthetic_batch(cfg: ModelConfig, dcfg: DataConfig, step: int) -> Dict[str, jax.Array]:
    """One global batch.  Markov structure: token_{t+1} ≡ token_t + drift (mod V)
    with probability 0.75, else uniform — learnable but non-trivial."""
    vocab = cfg.vocab_size
    base = _hash_tokens(dcfg.seed, step, dcfg.batch, dcfg.seq, vocab)
    idx = jnp.arange(dcfg.batch * dcfg.seq, dtype=jnp.uint32).reshape(dcfg.batch, dcfg.seq)
    keep = hash_uniform(dcfg.seed ^ 0xBEEF, idx, step) < 0.75
    drift = (jnp.arange(dcfg.seq, dtype=jnp.int32) * 7919) % vocab
    markov = (base[:, :1] + drift[None, :]) % vocab
    tokens = jnp.where(keep, markov, base)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "vit_stub":
        f = jnp.arange(cfg.n_frontend_tokens * cfg.d_model, dtype=jnp.uint32)
        u = hash_uniform(dcfg.seed ^ 0xF00D, f, step).reshape(
            1, cfg.n_frontend_tokens, cfg.d_model)
        batch["embeds"] = jnp.broadcast_to(
            (u - 0.5).astype(jnp.bfloat16), (dcfg.batch, cfg.n_frontend_tokens, cfg.d_model))
    if cfg.is_encdec:
        f = jnp.arange(cfg.n_enc_tokens * cfg.d_model, dtype=jnp.uint32)
        u = hash_uniform(dcfg.seed ^ 0xFEED, f, step).reshape(
            1, cfg.n_enc_tokens, cfg.d_model)
        batch["frames"] = jnp.broadcast_to(
            (u - 0.5).astype(jnp.bfloat16), (dcfg.batch, cfg.n_enc_tokens, cfg.d_model))
    return batch


def data_iterator(cfg: ModelConfig, dcfg: DataConfig, start_step: int = 0) -> Iterator:
    step = start_step
    while True:
        yield synthetic_batch(cfg, dcfg, step)
        step += 1
