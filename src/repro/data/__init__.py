"""repro.data"""
