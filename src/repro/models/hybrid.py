"""RecurrentGemma-style RG-LRU recurrent block (arXiv:2402.19427).

Block = two branches: (linear → causal conv → RG-LRU) ⊙ (linear → GeLU),
then an output projection.  Gates are block-diagonal over heads (the paper's
structure); the linear recurrence h_t = a_t ⊙ h_{t-1} + √(1−a_t²)·(i_t ⊙ x_t)
runs as a log-depth ``associative_scan`` for train/prefill and an O(1) state
update for decode — sub-quadratic, so the hybrid arch serves ``long_500k``.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.numerics.policy import QuantPolicy, dense

Params = Dict[str, Any]

__all__ = ["init_rglru", "rglru_block", "rglru_decode_step", "init_rglru_state"]

_C = 8.0  # the RG-LRU temperature constant


def _init(key, shape, scale=None, dtype=jnp.bfloat16):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[-2])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _n_blocks(cfg: ModelConfig) -> int:
    return max(1, cfg.n_heads)


def init_rglru(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    lru = d  # lru_width = d_model (RG-9B)
    nb = _n_blocks(cfg)
    bd = lru // nb
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "in_x": _init(k1, (d, lru)),
        "in_gate": _init(k2, (d, lru)),
        "conv_w": _init(k3, (cfg.rglru_conv_width, lru), scale=0.5),
        "gate_a": _init(k4, (nb, bd, bd)),       # recurrence gate (block-diag)
        "gate_x": _init(k5, (nb, bd, bd)),       # input gate (block-diag)
        "lam": jnp.linspace(0.9, 0.999, lru).astype(jnp.float32),  # Λ init
        "out": _init(k6, (lru, d)),
    }


def init_rglru_state(cfg: ModelConfig, batch: int):
    lru = cfg.d_model
    return {
        "h": jnp.zeros((batch, lru), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1, lru), jnp.bfloat16),
    }


def _gates(params, xb, nb, bd):
    """Block-diagonal sigmoid gates.  xb: (..., lru) → r, i: (..., lru)."""
    lead = xb.shape[:-1]
    xg = xb.reshape(*lead, nb, bd).astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("...nb,nbc->...nc", xg, params["gate_a"].astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("...nb,nbc->...nc", xg, params["gate_x"].astype(jnp.float32)))
    return r.reshape(*lead, nb * bd), i.reshape(*lead, nb * bd)


def _conv(seq, w, carry=None):
    wlen = w.shape[0]
    if carry is None:
        pad = jnp.zeros((seq.shape[0], wlen - 1, seq.shape[2]), seq.dtype)
    else:
        pad = carry.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)
    return sum(full[:, i : i + seq.shape[1], :] * w[i][None, None, :] for i in range(wlen))


def rglru_block(
    params: Params,
    cfg: ModelConfig,
    u: jax.Array,
    policy: Optional[QuantPolicy] = None,
    counter=0,
) -> jax.Array:
    """Full-sequence RG-LRU block.  u: (B, L, d) → (B, L, d)."""
    nb = _n_blocks(cfg)
    lru = cfg.d_model
    bd = lru // nb
    x = dense(u, params["in_x"], policy, counter, seed=31)
    gate = dense(u, params["in_gate"], policy, counter, seed=32)
    x = _conv(x, params["conv_w"])

    r, i = _gates(params, x, nb, bd)
    log_a0 = jnp.log(jax.nn.sigmoid(params["lam"]))  # per-channel base decay (<0)
    log_a = _C * r * log_a0[None, None, :]           # (B,L,lru), ≤ 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (
        i * x.astype(jnp.float32)
    )

    # linear recurrence via associative scan over L: h_t = a_t h_{t-1} + b_t
    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = h.astype(u.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(u.dtype)
    return dense(y, params["out"], policy, counter, seed=33)


def rglru_decode_step(
    params: Params,
    cfg: ModelConfig,
    u: jax.Array,
    state: Params,
    policy: Optional[QuantPolicy] = None,
    counter=0,
):
    """Single-token decode.  u: (B, 1, d) → (B, 1, d), new state."""
    nb = _n_blocks(cfg)
    lru = cfg.d_model
    bd = lru // nb
    x = dense(u, params["in_x"], policy, counter, seed=31)
    gate = dense(u, params["in_gate"], policy, counter, seed=32)
    conv_out = _conv(x, params["conv_w"], carry=state["conv"])
    new_conv = jnp.concatenate([state["conv"], x.astype(state["conv"].dtype)], axis=1)[:, 1:]
    xc = conv_out[:, 0]

    r, i = _gates(params, xc, nb, bd)
    log_a0 = jnp.log(jax.nn.sigmoid(params["lam"]))
    log_a = _C * r * log_a0[None, :]
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (
        i * xc.astype(jnp.float32)
    )
    h = state["h"] * a + b
    y = h[:, None, :].astype(u.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(u.dtype)
    out = dense(y, params["out"], policy, counter, seed=33)
    return out, {"h": h, "conv": new_conv}
