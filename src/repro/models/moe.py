"""Mixture-of-Experts FFN (granite-3-moe, qwen2-moe) with expert parallelism.

Dispatch is scatter-based (sort-free grouped matmul): top-k routing → per-
expert capacity slots computed with a cumulative-count trick → scatter-add
into an (E, C, d) buffer → batched expert matmuls (shardable on the expert
axis = EP on the 'model' mesh axis) → gather-combine.  No (T, E, C) one-hot
einsum (that dispatch costs more FLOPs than the experts themselves at scale)
and no data-dependent shapes (capacity C is static; overflow tokens drop,
standard Switch-style).

qwen2-moe additionally has shared experts (always-on SwiGLU of width
``shared_d_ff``) added to the routed output.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.dist import ctx
from repro.models.config import ModelConfig
from repro.numerics.policy import QuantPolicy, fake_quant

Params = Dict[str, Any]

__all__ = ["init_moe", "moe_ffn", "moe_capacity"]


def _init(key, shape, scale=None, dtype=jnp.bfloat16):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[-2])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def moe_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(
        math.ceil(n_tokens * cfg.n_experts_active * cfg.capacity_factor / cfg.n_experts)
    )
    return max(cap, 1)


def init_moe(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    p = {
        "router": _init(kr, (d, e), scale=0.02, dtype=jnp.float32),
        "wg": _init(kg, (e, d, f)),
        "wu": _init(ku, (e, d, f)),
        "wd": _init(kd, (e, f, d)),
    }
    if cfg.shared_d_ff:
        k1, k2, k3 = jax.random.split(ks, 3)
        p["shared"] = {
            "wg": _init(k1, (d, cfg.shared_d_ff)),
            "wu": _init(k2, (d, cfg.shared_d_ff)),
            "wd": _init(k3, (cfg.shared_d_ff, d)),
        }
    return p


def moe_ffn(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,
    policy: Optional[QuantPolicy] = None,
    counter=0,
) -> jax.Array:
    """x: (B, S, d) → (B, S, d).  Static capacity, drop on overflow.

    Data-parallel-local dispatch (DESIGN.md §5): capacity is
    allocated PER data shard and the scatter/gather run as a vmap over the
    shard axis, so GSPMD keeps dispatch local to each DP rank instead of
    all-reducing a global (e·cap, d) buffer every layer (the baseline's 299 s
    collective term on qwen2-moe).  Cross-device traffic is then only the
    expert einsums' TP/EP collectives — the intrinsic MoE cost.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.n_experts_active
    dp = ctx.dp_shards()
    if t % dp:
        dp = 1
    tl = t // dp                        # tokens per data shard
    cap = moe_capacity(tl, cfg)
    xf = x.reshape(t, d)

    # --- routing (always fp32: small and accuracy-critical; DESIGN.md §5) ---
    logits = jnp.matmul(xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)              # (t, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # --- per-shard capacity ranks -------------------------------------------
    expert = idx.reshape(dp, tl * k)
    oh = jax.nn.one_hot(expert, e, dtype=jnp.int32)              # (dp, tl·k, e)
    ranks = jnp.cumsum(oh, axis=1) - 1
    pos = jnp.sum(ranks * oh, axis=-1)                           # (dp, tl·k)
    valid = pos < cap
    pos = jnp.where(valid, pos, 0)

    # --- dispatch: batched scatter, one (e, cap, d) buffer per shard --------
    token_ids = jnp.repeat(jnp.arange(tl), k)
    xs = xf.reshape(dp, tl, d)
    upd = jnp.take(xs, token_ids, axis=1) * valid[..., None].astype(x.dtype)
    upd = ctx.constrain(upd, ctx.dp_axes(), None, None)

    def scatter_one(ei, pi, up):
        return jnp.zeros((e, cap, d), x.dtype).at[ei, pi].add(up, mode="drop")

    buf = jax.vmap(scatter_one)(expert, pos, upd)                # (dp, e, cap, d)
    buf = ctx.constrain(buf, ctx.dp_axes(), None, None, None)

    # --- expert SwiGLU, true EP: pad experts to the TP axis so the expert
    # dim shards on 'model' even when tp ∤ e (qwen2-moe: 60 → 64, 6% padded
    # compute).  Slicing the DP-replicated buffer onto expert shards is
    # free; all three expert einsums then run fully local per EP rank and
    # only the combine gather crosses the axis (DESIGN.md §5).
    tp = ctx.tp_size()
    e_pad = ((e + tp - 1) // tp) * tp if tp > 1 else e

    def pad_e(w):
        if e_pad == e:
            return w
        w = jnp.pad(w, ((0, e_pad - e),) + ((0, 0),) * (w.ndim - 1))
        return ctx.constrain(w, "model", None, None)

    wg = pad_e(fake_quant(params["wg"], policy, counter, seed=11))
    wu = pad_e(fake_quant(params["wu"], policy, counter, seed=12))
    wd = pad_e(fake_quant(params["wd"], policy, counter, seed=13))
    if e_pad != e:
        pad_buf = jnp.zeros((dp, e_pad - e, cap, d), x.dtype)
        buf = jnp.concatenate([buf, pad_buf], axis=1)
    buf = ctx.constrain(buf, ctx.dp_axes(), "model", None, None)
    bufq = fake_quant(buf, policy, counter, seed=14)
    g = jnp.einsum("secd,edf->secf", bufq, wg)
    u = jnp.einsum("secd,edf->secf", bufq, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    hq = fake_quant(h, policy, counter, seed=15)
    y = jnp.einsum("secf,efd->secd", hq, wd)                 # (dp, e_pad, cap, d)
    y = y[:, :e] if e_pad != e else y

    # --- combine: batched gather back to tokens -----------------------------
    def gather_one(ys, ei, pi):
        return ys[ei, pi]

    y_assign = jax.vmap(gather_one)(y, expert, pos)              # (dp, tl·k, d)
    w_assign = (gate.reshape(dp, tl * k) * valid)[..., None].astype(x.dtype)
    out = jnp.sum((y_assign * w_assign).reshape(t, k, d), axis=1)

    if "shared" in params:
        from repro.models.layers import mlp  # late import (cycle)
        out = out + mlp(params["shared"], xf, "swiglu", policy, counter)
    return out.reshape(b, s, d)
