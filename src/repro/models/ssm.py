"""Mamba-2 (SSD, state-space duality — arXiv:2405.21060) block in JAX.

Chunked SSD algorithm: the sequence is split into chunks of length Q; within
a chunk the output is a masked quadratic form (MXU-friendly), across chunks a
small recurrent state (H, hd, N) is propagated with per-chunk decay — a
lax.scan over nc chunks, so prefill is O(L·Q) not O(L²), and single-token
decode is a pure state update (O(1) per token) — this is what makes the
``long_500k`` shape runnable (DESIGN.md §5).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.numerics.policy import QuantPolicy, dense

Params = Dict[str, Any]

__all__ = ["init_ssm", "ssm_block", "ssm_decode_step", "init_ssm_state"]


def _init(key, shape, scale=None, dtype=jnp.bfloat16):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_head_dim, cfg.ssm_state


def init_ssm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_in, nh, hd, n = _dims(cfg)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    # fused input projection → [x, z, B, C, dt]
    proj_out = 2 * d_in + 2 * n + nh
    return {
        "in_proj": _init(k1, (d, proj_out)),
        "conv_w": _init(k2, (cfg.ssm_conv_width, d_in + 2 * n), scale=0.5),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": jnp.ones((d_in,), jnp.bfloat16),
        "out_proj": _init(k4, (d_in, d)),
    }


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_in, nh, hd, n = _dims(cfg)
    return {
        "h": jnp.zeros((batch, nh, hd, n), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, d_in + 2 * n), jnp.bfloat16),
    }


def _split_proj(cfg, proj):
    d_in, nh, hd, n = _dims(cfg)
    xz, rest = proj[..., : 2 * d_in], proj[..., 2 * d_in :]
    x, z = xz[..., :d_in], xz[..., d_in:]
    bmat, cmat, dt = rest[..., :n], rest[..., n : 2 * n], rest[..., 2 * n :]
    return x, z, bmat, cmat, dt


def _causal_conv(seq: jax.Array, w: jax.Array, carry: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  seq: (B, L, C), w: (W, C)."""
    wlen = w.shape[0]
    if carry is None:
        pad = jnp.zeros((seq.shape[0], wlen - 1, seq.shape[2]), seq.dtype)
    else:
        pad = carry.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)
    out = sum(
        full[:, i : i + seq.shape[1], :] * w[i][None, None, :] for i in range(wlen)
    )
    new_carry = full[:, -(wlen - 1) :, :] if wlen > 1 else None
    return jax.nn.silu(out.astype(jnp.float32)).astype(seq.dtype), new_carry


def ssm_block(
    params: Params,
    cfg: ModelConfig,
    u: jax.Array,
    policy: Optional[QuantPolicy] = None,
    counter=0,
) -> jax.Array:
    """Full-sequence SSD (training / prefill).  u: (B, L, d_model)."""
    b, l, _ = u.shape
    d_in, nh, hd, n = _dims(cfg)
    q = min(cfg.ssm_chunk, l)
    # pad L to a multiple of the chunk
    pad = (-l) % q
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
    lp = u.shape[1]
    nc = lp // q

    proj = dense(u, params["in_proj"], policy, counter, seed=21)
    x, z, bmat, cmat, dt = _split_proj(cfg, proj)
    xbc = jnp.concatenate([x, bmat, cmat], axis=-1)
    xbc, _ = _causal_conv(xbc, params["conv_w"])
    x, bmat, cmat = xbc[..., :d_in], xbc[..., d_in : d_in + n], xbc[..., d_in + n :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])      # (B,L,H)
    a = -jnp.exp(params["a_log"])                                          # (H,)
    da = dt * a                                                            # (B,L,H) ≤ 0

    xh = x.reshape(b, lp, nh, hd)
    # chunk
    xc = xh.reshape(b, nc, q, nh, hd)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)
    dac = da.reshape(b, nc, q, nh)
    dtc = dt.reshape(b, nc, q, nh)

    # intra-chunk cumulative decay
    seg = jnp.cumsum(dac, axis=2)                                          # (B,nc,q,H)
    # L matrix: exp(seg_i - seg_j) masked to i ≥ j.  Valid entries have
    # diff ≤ 0 (seg is non-increasing); clamp BEFORE exp so masked +diff
    # entries never produce inf (0·inf → NaN in the backward pass).
    diff = seg[:, :, :, None, :] - seg[:, :, None, :, :]                   # (B,nc,q,q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    lmat = jnp.where(mask, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)

    # diagonal (intra-chunk) term: Y_d = (L ∘ (C Bᵀ)) · (dt x)
    cb = jnp.einsum("bcqn,bckn->bcqk", cc.astype(jnp.float32), bc.astype(jnp.float32))
    att = cb[..., None] * lmat                                             # (B,nc,q,q,H)
    dtx = dtc[..., None] * xc.astype(jnp.float32)                          # (B,nc,q,H,hd)
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", att, dtx)

    # chunk summary states: S_c = Σ_k exp(seg_last - seg_k) B_k (dt x)_k
    decay_tail = jnp.exp(seg[:, :, -1:, :] - seg)                          # (B,nc,q,H)
    s_chunk = jnp.einsum("bckn,bckh,bckhp->bchpn", bc.astype(jnp.float32),
                         decay_tail, dtx)

    # inter-chunk recurrence: H_{c} = exp(seg_last_c) H_{c-1} + S_c
    chunk_decay = jnp.exp(seg[:, :, -1, :])                                # (B,nc,H)

    def scan_fn(h, inp):
        s_c, dec = inp
        h_new = h * dec[:, :, None, None] + s_c
        return h_new, h

    h0 = jnp.zeros((b, nh, hd, n), jnp.float32)
    _, h_prev = jax.lax.scan(
        scan_fn,
        h0,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)                                    # (B,nc,H,hd,N)

    # off-diagonal term: contribution of previous-chunk state
    decay_in = jnp.exp(seg)                                                # (B,nc,q,H)
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cc.astype(jnp.float32),
                       decay_in, h_prev)

    y = (y_diag + y_off).reshape(b, lp, nh, hd)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, lp, d_in).astype(u.dtype)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(u.dtype) * params["norm"]
    out = dense(y, params["out_proj"], policy, counter, seed=22)
    return out[:, :l]


def ssm_decode_step(
    params: Params,
    cfg: ModelConfig,
    u: jax.Array,
    state: Params,
    policy: Optional[QuantPolicy] = None,
    counter=0,
):
    """Single-token decode.  u: (B, 1, d_model) → (B, 1, d_model), new state."""
    b = u.shape[0]
    d_in, nh, hd, n = _dims(cfg)
    proj = dense(u, params["in_proj"], policy, counter, seed=21)
    x, z, bmat, cmat, dt = _split_proj(cfg, proj)
    xbc = jnp.concatenate([x, bmat, cmat], axis=-1)                        # (B,1,·)
    xbc_out, _ = _causal_conv(xbc, params["conv_w"], carry=state["conv"])
    new_conv = jnp.concatenate([state["conv"], xbc.astype(state["conv"].dtype)], axis=1)[:, 1:]
    x, bmat, cmat = (xbc_out[..., :d_in], xbc_out[..., d_in : d_in + n],
                     xbc_out[..., d_in + n :])

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])
    dec = jnp.exp(dt * a)                                                   # (B,H)
    xh = x[:, 0].reshape(b, nh, hd).astype(jnp.float32)
    dtx = dt[:, :, None] * xh                                               # (B,H,hd)
    h = state["h"] * dec[:, :, None, None] + jnp.einsum(
        "bn,bhp->bhpn", bmat[:, 0].astype(jnp.float32), dtx
    )
    y = jnp.einsum("bhpn,bn->bhp", h, cmat[:, 0].astype(jnp.float32))
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(b, 1, d_in).astype(u.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(u.dtype) * params["norm"]
    out = dense(y, params["out_proj"], policy, counter, seed=22)
    return out, {"h": h, "conv": new_conv}
