"""Decoder-only model assembly for every assigned architecture family.

Layers are *stacked per pattern position and scanned* (MaxText-style
scan-over-layers): for a block pattern of period P and R repeats, parameters
live as P pytrees whose leaves carry a leading (R, ...) axis, and the forward
pass is one ``lax.scan`` over R — this keeps HLO size and compile time
independent of depth (essential for the 512-device dry-run) and gives
per-repeat remat for free.  ``n_layers % P`` remainder layers are unrolled.

Decode uses a unified ring-buffer KV cache: capacity C = window (local
attention) or max_len (full attention), with an absolute-position array
``k_pos`` driving the mask — one code path for full, sliding-window, SSM and
RG-LRU layers (the latter two carry O(1) recurrent states instead).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import hybrid, layers, moe, ssm
from repro.models.config import ModelConfig
from repro.numerics.policy import QuantPolicy, dense

Params = Dict[str, Any]

__all__ = [
    "init_params", "forward", "decode_step", "init_cache", "prefill",
]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _period(cfg: ModelConfig) -> int:
    return len(cfg.block_pattern) if cfg.block_pattern else 1


def _init_block(key, cfg: ModelConfig, kind: str) -> Params:
    d = cfg.d_model
    keys = jax.random.split(key, 4)
    p: Params = {"ln1": jnp.ones((d,), jnp.bfloat16)}
    if kind == "attn":
        p["attn"] = layers.init_attention(keys[0], cfg)
    elif kind == "rglru":
        p["rec"] = hybrid.init_rglru(keys[0], cfg)
    elif kind == "ssm":
        p["ssm"] = ssm.init_ssm(keys[0], cfg)
    else:
        raise ValueError(kind)
    if kind != "ssm":  # mamba2 blocks are norm→SSD only
        p["ln2"] = jnp.ones((d,), jnp.bfloat16)
        if cfg.n_experts:
            p["moe"] = moe.init_moe(keys[1], cfg)
        else:
            p["mlp"] = layers.init_mlp(keys[1], d, cfg.d_ff, cfg.mlp_act)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    p_ = _period(cfg)
    rep, rem = divmod(cfg.n_layers, p_)
    k_embed, k_head, k_blocks, k_rem = jax.random.split(key, 4)

    blocks = []
    if rep:
        for pos in range(p_):
            kind = cfg.layer_kind(pos)
            inits = [
                _init_block(jax.random.fold_in(k_blocks, pos * 1000 + r), cfg, kind)
                for r in range(rep)
            ]
            blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *inits))
    remainder = [
        _init_block(jax.random.fold_in(k_rem, i), cfg, cfg.layer_kind(rep * p_ + i))
        for i in range(rem)
    ]

    vp = cfg.vocab_padded()
    params: Params = {
        "embed": layers.init_embedding(k_embed, vp, cfg.d_model),
        "blocks": blocks,
        "remainder": remainder,
        "final_norm": jnp.ones((cfg.d_model,), jnp.bfloat16),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers._init(k_head, (cfg.d_model, vp), scale=0.02)
    return params


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


def _cache_entry(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                 kv_quant: bool = False):
    if kind == "attn":
        cap = min(cfg.window, max_len) if cfg.window else max_len
        if kv_quant:
            # Dither-quantised int8 cache (§Perf it.10 — the paper's
            # unbiased rounding applied to KV compression): codes + one
            # per-position, per-head scale; written with counter = pos, so
            # re-decodes of the same slot over time average out (§VII).
            return {
                "k": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.hd()), jnp.int8),
                "v": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.hd()), jnp.int8),
                "k_scale": jnp.zeros((batch, cap, cfg.n_kv_heads), jnp.float32),
                "v_scale": jnp.zeros((batch, cap, cfg.n_kv_heads), jnp.float32),
                "k_pos": jnp.full((cap,), -1, jnp.int32),
            }
        return {
            "k": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.hd()), jnp.bfloat16),
            "v": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.hd()), jnp.bfloat16),
            "k_pos": jnp.full((cap,), -1, jnp.int32),
        }
    if kind == "rglru":
        return hybrid.init_rglru_state(cfg, batch)
    if kind == "ssm":
        return ssm.init_ssm_state(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               kv_quant: bool = False) -> Params:
    p_ = _period(cfg)
    rep, rem = divmod(cfg.n_layers, p_)
    stacked = []
    if rep:
        for pos in range(p_):
            kind = cfg.layer_kind(pos)
            one = _cache_entry(cfg, kind, batch, max_len, kv_quant)
            stacked.append(
                jax.tree.map(lambda x: jnp.broadcast_to(x, (rep,) + x.shape), one)
            )
    remainder = [
        _cache_entry(cfg, cfg.layer_kind(rep * p_ + i), batch, max_len, kv_quant)
        for i in range(rem)
    ]
    return {"pos": jnp.zeros((), jnp.int32), "layers": stacked, "remainder": remainder}


# ---------------------------------------------------------------------------
# decode attention over the ring cache
# ---------------------------------------------------------------------------


def _attention_decode(params, cfg: ModelConfig, x, cache, pos, policy, counter):
    """One-token attention against the ring cache.  x: (B, 1, d)."""
    b = x.shape[0]
    hd, nh, nkv = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    cap = cache["k"].shape[1]

    q = dense(x, params["wq"], policy, counter, seed=1).reshape(b, 1, nh, hd)
    k = dense(x, params["wk"], policy, counter, seed=2).reshape(b, 1, nkv, hd)
    v = dense(x, params["wv"], policy, counter, seed=3).reshape(b, 1, nkv, hd)
    if cfg.qkv_bias and "bq" in params:
        q = q + params["bq"].reshape(1, 1, nh, hd)
        k = k + params["bk"].reshape(1, 1, nkv, hd)
        v = v + params["bv"].reshape(1, 1, nkv, hd)
    posv = jnp.full((b, 1), pos)
    q = layers.rope(q, posv, cfg.rope_theta)
    k = layers.rope(k, posv, cfg.rope_theta)

    slot = jnp.mod(pos, cap)
    quantized = cache["k"].dtype == jnp.int8
    if quantized:
        # dither-round the new K/V token into int8 codes (counter = pos)
        from repro.core import rounding as _rnd

        def q8(t, seed):
            scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) + 1e-6
            scaled = t.astype(jnp.float32) / scale[..., None] * 127.0 + 128.0
            idx = jnp.arange(t.size, dtype=jnp.uint32).reshape(t.shape)
            slot_d = _rnd.lcg_slot(pos, idx, 16, seed=seed)
            u = _rnd.hash_uniform(seed ^ 0xD1CE, idx, pos)
            codes = jnp.floor(scaled) + _rnd.dither_bit(
                scaled - jnp.floor(scaled), slot_d, u, 16)
            return (jnp.clip(codes, 0.0, 255.0) - 128.0).astype(jnp.int8), scale

        kq, ks = q8(k, 101)
        vq, vs = q8(v, 102)
        ck = jax.lax.dynamic_update_slice(cache["k"], kq, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], vq, (0, slot, 0, 0))
        kss = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, slot, 0))
        vss = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, slot, 0))
        k_pos = jax.lax.dynamic_update_slice(
            cache["k_pos"], pos[None].astype(jnp.int32), (slot,))
        new_cache = {"k": ck, "v": cv, "k_scale": kss, "v_scale": vss,
                     "k_pos": k_pos}
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        k_pos = jax.lax.dynamic_update_slice(cache["k_pos"], pos[None].astype(jnp.int32), (slot,))
        new_cache = {"k": ck, "v": cv, "k_pos": k_pos}

    valid = (k_pos >= 0) & (k_pos <= pos)
    if cfg.window:
        valid = valid & (k_pos > pos - cfg.window)

    # grouped GQA decode: read the cache once, no repeated-KV materialisation
    group = nh // nkv
    qg = q.reshape(b, 1, nkv, group, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                        ck.astype(x.dtype)).astype(jnp.float32) / math.sqrt(hd)
    if quantized:
        # fold per-position/per-head key scales in after the int8 dot
        logits = logits * (new_cache["k_scale"] / 127.0).transpose(0, 2, 1)[:, :, None, None, :]
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    if quantized:
        # per-position value scales attach to the probabilities
        pv = probs * (new_cache["v_scale"] / 127.0).transpose(0, 2, 1)[:, :, None, None, :].astype(probs.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", pv, cv.astype(x.dtype)).reshape(b, 1, nh * hd)
    else:
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, cv).reshape(b, 1, nh * hd)
    return dense(out, params["wo"], policy, counter, seed=4), new_cache


# ---------------------------------------------------------------------------
# block application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------


def _apply_block(
    bp: Params,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    positions,
    *,
    policy,
    counter,
    cache_entry=None,
    pos=None,
    window_override=None,
):
    h = layers.rms_norm(x, bp["ln1"], cfg.norm_eps)
    new_cache = cache_entry
    if kind == "attn":
        window = cfg.window if window_override is None else window_override
        if cache_entry is not None:
            out, new_cache = _attention_decode(bp["attn"], cfg, h, cache_entry, pos, policy, counter)
        else:
            out, _ = layers.attention(
                bp["attn"], cfg, h, positions, causal=True, window=window,
                policy=policy, counter=counter,
            )
    elif kind == "rglru":
        if cache_entry is not None:
            out, new_cache = hybrid.rglru_decode_step(bp["rec"], cfg, h, cache_entry, policy, counter)
        else:
            out = hybrid.rglru_block(bp["rec"], cfg, h, policy, counter)
    elif kind == "ssm":
        if cache_entry is not None:
            out, new_cache = ssm.ssm_decode_step(bp["ssm"], cfg, h, cache_entry, policy, counter)
        else:
            out = ssm.ssm_block(bp["ssm"], cfg, h, policy, counter)
    else:
        raise ValueError(kind)
    x = x + out

    if "mlp" in bp or "moe" in bp:
        h2 = layers.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if "moe" in bp:
            x = x + moe.moe_ffn(bp["moe"], cfg, h2, policy, counter)
        else:
            x = x + layers.mlp(bp["mlp"], h2, cfg.mlp_act, policy, counter)
    return x, new_cache


# ---------------------------------------------------------------------------
# forward (train / prefill) and decode
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg, tokens, embeds):
    x = jnp.take(params["embed"], tokens, axis=0)
    if embeds is not None:  # multimodal stub frontend: prepend patch/frame embeds
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return x


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    embeds: Optional[jax.Array] = None,
    policy: Optional[QuantPolicy] = None,
    counter=0,
    remat: bool = True,
) -> jax.Array:
    """Full-sequence forward → logits (B, S_total, vocab)."""
    x = _embed_inputs(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    p_ = _period(cfg)

    def body(carry, xs):
        h = carry
        for pos_i in range(p_):
            kind = cfg.layer_kind(pos_i)
            h, _ = _apply_block(
                xs[pos_i], cfg, kind, h, positions, policy=policy, counter=counter,
            )
        return h, None

    body_fn = jax.checkpoint(body) if remat else body
    if params["blocks"]:
        x, _ = jax.lax.scan(body_fn, x, tuple(params["blocks"]))
    rep = cfg.n_layers // p_
    for i, bp in enumerate(params["remainder"]):
        kind = cfg.layer_kind(rep * p_ + i)
        x, _ = _apply_block(bp, cfg, kind, x, positions, policy=policy, counter=counter)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return dense(x, head, policy, counter, seed=9).astype(jnp.float32)


def prefill(params, cfg, tokens, *, embeds=None, policy=None, counter=0):
    """Prefill forward (no cache materialisation — dry-run measures compute).

    Production serving would also emit the cache; for the benchmark shapes
    prefill cost is the forward pass itself.
    """
    return forward(params, cfg, tokens, embeds=embeds, policy=policy,
                   counter=counter, remat=False)


def decode_step(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,   # (B,) int32 — the most recent token
    cache: Params,
    *,
    policy: Optional[QuantPolicy] = None,
    counter=0,
):
    """One decode step: (B,) token + cache → (B, vocab) logits, new cache."""
    pos = cache["pos"]
    x = jnp.take(params["embed"], token[:, None], axis=0)
    b = x.shape[0]
    positions = jnp.full((b, 1), pos)
    p_ = _period(cfg)

    def body(carry, xs):
        h = carry
        bp, ce = xs
        new_entries = []
        for pos_i in range(p_):
            kind = cfg.layer_kind(pos_i)
            h, ne = _apply_block(
                bp[pos_i], cfg, kind, h, positions, policy=policy,
                counter=counter, cache_entry=ce[pos_i], pos=pos,
            )
            new_entries.append(ne)
        return h, tuple(new_entries)

    if params["blocks"]:
        x, new_layer_caches = jax.lax.scan(
            body, x, (tuple(params["blocks"]), tuple(cache["layers"]))
        )
    else:
        new_layer_caches = ()
    rep = cfg.n_layers // p_
    new_rem = []
    for i, bp in enumerate(params["remainder"]):
        kind = cfg.layer_kind(rep * p_ + i)
        x, ne = _apply_block(
            bp, cfg, kind, x, positions, policy=policy, counter=counter,
            cache_entry=cache["remainder"][i], pos=pos,
        )
        new_rem.append(ne)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = dense(x, head, policy, counter, seed=9)[:, 0].astype(jnp.float32)
    logits = logits[:, : cfg.vocab_size]  # drop vocab padding for sampling
    new_cache = {
        "pos": pos + 1,
        "layers": list(new_layer_caches),
        "remainder": new_rem,
    }
    return logits, new_cache
