"""Decoder-only model assembly for every assigned architecture family.

Layers are *stacked per pattern position and scanned* (MaxText-style
scan-over-layers): for a block pattern of period P and R repeats, parameters
live as P pytrees whose leaves carry a leading (R, ...) axis, and the forward
pass is one ``lax.scan`` over R — this keeps HLO size and compile time
independent of depth (essential for the 512-device dry-run) and gives
per-repeat remat for free.  ``n_layers % P`` remainder layers are unrolled.

Decode uses a unified ring-buffer KV cache: capacity C = window (local
attention) or max_len (full attention), with *per-slot* absolute positions
(``cache["pos"]`` (B,), ``k_pos`` (B, C)) driving the mask — slots admitted
at different times decode independently, which is what the serving engine's
continuous batching needs (DESIGN.md §6).  One code path covers full,
sliding-window, SSM and RG-LRU layers (the latter two carry O(1) recurrent
states instead).  ``prefill_with_cache`` materialises the same cache from a
single batched forward.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.dist import ctx as dist_ctx
from repro.models import hybrid, layers, moe, ssm
from repro.models.config import ModelConfig
from repro.numerics.policy import QuantPolicy, dense

Params = Dict[str, Any]

__all__ = [
    "init_params", "forward", "decode_step", "init_cache", "prefill",
    "prefill_with_cache", "prefill_with_cache_chunked",
    "prefill_with_cache_paged", "merge_cache", "verify_step", "spec_commit",
]


def _kv_q8(t, ctr, idx, seed):
    """Dither-round K/V to int8 codes + per-position scales (DESIGN.md §2/§6).

    One quantiser for every cache write path — decode step, ring prefill
    scatter and paged prefill scatter — so the codes a position holds are a
    function of (value, absolute position + per-request offset, element
    index) only, never of *which* path wrote them.  That invariance is what
    makes paged prefix blocks bit-reusable across requests (DESIGN.md §6):
    dither codes are deterministic in absolute position (the Θ(1/N²)
    construction), where stochastic rounding would need hidden RNG state.
    ``ctr`` and ``idx`` broadcast against ``t``; callers pass the absolute
    position (+ offset) as ``ctr`` and the decode-step element index
    pattern as ``idx``.
    """
    from repro.core import rounding as _rnd

    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) + 1e-6
    scaled = t.astype(jnp.float32) / scale[..., None] * 127.0 + 128.0
    slot_d = _rnd.lcg_slot(ctr, idx, 16, seed=seed)
    u = _rnd.hash_uniform(seed ^ 0xD1CE, idx, ctr)
    codes = jnp.floor(scaled) + _rnd.dither_bit(
        scaled - jnp.floor(scaled), slot_d, u, 16)
    return (jnp.clip(codes, 0.0, 255.0) - 128.0).astype(jnp.int8), scale


def _kv_elem_idx(nkv: int, hd: int) -> jax.Array:
    """The (1, 1, nkv, hd) element-index pattern every KV-quantiser call
    site hashes with: global index head·hd + lane, broadcasting over batch
    rows and sequence positions.

    Deliberately *independent of the batch row*: a position's int8 codes
    must be a pure function of (value, absolute position + request offset,
    head, lane) — the bit-reusability contract behind paged prefix sharing
    (a shared block must not remember which slot wrote it, DESIGN.md §6)
    and behind sharded serving (continuous-batching slot placement shifts
    when slots partition across data shards, and the stream must not shift
    with it, DESIGN.md §9).  Distinct requests decorrelate through the
    counter term instead (position + per-request ``counter_offset``).
    Under tensor-parallel head sharding the model sees local heads; the
    shard's global head offset comes from ``dist.ctx.serve_shard_scope``.
    """
    info = dist_ctx.kv_shard_info()
    head0 = (info["head0"] if info is not None and info["heads_sharded"]
             else 0)
    head = jnp.asarray(head0, jnp.uint32) + jnp.arange(nkv, dtype=jnp.uint32)
    lane = jnp.arange(hd, dtype=jnp.uint32)
    return (head[:, None] * jnp.uint32(hd)
            + lane[None, :]).reshape(1, 1, nkv, hd)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _period(cfg: ModelConfig) -> int:
    return len(cfg.block_pattern) if cfg.block_pattern else 1


def _init_block(key, cfg: ModelConfig, kind: str) -> Params:
    d = cfg.d_model
    keys = jax.random.split(key, 4)
    p: Params = {"ln1": jnp.ones((d,), jnp.bfloat16)}
    if kind == "attn":
        p["attn"] = layers.init_attention(keys[0], cfg)
    elif kind == "rglru":
        p["rec"] = hybrid.init_rglru(keys[0], cfg)
    elif kind == "ssm":
        p["ssm"] = ssm.init_ssm(keys[0], cfg)
    else:
        raise ValueError(kind)
    if kind != "ssm":  # mamba2 blocks are norm→SSD only
        p["ln2"] = jnp.ones((d,), jnp.bfloat16)
        if cfg.n_experts:
            p["moe"] = moe.init_moe(keys[1], cfg)
        else:
            p["mlp"] = layers.init_mlp(keys[1], d, cfg.d_ff, cfg.mlp_act)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    p_ = _period(cfg)
    rep, rem = divmod(cfg.n_layers, p_)
    k_embed, k_head, k_blocks, k_rem = jax.random.split(key, 4)

    blocks = []
    if rep:
        for pos in range(p_):
            kind = cfg.layer_kind(pos)
            inits = [
                _init_block(jax.random.fold_in(k_blocks, pos * 1000 + r), cfg, kind)
                for r in range(rep)
            ]
            blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *inits))
    remainder = [
        _init_block(jax.random.fold_in(k_rem, i), cfg, cfg.layer_kind(rep * p_ + i))
        for i in range(rem)
    ]

    vp = cfg.vocab_padded()
    params: Params = {
        "embed": layers.init_embedding(k_embed, vp, cfg.d_model),
        "blocks": blocks,
        "remainder": remainder,
        "final_norm": jnp.ones((cfg.d_model,), jnp.bfloat16),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers._init(k_head, (cfg.d_model, vp), scale=0.02)
    return params


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


def _cache_entry(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                 kv_quant: bool = False):
    if kind == "attn":
        cap = min(cfg.window, max_len) if cfg.window else max_len
        if kv_quant:
            # Dither-quantised int8 cache (DESIGN.md §6 — the paper's
            # unbiased rounding applied to KV compression): codes + one
            # per-position, per-head scale; written with counter = pos (plus
            # an optional per-request offset, DESIGN.md §6), so re-decodes
            # of the same slot over time average out (§VII).
            return {
                "k": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.hd()), jnp.int8),
                "v": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.hd()), jnp.int8),
                "k_scale": jnp.zeros((batch, cap, cfg.n_kv_heads), jnp.float32),
                "v_scale": jnp.zeros((batch, cap, cfg.n_kv_heads), jnp.float32),
                "k_pos": jnp.full((batch, cap), -1, jnp.int32),
            }
        return {
            "k": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.hd()), jnp.bfloat16),
            "v": jnp.zeros((batch, cap, cfg.n_kv_heads, cfg.hd()), jnp.bfloat16),
            "k_pos": jnp.full((batch, cap), -1, jnp.int32),
        }
    if kind == "rglru":
        return hybrid.init_rglru_state(cfg, batch)
    if kind == "ssm":
        return ssm.init_ssm_state(cfg, batch)
    raise ValueError(kind)


def _paged_cache_entry(cfg: ModelConfig, kind: str, num_blocks: int,
                       block_size: int, kv_quant: bool,
                       data_shards: int = 1):
    """One attention layer's share of the paged block pool (DESIGN.md §6):
    ``num_blocks`` usable blocks of ``block_size`` token slots each, plus a
    trailing *trash* block (physical id ``num_blocks``) that absorbs writes
    routed through unallocated block-table entries — scatters never need a
    validity branch, and reads of the trash block are always masked.

    Sharded serving (DESIGN.md §9) partitions the pool on the 'data' axis:
    the leading block axis holds ``data_shards`` shard-local pools of
    ``num_blocks + 1`` blocks back to back, each with its *own* trash block,
    so block-table entries stay shard-local physical ids and every shard's
    scatter/gather runs on its local (num_blocks+1, ...) slice."""
    if kind != "attn":
        raise ValueError("paged KV layout requires attention-only layers")
    nbp = data_shards * (num_blocks + 1)
    nkv, hd = cfg.n_kv_heads, cfg.hd()
    if kv_quant:
        return {
            "k": jnp.zeros((nbp, block_size, nkv, hd), jnp.int8),
            "v": jnp.zeros((nbp, block_size, nkv, hd), jnp.int8),
            "k_scale": jnp.zeros((nbp, block_size, nkv), jnp.float32),
            "v_scale": jnp.zeros((nbp, block_size, nkv), jnp.float32),
        }
    return {
        "k": jnp.zeros((nbp, block_size, nkv, hd), jnp.bfloat16),
        "v": jnp.zeros((nbp, block_size, nkv, hd), jnp.bfloat16),
    }


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               kv_quant: bool = False, kv_layout: str = "ring",
               block_size: Optional[int] = None,
               num_blocks: Optional[int] = None,
               data_shards: int = 1) -> Params:
    """Build the decode cache.  For the paged layout ``num_blocks`` counts
    usable blocks *per data shard* (``data_shards`` = 1 outside sharded
    serving, so it is simply the pool capacity) and ``block_tables`` entries
    are shard-local physical ids whose unset value is the shard-local trash
    block ``num_blocks`` (DESIGN.md §6/§9)."""
    paged = kv_layout == "paged"
    if kv_layout not in ("ring", "paged"):
        raise ValueError(f"unknown kv_layout {kv_layout!r}")
    if data_shards < 1 or batch % data_shards:
        raise ValueError(f"batch {batch} must divide into {data_shards} "
                         "data shards")
    if paged:
        if not block_size or block_size <= 0:
            raise ValueError("paged kv_layout requires a positive block_size")
        nbmax = -(-max_len // block_size)          # blocks per full request
        num_blocks = (num_blocks if num_blocks is not None
                      else (batch // data_shards) * nbmax)
    p_ = _period(cfg)
    rep, rem = divmod(cfg.n_layers, p_)
    stacked = []
    if rep:
        for pos in range(p_):
            kind = cfg.layer_kind(pos)
            one = (_paged_cache_entry(cfg, kind, num_blocks, block_size,
                                      kv_quant, data_shards) if paged
                   else _cache_entry(cfg, kind, batch, max_len, kv_quant))
            stacked.append(
                jax.tree.map(lambda x: jnp.broadcast_to(x, (rep,) + x.shape), one)
            )
    remainder = [
        (_paged_cache_entry(cfg, cfg.layer_kind(rep * p_ + i), num_blocks,
                            block_size, kv_quant, data_shards) if paged
         else _cache_entry(cfg, cfg.layer_kind(rep * p_ + i), batch, max_len,
                           kv_quant))
        for i in range(rem)
    ]
    # "pos" is *per-slot* (B,): the serving engine admits requests into slots
    # at different times, so every slot decodes at its own absolute position.
    cache = {"pos": jnp.zeros((batch,), jnp.int32), "layers": stacked,
             "remainder": remainder}
    if paged:
        # logical → physical block map per slot; unset entries point at the
        # trash block so writes through them are harmless and reads masked
        cache["block_tables"] = jnp.full((batch, nbmax), num_blocks,
                                         jnp.int32)
    return cache


# ---------------------------------------------------------------------------
# decode attention over the ring cache
# ---------------------------------------------------------------------------


def _attention_decode(params, cfg: ModelConfig, x, cache, pos, policy, counter,
                      kv_offset=None, block_tables=None):
    """One-token attention against the KV cache.  x: (B, 1, d).

    ``pos`` is the per-slot absolute position — scalar or (B,) — so slots
    admitted at different times decode independently.  ``kv_offset`` (B,)
    optionally shifts the dither counter of the int8 KV quantiser per slot
    (the engine threads each request's counter offset through it so
    concurrent requests walk independent pulse sequences, DESIGN.md §6).
    ``block_tables`` (B, nbmax) selects the *paged* cache layout: the new
    token scatters into pool block ``block_tables[b, pos//bs]`` at in-block
    slot ``pos % bs`` and attention gathers through the table
    (``dispatch.paged_decode_attention``); without it the cache is the
    dense per-slot ring.
    """
    b = x.shape[0]
    hd, nh, nkv = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    paged = block_tables is not None
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))

    q = dense(x, params["wq"], policy, counter, seed=1).reshape(b, 1, nh, hd)
    k = dense(x, params["wk"], policy, counter, seed=2).reshape(b, 1, nkv, hd)
    v = dense(x, params["wv"], policy, counter, seed=3).reshape(b, 1, nkv, hd)
    if cfg.qkv_bias and "bq" in params:
        q = q + params["bq"].reshape(1, 1, nh, hd)
        k = k + params["bk"].reshape(1, 1, nkv, hd)
        v = v + params["bv"].reshape(1, 1, nkv, hd)
    posv = pos[:, None]
    q = layers.rope(q, posv, cfg.rope_theta)
    k = layers.rope(k, posv, cfg.rope_theta)

    if paged:
        bs = cache["k"].shape[1]
        # physical block holding this token; engine guarantees it is
        # allocated (and uniquely owned — copy-on-write happens host-side)
        # before the tick, or points at the trash block for idle slots
        phys = jnp.take_along_axis(block_tables, (pos // bs)[:, None],
                                   axis=1)[:, 0]
        slot = jnp.mod(pos, bs)
    else:
        cap = cache["k"].shape[1]
        rows = jnp.arange(b)
        slot = jnp.mod(pos, cap)
    quantized = cache["k"].dtype == jnp.int8
    if quantized:
        # dither-round the new K/V token into int8 codes; the counter is the
        # per-slot absolute position (+ per-request offset)
        ctr = pos if kv_offset is None else pos + jnp.broadcast_to(
            jnp.asarray(kv_offset, jnp.int32), (b,))
        ctr4 = ctr.reshape(b, 1, 1, 1)
        idx4 = _kv_elem_idx(nkv, hd)
        kq, ks = _kv_q8(k, ctr4, idx4, 101)
        vq, vs = _kv_q8(v, ctr4, idx4, 102)
        if paged:
            new_cache = {
                "k": cache["k"].at[phys, slot].set(kq[:, 0]),
                "v": cache["v"].at[phys, slot].set(vq[:, 0]),
                "k_scale": cache["k_scale"].at[phys, slot].set(ks[:, 0]),
                "v_scale": cache["v_scale"].at[phys, slot].set(vs[:, 0]),
            }
        else:
            new_cache = {
                "k": cache["k"].at[rows, slot].set(kq[:, 0]),
                "v": cache["v"].at[rows, slot].set(vq[:, 0]),
                "k_scale": cache["k_scale"].at[rows, slot].set(ks[:, 0]),
                "v_scale": cache["v_scale"].at[rows, slot].set(vs[:, 0]),
                "k_pos": cache["k_pos"].at[rows, slot].set(pos),
            }
    elif paged:
        new_cache = {
            "k": cache["k"].at[phys, slot].set(k[:, 0].astype(cache["k"].dtype)),
            "v": cache["v"].at[phys, slot].set(v[:, 0].astype(cache["v"].dtype)),
        }
    else:
        ck = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
        k_pos = cache["k_pos"].at[rows, slot].set(pos)
        new_cache = {"k": ck, "v": cv, "k_pos": k_pos}

    # flash-decode over the cache through the kernel dispatcher (DESIGN.md
    # §2/§3): int8 codes stay codes — upcast tile-by-tile in VMEM,
    # per-position scales folded in after the dot — with validity /
    # causality / sliding-window masking and length-aware block skipping
    # in-kernel.  Backend: $REPRO_KERNEL_BACKEND or the platform default
    # (TPU → pallas-tpu, else the jitted xla-ref oracle).
    from repro.kernels import dispatch as _dispatch

    group = nh // nkv
    qg = q[:, 0].reshape(b, nkv, group, hd)
    if paged:
        attn = _dispatch.paged_decode_attention(
            qg, new_cache["k"], new_cache["v"], block_tables, pos,
            k_scale=new_cache.get("k_scale"),
            v_scale=new_cache.get("v_scale"),
            window=cfg.window or 0,
        )
    else:
        attn = _dispatch.decode_attention(
            qg, new_cache["k"], new_cache["v"], new_cache["k_pos"], pos,
            k_scale=new_cache.get("k_scale"),
            v_scale=new_cache.get("v_scale"),
            window=cfg.window or 0,
        )
    # sharded serving: heads all-gather before the replicated W_O so the
    # output contraction stays whole (bitwise contract, DESIGN.md §9);
    # identity outside a serve shard scope / under the GQA fallback
    out = dist_ctx.gather_heads(attn.astype(x.dtype).reshape(b, 1, nh * hd))
    return dense(out, params["wo"], policy, counter, seed=4), new_cache


# ---------------------------------------------------------------------------
# block application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------


def _apply_block(
    bp: Params,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    positions,
    *,
    policy,
    counter,
    cache_entry=None,
    pos=None,
    window_override=None,
    kv_offset=None,
    collect_kv=False,
    block_tables=None,
):
    h = layers.rms_norm(x, bp["ln1"], cfg.norm_eps)
    new_cache = cache_entry
    if kind == "attn":
        window = cfg.window if window_override is None else window_override
        if cache_entry is not None:
            out, new_cache = _attention_decode(bp["attn"], cfg, h, cache_entry,
                                               pos, policy, counter,
                                               kv_offset=kv_offset,
                                               block_tables=block_tables)
        else:
            out, kv = layers.attention(
                bp["attn"], cfg, h, positions, causal=True, window=window,
                policy=policy, counter=counter, return_kv=collect_kv,
            )
            if collect_kv:
                new_cache = kv
    elif kind == "rglru":
        if cache_entry is not None:
            out, new_cache = hybrid.rglru_decode_step(bp["rec"], cfg, h, cache_entry, policy, counter)
        else:
            out = hybrid.rglru_block(bp["rec"], cfg, h, policy, counter)
    elif kind == "ssm":
        if cache_entry is not None:
            out, new_cache = ssm.ssm_decode_step(bp["ssm"], cfg, h, cache_entry, policy, counter)
        else:
            out = ssm.ssm_block(bp["ssm"], cfg, h, policy, counter)
    else:
        raise ValueError(kind)
    x = x + out

    if "mlp" in bp or "moe" in bp:
        h2 = layers.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if "moe" in bp:
            x = x + moe.moe_ffn(bp["moe"], cfg, h2, policy, counter)
        else:
            x = x + layers.mlp(bp["mlp"], h2, cfg.mlp_act, policy, counter)
    return x, new_cache


# ---------------------------------------------------------------------------
# forward (train / prefill) and decode
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg, tokens, embeds):
    x = jnp.take(params["embed"], tokens, axis=0)
    if embeds is not None:  # multimodal stub frontend: prepend patch/frame embeds
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return x


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    embeds: Optional[jax.Array] = None,
    policy: Optional[QuantPolicy] = None,
    counter=0,
    remat: bool = True,
) -> jax.Array:
    """Full-sequence forward → logits (B, S_total, vocab)."""
    x = _embed_inputs(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    p_ = _period(cfg)

    def body(carry, xs):
        h = carry
        for pos_i in range(p_):
            kind = cfg.layer_kind(pos_i)
            h, _ = _apply_block(
                xs[pos_i], cfg, kind, h, positions, policy=policy, counter=counter,
            )
        return h, None

    body_fn = jax.checkpoint(body) if remat else body
    if params["blocks"]:
        x, _ = jax.lax.scan(body_fn, x, tuple(params["blocks"]))
    rep = cfg.n_layers // p_
    for i, bp in enumerate(params["remainder"]):
        kind = cfg.layer_kind(rep * p_ + i)
        x, _ = _apply_block(bp, cfg, kind, x, positions, policy=policy, counter=counter)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return dense(x, head, policy, counter, seed=9).astype(jnp.float32)


def prefill(params, cfg, tokens, *, embeds=None, policy=None, counter=0):
    """Prefill forward, logits only (the dry-run's compute-roofline cell).

    The serving engine uses ``prefill_with_cache`` below, which additionally
    materialises the ring-buffer decode cache; for roofline purposes prefill
    cost is the forward pass itself.
    """
    return forward(params, cfg, tokens, embeds=embeds, policy=policy,
                   counter=counter, remat=False)


def _prefill_entry(cfg: ModelConfig, kv, lengths, cap: int, kv_quant: bool,
                   kv_offset):
    """Scatter one attention layer's full-sequence K/V into a ring cache entry.

    kv: post-RoPE ``(k, v)``, each (B, S, n_kv_heads, hd).  Ring slot j ends
    up holding the *last* prompt position p ≡ j (mod cap) below the slot's
    prompt length — bit-identical layout to what token-by-token decode
    writes would have left behind (including the dither-quantised int8
    codes, whose counter is the absolute position + per-request offset).
    """
    k_full, v_full = kv
    b, s = k_full.shape[0], k_full.shape[1]
    j = jnp.arange(cap, dtype=jnp.int32)[None, :]
    last = lengths[:, None].astype(jnp.int32) - 1              # (B, 1)
    pj = last - jnp.mod(last - j, cap)                         # (B, cap)
    valid = pj >= 0
    idx = jnp.clip(pj, 0, s - 1)
    gk = jnp.take_along_axis(k_full, idx[:, :, None, None], axis=1)
    gv = jnp.take_along_axis(v_full, idx[:, :, None, None], axis=1)
    k_pos = jnp.where(valid, pj, -1).astype(jnp.int32)

    if not kv_quant:
        zero = jnp.zeros((), jnp.bfloat16)
        return {
            "k": jnp.where(valid[:, :, None, None], gk.astype(jnp.bfloat16), zero),
            "v": jnp.where(valid[:, :, None, None], gv.astype(jnp.bfloat16), zero),
            "k_pos": k_pos,
        }

    off = (jnp.zeros((b,), jnp.int32) if kv_offset is None
           else jnp.broadcast_to(jnp.asarray(kv_offset, jnp.int32), (b,)))
    ctr = (pj + off[:, None])[:, :, None, None]                # (B, cap, 1, 1)
    nkv, hd = k_full.shape[2], k_full.shape[3]
    # same (row-independent) element indices as the decode-step quantiser —
    # see _kv_elem_idx for why the batch row must not enter the hash
    idx4 = _kv_elem_idx(nkv, hd)

    def q8(t, seed):
        q, scale = _kv_q8(t, ctr, idx4, seed)
        return (jnp.where(valid[:, :, None, None], q, jnp.int8(0)),
                jnp.where(valid[:, :, None], scale, 0.0))

    kq, ks = q8(gk, 101)
    vq, vs = q8(gv, 102)
    return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs, "k_pos": k_pos}


def prefill_with_cache(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,    # (B, S) right-padded prompts
    lengths: jax.Array,   # (B,) true prompt lengths (0 = inactive row)
    max_len: int,
    *,
    policy: Optional[QuantPolicy] = None,
    counter=0,
    kv_quant: bool = False,
    kv_offset=None,
):
    """Batched prefill: one full-sequence forward that also materialises the
    ring-buffer decode cache (DESIGN.md §6).

    Attention-only architectures: every prompt token's K/V is computed in a
    single batched forward (right-padded; causal masking keeps real tokens
    blind to the padding) and scattered into the per-slot ring cache, so
    prompt cost is one forward instead of O(prompt_len) decode ticks.
    Returns ``(logits, cache)`` — logits (B, S, vocab_size) f32, and a cache
    whose ``pos`` is ``lengths``.  Architectures with recurrent state (SSM /
    RG-LRU) or an encoder are served by the scanned fallback in
    ``models/registry.apply_prefill`` instead.
    """
    for i in range(cfg.n_layers):
        if cfg.layer_kind(i) != "attn":
            raise ValueError("prefill_with_cache requires attention-only "
                             "layers; use registry.apply_prefill")
    x = jnp.take(params["embed"], tokens, axis=0)
    b, s, _ = x.shape
    lengths = jnp.asarray(lengths, jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    p_ = _period(cfg)

    def body(carry, xs):
        h = carry
        kvs = []
        for pos_i in range(p_):
            h, kv = _apply_block(
                xs[pos_i], cfg, "attn", h, positions, policy=policy,
                counter=counter, collect_kv=True,
            )
            kvs.append(kv)
        return h, tuple(kvs)

    kv_stacked = ()
    if params["blocks"]:
        x, kv_stacked = jax.lax.scan(body, x, tuple(params["blocks"]))
    kv_rem = []
    for i, bp in enumerate(params["remainder"]):
        x, kv = _apply_block(bp, cfg, "attn", x, positions, policy=policy,
                             counter=counter, collect_kv=True)
        kv_rem.append(kv)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = dense(x, head, policy, counter, seed=9).astype(jnp.float32)
    logits = logits[:, :, : cfg.vocab_size]

    cap = min(cfg.window, max_len) if cfg.window else max_len
    entry = functools.partial(_prefill_entry, cfg, lengths=lengths, cap=cap,
                              kv_quant=kv_quant, kv_offset=kv_offset)
    # stacked pattern positions carry a leading repeat axis — vmap over it
    stacked = [jax.vmap(lambda kv: entry(kv))(kv) for kv in kv_stacked]
    remainder = [entry(kv) for kv in kv_rem]
    cache = {"pos": lengths, "layers": stacked, "remainder": remainder}
    return logits, cache


# ---------------------------------------------------------------------------
# chunked ring prefill: chunk forward + ring-history join + ring scatter
# ---------------------------------------------------------------------------


def _ring_scatter_chunk(entry, k, v, lengths, starts, kv_quant: bool,
                        kv_offset):
    """Merge one chunk's post-RoPE K/V (B, S, nkv, hd) into a live ring
    entry.  Gather-select form (the `_prefill_entry` idiom, inverted): for
    every ring slot j the chunk *covers* j iff some chunk position p ≡ j
    (mod cap) with p < starts + lengths — chunk positions are consecutive
    and the engine clamps chunks to ≤ cap tokens, so each slot is covered
    at most once and non-covered slots keep their old contents exactly
    (no scatter, no duplicate-index ordering hazard).  The int8 path
    quantises with counter = absolute position (+ per-request offset) and
    the decode-step element indices, so a chunk writes codes bit-identical
    to what whole-prompt prefill or token-by-token decode would have left
    at the same positions (DESIGN.md §6/§11)."""
    cap = entry["k"].shape[1]
    b, s = k.shape[0], k.shape[1]
    nkv, hd = k.shape[2], k.shape[3]
    j = jnp.arange(cap, dtype=jnp.int32)[None, :]              # (1, cap)
    st = starts[:, None].astype(jnp.int32)
    t = jnp.mod(j - st, cap)                                   # chunk index
    covered = t < lengths[:, None]                             # (B, cap)
    pj = st + t                                                # absolute pos
    idx = jnp.clip(t, 0, s - 1)
    gk = jnp.take_along_axis(k, idx[:, :, None, None], axis=1)
    gv = jnp.take_along_axis(v, idx[:, :, None, None], axis=1)
    k_pos = jnp.where(covered, pj, entry["k_pos"]).astype(jnp.int32)
    c4 = covered[:, :, None, None]

    if not kv_quant:
        dt = entry["k"].dtype
        return {"k": jnp.where(c4, gk.astype(dt), entry["k"]),
                "v": jnp.where(c4, gv.astype(dt), entry["v"]),
                "k_pos": k_pos}

    off = (jnp.zeros((b,), jnp.int32) if kv_offset is None
           else jnp.broadcast_to(jnp.asarray(kv_offset, jnp.int32), (b,)))
    ctr = (pj + off[:, None])[:, :, None, None]
    idx4 = _kv_elem_idx(nkv, hd)
    kq, ks = _kv_q8(gk, ctr, idx4, 101)
    vq, vs = _kv_q8(gv, ctr, idx4, 102)
    c3 = covered[:, :, None]
    return {"k": jnp.where(c4, kq, entry["k"]),
            "v": jnp.where(c4, vq, entry["v"]),
            "k_scale": jnp.where(c3, ks, entry["k_scale"]),
            "v_scale": jnp.where(c3, vs, entry["v_scale"]),
            "k_pos": k_pos}


def _ring_chunk_attention(params, cfg: ModelConfig, x, positions, lengths,
                          starts, entry, policy, counter, kv_quant: bool,
                          kv_offset):
    """Chunk attention for the chunked ring prefill: queries at absolute
    positions ``starts + t`` attend the in-batch chunk K/V
    (relative-causal, the cold path's grouped einsums) plus the slot's
    *already-written history* gathered from the live ring entry —
    positions with ``0 <= k_pos < start``, dequantised per position and
    joined before the softmax, exactly the paged prefill's prefix-join
    construction applied to the ring layout (DESIGN.md §11).  Returns
    ``(out, new_entry)`` with the chunk K/V merged into the ring."""
    b, s, _ = x.shape
    hd, nh, nkv = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    import math as _math

    q = dense(x, params["wq"], policy, counter, seed=1)
    k = dense(x, params["wk"], policy, counter, seed=2)
    v = dense(x, params["wv"], policy, counter, seed=3)
    if cfg.qkv_bias and "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, s, nkv, hd)
    v = v.reshape(b, s, nkv, hd)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)

    window = cfg.window or 0
    group = nh // nkv
    qg = q.reshape(b, s, nkv, group, hd)
    m_ss = layers.make_causal_mask(s, s, window=window)
    logits_s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) \
        / _math.sqrt(hd)
    logits_s = jnp.where(m_ss[None, None, None, :, :], logits_s, -1e30)

    # history join: the ring holds every still-reachable earlier position
    # (k_pos ∈ [0, start)); garbage slots carry k_pos = -1 or ≥ start and
    # mask out, so idle-window writes never leak into chunk attention
    hk, hv = entry["k"], entry["v"]
    if "k_scale" in entry:
        hk = (hk.astype(jnp.float32)
              * (entry["k_scale"][..., None] / 127.0)).astype(x.dtype)
        hv = (hv.astype(jnp.float32)
              * (entry["v_scale"][..., None] / 127.0)).astype(x.dtype)
    kp = entry["k_pos"][:, None, :]                        # (B, 1, cap)
    q_abs = positions[:, :, None]                          # (B, S, 1)
    vp = (kp >= 0) & (kp < starts[:, None, None])
    if window:
        vp = vp & (kp > q_abs - window)
    logits_p = jnp.einsum("bqhgd,bkhd->bhgqk", qg, hk).astype(jnp.float32) \
        / _math.sqrt(hd)
    logits_p = jnp.where(vp[:, None, None, :, :], logits_p, -1e30)
    cap = entry["k"].shape[1]
    probs = jax.nn.softmax(
        jnp.concatenate([logits_p, logits_s], axis=-1), axis=-1
    ).astype(x.dtype)
    out = (jnp.einsum("bhgqk,bkhd->bqhgd", probs[..., :cap], hv)
           + jnp.einsum("bhgqk,bkhd->bqhgd", probs[..., cap:], v))
    out = dist_ctx.gather_heads(out.reshape(b, s, nh * hd))
    out = dense(out, params["wo"], policy, counter, seed=4)

    new_entry = _ring_scatter_chunk(entry, k, v, lengths, starts, kv_quant,
                                    kv_offset)
    return out, new_entry


def _ring_chunk_block(bp, cfg: ModelConfig, x, positions, lengths, starts,
                      entry, policy, counter, kv_quant, kv_offset):
    """One transformer block of the chunked ring prefill — ``_apply_block``'s
    attn branch with the history-joining attention above."""
    h = layers.rms_norm(x, bp["ln1"], cfg.norm_eps)
    out, new_entry = _ring_chunk_attention(
        bp["attn"], cfg, h, positions, lengths, starts, entry, policy,
        counter, kv_quant, kv_offset)
    x = x + out
    if "mlp" in bp or "moe" in bp:
        h2 = layers.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if "moe" in bp:
            x = x + moe.moe_ffn(bp["moe"], cfg, h2, policy, counter)
        else:
            x = x + layers.mlp(bp["mlp"], h2, cfg.mlp_act, policy, counter)
    return x, new_entry


def prefill_with_cache_chunked(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,    # (B, S) right-padded prompt *chunks*
    lengths: jax.Array,   # (B,) chunk lengths (0 = inactive row)
    starts: jax.Array,    # (B,) absolute position of each chunk's token 0
    cache: Params,        # live ring cache; chunk KV merges in place
    *,
    policy: Optional[QuantPolicy] = None,
    counter=0,
    kv_quant: bool = False,
    kv_offset=None,
):
    """Chunked ring prefill: one batched forward over per-slot prompt
    *chunks* that merges their K/V into the live ring cache (DESIGN.md
    §11).  A continuation chunk sets ``starts[b] > 0``: tokens before the
    start are not recomputed — their K/V is read back from the slot's own
    ring entry inside each layer's attention and joined before the
    softmax, so every chunk sees one joint distribution over its whole
    history.  ``starts = 0`` with the full prompt length degenerates to
    whole-prompt prefill of a fresh slot.  Chunks must be ≤ the ring
    capacity (the engine clamps).  Returns ``(logits (B, S, vocab_size),
    cache')`` with per-slot ``pos`` advanced to ``starts + lengths`` for
    active rows."""
    for i in range(cfg.n_layers):
        if cfg.layer_kind(i) != "attn":
            raise ValueError("chunked prefill requires attention-only "
                             "layers; use registry.apply_prefill")
    x = jnp.take(params["embed"], tokens, axis=0)
    b, s, _ = x.shape
    lengths = jnp.asarray(lengths, jnp.int32)
    starts = jnp.asarray(starts, jnp.int32)
    positions = starts[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    p_ = _period(cfg)

    def body(carry, xs):
        h = carry
        bp, ce = xs
        new_entries = []
        for pos_i in range(p_):
            h, ne = _ring_chunk_block(
                bp[pos_i], cfg, h, positions, lengths, starts, ce[pos_i],
                policy, counter, kv_quant, kv_offset)
            new_entries.append(ne)
        return h, tuple(new_entries)

    if params["blocks"]:
        x, new_layers = jax.lax.scan(
            body, x, (tuple(params["blocks"]), tuple(cache["layers"])))
    else:
        new_layers = ()
    new_rem = []
    for i, bp in enumerate(params["remainder"]):
        x, ne = _ring_chunk_block(
            bp, cfg, x, positions, lengths, starts, cache["remainder"][i],
            policy, counter, kv_quant, kv_offset)
        new_rem.append(ne)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = dense(x, head, policy, counter, seed=9).astype(jnp.float32)
    logits = logits[:, :, : cfg.vocab_size]
    new_cache = {
        "pos": jnp.where(lengths > 0, starts + lengths, cache["pos"]),
        "layers": list(new_layers),
        "remainder": new_rem,
    }
    return logits, new_cache


# ---------------------------------------------------------------------------
# paged prefill: suffix forward + prefix gather + block-pool scatter
# ---------------------------------------------------------------------------


def _gather_prefix(entry, block_tables, prefix_blocks: int):
    """Gather the leading ``prefix_blocks`` logical blocks of every slot
    from one layer's pool → tensors over (B, prefix_blocks·bs, ...).
    Unallocated table entries point at the trash block; the caller masks
    those positions (implicit position ≥ the slot's prefix length)."""
    bt = block_tables[:, :prefix_blocks]                   # (B, P)
    gk = jnp.take(entry["k"], bt, axis=0)                  # (B, P, bs, nkv, hd)
    gv = jnp.take(entry["v"], bt, axis=0)
    b, p, bs = gk.shape[0], gk.shape[1], gk.shape[2]
    out = [gk.reshape(b, p * bs, *gk.shape[3:]),
           gv.reshape(b, p * bs, *gv.shape[3:])]
    if "k_scale" in entry:
        out += [jnp.take(entry["k_scale"], bt, axis=0).reshape(b, p * bs, -1),
                jnp.take(entry["v_scale"], bt, axis=0).reshape(b, p * bs, -1)]
    else:
        out += [None, None]
    return out


def _paged_scatter_entry(entry, k, v, positions, lengths, starts,
                         block_tables, kv_quant: bool, kv_offset):
    """Scatter one layer's suffix K/V (post-RoPE, (B, S, nkv, hd)) into its
    pool blocks.  Suffix token s lands in logical block ``starts//bs + s//bs``
    at in-block slot ``s % bs`` (starts are block-aligned); blocks beyond the
    suffix length route to the trash block.  The int8 path quantises with
    counter = absolute position (+ per-request offset) and the decode-step
    element indices, so the codes are bit-identical to what token-by-token
    decode would have written — the bit-reusability contract behind prefix
    sharing (DESIGN.md §6)."""
    nbp, bs = entry["k"].shape[0], entry["k"].shape[1]
    trash = nbp - 1
    b, s = k.shape[0], k.shape[1]
    nkv, hd = k.shape[2], k.shape[3]
    nbmax = block_tables.shape[1]
    s_pad = -(-s // bs) * bs
    if s_pad != s:
        pad = [(0, 0), (0, s_pad - s), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    pos_pad = starts[:, None] + jnp.arange(s_pad, dtype=jnp.int32)[None, :]
    jb_count = s_pad // bs
    jb = jnp.arange(jb_count, dtype=jnp.int32)[None, :]              # (1, JB)
    needed = jb * bs < lengths[:, None]                              # (B, JB)
    tj = jnp.clip(starts[:, None] // bs + jb, 0, nbmax - 1)
    phys = jnp.where(needed, jnp.take_along_axis(block_tables, tj, axis=1),
                     trash).reshape(-1)                              # (B·JB,)

    def blocks(t):
        return t.reshape((b * jb_count, bs) + t.shape[2:])

    if not kv_quant:
        dt = entry["k"].dtype
        return {"k": entry["k"].at[phys].set(blocks(k.astype(dt))),
                "v": entry["v"].at[phys].set(blocks(v.astype(dt)))}

    off = (jnp.zeros((b,), jnp.int32) if kv_offset is None
           else jnp.broadcast_to(jnp.asarray(kv_offset, jnp.int32), (b,)))
    ctr = (pos_pad + off[:, None])[:, :, None, None]     # (B, S_pad, 1, 1)
    idx4 = _kv_elem_idx(nkv, hd)
    kq, ks = _kv_q8(k, ctr, idx4, 101)
    vq, vs = _kv_q8(v, ctr, idx4, 102)
    return {"k": entry["k"].at[phys].set(blocks(kq)),
            "v": entry["v"].at[phys].set(blocks(vq)),
            "k_scale": entry["k_scale"].at[phys].set(blocks(ks)),
            "v_scale": entry["v_scale"].at[phys].set(blocks(vs))}


def _paged_prefill_attention(params, cfg: ModelConfig, x, positions, lengths,
                             starts, block_tables, entry, policy, counter,
                             kv_quant: bool, kv_offset, prefix_blocks: int):
    """Suffix attention for the paged prefill: queries at absolute positions
    ``starts + t`` attend the in-batch suffix K/V (relative-causal, exactly
    the cold path's ``layers.attention`` grouped-einsum ops) plus — when
    ``prefix_blocks > 0`` — the prefix K/V gathered from the shared pool
    blocks, dequantised per position and joined *before* the softmax, so a
    prefix-hit request sees one joint distribution over its whole history.
    Returns ``(out, new_entry)`` with the suffix K/V scattered into the
    pool."""
    b, s, _ = x.shape
    hd, nh, nkv = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    import math as _math

    q = dense(x, params["wq"], policy, counter, seed=1)
    k = dense(x, params["wk"], policy, counter, seed=2)
    v = dense(x, params["wv"], policy, counter, seed=3)
    if cfg.qkv_bias and "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, s, nkv, hd)
    v = v.reshape(b, s, nkv, hd)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)

    window = cfg.window or 0
    group = nh // nkv
    qg = q.reshape(b, s, nkv, group, hd)
    # within-suffix mask is relative (suffix rows share one block-aligned
    # start each), identical to the cold path's make_causal_mask
    m_ss = layers.make_causal_mask(s, s, window=window)
    logits_s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) \
        / _math.sqrt(hd)
    logits_s = jnp.where(m_ss[None, None, None, :, :], logits_s, -1e30)

    if prefix_blocks:
        pk, pv, pks, pvs = _gather_prefix(entry, block_tables, prefix_blocks)
        if pks is not None:
            pk = (pk.astype(jnp.float32) * (pks[..., None] / 127.0)).astype(x.dtype)
            pv = (pv.astype(jnp.float32) * (pvs[..., None] / 127.0)).astype(x.dtype)
        s_pre = pk.shape[1]
        kp = jnp.arange(s_pre, dtype=jnp.int32)[None, None, :]   # implicit pos
        q_abs = positions[:, :, None]
        vp = kp < starts[:, None, None]                          # (B, S, S_pre)
        if window:
            vp = vp & (kp > q_abs - window)
        logits_p = jnp.einsum("bqhgd,bkhd->bhgqk", qg, pk).astype(jnp.float32) \
            / _math.sqrt(hd)
        logits_p = jnp.where(vp[:, None, None, :, :], logits_p, -1e30)
        probs = jax.nn.softmax(
            jnp.concatenate([logits_p, logits_s], axis=-1), axis=-1
        ).astype(x.dtype)
        out = (jnp.einsum("bhgqk,bkhd->bqhgd", probs[..., :s_pre], pv)
               + jnp.einsum("bhgqk,bkhd->bqhgd", probs[..., s_pre:], v))
    else:
        probs = jax.nn.softmax(logits_s, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    out = dist_ctx.gather_heads(out.reshape(b, s, nh * hd))
    out = dense(out, params["wo"], policy, counter, seed=4)

    new_entry = _paged_scatter_entry(entry, k, v, positions, lengths, starts,
                                     block_tables, kv_quant, kv_offset)
    return out, new_entry


def _paged_prefill_block(bp, cfg: ModelConfig, x, positions, lengths, starts,
                         block_tables, entry, policy, counter, kv_quant,
                         kv_offset, prefix_blocks):
    """One transformer block of the paged prefill — ``_apply_block``'s attn
    branch with the prefix-aware attention above in place of
    ``layers.attention``."""
    h = layers.rms_norm(x, bp["ln1"], cfg.norm_eps)
    out, new_entry = _paged_prefill_attention(
        bp["attn"], cfg, h, positions, lengths, starts, block_tables, entry,
        policy, counter, kv_quant, kv_offset, prefix_blocks)
    x = x + out
    if "mlp" in bp or "moe" in bp:
        h2 = layers.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if "moe" in bp:
            x = x + moe.moe_ffn(bp["moe"], cfg, h2, policy, counter)
        else:
            x = x + layers.mlp(bp["mlp"], h2, cfg.mlp_act, policy, counter)
    return x, new_entry


def prefill_with_cache_paged(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,    # (B, S) right-padded prompt *suffixes*
    lengths: jax.Array,   # (B,) suffix lengths (0 = inactive row)
    starts: jax.Array,    # (B,) block-aligned absolute position of token 0
    block_tables: jax.Array,  # (B, nbmax) int32 — full logical→physical map
    cache: Params,        # live paged cache; suffix KV scatters in place
    *,
    policy: Optional[QuantPolicy] = None,
    counter=0,
    kv_quant: bool = False,
    kv_offset=None,
    prefix_blocks: int = 0,
):
    """Batched paged prefill: one forward over the prompt *suffixes* that
    scatters their K/V into pool blocks (DESIGN.md §6).

    A prefix-cache hit sets ``starts[b] > 0``: tokens before the start are
    *not* recomputed — their K/V is gathered from the shared, refcounted
    pool blocks inside each layer's attention (``prefix_blocks`` bounds the
    gather; 0 on cold waves makes this exactly the cold batched prefill).
    ``starts`` must be multiples of the pool block size.  Returns
    ``(logits (B, S, vocab_size), cache')`` where ``cache'`` is the live
    cache with the suffix blocks written, per-slot ``pos`` advanced to
    ``starts + lengths`` for active rows, and ``block_tables`` installed.
    """
    for i in range(cfg.n_layers):
        if cfg.layer_kind(i) != "attn":
            raise ValueError("paged prefill requires attention-only layers")
    x = jnp.take(params["embed"], tokens, axis=0)
    b, s, _ = x.shape
    lengths = jnp.asarray(lengths, jnp.int32)
    starts = jnp.asarray(starts, jnp.int32)
    block_tables = jnp.asarray(block_tables, jnp.int32)
    positions = starts[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    p_ = _period(cfg)

    def body(carry, xs):
        h = carry
        bp, ce = xs
        new_entries = []
        for pos_i in range(p_):
            h, ne = _paged_prefill_block(
                bp[pos_i], cfg, h, positions, lengths, starts, block_tables,
                ce[pos_i], policy, counter, kv_quant, kv_offset,
                prefix_blocks)
            new_entries.append(ne)
        return h, tuple(new_entries)

    if params["blocks"]:
        x, new_layers = jax.lax.scan(
            body, x, (tuple(params["blocks"]), tuple(cache["layers"])))
    else:
        new_layers = ()
    new_rem = []
    for i, bp in enumerate(params["remainder"]):
        x, ne = _paged_prefill_block(
            bp, cfg, x, positions, lengths, starts, block_tables,
            cache["remainder"][i], policy, counter, kv_quant, kv_offset,
            prefix_blocks)
        new_rem.append(ne)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = dense(x, head, policy, counter, seed=9).astype(jnp.float32)
    logits = logits[:, :, : cfg.vocab_size]
    new_cache = {
        "pos": jnp.where(lengths > 0, starts + lengths, cache["pos"]),
        "block_tables": block_tables,
        "layers": list(new_layers),
        "remainder": new_rem,
    }
    return logits, new_cache


def merge_cache(old: Params, new: Params, active: jax.Array) -> Params:
    """Per-slot cache insertion: rows of ``new`` where ``active`` (B,) bool
    replace rows of ``old`` — how prefill results enter the live engine
    cache, and how the scanned-prefill fallback freezes finished slots.

    Stacked pattern entries carry batch at axis 1 (leading repeat axis),
    remainder entries at axis 0; ``pos`` is (B,).  Paged caches never merge
    — their prefill scatters into the shared pool in place.
    """
    if "block_tables" in old or "block_tables" in new:
        raise ValueError("merge_cache applies to ring caches only; the paged "
                         "prefill writes the pool in place")
    def sel(axis):
        def f(o, n):
            shp = [1] * n.ndim
            shp[axis] = active.shape[0]
            return jnp.where(active.reshape(shp), n, o)
        return f

    return {
        "pos": jnp.where(active, new["pos"], old["pos"]),
        "layers": jax.tree.map(sel(1), old["layers"], new["layers"]),
        "remainder": jax.tree.map(sel(0), old["remainder"], new["remainder"]),
    }


def decode_step(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,   # (B,) int32 — the most recent token
    cache: Params,
    *,
    policy: Optional[QuantPolicy] = None,
    counter=0,
    kv_offset=None,
):
    """One decode step: (B,) token + cache → (B, vocab) logits, new cache.

    ``cache["pos"]`` is per-slot (B,); every slot advances by one.
    ``kv_offset`` (B,) shifts the int8-KV dither counter per slot
    (per-request counter offsets, DESIGN.md §6).  A cache carrying
    ``block_tables`` decodes against the paged block pool instead of the
    ring (the tables are loop-invariant across layers — every layer's pool
    shares one logical→physical map).
    """
    pos = cache["pos"]
    block_tables = cache.get("block_tables")
    x = jnp.take(params["embed"], token[:, None], axis=0)
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None]
    p_ = _period(cfg)

    def body(carry, xs):
        h = carry
        bp, ce = xs
        new_entries = []
        for pos_i in range(p_):
            kind = cfg.layer_kind(pos_i)
            h, ne = _apply_block(
                bp[pos_i], cfg, kind, h, positions, policy=policy,
                counter=counter, cache_entry=ce[pos_i], pos=pos,
                kv_offset=kv_offset, block_tables=block_tables,
            )
            new_entries.append(ne)
        return h, tuple(new_entries)

    if params["blocks"]:
        x, new_layer_caches = jax.lax.scan(
            body, x, (tuple(params["blocks"]), tuple(cache["layers"]))
        )
    else:
        new_layer_caches = ()
    rep = cfg.n_layers // p_
    new_rem = []
    for i, bp in enumerate(params["remainder"]):
        kind = cfg.layer_kind(rep * p_ + i)
        x, ne = _apply_block(
            bp, cfg, kind, x, positions, policy=policy, counter=counter,
            cache_entry=cache["remainder"][i], pos=pos, kv_offset=kv_offset,
            block_tables=block_tables,
        )
        new_rem.append(ne)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = dense(x, head, policy, counter, seed=9)[:, 0].astype(jnp.float32)
    logits = logits[:, : cfg.vocab_size]  # drop vocab padding for sampling
    new_cache = {
        "pos": pos + 1,
        "layers": list(new_layer_caches),
        "remainder": new_rem,
    }
    if block_tables is not None:
        new_cache["block_tables"] = block_tables
    return logits, new_cache


# ---------------------------------------------------------------------------
# speculative verify: k-token scoring + bulk commit (DESIGN.md §14)
# ---------------------------------------------------------------------------


def _attention_verify(params, cfg: ModelConfig, x, cache, pos, policy, counter,
                      kv_offset=None, alive=None, wcap=None,
                      block_tables=None):
    """k-token verify attention against the KV cache.  x: (B, K, d).

    Row t scores draft position ``pos + t``; every op is the *row-pure*
    analogue of ``_attention_decode`` so row t is bitwise what a one-token
    decode at ``pos + t`` would compute (given the same inputs — the
    bulk-commit contract, DESIGN.md §14).  The dense projections run fused
    over (B, K, d) — XLA keeps plain matmuls row-pure across M — but the
    attention dots go through the per-row verify kernels, and the dither
    quantiser sees the same (value, position + offset, element index)
    triples decode would.

    All K draft positions are written up-front; the per-position causal mask
    (``k_pos``/implicit block positions ≤ query position) hides not-yet-
    "real" slots from earlier rows exactly as empty slots are hidden in
    decode.  ``alive`` (B,) bool and ``wcap`` (B,) bound the writes: row t
    of slot b writes only when ``alive[b] and t < wcap[b]`` — dead rows and
    over-budget draft positions route to a dropped out-of-bounds ring index
    or the paged trash block, so the verify forward never dirties cache
    state the commit cannot account for.
    """
    b, kq = x.shape[0], x.shape[1]
    hd, nh, nkv = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    paged = block_tables is not None
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))

    q = dense(x, params["wq"], policy, counter, seed=1).reshape(b, kq, nh, hd)
    k = dense(x, params["wk"], policy, counter, seed=2).reshape(b, kq, nkv, hd)
    v = dense(x, params["wv"], policy, counter, seed=3).reshape(b, kq, nkv, hd)
    if cfg.qkv_bias and "bq" in params:
        q = q + params["bq"].reshape(1, 1, nh, hd)
        k = k + params["bk"].reshape(1, 1, nkv, hd)
        v = v + params["bv"].reshape(1, 1, nkv, hd)
    offs = jnp.arange(kq, dtype=jnp.int32)[None, :]
    posv = pos[:, None] + offs                                  # (B, K)
    q = layers.rope(q, posv, cfg.rope_theta)
    k = layers.rope(k, posv, cfg.rope_theta)

    if alive is None:
        alive = jnp.ones((b,), bool)
    if wcap is None:
        wcap = jnp.full((b,), kq, jnp.int32)
    writable = ((offs < jnp.asarray(wcap, jnp.int32)[:, None])
                & jnp.asarray(alive, bool)[:, None])            # (B, K)

    if paged:
        bs = cache["k"].shape[1]
        nbp = cache["k"].shape[0]
        lb = jnp.clip(posv // bs, 0, block_tables.shape[1] - 1)
        phys = jnp.take_along_axis(block_tables, lb, axis=1)
        # non-writable draft positions go to the trash block (their logical
        # block may be unallocated or beyond this row's write budget)
        phys = jnp.where(writable, phys, nbp - 1)
        slot = jnp.mod(posv, bs)
    else:
        cap = cache["k"].shape[1]
        rows = jnp.arange(b)[:, None]
        # slot == cap is out of bounds: the scatter drops those writes
        slot = jnp.where(writable, jnp.mod(posv, cap), cap)
    quantized = cache["k"].dtype == jnp.int8
    if quantized:
        ctr = posv if kv_offset is None else posv + jnp.broadcast_to(
            jnp.asarray(kv_offset, jnp.int32), (b,))[:, None]
        ctr4 = ctr.reshape(b, kq, 1, 1)
        idx4 = _kv_elem_idx(nkv, hd)
        k8, ks = _kv_q8(k, ctr4, idx4, 101)
        v8, vs = _kv_q8(v, ctr4, idx4, 102)
        if paged:
            new_cache = {
                "k": cache["k"].at[phys, slot].set(k8),
                "v": cache["v"].at[phys, slot].set(v8),
                "k_scale": cache["k_scale"].at[phys, slot].set(ks),
                "v_scale": cache["v_scale"].at[phys, slot].set(vs),
            }
        else:
            new_cache = {
                "k": cache["k"].at[rows, slot].set(k8),
                "v": cache["v"].at[rows, slot].set(v8),
                "k_scale": cache["k_scale"].at[rows, slot].set(ks),
                "v_scale": cache["v_scale"].at[rows, slot].set(vs),
                "k_pos": cache["k_pos"].at[rows, slot].set(posv),
            }
    elif paged:
        new_cache = {
            "k": cache["k"].at[phys, slot].set(k.astype(cache["k"].dtype)),
            "v": cache["v"].at[phys, slot].set(v.astype(cache["v"].dtype)),
        }
    else:
        ck = cache["k"].at[rows, slot].set(k.astype(cache["k"].dtype))
        cv = cache["v"].at[rows, slot].set(v.astype(cache["v"].dtype))
        k_pos = cache["k_pos"].at[rows, slot].set(posv)
        new_cache = {"k": ck, "v": cv, "k_pos": k_pos}

    from repro.kernels import dispatch as _dispatch

    group = nh // nkv
    qg = q.reshape(b, kq, nkv, group, hd)
    if paged:
        attn = _dispatch.paged_verify_attention(
            qg, new_cache["k"], new_cache["v"], block_tables, pos,
            k_scale=new_cache.get("k_scale"),
            v_scale=new_cache.get("v_scale"),
            window=cfg.window or 0,
        )
    else:
        attn = _dispatch.verify_attention(
            qg, new_cache["k"], new_cache["v"], new_cache["k_pos"], pos,
            k_scale=new_cache.get("k_scale"),
            v_scale=new_cache.get("v_scale"),
            window=cfg.window or 0,
        )
    out = dist_ctx.gather_heads(attn.astype(x.dtype).reshape(b, kq, nh * hd))
    return dense(out, params["wo"], policy, counter, seed=4), new_cache


def _apply_verify_block(bp, cfg: ModelConfig, x, *, policy, counter,
                        cache_entry, pos, kv_offset, alive, wcap,
                        block_tables):
    """Verify-forward transformer block: attention-only archs (the
    ``supports_spec_decode`` gate), so no SSM/RG-LRU branches.  MLP and
    norms are row-pure as-is; MoE is excluded by the gate (its capacity
    ranks cumsum over every token in the dispatch, so a verify row would
    compete with its own future draft positions)."""
    h = layers.rms_norm(x, bp["ln1"], cfg.norm_eps)
    out, new_cache = _attention_verify(bp["attn"], cfg, h, cache_entry, pos,
                                       policy, counter, kv_offset=kv_offset,
                                       alive=alive, wcap=wcap,
                                       block_tables=block_tables)
    x = x + out
    if "mlp" in bp or "moe" in bp:
        h2 = layers.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if "moe" in bp:
            x = x + moe.moe_ffn(bp["moe"], cfg, h2, policy, counter)
        else:
            x = x + layers.mlp(bp["mlp"], h2, cfg.mlp_act, policy, counter)
    return x, new_cache


def verify_step(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, K) int32 — last committed token + k-1 drafts
    cache: Params,
    *,
    policy: Optional[QuantPolicy] = None,
    counter=0,
    kv_offset=None,
    alive=None,         # (B,) bool — rows holding a live request
    wcap=None,          # (B,) int32 — per-row cache-write budget (≤ K)
):
    """Score K draft positions per slot in one forward → (B, K, vocab)
    logits + the cache with all K positions written (DESIGN.md §14).

    ``logits[:, t]`` is bitwise the (B, vocab) logits ``decode_step`` would
    return at position ``pos + t`` after sequentially committing
    ``tokens[:, 1..t]`` — provided those tokens match what the sequential
    stream would have sampled (the accept condition the engine checks).
    ``cache["pos"]`` is *not* advanced: the caller commits the accepted
    prefix with ``spec_commit`` once accept lengths are known.
    """
    pos = cache["pos"]
    block_tables = cache.get("block_tables")
    x = jnp.take(params["embed"], tokens, axis=0)
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    p_ = _period(cfg)

    def body(carry, xs):
        h = carry
        bp, ce = xs
        new_entries = []
        for pos_i in range(p_):
            h, ne = _apply_verify_block(
                bp[pos_i], cfg, h, policy=policy, counter=counter,
                cache_entry=ce[pos_i], pos=pos, kv_offset=kv_offset,
                alive=alive, wcap=wcap, block_tables=block_tables,
            )
            new_entries.append(ne)
        return h, tuple(new_entries)

    if params["blocks"]:
        x, new_layer_caches = jax.lax.scan(
            body, x, (tuple(params["blocks"]), tuple(cache["layers"]))
        )
    else:
        new_layer_caches = ()
    rep = cfg.n_layers // p_
    new_rem = []
    for i, bp in enumerate(params["remainder"]):
        x, ne = _apply_verify_block(
            bp, cfg, x, policy=policy, counter=counter,
            cache_entry=cache["remainder"][i], pos=pos, kv_offset=kv_offset,
            alive=alive, wcap=wcap, block_tables=block_tables,
        )
        new_rem.append(ne)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = dense(x, head, policy, counter, seed=9).astype(jnp.float32)
    logits = logits[..., : cfg.vocab_size]
    new_cache = {
        "pos": pos,
        "layers": list(new_layer_caches),
        "remainder": new_rem,
    }
    if block_tables is not None:
        new_cache["block_tables"] = block_tables
    return logits, new_cache


def spec_commit(cache: Params, new_pos, written, *, draft_k: int) -> Params:
    """Bulk-commit a verified window: ``pos`` advances to ``new_pos`` and the
    rejected suffix — draft positions in ``[new_pos, pos + written)`` — is
    scrubbed back to the never-written state (codes/scales zeroed, ring
    ``k_pos`` reset to -1) so the cache is byte-identical to one that only
    ever decoded the accepted tokens (DESIGN.md §14).

    The accepted prefix needs no touch-up: dither codes are position-pure,
    so the bytes the verify forward wrote at positions ``< new_pos`` are
    already exactly what sequential decode would have written.  ``written``
    (B,) is the per-row write budget the verify forward ran with (0 for
    dead rows); ``draft_k`` is the static window width.
    """
    old = jnp.asarray(cache["pos"], jnp.int32)
    new_pos = jnp.asarray(new_pos, jnp.int32)
    written = jnp.asarray(written, jnp.int32)
    b = old.shape[0]
    offs = jnp.arange(draft_k, dtype=jnp.int32)[None, :]
    p = old[:, None] + offs                                     # (B, K)
    stale = (offs < written[:, None]) & (p >= new_pos[:, None])
    block_tables = cache.get("block_tables")
    paged = block_tables is not None

    def scrub_ring(e, lead):
        cap = e["k"].shape[-3]
        rows = jnp.arange(b)[:, None]
        slot = jnp.where(stale, jnp.mod(p, cap), cap)  # cap → dropped OOB
        ix = (slice(None), rows, slot) if lead else (rows, slot)
        out = {
            "k": e["k"].at[ix].set(0),
            "v": e["v"].at[ix].set(0),
            "k_pos": e["k_pos"].at[ix].set(-1),
        }
        if "k_scale" in e:
            out["k_scale"] = e["k_scale"].at[ix].set(0.0)
            out["v_scale"] = e["v_scale"].at[ix].set(0.0)
        return out

    def scrub_paged(e, lead):
        nbp, bs = e["k"].shape[-4], e["k"].shape[-3]
        lb = jnp.clip(p // bs, 0, block_tables.shape[1] - 1)
        phys = jnp.take_along_axis(block_tables, lb, axis=1)
        phys = jnp.where(stale, phys, nbp - 1)         # non-stale → trash
        slot = jnp.mod(p, bs)
        ix = (slice(None), phys, slot) if lead else (phys, slot)
        out = {"k": e["k"].at[ix].set(0), "v": e["v"].at[ix].set(0)}
        if "k_scale" in e:
            out["k_scale"] = e["k_scale"].at[ix].set(0.0)
            out["v_scale"] = e["v_scale"].at[ix].set(0.0)
        return out

    scrub = scrub_paged if paged else scrub_ring
    new_cache = {
        "pos": new_pos,
        "layers": [scrub(e, True) for e in cache["layers"]],
        "remainder": [scrub(e, False) for e in cache["remainder"]],
    }
    if paged:
        new_cache["block_tables"] = block_tables
    return new_cache
