"""Whisper-style encoder-decoder (arXiv:2212.04356) — transformer backbone.

Per the assignment the conv audio frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, n_enc_tokens, d_model).  Encoder is
bidirectional; decoder is causal self-attention + cross-attention over the
encoder output.  Decoder self-attention uses RoPE (deviation from Whisper's
learned positions, noted in DESIGN.md §7 — keeps position tables O(1) for the
assigned 32k decode shape).  GELU MLPs, pre-LayerNorm, as in Whisper.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.transformer import _attention_decode
from repro.numerics.policy import QuantPolicy, dense

Params = Dict[str, Any]

__all__ = ["init_encdec", "encode", "forward_encdec", "decode_step_encdec",
           "init_encdec_cache", "merge_cache_encdec"]


def _ln(d):
    return {"g": jnp.ones((d,), jnp.bfloat16), "b": jnp.zeros((d,), jnp.bfloat16)}


def _init_enc_layer(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": _ln(cfg.d_model),
        "attn": layers.init_attention(k1, cfg),
        "ln2": _ln(cfg.d_model),
        "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff, "gelu"),
    }


def _init_dec_layer(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": _ln(cfg.d_model),
        "attn": layers.init_attention(k1, cfg),
        "ln_x": _ln(cfg.d_model),
        "xattn": layers.init_attention(k2, cfg, cross=True),
        "ln2": _ln(cfg.d_model),
        "mlp": layers.init_mlp(k3, cfg.d_model, cfg.d_ff, "gelu"),
    }


def init_encdec(key, cfg: ModelConfig) -> Params:
    ke, kd, kt, kp = jax.random.split(key, 4)
    enc = [_init_enc_layer(jax.random.fold_in(ke, i), cfg) for i in range(cfg.n_enc_layers)]
    dec = [_init_dec_layer(jax.random.fold_in(kd, i), cfg) for i in range(cfg.n_layers)]
    return {
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "enc_pos": layers._init(kp, (cfg.n_enc_tokens, cfg.d_model), scale=0.02),
        "enc_norm": _ln(cfg.d_model),
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "embed": layers.init_embedding(kt, cfg.vocab_padded(), cfg.d_model),
        "final_norm": _ln(cfg.d_model),
    }


def _lnorm(x, p, eps):
    return layers.layer_norm(x, p["g"], p["b"], eps)


def encode(params, cfg: ModelConfig, frames: jax.Array, *, policy=None, counter=0):
    """frames: (B, n_enc_tokens, d_model) stub embeddings → encoder output."""
    b, s, _ = frames.shape
    x = frames.astype(jnp.bfloat16) + params["enc_pos"][None, :s]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(h, bp):
        a, _ = layers.attention(
            bp["attn"], cfg, _lnorm(h, bp["ln1"], cfg.norm_eps), positions,
            causal=False, policy=policy, counter=counter, use_rope=False,
        )
        h = h + a
        h = h + layers.mlp(bp["mlp"], _lnorm(h, bp["ln2"], cfg.norm_eps), "gelu",
                           policy, counter)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return _lnorm(x, params["enc_norm"], cfg.norm_eps)


def forward_encdec(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    frames: jax.Array,
    *,
    policy: Optional[QuantPolicy] = None,
    counter=0,
    remat: bool = True,
):
    """Training / prefill forward → logits (B, S, vocab)."""
    enc = encode(params, cfg, frames, policy=policy, counter=counter)
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(h, bp):
        a, _ = layers.attention(
            bp["attn"], cfg, _lnorm(h, bp["ln1"], cfg.norm_eps), positions,
            causal=True, policy=policy, counter=counter,
        )
        h = h + a
        c, _ = layers.attention(
            bp["xattn"], cfg, _lnorm(h, bp["ln_x"], cfg.norm_eps), positions,
            causal=False, kv_src=enc, policy=policy, counter=counter,
            use_rope=False,
        )
        h = h + c
        h = h + layers.mlp(bp["mlp"], _lnorm(h, bp["ln2"], cfg.norm_eps), "gelu",
                           policy, counter)
        return h, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_blocks"])
    x = _lnorm(x, params["final_norm"], cfg.norm_eps)
    return jnp.matmul(x, params["embed"].T).astype(jnp.float32)  # tied head


def init_encdec_cache(params, cfg: ModelConfig, frames, batch: int, max_len: int,
                      *, policy=None):
    """Build the decode cache: ring self-KV per layer + precomputed cross-KV.

    ``pos`` / ``k_pos`` are per-slot, matching the decoder-only cache layout
    (the serving engine admits requests into slots at different times).
    """
    enc = encode(params, cfg, frames, policy=policy)
    hd, nkv = cfg.hd(), cfg.n_kv_heads
    xk, xv = _stacked_xkv(params, enc, cfg, batch)
    self_kv = {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, nkv, hd), jnp.bfloat16),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, nkv, hd), jnp.bfloat16),
        "k_pos": jnp.broadcast_to(jnp.full((batch, max_len), -1, jnp.int32),
                                  (cfg.n_layers, batch, max_len)),
    }
    return {"pos": jnp.zeros((batch,), jnp.int32), "self": self_kv,
            "cross_k": xk, "cross_v": xv}


def merge_cache_encdec(old, new, active):
    """Per-slot cache insertion (cf. transformer.merge_cache): rows of ``new``
    where ``active`` (B,) replace rows of ``old``.  Self-KV leaves carry batch
    at axis 1 (leading layer axis); the static cross-KV is kept from ``old``."""
    def sel(o, n):
        shp = [1] * n.ndim
        shp[1] = active.shape[0]
        return jnp.where(active.reshape(shp), n, o)

    return {
        "pos": jnp.where(active, new["pos"], old["pos"]),
        "self": jax.tree.map(sel, old["self"], new["self"]),
        "cross_k": old["cross_k"], "cross_v": old["cross_v"],
    }


def _stacked_xkv(params, enc, cfg, batch):
    hd, nkv = cfg.hd(), cfg.n_kv_heads

    def body(_, bp):
        k = jnp.matmul(enc, bp["xattn"]["wk"]).reshape(batch, -1, nkv, hd)
        v = jnp.matmul(enc, bp["xattn"]["wv"]).reshape(batch, -1, nkv, hd)
        return None, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

    _, (xk, xv) = jax.lax.scan(body, None, params["dec_blocks"])
    return xk, xv


def decode_step_encdec(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,  # (B,)
    cache: Params,
    *,
    policy: Optional[QuantPolicy] = None,
    counter=0,
    kv_offset=None,  # accepted for API parity; the encdec self-KV is bf16
):
    """One decoder token with self-KV ring cache and static cross-KV.

    ``cache["pos"]`` is per-slot (B,), as in the decoder-only path.
    """
    import math as _math

    pos = cache["pos"]
    x = jnp.take(params["embed"], token[:, None], axis=0)
    b = x.shape[0]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd()

    def body(h, xs):
        bp, ck, cv, ckpos, xk, xv = xs
        entry = {"k": ck, "v": cv, "k_pos": ckpos}
        a, ne = _attention_decode(
            bp["attn"], cfg, _lnorm(h, bp["ln1"], cfg.norm_eps), entry, pos,
            policy, counter,
        )
        h = h + a
        # cross attention against the precomputed encoder KV
        hq = _lnorm(h, bp["ln_x"], cfg.norm_eps)
        q = dense(hq, bp["xattn"]["wq"], policy, counter, seed=1).reshape(
            b, 1, nkv, nh // nkv, hd)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, xk).astype(jnp.float32) / _math.sqrt(hd)
        probs = jax.nn.softmax(logits, axis=-1).astype(h.dtype)
        c = jnp.einsum("bhgqk,bkhd->bqhgd", probs, xv).reshape(b, 1, nh * hd)
        h = h + dense(c, bp["xattn"]["wo"], policy, counter, seed=4)
        h = h + layers.mlp(bp["mlp"], _lnorm(h, bp["ln2"], cfg.norm_eps), "gelu",
                           policy, counter)
        return h, (ne["k"], ne["v"], ne["k_pos"])

    xs = (
        params["dec_blocks"],
        cache["self"]["k"], cache["self"]["v"], cache["self"]["k_pos"],
        cache["cross_k"], cache["cross_v"],
    )
    x, (nk, nv, nkpos) = jax.lax.scan(body, x, xs)
    x = _lnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.matmul(x, params["embed"].T)[:, 0].astype(jnp.float32)
    logits = logits[:, : cfg.vocab_size]  # drop vocab padding for sampling
    new_cache = {
        "pos": pos + 1,
        "self": {"k": nk, "v": nv, "k_pos": nkpos},
        "cross_k": cache["cross_k"], "cross_v": cache["cross_v"],
    }
    return logits, new_cache
