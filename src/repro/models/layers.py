"""Model layers shared by the architecture zoo (pure functional JAX).

Params are plain nested dicts of jnp arrays; every matmul routes through
``repro.numerics.policy.dense`` so the paper's dither-rounding numerics can
be switched on for any architecture.  Sharding is applied by the caller via
in_shardings / with_sharding_constraint (dist/sharding.py).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.dist import ctx
from repro.models.config import ModelConfig
from repro.numerics.policy import QuantPolicy, dense

Params = Dict[str, Any]

__all__ = [
    "rms_norm", "layer_norm", "rope", "init_attention", "attention",
    "init_mlp", "mlp", "init_embedding", "make_causal_mask",
]


def _init(key, shape, scale=None, dtype=jnp.bfloat16):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms & rotary
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma + beta


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary position embedding.  x: (B, S, H, hd), positions: (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA / MQA / MHA, causal / bidirectional / sliding-window / cross)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.hd()
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    p = {
        "wq": _init(kq, (d, cfg.n_heads * hd)),
        "wk": _init(kk, (d, cfg.n_kv_heads * hd)),
        "wv": _init(kv, (d, cfg.n_kv_heads * hd)),
        "wo": _init(ko, (cfg.n_heads * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.bfloat16)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.bfloat16)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.bfloat16)
    return p


def make_causal_mask(s_q: int, s_k: int, window: int = 0, offset: int = 0) -> jax.Array:
    """(s_q, s_k) bool mask.  offset = absolute position of query row 0."""
    q_pos = jnp.arange(s_q)[:, None] + offset
    k_pos = jnp.arange(s_k)[None, :]
    m = k_pos <= q_pos
    if window:
        m = m & (k_pos > q_pos - window)
    return m


def attention(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    mask: Optional[jax.Array] = None,
    causal: bool = True,
    window: int = 0,
    cache: Optional[Params] = None,
    kv_src: Optional[jax.Array] = None,
    policy: Optional[QuantPolicy] = None,
    counter=0,
    use_rope: bool = True,
    return_kv: bool = False,
):
    """Multi-head attention with GQA and an optional decode KV cache.

    cache: {"k": (B, S_max, Hkv, hd), "v": ..., "pos": ()} — decode appends
    at index ``pos`` and attends over the full cache (masked).
    Returns (out, new_cache); with ``return_kv=True`` the second element is
    instead the post-RoPE ``(k, v)`` of *this call's* tokens, each
    (B, S, n_kv_heads, hd) — the batched-prefill path
    (models/transformer.prefill_with_cache, DESIGN.md §6) scatters these
    into the ring-buffer decode cache.
    """
    b, s, d = x.shape
    hd, nh, nkv = cfg.hd(), cfg.n_heads, cfg.n_kv_heads
    src = kv_src if kv_src is not None else x

    # Sequence-parallel attention (Megatron-SP): when the head count doesn't
    # divide the TP axis, head-sharding is impossible without mid-head
    # splits (reshape all-gathers).  Instead the sequence dim shards over
    # 'model' — QKV/O weights are replicated (dist/sharding.py rule), every
    # token is computed on exactly one device, and only the (small, GQA) K/V
    # tensors all-gather for the score einsum.
    seq_par = s > 1 and ctx.seq_shard_attention(nh) and s % max(ctx.tp_size(), 1) == 0
    if seq_par:
        x = ctx.constrain(x, ctx.dp_axes(), "model", None)
        if kv_src is None:
            src = x

    q = dense(x, params["wq"], policy, counter, seed=1)
    k = dense(src, params["wk"], policy, counter, seed=2)
    v = dense(src, params["wv"], policy, counter, seed=3)
    if cfg.qkv_bias and "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, src.shape[1], nkv, hd)
    v = v.reshape(b, src.shape[1], nkv, hd)

    if use_rope and kv_src is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    kv_out = (k, v) if return_kv else None
    new_cache = None
    if cache is not None:
        pos = cache["pos"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        new_cache = {"k": ck, "v": cv, "pos": pos + s}
        k, v = ck, cv
        s_k = k.shape[1]
        k_pos = jnp.arange(s_k)
        q_pos = pos + jnp.arange(s)
        m = k_pos[None, :] <= q_pos[:, None]
        if window:
            m = m & (k_pos[None, :] > q_pos[:, None] - window)
        mask = m
    elif mask is None and causal:
        # defer (or skip) materialising the (s, s) mask when the chunked
        # prefill path below will build per-chunk masks instead
        _chunk = 4096
        _use_chunked = (kv_src is None and not (s > 1 and ctx.seq_shard_attention(nh)
                        and s % max(ctx.tp_size(), 1) == 0)
                        and s > _chunk and s % _chunk == 0)
        if not _use_chunked:
            mask = make_causal_mask(s, src.shape[1], window=window)

    group = nh // nkv
    tp = ctx.tp_size()
    # Flash-style chunked prefill: at 32k context the (b, h, s, s) score
    # tensor alone exceeds HBM (granite-3-8b: 38 GB/device).  Scanning query
    # chunks keeps the working set at (b, h, C, s) — the TPU-native analogue
    # of flash attention's tiling (a Pallas flash kernel would fuse further;
    # the scan gives the same asymptotic memory).  DESIGN.md §5's
    # prefill_32k cell is what forces this path to exist.
    chunk = 4096
    if (cache is None and kv_src is None and not seq_par and causal
            and mask is None and s > chunk and s % chunk == 0):
        # (mask is None here exactly when the deferred-mask branch above
        # decided chunking applies)
        if group > 1 and tp > 1 and nh % tp == 0:
            k = jnp.repeat(k, group, axis=2)
            v = jnp.repeat(v, group, axis=2)
            kk, vv, heads = k, v, nh
            grouped = False
        else:
            kk, vv, heads = k, v, nkv
            grouped = True
        nc = s // chunk
        qs = jnp.swapaxes(q.reshape(b, nc, chunk, nh, hd), 0, 1)
        offsets = jnp.arange(nc) * chunk

        def body(_, xs):
            qc, off = xs
            q_pos = off + jnp.arange(chunk)
            k_pos = jnp.arange(s)
            m = k_pos[None, :] <= q_pos[:, None]
            if window:
                m = m & (k_pos[None, :] > q_pos[:, None] - window)
            if grouped:
                qg = qc.reshape(b, chunk, nkv, group, hd)
                lg = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kk).astype(jnp.float32)
                lg = lg / math.sqrt(hd)
                lg = jnp.where(m[None, None, None, :, :], lg, -1e30)
                pr = jax.nn.softmax(lg, axis=-1).astype(x.dtype)
                oc = jnp.einsum("bhgqk,bkhd->bqhgd", pr, vv)
            else:
                lg = jnp.einsum("bqhd,bkhd->bhqk", qc, kk).astype(jnp.float32)
                lg = lg / math.sqrt(hd)
                lg = jnp.where(m[None, None, :, :], lg, -1e30)
                pr = jax.nn.softmax(lg, axis=-1).astype(x.dtype)
                oc = jnp.einsum("bhqk,bkhd->bqhd", pr, vv)
            return None, oc.reshape(b, chunk, nh * hd)

        _, outs = jax.lax.scan(body, None, (qs, offsets))
        out = jnp.swapaxes(outs, 0, 1).reshape(b, s, nh * hd)
        out = ctx.gather_heads(out)   # sharded serving (DESIGN.md §9)
        out = dense(out, params["wo"], policy, counter, seed=4)
        return out, (kv_out if return_kv else new_cache)

    if not seq_par and group > 1 and tp > 1 and nh % tp == 0:
        # Head-parallel TP: the score einsum must expose a single head dim
        # divisible by the model axis.  The 5-D grouped layout (nkv, g) has
        # two small dims GSPMD cannot shard 16-way → per-layer reshuffles
        # (+11 GB/layer of all-gathers on granite-3-8b, DESIGN.md §5).
        # Repeat the (small, replicated) KV heads instead —
        # group× HBM reads of KV are ~1% of the collective bytes saved.
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / math.sqrt(hd)
        if mask is not None:
            logits = jnp.where(mask[None, None, :, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, nh * hd)
    else:
        # grouped einsum (reads KV once) — sequence-parallel or single-device
        qg = q.reshape(b, s, nkv, group, hd)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) / math.sqrt(hd)
        if mask is not None:
            logits = jnp.where(mask[None, None, None, :, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v).reshape(b, s, nh * hd)
    # sharded serving runs this inside shard_map on local heads: all-gather
    # them before the replicated W_O so the contraction stays whole and the
    # stream stays bitwise shard-count-invariant (DESIGN.md §9); identity
    # outside a serve shard scope (training shards via GSPMD instead).
    out = ctx.gather_heads(out)
    out = dense(out, params["wo"], policy, counter, seed=4)
    if seq_par:  # hand tokens back to the TP regions replicated over 'model'
        out = ctx.constrain(out, ctx.dp_axes(), None, None)
    return out, (kv_out if return_kv else new_cache)


# ---------------------------------------------------------------------------
# MLP (SwiGLU or GELU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, act: str = "swiglu") -> Params:
    kg, ku, kd = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "wg": _init(kg, (d_model, d_ff)),
            "wu": _init(ku, (d_model, d_ff)),
            "wd": _init(kd, (d_ff, d_model)),
        }
    return {"wu": _init(ku, (d_model, d_ff)), "wd": _init(kd, (d_ff, d_model)),
            "bu": jnp.zeros((d_ff,), jnp.bfloat16), "bd": jnp.zeros((d_model,), jnp.bfloat16)}


def mlp(params: Params, x: jax.Array, act: str = "swiglu",
        policy: Optional[QuantPolicy] = None, counter=0) -> jax.Array:
    if act == "swiglu":
        g = dense(x, params["wg"], policy, counter, seed=5)
        u = dense(x, params["wu"], policy, counter, seed=6)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return dense(h, params["wd"], policy, counter, seed=7)
    h = dense(x, params["wu"], policy, counter, seed=5) + params["bu"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return dense(h, params["wd"], policy, counter, seed=7) + params["bd"]


def init_embedding(key, vocab: int, d_model: int) -> jax.Array:
    return _init(key, (vocab, d_model), scale=0.02)
