"""Model configuration shared by all assigned architectures."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Tuple

__all__ = ["ModelConfig"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_experts_active: int = 0
    shared_d_ff: int = 0           # shared-expert FFN width (qwen2-moe)
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- hybrid (recurrentgemma) ---------------------------------------------
    block_pattern: Tuple[str, ...] = ()  # per-layer: "attn" | "rglru" | "ssm"
    window: int = 0                      # local-attention window (0 = full)
    rglru_conv_width: int = 4

    # --- encoder-decoder (whisper) --------------------------------------------
    is_encdec: bool = False
    n_enc_layers: int = 0
    n_enc_tokens: int = 1500   # precomputed audio-frame embeddings (stub frontend)

    # --- multimodal stub frontend ----------------------------------------------
    frontend: str = "none"     # none | vit_stub | audio_stub
    n_frontend_tokens: int = 0  # image/patch tokens prepended to the sequence
    mlp_act: str = "swiglu"     # swiglu | gelu

    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 16 so the logits dim shards on the
        TP axis — the loss then runs on vocab-sharded logits instead of
        all-reducing a full f32 (B,S,V) tensor (DESIGN.md §5).
        Pad columns have zero weights; the loss and decode mask them."""
        return ((self.vocab_size + 15) // 16) * 16

    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def layer_kind(self, i: int) -> str:
        if not self.block_pattern:
            return "ssm" if self.family == "ssm" else "attn"
        return self.block_pattern[i % len(self.block_pattern)]

    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token decode (no full-attention KV scaling)?"""
        if self.family == "ssm":
            return True
        if self.block_pattern and self.window:
            return all(k != "attn" or self.window for k in self.block_pattern)
        return False

    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        return replace(
            self,
            n_layers=min(self.n_layers, max(2, len(self.block_pattern))),
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=128,
            n_heads=4,
            # keep the GQA/MQA/MHA character but stay a divisor of 4 heads
            n_kv_heads=(
                0 if not self.n_kv_heads
                else 1 if self.n_kv_heads == 1
                else 2 if self.n_kv_heads < self.n_heads
                else 4
            ),
            head_dim=32,
            d_ff=256,
            shared_d_ff=256 if self.shared_d_ff else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 8),
            n_experts_active=min(self.n_experts_active, 2),
            ssm_state=min(self.ssm_state, 32),
            ssm_head_dim=32,
            ssm_chunk=32,
            window=min(self.window, 64) if self.window else 0,
            n_enc_tokens=min(self.n_enc_tokens, 32),
            n_frontend_tokens=min(self.n_frontend_tokens, 16),
        )

    # parameter-count estimate (for 6ND model-FLOPs accounting)
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.hd()
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
        if self.mlp_act == "swiglu":
            per_mlp = 3 * d * self.d_ff
        else:
            per_mlp = 2 * d * self.d_ff
        n_dec = self.n_layers
        total = emb
        for i in range(n_dec):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += per_attn + 2 * d
            elif kind == "rglru":
                di = d  # rglru block width = d_model (proj in/out)
                total += 2 * d * di + di * self.rglru_conv_width + 3 * di * di // 1 + 2 * d
            elif kind == "ssm":
                d_in = self.ssm_expand * d
                nh = d_in // self.ssm_head_dim
                total += d * (2 * d_in + 2 * self.ssm_state + nh) + d_in * d + 2 * d
            if self.n_experts:
                e = self.n_experts_active if active_only else self.n_experts
                total += e * 3 * d * self.d_ff + d * self.n_experts
                if self.shared_d_ff:
                    total += 3 * d * self.shared_d_ff
            elif kind == "attn" or not self.block_pattern:
                total += per_mlp
            else:
                total += per_mlp
        if self.is_encdec:
            for _ in range(self.n_enc_layers):
                total += per_attn + per_mlp + 4 * d
            total += n_dec * (per_attn + 2 * d)  # cross-attention
        return total
