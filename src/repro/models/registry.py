"""Uniform model API over all architecture families.

``init_model / apply_model / make_cache / apply_decode`` hide the
decoder-only vs encoder-decoder split so the trainer, server, dry-run and
tests treat every assigned arch identically.  Batches are dicts:

  tokens  (B, S) int32            — always present
  embeds  (B, F, d_model) bf16    — vlm patch embeddings (stub frontend)
  frames  (B, T_enc, d_model) bf16 — audio frame embeddings (stub frontend)
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelConfig
from repro.numerics.policy import QuantPolicy

Params = Dict[str, Any]

__all__ = ["init_model", "apply_model", "make_cache", "apply_decode", "batch_spec"]


def init_model(key, cfg: ModelConfig) -> Params:
    if cfg.is_encdec:
        return encdec.init_encdec(key, cfg)
    return transformer.init_params(key, cfg)


def apply_model(
    params: Params,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    *,
    policy: Optional[QuantPolicy] = None,
    counter=0,
    remat: bool = True,
) -> jax.Array:
    """Full-sequence logits for training / prefill."""
    if cfg.is_encdec:
        return encdec.forward_encdec(
            params, cfg, batch["tokens"], batch["frames"],
            policy=policy, counter=counter, remat=remat,
        )
    return transformer.forward(
        params, cfg, batch["tokens"], embeds=batch.get("embeds"),
        policy=policy, counter=counter, remat=remat,
    )


def make_cache(params: Params, cfg: ModelConfig, batch_size: int, max_len: int,
               frames: Optional[jax.Array] = None, *, policy=None,
               kv_quant: bool = False) -> Params:
    if cfg.is_encdec:
        assert frames is not None
        return encdec.init_encdec_cache(params, cfg, frames, batch_size, max_len,
                                        policy=policy)
    return transformer.init_cache(cfg, batch_size, max_len, kv_quant=kv_quant)


def apply_decode(params: Params, cfg: ModelConfig, token: jax.Array, cache: Params,
                 *, policy=None, counter=0):
    if cfg.is_encdec:
        return encdec.decode_step_encdec(params, cfg, token, cache,
                                         policy=policy, counter=counter)
    return transformer.decode_step(params, cfg, token, cache,
                                   policy=policy, counter=counter)


def batch_spec(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for a training batch (launch/dryrun)."""
    spec = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.frontend == "vit_stub":
        spec["embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        spec["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_enc_tokens, cfg.d_model), jnp.bfloat16)
    return spec
