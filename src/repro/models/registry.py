"""Uniform model API over all architecture families.

``init_model / apply_model / make_cache / apply_decode`` hide the
decoder-only vs encoder-decoder split so the trainer, server, dry-run and
tests treat every assigned arch identically.  Batches are dicts:

  tokens  (B, S) int32            — always present
  embeds  (B, F, d_model) bf16    — vlm patch embeddings (stub frontend)
  frames  (B, T_enc, d_model) bf16 — audio frame embeddings (stub frontend)
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelConfig
from repro.numerics.policy import QuantPolicy

Params = Dict[str, Any]

__all__ = [
    "init_model", "apply_model", "make_cache", "apply_decode", "batch_spec",
    "apply_prefill", "apply_prefill_chunked", "apply_prefill_paged",
    "merge_prefill", "supports_batched_prefill", "supports_paged_kv",
    "supports_chunked_prefill", "supports_spec_decode", "apply_verify",
    "spec_commit",
]


def init_model(key, cfg: ModelConfig) -> Params:
    if cfg.is_encdec:
        return encdec.init_encdec(key, cfg)
    return transformer.init_params(key, cfg)


def apply_model(
    params: Params,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    *,
    policy: Optional[QuantPolicy] = None,
    counter=0,
    remat: bool = True,
) -> jax.Array:
    """Full-sequence logits for training / prefill."""
    if cfg.is_encdec:
        return encdec.forward_encdec(
            params, cfg, batch["tokens"], batch["frames"],
            policy=policy, counter=counter, remat=remat,
        )
    return transformer.forward(
        params, cfg, batch["tokens"], embeds=batch.get("embeds"),
        policy=policy, counter=counter, remat=remat,
    )


def make_cache(params: Params, cfg: ModelConfig, batch_size: int, max_len: int,
               frames: Optional[jax.Array] = None, *, policy=None,
               kv_quant: bool = False, kv_layout: str = "ring",
               block_size: Optional[int] = None,
               num_blocks: Optional[int] = None,
               data_shards: int = 1) -> Params:
    """Decode-cache constructor.  ``data_shards`` > 1 lays the paged block
    pool out as shard-local sub-pools (one trash block each) for the sharded
    serving engine — ``num_blocks`` then counts blocks per shard
    (DESIGN.md §9); ring caches need no layout change (the slot dim shards
    directly)."""
    if cfg.is_encdec:
        assert frames is not None
        if kv_layout != "ring":
            raise ValueError("paged KV layout requires an attention-only "
                             "decoder (see supports_paged_kv)")
        return encdec.init_encdec_cache(params, cfg, frames, batch_size, max_len,
                                        policy=policy)
    if kv_layout != "ring" and not supports_paged_kv(cfg):
        raise ValueError("paged KV layout requires an attention-only decoder "
                         f"(arch {cfg.name!r} has recurrent state)")
    return transformer.init_cache(cfg, batch_size, max_len, kv_quant=kv_quant,
                                  kv_layout=kv_layout, block_size=block_size,
                                  num_blocks=num_blocks,
                                  data_shards=data_shards)


def apply_decode(params: Params, cfg: ModelConfig, token: jax.Array, cache: Params,
                 *, policy=None, counter=0, kv_offset=None):
    if cfg.is_encdec:
        return encdec.decode_step_encdec(params, cfg, token, cache,
                                         policy=policy, counter=counter,
                                         kv_offset=kv_offset)
    return transformer.decode_step(params, cfg, token, cache,
                                   policy=policy, counter=counter,
                                   kv_offset=kv_offset)


def supports_batched_prefill(cfg: ModelConfig) -> bool:
    """True when prompts can prefill in one batched forward that also emits
    the decode cache: attention-only decoders.  SSM / RG-LRU layers carry
    recurrent state whose value at each slot's prompt boundary is not
    recoverable from the chunked full-sequence pass, and the encoder-decoder
    shares that constraint through its fallback — both use the scanned
    prefill inside ``apply_prefill`` instead (DESIGN.md §6)."""
    return (not cfg.is_encdec
            and all(cfg.layer_kind(i) == "attn" for i in range(cfg.n_layers)))


def supports_spec_decode(cfg: ModelConfig) -> bool:
    """True when draft-and-verify decode (DESIGN.md §14) preserves the
    bitwise stream contract: attention-only decoders without MoE.  SSM /
    RG-LRU recurrences have no multi-token verify form, and MoE capacity
    ranks are a cumsum over every token in a dispatch — a k-token verify row
    would compete for expert capacity with its own future draft positions,
    which sequential decode never does."""
    return supports_batched_prefill(cfg) and not cfg.n_experts


def apply_verify(params: Params, cfg: ModelConfig, tokens: jax.Array,
                 cache: Params, *, policy=None, counter=0, kv_offset=None,
                 alive=None, wcap=None):
    """Score K draft positions per slot in one forward (transformer
    ``verify_step``); requires ``supports_spec_decode(cfg)``."""
    return transformer.verify_step(params, cfg, tokens, cache, policy=policy,
                                   counter=counter, kv_offset=kv_offset,
                                   alive=alive, wcap=wcap)


def spec_commit(cache: Params, new_pos, written, *, draft_k: int) -> Params:
    """Bulk-commit + rejected-suffix scrub after a verify forward."""
    return transformer.spec_commit(cache, new_pos, written, draft_k=draft_k)


def supports_paged_kv(cfg: ModelConfig) -> bool:
    """True when the arch can serve from the paged block-pool KV cache
    (DESIGN.md §6): attention-only decoders.  Recurrent layers carry O(1)
    state with no per-position cache to page, and the encoder-decoder's
    cross-KV is a fixed full-precision tensor — both stay on the ring/dense
    layout."""
    return supports_batched_prefill(cfg)


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """True when prompts can prefill in block-aligned or ring chunks spread
    over several engine steps (DESIGN.md §11): attention-only decoders.
    Recurrent layers would need their hidden state checkpointed at every
    chunk boundary; they keep the scanned whole-prompt fallback."""
    return supports_batched_prefill(cfg)


def apply_prefill_chunked(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,    # (B, S) right-padded prompt chunks
    lengths: jax.Array,   # (B,) chunk lengths; 0 marks an inactive row
    starts: jax.Array,    # (B,) absolute start position of each chunk
    cache: Params,        # live ring cache (merged in place by the caller)
    *,
    policy=None,
    counter=0,
    kv_quant: bool = False,
    kv_offset=None,
):
    """Chunked ring prefill → (last-chunk-token logits (B, vocab_size), the
    live cache with the chunk K/V merged in).  Continuation chunks
    (``starts > 0``) re-read their slot's earlier positions from the ring
    inside attention instead of recomputing them (DESIGN.md §11)."""
    b, s = tokens.shape
    lengths = jnp.asarray(lengths, jnp.int32)
    logits, cache = transformer.prefill_with_cache_chunked(
        params, cfg, tokens, lengths, starts, cache, policy=policy,
        counter=counter, kv_quant=kv_quant, kv_offset=kv_offset)
    last = jnp.clip(lengths - 1, 0, s - 1)
    last_logits = jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]
    return last_logits, cache


def apply_prefill_paged(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,    # (B, S) right-padded prompt suffixes
    lengths: jax.Array,   # (B,) suffix lengths; 0 marks an inactive row
    starts: jax.Array,    # (B,) block-aligned absolute start positions
    block_tables: jax.Array,
    cache: Params,
    *,
    policy=None,
    counter=0,
    kv_quant: bool = False,
    kv_offset=None,
    prefix_blocks: int = 0,
):
    """Paged batched prefill → (last-suffix-token logits (B, vocab_size),
    the live cache with the suffix blocks scattered in).  Prefix-hit rows
    (``starts > 0``) skip recomputing the shared prefix — its K/V is
    gathered from the refcounted pool blocks inside attention."""
    b, s = tokens.shape
    lengths = jnp.asarray(lengths, jnp.int32)
    logits, cache = transformer.prefill_with_cache_paged(
        params, cfg, tokens, lengths, starts, block_tables, cache,
        policy=policy, counter=counter, kv_quant=kv_quant,
        kv_offset=kv_offset, prefix_blocks=prefix_blocks)
    last = jnp.clip(lengths - 1, 0, s - 1)
    last_logits = jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]
    return last_logits, cache


def merge_prefill(cfg: ModelConfig, old: Params, new: Params,
                  active: jax.Array) -> Params:
    """Per-slot cache insertion: rows of ``new`` where ``active`` (B,) bool
    replace rows of ``old`` — how a prefill result enters the engine cache."""
    if cfg.is_encdec:
        return encdec.merge_cache_encdec(old, new, active)
    return transformer.merge_cache(old, new, active)


def apply_prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,    # (B, S) right-padded prompts
    lengths: jax.Array,   # (B,) true lengths; 0 marks an inactive row
    max_len: int,
    *,
    policy=None,
    counter=0,
    kv_quant: bool = False,
    kv_offset=None,
    cache0: Optional[Params] = None,
    frames: Optional[jax.Array] = None,
):
    """Batched prefill → (last-token logits (B, vocab_size), decode cache).

    Attention-only decoders run ``transformer.prefill_with_cache`` (one
    batched forward, KV scattered into the ring cache).  Architectures with
    recurrent state (SSM / RG-LRU) or an encoder fall back to a *scanned*
    prefill: ``lax.scan`` of the decode step over the padded prompt inside
    this one jitted call (active-masked so short prompts freeze early) —
    still O(S) sequential steps, but batched on-device with no host
    round-trips.  ``cache0`` seeds the fallback (required for enc-dec, whose
    cross-KV comes from ``frames`` otherwise).
    """
    b, s = tokens.shape
    lengths = jnp.asarray(lengths, jnp.int32)
    if supports_batched_prefill(cfg):
        logits, cache = transformer.prefill_with_cache(
            params, cfg, tokens, lengths, max_len, policy=policy,
            counter=counter, kv_quant=kv_quant, kv_offset=kv_offset)
        last = jnp.clip(lengths - 1, 0, s - 1)
        last_logits = jnp.take_along_axis(
            logits, last[:, None, None], axis=1)[:, 0]
        return last_logits, cache

    if cache0 is None:
        cache0 = make_cache(params, cfg, b, max_len, frames=frames,
                            policy=policy, kv_quant=kv_quant)

    def step(carry, xs):
        cache, last_logits = carry
        tok, t = xs
        logits, new_cache = apply_decode(params, cfg, tok, cache,
                                         policy=policy, counter=counter,
                                         kv_offset=kv_offset)
        active = t < lengths
        cache = merge_prefill(cfg, cache, new_cache, active)
        last_logits = jnp.where(active[:, None], logits, last_logits)
        return (cache, last_logits), None

    init = (cache0, jnp.zeros((b, cfg.vocab_size), jnp.float32))
    (cache, last_logits), _ = jax.lax.scan(
        step, init, (tokens.T, jnp.arange(s, dtype=jnp.int32)))
    return last_logits, cache


def batch_spec(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for a training batch (launch/dryrun)."""
    spec = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.frontend == "vit_stub":
        spec["embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        spec["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_enc_tokens, cfg.d_model), jnp.bfloat16)
    return spec
