"""Architecture zoo: dense / MoE / SSM / hybrid / VLM / enc-dec backbones."""
