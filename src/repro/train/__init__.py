"""repro.train"""
