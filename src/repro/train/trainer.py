"""Training step: loss, grads, optimizer, numerics policy, microbatching.

``make_train_step`` builds the pjit-able function the launcher (and the
dry-run) lowers:  state = {params, opt, counter} → state', metrics.  The
dither counter i_s advances once per step — "rounding in time" (§VII).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.models.config import ModelConfig
from repro.numerics.policy import QuantPolicy
from repro.optim import adamw, grad_compress

__all__ = ["init_train_state", "make_train_step", "loss_fn"]


def loss_fn(params, cfg: ModelConfig, batch, policy, counter, remat=True):
    """Next-token cross entropy over the token region (frontend tokens
    skipped).  Logits stay vocab-padded (and vocab-SHARDED on TP meshes —
    DESIGN.md §5): the pad columns are masked to -∞, the softmax reductions
    over the sharded vocab axis are tiny (B,S) collectives, and the label
    gather never materialises a replicated (B,S,V) tensor."""
    logits = registry.apply_model(params, cfg, batch, policy=policy,
                                  counter=counter, remat=remat)
    tokens = batch["tokens"]
    n_front = logits.shape[1] - tokens.shape[1]
    logits = logits[:, n_front:, :]
    vp = logits.shape[-1]
    if vp != cfg.vocab_size:  # mask vocab padding out of the softmax
        pad_mask = jnp.arange(vp) < cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], logits, -1e30)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[:, 1:, None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def init_train_state(key, cfg: ModelConfig) -> Dict[str, Any]:
    params = registry.init_model(key, cfg)
    return {
        "params": params,
        "opt": adamw.init_opt_state(params),
        "counter": jnp.zeros((), jnp.int32),
    }


def make_train_step(
    cfg: ModelConfig,
    opt: adamw.AdamW,
    policy: Optional[QuantPolicy] = None,
    grad_policy: Optional[QuantPolicy] = None,
    microbatch: int = 0,
    remat: bool = True,
):
    """Build train_step(state, batch) → (state, metrics).

    ``microbatch`` > 0 splits the batch into that many sequential chunks with
    gradient accumulation via lax.scan — compute/DP-reduce overlap at scale
    and a memory knob (DESIGN.md §4).

    Policies are resolved here (``QuantPolicy.resolved``) so backend aliases
    ('auto', 'pallas') pin to a concrete kernel-dispatcher backend once, at
    build time — every dense matmul in the traced step then routes through
    kernels/dispatch.py (DESIGN.md §3).
    """
    policy = policy.resolved() if policy is not None else None
    grad_policy = grad_policy.resolved() if grad_policy is not None else None

    def grads_of(params, batch, counter):
        return jax.value_and_grad(loss_fn)(params, cfg, batch, policy, counter, remat)

    def step(state, batch):
        params, counter = state["params"], state["counter"]
        if microbatch and microbatch > 1:
            def split(x):
                # batch-major reshape + swap: the DP sharding stays on the
                # batch dim (reshaping (mb, b/mb) directly would land the
                # sharded axis on the SCAN dim → every device recomputes the
                # full µbatch).
                b = x.shape[0]
                return x.reshape(b // microbatch, microbatch,
                                 *x.shape[1:]).swapaxes(0, 1)
            mbatches = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                loss_a, g_a = carry
                loss, g = grads_of(params, mb, counter)
                return (loss_a + loss, jax.tree.map(jnp.add, g_a, g)), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.float32(0), zero_g), mbatches)
            loss = loss / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)
        else:
            loss, grads = grads_of(params, batch, counter)

        if grad_policy is not None and grad_policy.enabled:
            grads = grad_compress.compress_grads(grads, grad_policy, counter)

        new_params, new_opt, om = adamw.apply_updates(opt, params, grads, state["opt"])
        metrics = {"loss": loss, **om}
        return (
            {"params": new_params, "opt": new_opt, "counter": counter + 1},
            metrics,
        )

    return step
