"""Production meshes.  Functions, not module constants — importing this file
never touches jax device state (the dry-run sets device-count env first)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """Target topology: one v5e pod = (data=16, model=16) = 256 chips;
    multi-pod adds a leading 'pod' DP axis (2 × 256 = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_local_mesh():
    """Whatever this host has (CPU container: 1 device) as (data, model)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), axis_types=_auto(2))
