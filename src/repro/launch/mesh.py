"""Production meshes.  Functions, not module constants — importing this file
never touches jax device state (the dry-run sets device-count env first)."""

from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_local_mesh", "make_serve_mesh",
           "parse_serve_mesh"]


def _auto_kw(n):
    """axis_types kwarg on jax versions that have AxisType; {} otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """Target topology: one v5e pod = (data=16, model=16) = 256 chips;
    multi-pod adds a leading 'pod' DP axis (2 × 256 = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_kw(len(axes)))


def make_local_mesh():
    """Whatever this host has (CPU container: 1 device) as (data, model)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), **_auto_kw(2))


def make_serve_mesh(data: int, model: int):
    """A concrete ('data', 'model') mesh for the sharded serving engine
    (DESIGN.md §9).  Plain ``jax.sharding.Mesh`` — the engine runs its steps
    under ``shard_map``, which wants explicitly-managed (non-Auto) axes.
    Works on any backend; CPU CI forces devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    devs = jax.devices()
    if data * model > len(devs):
        raise ValueError(
            f"mesh ({data}, {model}) needs {data * model} devices, have "
            f"{len(devs)} (on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    arr = np.asarray(devs[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(arr, ("data", "model"))


def parse_serve_mesh(spec: str):
    """Parse a CLI ``--mesh`` value ('DATA,MODEL', e.g. '2,2') into a serve
    mesh — the one parser both launch/serve.py and serve_bench.py use, so
    the flag's syntax and errors cannot drift between them."""
    try:
        data, model = (int(x) for x in spec.split(","))
    except ValueError:
        raise ValueError(
            f"--mesh expects DATA,MODEL (e.g. '2,2'); got {spec!r}"
        ) from None
    if data < 1 or model < 1:
        raise ValueError(f"--mesh axes must be positive; got {spec!r}")
    return make_serve_mesh(data, model)
