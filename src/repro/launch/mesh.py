"""Production meshes.  Functions, not module constants — importing this file
never touches jax device state (the dry-run sets device-count env first)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def _auto_kw(n):
    """axis_types kwarg on jax versions that have AxisType; {} otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """Target topology: one v5e pod = (data=16, model=16) = 256 chips;
    multi-pod adds a leading 'pod' DP axis (2 × 256 = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_kw(len(axes)))


def make_local_mesh():
    """Whatever this host has (CPU container: 1 device) as (data, model)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), **_auto_kw(2))
