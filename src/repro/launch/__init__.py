"""repro.launch"""
