"""Serving launcher: drive the two-phase engine over a synthetic request mix.

Admits requests through the scheduler, prefills prompts with the batched
``prefill_step`` and decodes under per-request sampling (DESIGN.md §6):

  PYTHONPATH=src python -m repro.launch.serve --arch smollm_135m --reduced \
      --requests 6 --batch 4 --max-new 8 --temperature 0.8 --top-k 40 \
      --sched priority
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import registry
from repro.numerics.policy import QuantPolicy
from repro.serve.engine import Engine, Request
from repro.serve.sampling import SamplingParams


def serve_main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=5)
    ap.add_argument("--policy", default="none",
                    choices=["none", "dither", "stochastic", "deterministic"])
    ap.add_argument("--kernel-backend", default="jnp",
                    help="policy matmul backend: 'jnp' (unfused fake-quant) "
                         "or a kernel-dispatcher backend/alias "
                         "(auto, pallas, pallas-interpret, pallas-tpu, xla-ref)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="dither-quantised int8 KV cache (2× decode memory)")
    ap.add_argument("--kv-layout", default="ring", choices=["ring", "paged"],
                    help="KV cache layout: dense per-slot ring, or the paged "
                         "block pool with prefix caching + continuous "
                         "batching (attention-only archs)")
    ap.add_argument("--block-size", type=int, default=None,
                    help="paged pool block size in tokens (default: autotune "
                         "model pick)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="paged pool capacity in blocks (default: matches "
                         "the dense ring, batch × ceil(max_len/bs))")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable cross-request prefix-block reuse")
    ap.add_argument("--decode-ticks", type=int, default=1,
                    help="decode ticks fused into one device dispatch; the "
                         "host drains tokens/metrics once per window "
                         "(DESIGN.md §11)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative draft-and-verify decode (DESIGN.md "
                         "§14): prompt-lookup drafting + one multi-token "
                         "verify dispatch per window; the emitted stream "
                         "stays bitwise the plain-decode stream")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="speculative window width: 1 pending token + "
                         "draft-k - 1 drafted tokens per verify dispatch")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="piggyback chunked prefill: admit prompts in chunks "
                         "of this many tokens between decode windows "
                         "(paged: rounded to a block multiple; default: "
                         "whole-prompt prefill)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="serve sharded on a (data, model) mesh, e.g. "
                         "'2,2' (DESIGN.md §9; needs data×model devices — "
                         "on CPU force them with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 = softmax sampling")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0,
                    help="base sampling seed (request r uses seed + r)")
    ap.add_argument("--sched", default="fcfs", choices=["fcfs", "priority"],
                    help="admission policy ('priority' favours high "
                         "Request.priority; the demo gives odd rids +1)")
    ap.add_argument("--metrics", default=None, metavar="SINK",
                    help="stream per-tick engine metrics (DESIGN.md §10): "
                         "'stdout', or 'jsonl:<path>' / a *.jsonl path.  "
                         "Unset = collect but don't stream; the summary "
                         "prints either way")
    ap.add_argument("--trace", default=None, metavar="SPEC",
                    help="per-request span tracing (DESIGN.md §13): 'mem' "
                         "(in-memory, enables the end-of-run attribution "
                         "summary), 'perfetto:<path>' (Chrome-trace JSON "
                         "for ui.perfetto.dev), 'jsonl:<path>' (streaming "
                         "event feed), comma-combinable.  Unset = off "
                         "(zero overhead)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request wall-clock deadline from submission "
                         "(DESIGN.md §12): queued or running, a request "
                         "past it finishes with reason 'deadline'")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="bounded admission queue: submissions past this "
                         "depth shed per --shed-policy (DESIGN.md §12)")
    ap.add_argument("--shed-policy", default="reject-new",
                    choices=["reject-new", "evict-lowest-priority"],
                    help="what a full queue sheds: the newcomer, or the "
                         "lowest-priority queued request when the "
                         "newcomer outranks it")
    ap.add_argument("--snapshot-path", default=None, metavar="PATH",
                    help="persist an atomic engine snapshot every window "
                         "(JSON; DESIGN.md §12) — the crash-recovery point")
    ap.add_argument("--resume", action="store_true",
                    help="restore from --snapshot-path if it exists and "
                         "continue (bitwise for policy-free serving) "
                         "instead of submitting the synthetic workload")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    policy = (None if args.policy == "none"
              else QuantPolicy(scheme=args.policy, backend=args.kernel_backend))

    mesh = None
    if args.mesh:
        from repro.launch.mesh import parse_serve_mesh
        try:
            mesh = parse_serve_mesh(args.mesh)
        except ValueError as e:
            ap.error(str(e))

    params = registry.init_model(jax.random.PRNGKey(0), cfg)
    frames = (jnp.zeros((args.batch, cfg.n_enc_tokens, cfg.d_model), jnp.bfloat16)
              if cfg.is_encdec else None)
    engine = Engine(params, cfg, args.batch, args.max_len, policy=policy,
                    frames=frames, kv_quant=args.kv_quant and not cfg.is_encdec,
                    scheduler=args.sched, kv_layout=args.kv_layout,
                    block_size=args.block_size, num_blocks=args.num_blocks,
                    prefix_cache=not args.no_prefix_cache, mesh=mesh,
                    metrics=args.metrics, trace=args.trace,
                    decode_ticks=args.decode_ticks,
                    prefill_chunk=args.prefill_chunk,
                    queue_cap=args.queue_cap, shed_policy=args.shed_policy,
                    snapshot_path=args.snapshot_path,
                    spec_decode=args.spec_decode, draft_k=args.draft_k)
    resumed = False
    if args.resume and args.snapshot_path and os.path.exists(args.snapshot_path):
        with open(args.snapshot_path) as fh:
            engine.restore(json.load(fh))
        resumed = True
        print(f"resumed from {args.snapshot_path} at tick {engine.tick} "
              f"({len(engine.finished)} finished, "
              f"{len(engine.scheduler)} queued)")
    if not resumed:
        for r in range(args.requests):
            prompt = [(7 * r + i) % (cfg.vocab_size - 1) + 1
                      for i in range(args.prompt_len)]
            engine.submit(Request(
                rid=r, prompt=prompt, priority=r % 2,
                deadline_s=(args.deadline_ms / 1e3
                            if args.deadline_ms is not None else None),
                sampling=SamplingParams(temperature=args.temperature,
                                        top_k=args.top_k, seed=args.seed + r,
                                        max_new=args.max_new,
                                        counter_offset=1000 * r)))
    t0 = time.time()
    done = engine.run(ticks=args.requests * (args.max_new + 6) + 20)
    dt = time.time() - t0
    for r in sorted(done, key=lambda x: x.rid):
        ttft = f"{1e3 * r.ttft:.0f}ms" if r.ttft is not None else "-"
        print(f"req {r.rid} [{r.finish_reason}] ttft={ttft}: {r.out}")
    st = engine.stats
    pf = st["prefill_tokens"] / st["prefill_s"] if st["prefill_s"] else 0.0
    dc = st["decode_tokens"] / st["decode_s"] if st["decode_s"] else 0.0
    print(f"served {len(done)}/{args.requests} requests in {dt:.2f}s "
          f"(prefill {pf:.0f} tok/s over {st['prefill_calls']} calls, "
          f"decode {dc:.0f} tok/s over {st['decode_calls']} ticks)")
    if args.kv_layout == "paged":
        ps = engine.pool_stats()            # summed across data-shard pools
        print(f"paged pool: block_size={engine.block_size} "
              f"blocks={engine.num_blocks} allocs={ps['allocated']} "
              f"evictions={ps['evicted']} "
              f"prefix_hit_tokens={st['prefix_hit_tokens']} "
              f"preemptions={st['preemptions']} "
              f"cached_now={ps['cached']}")
    if args.spec_decode:
        mc0 = engine.metrics.summary()["counters"]
        drafted = int(mc0.get("spec_draft_tokens", 0))
        acc = int(mc0.get("spec_accepted_tokens", 0))
        rate = acc / drafted if drafted else 0.0
        print(f"spec-decode: k={args.draft_k} "
              f"windows={int(mc0.get('spec_windows', 0))} "
              f"drafted={drafted} accepted={acc} accept_rate={rate:.2f} "
              f"emitted={int(mc0.get('spec_emitted_tokens', 0))}")
    if mesh is not None:
        print(f"mesh: data={engine.dp} model={engine.tp} "
              f"heads_sharded={engine.heads_sharded} "
              f"slots/shard={args.batch // engine.dp}")
    ms = engine.metrics.summary()
    mc = ms["counters"]
    print(f"metrics: ticks={ms['ticks']} "
          f"queue_depth_mean={ms['gauges'].get('queue_depth', {}).get('mean', 0):.2f} "
          f"occupancy_mean={ms['gauges'].get('batch_occupancy', {}).get('mean', 0):.2f} "
          f"ttft_p95={1e3 * ms['ttft_s']['p95']:.1f}ms "
          f"itl_p95={1e3 * ms['itl_s']['p95']:.1f}ms "
          f"sink_errors={ms['sink_errors']}")
    print(f"fault: deadline_expired={int(mc.get('finish_deadline', 0))} "
          f"shed={int(mc.get('finish_shed', 0))} "
          f"recoveries={int(mc.get('recoveries', 0))} "
          f"slow_windows={int(mc.get('slow_windows', 0))} "
          f"degrade_events={int(mc.get('degrade_events', 0))}")
    if engine.trace.enabled:
        # end-of-run latency attribution (DESIGN.md §13): one line per
        # finished request, wall time decomposed into phase shares
        from repro.serve.trace import format_explain
        for r in sorted(done, key=lambda x: x.rid):
            print("explain " + format_explain(engine.explain(r.rid)))
        engine.trace.close()      # flush the jsonl feed, write the perfetto
        if engine.trace.perfetto_path:
            print(f"trace: wrote perfetto export to "
                  f"{engine.trace.perfetto_path} "
                  f"(open at https://ui.perfetto.dev)")
    engine.metrics.close()


if __name__ == "__main__":
    serve_main()
