"""Serving launcher: batched request demo over the decode engine.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm_135m --reduced \
      --requests 6 --batch 4 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import registry
from repro.numerics.policy import QuantPolicy
from repro.serve.engine import Engine, Request


def serve_main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--policy", default="none",
                    choices=["none", "dither", "stochastic", "deterministic"])
    ap.add_argument("--kernel-backend", default="jnp",
                    help="policy matmul backend: 'jnp' (unfused fake-quant) "
                         "or a kernel-dispatcher backend/alias "
                         "(auto, pallas, pallas-interpret, pallas-tpu, xla-ref)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="dither-quantised int8 KV cache (2× decode memory)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    policy = (None if args.policy == "none"
              else QuantPolicy(scheme=args.policy, backend=args.kernel_backend))

    params = registry.init_model(jax.random.PRNGKey(0), cfg)
    frames = (jnp.zeros((args.batch, cfg.n_enc_tokens, cfg.d_model), jnp.bfloat16)
              if cfg.is_encdec else None)
    engine = Engine(params, cfg, args.batch, args.max_len, policy=policy,
                    frames=frames, kv_quant=args.kv_quant and not cfg.is_encdec)
    for r in range(args.requests):
        prompt = [(7 * r + i) % (cfg.vocab_size - 1) + 1 for i in range(5)]
        engine.submit(Request(rid=r, prompt=prompt, max_new=args.max_new))
    t0 = time.time()
    done = engine.run(ticks=args.requests * (args.max_new + 6) + 20)
    dt = time.time() - t0
    for r in sorted(done, key=lambda x: x.rid):
        print(f"req {r.rid}: {r.out}")
    print(f"served {len(done)}/{args.requests} requests in {dt:.2f}s")


if __name__ == "__main__":
    serve_main()
