import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be the first import side effect: the two lines above run before jax
locks the device count (do not move them; do not import repro/jax first).

For each cell this proves, without hardware:
  * the sharding config is coherent (SPMD partitioning succeeds),
  * the program fits (memory_analysis per device),
  * and extracts the roofline terms (cost_analysis FLOPs/bytes + collective
    bytes parsed from the post-SPMD HLO).

Cells (DESIGN.md §5):
  train_4k     train_step   seq 4096,   global batch 256
  prefill_32k  prefill      seq 32768,  global batch 32
  decode_32k   decode_step  cache 32768, batch 128 (1 new token)
  long_500k    decode_step  cache 524288, batch 1 — sub-quadratic archs only

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single,multi --out experiments/dryrun.json
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

def np_prod(shape):
    out = 1
    for s in shape:
        out *= int(s)
    return out

from repro.configs import ARCH_IDS, get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.models.config import ModelConfig
from repro.numerics.policy import QuantPolicy
from repro.optim.adamw import AdamW
from repro.train import trainer

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# v5e-class hardware model (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s/link

_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9a-z]*)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shapes_bytes(text: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str, loop_factor: int = 1) -> Dict[str, float]:
    """Sum result bytes of every collective in the post-SPMD HLO.

    Collectives inside non-ENTRY computations (scan-over-layers while bodies,
    remat bodies) execute once per loop iteration, so they are weighted by
    ``loop_factor`` (= layer-scan trip count) — the HLO text lists them once.
    Wire accounting: all-reduce ≈ 2× its size over a ring; all-gather /
    reduce-scatter / all-to-all / permute ≈ 1×.

    bf16 normalisation: the CPU backend's float-normalisation pass upcasts
    every bf16 tensor (and all-reduce reducer) to f32 — a TPU compile keeps
    them bf16.  f32 collectives that are provably promoted bf16 (reducer
    named '*promoted*', or fed by a convert fusion) are counted at half
    size; genuine f32 collectives (fp32 logits/loss) count fully.
    """
    sums: Dict[str, float] = {}
    factor = 1.0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("(" in stripped and ")" in stripped):
            # computation header — ENTRY runs once, others are loop/remat bodies
            factor = 1.0 if stripped.startswith("ENTRY") else float(loop_factor)
            continue
        for op in _OPS:
            i = line.find(op + "(")
            if i <= 0 or line[i - 1] not in " %=":
                continue
            left = line[:i]
            if "=" not in left:
                continue
            b = _shapes_bytes(left.split("=", 1)[1])
            if "f32" in left and ("promoted" in line or "convert" in line):
                b *= 0.5  # promoted-bf16 collective: TPU moves bf16
            sums[op] = sums.get(op, 0.0) + b * factor
            break
    wire = (
        2.0 * sums.get("all-reduce", 0.0)
        + sums.get("all-gather", 0.0)
        + sums.get("reduce-scatter", 0.0)
        + sums.get("all-to-all", 0.0)
        + sums.get("collective-permute", 0.0)
    )
    sums["wire_bytes"] = wire
    return sums


def _sds_with_sharding(tree_shapes, specs, mesh):
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(mesh, shd._validated(sp, s.shape, mesh))),
        tree_shapes, specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )


def _replicated_sds(tree_shapes, mesh):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                       sharding=NamedSharding(mesh, P())),
        tree_shapes,
    )


def build_lowered(arch: str, shape: str, mesh, *, policy=None, microbatch: int = 0,
                  remat: bool = True, kv_quant: bool = False,
                  extra: dict | None = None):
    """Lower one cell.  Returns (lowered, info) or raises."""
    cfg = get_config(arch)
    meta = SHAPES[shape]
    kind, seq, batch = meta["kind"], meta["seq"], meta["batch"]
    if extra:
        cfg = cfg  # reserved for per-cell config overrides

    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(lambda k: registry.init_model(k, cfg), key)
    pspecs = shd.param_specs(params_shapes, cfg, mesh)
    params_sds = _sds_with_sharding(params_shapes, pspecs, mesh)
    dp = shd.data_axes(mesh)

    if kind == "train":
        state_shapes = jax.eval_shape(lambda k: trainer.init_train_state(k, cfg), key)
        sspecs = {
            "params": pspecs,
            "opt": {
                "m": pspecs, "v": pspecs,
                "step": P(),
            },
            "counter": P(),
        }
        state_sds = _sds_with_sharding(state_shapes, sspecs, mesh)
        bspecs = shd.batch_specs(cfg, mesh)
        batch_shapes = registry.batch_spec(cfg, batch, seq)
        batch_sds = {
            k: jax.ShapeDtypeStruct(
                v.shape, v.dtype,
                sharding=NamedSharding(mesh, shd._validated(bspecs[k], v.shape, mesh)))
            for k, v in batch_shapes.items()
        }
        step_fn = trainer.make_train_step(
            cfg, AdamW(lr=1e-4), policy=policy, microbatch=microbatch, remat=remat)
        jitted = jax.jit(step_fn, donate_argnums=(0,))
        lowered = jitted.lower(state_sds, batch_sds)
        return lowered, dict(cfg=cfg, kind=kind, seq=seq, batch=batch,
                             microbatch=microbatch)

    if kind == "prefill":
        bspecs = shd.batch_specs(cfg, mesh)
        batch_shapes = registry.batch_spec(cfg, batch, seq)
        batch_shapes.pop("labels")
        batch_sds = {
            k: jax.ShapeDtypeStruct(
                v.shape, v.dtype,
                sharding=NamedSharding(mesh, shd._validated(bspecs[k], v.shape, mesh)))
            for k, v in batch_shapes.items()
        }
        chunks = max(int(extra.get("prefill_chunks", 1)) if extra else 1, 1)

        def prefill_fn(params, b):
            if chunks > 1:
                # batch-chunked prefill (lax.map): sequences stream through
                # in waves — the serving layer's natural behaviour — cutting
                # activation HBM by the chunk count.  Batch-major split so
                # DP sharding survives the reshape (same trick as µbatch).
                def split(x):
                    n = x.shape[0]
                    return x.reshape(n // chunks, chunks,
                                     *x.shape[1:]).swapaxes(0, 1)
                bs = jax.tree.map(split, b)
                return jax.lax.map(
                    lambda mb: registry.apply_model(params, cfg, mb,
                                                    policy=policy, remat=False),
                    bs)
            return registry.apply_model(params, cfg, b, policy=policy, remat=False)

        lowered = jax.jit(prefill_fn).lower(params_sds, batch_sds)
        return lowered, dict(cfg=cfg, kind=kind, seq=seq, batch=batch,
                             prefill_chunks=chunks)

    # decode
    if not _decode_supported(cfg, shape):
        raise SkipCell(f"{arch} × {shape}: needs sub-quadratic attention "
                       f"(full-attention KV at 500k is skipped per DESIGN.md §5)")
    frames_sds = None
    if cfg.is_encdec:
        frames_sds = jax.ShapeDtypeStruct(
            (batch, cfg.n_enc_tokens, cfg.d_model), jnp.bfloat16)
    cache_shapes = jax.eval_shape(
        lambda p, f: registry.make_cache(p, cfg, batch, seq, frames=f,
                                         kv_quant=kv_quant),
        params_shapes, frames_sds,
    )
    cspecs = shd.cache_specs(cache_shapes, cfg, mesh)
    cache_sds = _sds_with_sharding(cache_shapes, cspecs, mesh)
    token_sds = jax.ShapeDtypeStruct(
        (batch,), jnp.int32,
        sharding=NamedSharding(mesh, shd._validated(P(dp), (batch,), mesh)))

    def decode_fn(params, token, cache):
        return registry.apply_decode(params, cfg, token, cache, policy=policy)

    lowered = jax.jit(decode_fn, donate_argnums=(2,)).lower(
        params_sds, token_sds, cache_sds)
    cache_bytes = sum(
        float(np_prod(l.shape)) * jnp.dtype(l.dtype).itemsize
        for l in jax.tree.leaves(cache_shapes)
    )
    return lowered, dict(cfg=cfg, kind=kind, seq=seq, batch=batch,
                         cache_bytes_global=cache_bytes)


class SkipCell(Exception):
    pass


def _decode_supported(cfg: ModelConfig, shape: str) -> bool:
    if shape != "long_500k":
        return True
    return cfg.sub_quadratic()


def analytic_memory_bytes(info, mesh) -> float:
    """Model-based per-device HBM traffic (the roofline memory term).

    The HLO-parsed byte sums reflect CPU-backend fusion granularity (every
    elementwise op streams HBM) and overestimate a real TPU compile 5-100×;
    they are recorded as diagnostics.  This analytic estimate assumes
    TPU-grade fusion:

      train:   2 param reads (fwd+bwd) + f32 optimizer m/v read+write +
               param write + ~12 activation passes per layer (remat reload
               included) over the local token slab
      prefill: 1 param read + ~6 activation passes per layer
      decode:  1 param read + 1 full cache read + cache slice write
    """
    cfg, kind = info["cfg"], info["kind"]
    tp = mesh.shape.get("model", 1)
    dp = mesh.size // tp
    p_bytes = cfg.param_count() * 2.0 / tp          # bf16 shards
    tokens_dev = info["batch"] * info["seq"] / dp
    act_pass = tokens_dev * cfg.d_model * 2.0       # one bf16 tensor pass
    L = cfg.n_layers + (cfg.n_enc_layers if cfg.is_encdec else 0)
    if kind == "train":
        opt = cfg.param_count() * (4.0 + 4.0) * 2.0 / tp   # m,v f32 r+w
        return 3.0 * p_bytes + opt + 12.0 * act_pass * L
    if kind == "prefill":
        return p_bytes + 6.0 * act_pass * L
    cache = info.get("cache_bytes_global", 0.0) / max(dp, 1)
    return p_bytes + cache


def model_flops(cfg: ModelConfig, kind: str, seq: int, batch: int) -> float:
    """6·N·D (train) / 2·N·D (forward) with N = active params."""
    n = cfg.param_count(active_only=bool(cfg.n_experts))
    if kind == "train":
        tokens = batch * seq
        return 6.0 * n * tokens
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch  # decode: one token per sequence


def loop_factors(info) -> list:
    """Per-nesting-depth while trip counts:
    [µbatches,] layer_repeats [, ssd_chunks | attention_q_chunks]."""
    cfg0 = info["cfg"]
    p_ = len(cfg0.block_pattern) if cfg0.block_pattern else 1
    rep = max(cfg0.n_layers // p_, 1)
    factors = [rep]
    if info["kind"] in ("train", "prefill"):
        if cfg0.family == "ssm":
            factors.append(max(info["seq"] // max(cfg0.ssm_chunk, 1), 1))
        elif (info["seq"] > 4096 and cfg0.n_heads
              and cfg0.n_heads % 16 == 0):
            # chunked-prefill attention scan (layers.attention)
            factors.append(info["seq"] // 4096)
    mb = info.get("microbatch", 0)
    if mb and mb > 1 and info["kind"] == "train":
        factors = [mb] + factors
    pc = info.get("prefill_chunks", 0)
    if pc and pc > 1 and info["kind"] == "prefill":
        factors = [pc] + factors
    return factors


def analyse(lowered, info, mesh) -> Dict[str, Any]:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    n_dev = mesh.size
    factors = loop_factors(info)
    rep = factors[0] if len(factors) == 1 else factors[1] if info.get("microbatch", 0) > 1 else factors[0]

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    hlo_text = compiled.as_text()
    from repro.launch.hlo_cost import hlo_cost
    weighted = hlo_cost(hlo_text, loop_factor=factors)
    raw_flops, raw_bytes = flops_dev, bytes_dev
    # loop-weighted dot flops (cost_analysis counts scan bodies 1×); the
    # memory term uses the fusion-optimistic stream-bytes estimate, with the
    # unfused upper bound recorded alongside.
    flops_dev = max(weighted["dot_flops"], flops_dev)
    bytes_upper = max(weighted["hbm_bytes"], bytes_dev)
    bytes_dev = weighted["stream_bytes"] or bytes_upper
    coll = {k: v for k, v in weighted["collectives"].items()}
    coll["wire_bytes"] = weighted["wire_bytes"]

    cfg, kind = info["cfg"], info["kind"]
    mf = model_flops(cfg, kind, info["seq"], info["batch"])
    compute_s = flops_dev / PEAK_FLOPS
    mem_model_bytes = analytic_memory_bytes(info, mesh)
    memory_s = mem_model_bytes / HBM_BW
    memory_parsed_s = bytes_dev / HBM_BW
    collective_s = coll["wire_bytes"] / ICI_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", collective_s)],
        key=lambda kv: kv[1],
    )[0]
    return {
        "devices": n_dev,
        "compile_seconds": round(compile_s, 1),
        "per_device": {
            "flops": flops_dev,
            "hbm_bytes": bytes_dev,
            "hbm_bytes_unfused_upper": bytes_upper,
            "raw_cost_analysis": {"flops": raw_flops, "bytes": raw_bytes},
            "loop_factor": rep,
            "collective_wire_bytes": coll["wire_bytes"],
            "collectives": {k: v for k, v in coll.items() if k != "wire_bytes"},
            "memory_analysis": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            },
        },
        "roofline_seconds": {
            "compute": compute_s,
            "memory": memory_s,
            "memory_hlo_parsed": memory_parsed_s,
            "collective": collective_s,
        },
        "memory_model_bytes": mem_model_bytes,
        "dominant": dominant,
        "model_flops_total": mf,
        "useful_flops_ratio": (mf / (flops_dev * n_dev)) if flops_dev else 0.0,
    }


HBM_BUDGET = 14e9  # leave ~2 GB headroom on a 16 GB v5e chip


def run_cell(arch: str, shape: str, mesh_kind: str, auto_microbatch: bool = False,
             **kw) -> Dict[str, Any]:
    devices = jax.devices()
    if mesh_kind == "multi":
        mesh = make_production_mesh(multi_pod=True)
    else:
        import numpy as np
        mesh = jax.sharding.Mesh(
            np.array(devices[:256]).reshape(16, 16), ("data", "model"))
    try:
        from repro.dist import ctx
        with ctx.mesh_context(mesh):
            mb = kw.pop("microbatch", 0) or 1
            dp_total = mesh.size // mesh.shape.get("model", 1)
            mb_cap = max(SHAPES[shape]["batch"] // dp_total, 1)
            kind = SHAPES[shape]["kind"]
            pc = 1
            while True:
                lowered, info = build_lowered(
                    arch, shape, mesh, microbatch=mb,
                    extra={"prefill_chunks": pc}, **kw)
                out = analyse(lowered, info, mesh)
                temp = out["per_device"]["memory_analysis"]["temp_bytes"]
                if not auto_microbatch or temp <= HBM_BUDGET:
                    break
                if kind == "train" and mb < mb_cap:
                    mb *= 2  # gradient accumulation until the step fits
                elif kind == "prefill" and pc < mb_cap:
                    pc *= 2  # batch-chunked prefill waves
                else:
                    break
            out["prefill_chunks"] = pc
            out["microbatch"] = mb
            out["fits_hbm"] = bool(temp <= HBM_BUDGET + 2e9)
        out.update(status="ok", arch=arch, shape=shape, mesh=mesh_kind)
    except SkipCell as e:
        out = dict(status="skip", arch=arch, shape=shape, mesh=mesh_kind, reason=str(e))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--policy", default="none",
                    choices=["none", "dither", "stochastic", "deterministic"])
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--auto-microbatch", action="store_true",
                    help="double gradient-accumulation µbatches until the "
                         "train step fits the 16 GB HBM budget")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--kv-quant", action="store_true",
                    help="dither-quantised int8 KV cache for decode cells")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")
    policy = None if args.policy == "none" else QuantPolicy(scheme=args.policy)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                if (arch, shape, mk) in done:
                    continue
                t0 = time.time()
                try:
                    r = run_cell(arch, shape, mk, policy=policy,
                                 microbatch=args.microbatch,
                                 auto_microbatch=args.auto_microbatch,
                                 remat=not args.no_remat,
                                 kv_quant=args.kv_quant)
                except Exception as e:  # noqa: BLE001 — record, keep sweeping
                    r = dict(status="error", arch=arch, shape=shape, mesh=mk,
                             error=f"{type(e).__name__}: {e}",
                             trace=traceback.format_exc()[-2000:])
                r["wall_seconds"] = round(time.time() - t0, 1)
                results.append(r)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                status = r["status"]
                dom = r.get("dominant", "-")
                print(f"[{status:5s}] {arch:24s} {shape:12s} {mk:6s} "
                      f"dom={dom} wall={r['wall_seconds']}s", flush=True)


if __name__ == "__main__":
    main()
