"""Loop-aware cost extraction from post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
scan-over-layers while body with R iterations is counted at 1/R of its true
cost.  This module rebuilds totals from the HLO text:

  * per-computation symbol tables (instruction name → shape) because
    post-optimization HLO omits operand shapes at call sites,
  * a call-graph walk assigning execution multipliers: ENTRY ×1, while
    bodies ×loop_factor (caller-supplied trip count), fusions/reducers
    inherit the caller's multiplier,
  * FLOPs from ``dot(`` ops: 2 × result_elems × contraction_size,
  * HBM traffic from "stream" ops only (dot / fusion boundaries /
    dynamic slices / gathers / collectives / custom-calls) — elementwise
    chains fuse on TPU; CPU copies/transposes are layout artifacts and are
    excluded.  dynamic-update-slice aliases its big operand (in-place cache
    write) and is charged only for the updated slice.

Validated against cost_analysis() on unrolled (scan-free) graphs in
tests/test_hlo_cost.py.
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

__all__ = ["hlo_cost", "parse_computations"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9a-z]*)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_DEF_RE = re.compile(r"^\s*%?([\w.\-]+)\s*=\s*")
_OPNAME_RE = re.compile(r"=\s*(?:\([^)]*\)|[a-z]+[0-9a-z]*\[[\d,]*\](?:\{[\d,]*\})?)\s*"
                        r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_REF_LOOP_RE = re.compile(r"(?:body|condition)=%?([\w.\-]+)")
_REF_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_REF_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")

_STREAM_OPNAMES = {
    "dot", "fusion", "custom-call", "dynamic-update-slice", "dynamic-slice",
    "gather", "scatter", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute", "convolution",
    "all-reduce-start", "all-gather-start", "collective-permute-start",
}


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shape(text: str) -> Tuple[str, List[int], int]:
    """First dtype[dims] in text → (dtype, dims list, bytes); ('', [], 0) if none."""
    for m in _SHAPE_RE.finditer(text):
        if m.group(1) in _DTYPE_BYTES:
            dims = [int(d) for d in m.group(2).split(",") if d]
            n = 1
            for d in dims:
                n *= d
            return m.group(1), dims, n * _DTYPE_BYTES[m.group(1)]
    return "", [], 0


def _all_shapes_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        if m.group(1) in _DTYPE_BYTES:
            total += _elems(m.group(2)) * _DTYPE_BYTES[m.group(1)]
    return total


def parse_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    entry = None
    cur: List[str] | None = None
    for line in hlo.splitlines():
        m = _HEADER_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            name = m.group(2)
            cur = comps.setdefault(name, [])
            if m.group(1):
                entry = name
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                cur.append(line)
    comps["__entry__"] = [entry or ""]
    return comps


def _symtab(lines: List[str]) -> Dict[str, Tuple[str, List[int], int]]:
    """instruction name → (dtype, dims, bytes) of its result (first shape)."""
    tab = {}
    for line in lines:
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        rhs = line.split("=", 1)[1]
        tab[dm.group(1)] = _first_shape(rhs)
    return tab


def _operands(line: str, opname: str) -> List[str]:
    i = line.find(opname + "(")
    if i < 0:
        return []
    seg = line[i + len(opname) + 1:]
    depth = 1
    out = []
    buf = []
    for ch in seg:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    return [m.group(1) for m in _OPERAND_RE.finditer("".join(buf))]


def _dot_flops(line: str, tab) -> float:
    res_dtype, res_dims, _ = _first_shape(line.split("=", 1)[1])
    res_elems = 1
    for d in res_dims:
        res_elems *= d
    ops = _operands(line, "dot")
    cm = _CONTRACT_RE.search(line)
    contract = 1
    if ops and cm:
        lhs = tab.get(ops[0], ("", [], 0))[1]
        for ci in (int(c) for c in cm.group(1).split(",") if c):
            if ci < len(lhs):
                contract *= lhs[ci]
    return 2.0 * res_elems * contract


def _op_traffic(line: str, opname: str, tab) -> float:
    """HBM bytes for one stream op: result + operands (symbol-table lookup)."""
    _, _, res_bytes = _first_shape(line.split("=", 1)[1])
    # tuple results: sum all shapes in the result segment
    rhs = line.split("=", 1)[1]
    head = rhs[: rhs.find(opname + "(")] if opname + "(" in rhs else rhs
    res_bytes = _all_shapes_bytes(head)
    names = _operands(line, opname)
    op_bytes = [tab.get(n, ("", [], 0))[2] for n in names]
    if opname == "dynamic-update-slice":
        # in-place: charge the update slice (operand 1), not the buffer
        return float(sum(op_bytes[1:]))
    if opname in ("dynamic-slice", "gather"):
        return 2.0 * res_bytes
    return float(res_bytes + sum(op_bytes))


_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def hlo_cost(hlo: str, loop_factor=1.0) -> Dict[str, float]:
    """Loop-weighted totals.  ``loop_factor`` is either a scalar (every while
    level multiplies by it) or a list of per-nesting-depth trip counts, e.g.
    [microbatches, layer_repeats, ssd_chunks] — while bodies at depth i
    multiply by factors[min(i, len-1)]; deeper-than-listed levels reuse the
    last entry.

    Also aggregates collective wire bytes per op kind, halving f32
    collectives that are provably promoted bf16 (CPU float-normalisation
    artifact; a TPU compile keeps them bf16 — see dryrun.collective_bytes).
    """
    factors = list(loop_factor) if isinstance(loop_factor, (list, tuple)) \
        else [float(loop_factor)]
    comps = parse_computations(hlo)
    entry = comps.pop("__entry__")[0]
    out = {"dot_flops": 0.0, "hbm_bytes": 0.0, "stream_bytes": 0.0}
    if not entry:
        out["collectives"] = {}
        return out

    mult: Dict[str, float] = {entry: 1.0}
    depth: Dict[str, int] = {entry: 0}
    fusion_internal: set = set()
    work = [entry]
    seen = {entry}
    while work:
        name = work.pop()
        f = mult.get(name, 1.0)
        d = depth.get(name, 0)
        for line in comps.get(name, ()):
            for ref in _REF_LOOP_RE.findall(line):
                step = factors[min(d, len(factors) - 1)]
                if f * step > mult.get(ref, 0.0):
                    mult[ref] = f * step
                    depth[ref] = d + 1
                if ref not in seen:
                    seen.add(ref)
                    work.append(ref)
            for ref in _REF_CALL_RE.findall(line):
                if f > mult.get(ref, 0.0):
                    mult[ref] = f
                    depth[ref] = d
                if "fusion(" in line:
                    fusion_internal.add(ref)
                if ref not in seen:
                    seen.add(ref)
                    work.append(ref)
            bm = _REF_BRANCH_RE.search(line)
            if bm:
                for ref in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                    if f > mult.get(ref, 0.0):
                        mult[ref] = f
                        depth[ref] = d
                    if ref not in seen:
                        seen.add(ref)
                        work.append(ref)

    coll: Dict[str, float] = {}
    for name, lines in comps.items():
        f = mult.get(name, 0.0)
        if f <= 0.0:
            continue
        tab = _symtab(lines)
        inside_fusion = name in fusion_internal
        for line in lines:
            om = _OPNAME_RE.search(line)
            if not om:
                continue
            opname = om.group(1)
            if opname == "dot":
                out["dot_flops"] += f * _dot_flops(line, tab)
            if inside_fusion:
                continue
            if opname in ("parameter", "constant", "get-tuple-element",
                          "tuple", "bitcast", "after-all", "opt-barrier"):
                continue
            traffic = _op_traffic(line, opname, tab)
            out["hbm_bytes"] += f * traffic
            if opname in _STREAM_OPNAMES:
                out["stream_bytes"] += f * traffic
            base = opname.split("-start")[0]
            if base in _COLLECTIVES and not opname.endswith("-done"):
                # result bytes only, from the def segment left of the op call
                head = line.split("=", 1)[1]
                head = head[: head.find(opname + "(")]
                b = _all_shapes_bytes(head)
                if "f32" in head and ("promoted" in line or "convert" in line):
                    b *= 0.5  # promoted bf16 → TPU moves bf16
                coll[base] = coll.get(base, 0.0) + f * b
    out["collectives"] = coll
    out["wire_bytes"] = (
        2.0 * coll.get("all-reduce", 0.0)
        + coll.get("all-gather", 0.0)
        + coll.get("reduce-scatter", 0.0)
        + coll.get("all-to-all", 0.0)
        + coll.get("collective-permute", 0.0)
    )
    return out
