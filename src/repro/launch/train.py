"""Training launcher: end-to-end driver with checkpoint/restart.

CPU-scale example (reduced configs) and the production entry point (full
configs under a real TPU mesh — same code path, bigger mesh):

  PYTHONPATH=src python -m repro.launch.train --arch smollm_135m --reduced \
      --steps 50 --batch 8 --seq 128 --policy dither --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.dist import ctx
from repro.dist.fault_tolerance import FailureInjector, StragglerWatchdog
from repro.launch.mesh import make_local_mesh
from repro.numerics.policy import QuantPolicy
from repro.optim.adamw import AdamW
from repro.optim.schedules import cosine_schedule, wsd_schedule
from repro.train import trainer

__all__ = ["train_main", "run_training"]


def run_training(
    cfg,
    *,
    steps: int,
    batch: int,
    seq: int,
    policy=None,
    grad_policy=None,
    ckpt_dir=None,
    ckpt_every: int = 20,
    seed: int = 0,
    schedule: str = "cosine",
    peak_lr: float = 3e-4,
    injector: FailureInjector | None = None,
    log=print,
):
    """One training run; resumes from the latest checkpoint if present.
    Returns (final_state_step, losses)."""
    mesh = make_local_mesh()
    lr = (wsd_schedule(peak_lr, 10, steps // 2, steps // 2)
          if schedule == "wsd" else cosine_schedule(peak_lr, 10, steps))
    opt = AdamW(lr=lr)
    step_fn = jax.jit(trainer.make_train_step(cfg, opt, policy=policy,
                                              grad_policy=grad_policy))
    state = trainer.init_train_state(jax.random.PRNGKey(seed), cfg)

    ck = Checkpointer(ckpt_dir) if ckpt_dir else None
    start = 0
    if ck is not None:
        latest = ck.latest_step()
        if latest is not None:
            state = ck.restore(latest, state)
            start = latest
            log(f"resumed from step {start}")

    dcfg = DataConfig(batch=batch, seq=seq, seed=seed)
    watchdog = StragglerWatchdog()
    losses = []
    with ctx.mesh_context(mesh):
        for step in range(start, steps):
            t0 = time.time()
            data = synthetic_batch(cfg, dcfg, step)
            if injector:
                injector.maybe_fail(step, "before_save")
            state, metrics = step_fn(state, data)
            losses.append(float(metrics["loss"]))
            dt = time.time() - t0
            if watchdog.observe(step, dt):
                log(f"straggler flagged at step {step} ({dt:.2f}s)")
            if ck is not None and (step + 1) % ckpt_every == 0:
                ck.save_async(step + 1, state)
                if injector:
                    injector.maybe_fail(step, "after_save")
            if step % 10 == 0:
                log(f"step {step:5d} loss {losses[-1]:.4f} ({dt*1e3:.0f} ms)")
    if ck is not None:
        ck.wait()
        ck.save(steps, state)
    return steps, losses


def train_main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--policy", default="none",
                    choices=["none", "dither", "stochastic", "deterministic"])
    ap.add_argument("--policy-bits", type=int, default=8)
    ap.add_argument("--kernel-backend", default="jnp",
                    help="policy matmul backend: 'jnp' (unfused fake-quant) "
                         "or a kernel-dispatcher backend/alias "
                         "(auto, pallas, pallas-interpret, pallas-tpu, xla-ref)")
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "dither", "stochastic"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    policy = (None if args.policy == "none"
              else QuantPolicy(scheme=args.policy, bits=args.policy_bits,
                               backend=args.kernel_backend))
    gpolicy = (None if args.grad_compress == "none"
               else QuantPolicy(scheme=args.grad_compress, bits=8))
    steps, losses = run_training(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        policy=policy, grad_policy=gpolicy, ckpt_dir=args.ckpt_dir,
        schedule=args.schedule, peak_lr=args.lr,
    )
    print(f"done: {steps} steps; loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    train_main()
