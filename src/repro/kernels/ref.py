"""Pure-jnp oracles for the Pallas kernels.

Bit-exact references: the kernels and these oracles share the same integer
hash / permutation / dither-bit math (repro.core.rounding), so tests assert
exact equality of integer codes and tight allclose on float outputs.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import rounding

__all__ = [
    "quantize_codes_ref",
    "dither_round_ref",
    "stochastic_round_ref",
    "dither_matmul_ref",
    "decode_attention_ref",
    "paged_decode_attention_ref",
    "verify_attention_ref",
    "paged_verify_attention_ref",
]


def _flat_index(shape) -> jax.Array:
    n_rows, n_cols = shape
    r = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    return r * jnp.uint32(n_cols) + c


def quantize_codes_ref(
    x: jax.Array,
    *,
    scale: float,
    zero: float,
    bits: int,
    scheme: str,
    counter: int,
    seed: int,
    n_pulses: int,
    fmt: str = "spread",
) -> jax.Array:
    """Quantise a 2-D tensor to k-bit integer codes with the given rounding.

    codes = clip(round_scheme((x - zero) * scale), 0, 2^bits - 1), where the
    element index used by the hash PRNG is the *global* flattened (row-major)
    index — the same value the tiled kernel reconstructs from its grid
    coordinates.
    """
    assert x.ndim == 2
    levels = (1 << bits) - 1
    scaled = (x.astype(jnp.float32) - zero) * scale
    idx = _flat_index(x.shape)
    if scheme == "deterministic":
        codes = rounding.deterministic_round(scaled)
    elif scheme == "stochastic":
        u = rounding.hash_uniform(seed, idx, counter)
        fl = jnp.floor(scaled)
        codes = fl + (u < scaled - fl).astype(jnp.float32)
    elif scheme == "dither":
        fl = jnp.floor(scaled)
        slot = rounding.slot_index(counter, idx, n_pulses, seed=seed, fmt=fmt)
        u = rounding.hash_uniform(seed ^ 0xD1CE, idx, counter)
        codes = fl + rounding.dither_bit(scaled - fl, slot, u, n_pulses)
    else:
        raise ValueError(scheme)
    return jnp.clip(codes, 0.0, float(levels)).astype(jnp.int32)


def dither_round_ref(x, *, scale, zero, bits, counter, seed, n_pulses):
    return quantize_codes_ref(
        x, scale=scale, zero=zero, bits=bits, scheme="dither",
        counter=counter, seed=seed, n_pulses=n_pulses,
    )


def stochastic_round_ref(x, *, scale, zero, bits, counter, seed):
    return quantize_codes_ref(
        x, scale=scale, zero=zero, bits=bits, scheme="stochastic",
        counter=counter, seed=seed, n_pulses=2,
    )


def dither_matmul_ref(
    a: jax.Array,
    b: jax.Array,
    *,
    bits: int,
    scheme: str = "dither",
    a_range=(0.0, 1.0),
    b_range=(0.0, 1.0),
    counter: int = 0,
    seed: int = 0,
    fmt: str = "spread",
) -> jax.Array:
    """Oracle for the fused quantise+matmul kernel (the §VIII 'separate' variant).

    Both operands are quantised once (A with seed, B with seed+1; dither
    N_pulses: N_A = b.shape[1], N_B = a.shape[0] per §VII), multiplied on the
    integer grid, and affinely mapped back to the real domain.
    """
    (p, q), (q2, r) = a.shape, b.shape
    assert q == q2
    levels = float((1 << bits) - 1)
    sa = levels / (a_range[1] - a_range[0])
    sb = levels / (b_range[1] - b_range[0])
    ca = quantize_codes_ref(
        a, scale=sa, zero=a_range[0], bits=bits, scheme=scheme,
        counter=counter, seed=seed, n_pulses=max(r, 2), fmt=fmt,
    ).astype(jnp.float32)
    cb = quantize_codes_ref(
        b, scale=sb, zero=b_range[0], bits=bits, scheme=scheme,
        counter=counter, seed=seed + 1, n_pulses=max(p, 2), fmt=fmt,
    ).astype(jnp.float32)
    cc = ca @ cb
    out = cc / (sa * sb)
    if a_range[0] != 0.0 or b_range[0] != 0.0:
        out = (
            out
            + a_range[0] * cb.sum(axis=0)[None, :] / sb
            + b_range[0] * ca.sum(axis=1)[:, None] / sa
            + q * a_range[0] * b_range[0]
        )
    return out


def decode_attention_ref(
    q: jax.Array,        # (B, n_kv, group, hd) bf16/f32 — post-RoPE queries
    k: jax.Array,        # (B, cap, n_kv, hd) int8 codes or bf16
    v: jax.Array,        # (B, cap, n_kv, hd)
    k_pos: jax.Array,    # (B, cap) int32
    pos: jax.Array,      # (B,) int32 per-slot absolute decode position
    k_scale: jax.Array | None = None,   # (B, cap, n_kv) f32 when int8
    v_scale: jax.Array | None = None,
    *,
    window: int = 0,
    block: tuple | None = None,
) -> jax.Array:
    """Oracle for the flash-decode attention kernel → (B, n_kv, group, hd) f32.

    The dispatch-level contract for ``decode_attention`` is the *split-K
    online-softmax recurrence over cache-length blocks* — this function IS
    that contract, in plain jnp: a ``lax.scan`` over cap/bk blocks whose
    per-block ops (int8→query-dtype upcast, f32-accumulated dot, post-dot
    scale folding, -1e30 masking, running max/sum/value state) mirror the
    Pallas kernel body op-for-op, so ``pallas-interpret`` is bit-identical
    to this oracle for the same ``block``.  Mathematically it equals the
    pre-kernel full-softmax einsum path (softmax over every valid slot);
    numerically it differs only by float-summation association — and it is
    *more* precise, since the value dot accumulates in f32 instead of the
    einsum path's bf16 probabilities (tests/test_decode_attention.py pins
    both properties).

    ``block=None`` → one block of the whole cap: the recurrence collapses
    to a single masked softmax pass — the fast XLA path the serving engine
    uses off-TPU.
    """
    # late import: the kernel module hosts shrink_block (both paths MUST
    # shrink `block` to the same divisor of cap or the bit-parity contract
    # silently breaks); it only depends on pallas at pallas_call time
    from repro.kernels.decode_attention import shrink_block

    bsz, cap, nkv, hd = k.shape
    group = q.shape[2]
    quantized = k_scale is not None
    bk = shrink_block(cap if block is None else block[0], cap)
    nb = cap // bk
    inv = float(1.0 / math.sqrt(hd))
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (bsz,))
    last = pos // bk

    def gather(x, start):
        """Per-row (bk,)-long block of axis 1, starting at slot ``start``."""
        return jax.vmap(
            lambda xb, st: jax.lax.dynamic_slice_in_dim(xb, st, bk, axis=0)
        )(x, start)

    def step(carry, j):
        m, s, acc = carry
        jc = jnp.minimum(j, last) * bk                     # clamped block start
        kb = gather(k, jc)                                 # (B, bk, n_kv, hd)
        vb = gather(v, jc)
        kpb = gather(k_pos, jc)                            # (B, bk)
        kc = kb.astype(q.dtype)
        logits = jax.lax.dot_general(
            q, kc, dimension_numbers=(((3,), (3,)), ((0, 1), (0, 2))),
            preferred_element_type=jnp.float32,
        ) * inv                                            # (B, n_kv, group, bk)
        if quantized:
            ksb = gather(k_scale, jc).transpose(0, 2, 1)   # (B, n_kv, bk)
            logits = logits * (ksb[:, :, None, :] * (1.0 / 127.0))
        kp = kpb[:, None, None, :]
        pb = pos[:, None, None, None]
        valid = (kp >= 0) & (kp <= pb)
        if window:
            valid = valid & (kp > pb - window)
        logits = jnp.where(valid, logits, -1e30)

        m_new = jnp.maximum(m, jnp.max(logits, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new)
        s_new = s * alpha + jnp.sum(p, axis=-1, keepdims=True)
        if quantized:
            vsb = gather(v_scale, jc).transpose(0, 2, 1)
            p = p * (vsb[:, :, None, :] * (1.0 / 127.0))
        acc_new = acc * alpha + jax.lax.dot_general(
            p, vb.astype(jnp.float32),
            dimension_numbers=(((3,), (1,)), ((0, 1), (0, 2))),
            preferred_element_type=jnp.float32,
        )
        act = (j <= last)[:, None, None, None]
        return (jnp.where(act, m_new, m), jnp.where(act, s_new, s),
                jnp.where(act, acc_new, acc)), None

    init = (
        jnp.full((bsz, nkv, group, 1), -jnp.inf, jnp.float32),
        jnp.zeros((bsz, nkv, group, 1), jnp.float32),
        jnp.zeros((bsz, nkv, group, hd), jnp.float32),
    )
    (m, s, acc), _ = jax.lax.scan(step, init, jnp.arange(nb, dtype=jnp.int32))
    return acc / s


def paged_decode_attention_ref(
    q: jax.Array,        # (B, n_kv, group, hd) bf16/f32 — post-RoPE queries
    k: jax.Array,        # (n_blocks, bs, n_kv, hd) int8 codes or bf16 pool
    v: jax.Array,        # (n_blocks, bs, n_kv, hd)
    block_tables: jax.Array,  # (B, nbmax) int32 physical block per logical
    pos: jax.Array,      # (B,) int32 per-slot absolute decode position
    k_scale: jax.Array | None = None,   # (n_blocks, bs, n_kv) f32 when int8
    v_scale: jax.Array | None = None,
    *,
    window: int = 0,
) -> jax.Array:
    """Oracle for the paged flash-decode kernel → (B, n_kv, group, hd) f32.

    The same split-K online-softmax recurrence as ``decode_attention_ref``,
    with the per-block gather routed through the block table: logical block
    j of slot b lives at physical pool block ``block_tables[b, j]``, and the
    key position of in-block slot t is the *implicit* ``j·bs + t`` (the pool
    is append-only; no stored k_pos).  The cache-length tile is pinned to
    the pool block size, so for bs == bk this is bit-identical to the ring
    recurrence on the same token stream — the reuse guarantee that makes
    prefix blocks shareable across requests (DESIGN.md §6)."""
    nblk, bs, nkv, hd = k.shape
    bsz = q.shape[0]
    nbmax = block_tables.shape[1]
    group = q.shape[2]
    quantized = k_scale is not None
    inv = float(1.0 / math.sqrt(hd))
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (bsz,))
    block_tables = jnp.asarray(block_tables, jnp.int32)
    last = pos // bs

    def step(carry, j):
        m, s, acc = carry
        jc = jnp.minimum(j, last)                          # clamped logical
        phys = jax.vmap(lambda bt, i: bt[i])(block_tables, jc)   # (B,)
        kb = jnp.take(k, phys, axis=0)                     # (B, bs, n_kv, hd)
        vb = jnp.take(v, phys, axis=0)
        kc = kb.astype(q.dtype)
        logits = jax.lax.dot_general(
            q, kc, dimension_numbers=(((3,), (3,)), ((0, 1), (0, 2))),
            preferred_element_type=jnp.float32,
        ) * inv                                            # (B, n_kv, group, bs)
        if quantized:
            ksb = jnp.take(k_scale, phys, axis=0).transpose(0, 2, 1)
            logits = logits * (ksb[:, :, None, :] * (1.0 / 127.0))
        kp = (jc[:, None] * bs + jnp.arange(bs, dtype=jnp.int32)[None, :]
              )[:, None, None, :]
        pb = pos[:, None, None, None]
        valid = kp <= pb
        if window:
            valid = valid & (kp > pb - window)
        logits = jnp.where(valid, logits, -1e30)

        m_new = jnp.maximum(m, jnp.max(logits, axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new)
        s_new = s * alpha + jnp.sum(p, axis=-1, keepdims=True)
        if quantized:
            vsb = jnp.take(v_scale, phys, axis=0).transpose(0, 2, 1)
            p = p * (vsb[:, :, None, :] * (1.0 / 127.0))
        acc_new = acc * alpha + jax.lax.dot_general(
            p, vb.astype(jnp.float32),
            dimension_numbers=(((3,), (1,)), ((0, 1), (0, 2))),
            preferred_element_type=jnp.float32,
        )
        act = (j <= last)[:, None, None, None]
        return (jnp.where(act, m_new, m), jnp.where(act, s_new, s),
                jnp.where(act, acc_new, acc)), None

    init = (
        jnp.full((bsz, nkv, group, 1), -jnp.inf, jnp.float32),
        jnp.zeros((bsz, nkv, group, 1), jnp.float32),
        jnp.zeros((bsz, nkv, group, hd), jnp.float32),
    )
    (m, s, acc), _ = jax.lax.scan(step, init,
                                  jnp.arange(nbmax, dtype=jnp.int32))
    return acc / s


def verify_attention_ref(
    q: jax.Array,        # (B, kq, n_kv, group, hd) — post-RoPE draft queries
    k: jax.Array,        # (B, cap, n_kv, hd) int8 codes or bf16
    v: jax.Array,        # (B, cap, n_kv, hd)
    k_pos: jax.Array,    # (B, cap) int32
    pos: jax.Array,      # (B,) int32 per-slot base (first-row) position
    k_scale: jax.Array | None = None,   # (B, cap, n_kv) f32 when int8
    v_scale: jax.Array | None = None,
    *,
    window: int = 0,
    block: tuple | None = None,
) -> jax.Array:
    """Oracle for the multi-token verify kernel → (B, kq, n_kv, group, hd)
    f32 (DESIGN.md §14).

    *Literally* ``decode_attention_ref`` once per query row: row t runs
    the one-token recurrence at position pos+t over the same cache, and
    the rows stack on axis 1.  That construction — rather than one fused
    (kq·group, bk) logit tile — is deliberate: batched dots are not
    row-pure across the M dimension on every XLA backend (1-ulp
    association drift), and the spec-decode contract is that row t's
    output is *bitwise* what sequential decode at pos+t would produce.
    The Pallas verify kernels mirror this with a static per-row loop over
    one-token-shaped dots, so kernel↔oracle parity holds per row too
    (tests/test_spec_decode.py)."""
    kq = q.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    return jnp.stack(
        [decode_attention_ref(q[:, t], k, v, k_pos, pos + t,
                              k_scale, v_scale, window=window, block=block)
         for t in range(kq)], axis=1)


def paged_verify_attention_ref(
    q: jax.Array,        # (B, kq, n_kv, group, hd) — post-RoPE draft queries
    k: jax.Array,        # (n_blocks, bs, n_kv, hd) int8 codes or bf16 pool
    v: jax.Array,        # (n_blocks, bs, n_kv, hd)
    block_tables: jax.Array,  # (B, nbmax) int32 physical block per logical
    pos: jax.Array,      # (B,) int32 per-slot base (first-row) position
    k_scale: jax.Array | None = None,   # (n_blocks, bs, n_kv) f32 when int8
    v_scale: jax.Array | None = None,
    *,
    window: int = 0,
) -> jax.Array:
    """Oracle for the paged multi-token verify kernel →
    (B, kq, n_kv, group, hd) f32.  ``paged_decode_attention_ref`` once per
    query row at position pos+t, stacked on axis 1 — by construction
    bitwise what sequential paged decode produces per row, on every
    backend (the tile is pinned to the pool block; see
    ``verify_attention_ref`` on why the rows are not fused)."""
    kq = q.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    return jnp.stack(
        [paged_decode_attention_ref(q[:, t], k, v, block_tables, pos + t,
                                    k_scale, v_scale, window=window)
         for t in range(kq)], axis=1)
