"""Pure-jnp oracles for the Pallas kernels.

Bit-exact references: the kernels and these oracles share the same integer
hash / permutation / dither-bit math (repro.core.rounding), so tests assert
exact equality of integer codes and tight allclose on float outputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import rounding

__all__ = [
    "quantize_codes_ref",
    "dither_round_ref",
    "stochastic_round_ref",
    "dither_matmul_ref",
]


def _flat_index(shape) -> jax.Array:
    n_rows, n_cols = shape
    r = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    c = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    return r * jnp.uint32(n_cols) + c


def quantize_codes_ref(
    x: jax.Array,
    *,
    scale: float,
    zero: float,
    bits: int,
    scheme: str,
    counter: int,
    seed: int,
    n_pulses: int,
    fmt: str = "spread",
) -> jax.Array:
    """Quantise a 2-D tensor to k-bit integer codes with the given rounding.

    codes = clip(round_scheme((x - zero) * scale), 0, 2^bits - 1), where the
    element index used by the hash PRNG is the *global* flattened (row-major)
    index — the same value the tiled kernel reconstructs from its grid
    coordinates.
    """
    assert x.ndim == 2
    levels = (1 << bits) - 1
    scaled = (x.astype(jnp.float32) - zero) * scale
    idx = _flat_index(x.shape)
    if scheme == "deterministic":
        codes = rounding.deterministic_round(scaled)
    elif scheme == "stochastic":
        u = rounding.hash_uniform(seed, idx, counter)
        fl = jnp.floor(scaled)
        codes = fl + (u < scaled - fl).astype(jnp.float32)
    elif scheme == "dither":
        fl = jnp.floor(scaled)
        slot = rounding.slot_index(counter, idx, n_pulses, seed=seed, fmt=fmt)
        u = rounding.hash_uniform(seed ^ 0xD1CE, idx, counter)
        codes = fl + rounding.dither_bit(scaled - fl, slot, u, n_pulses)
    else:
        raise ValueError(scheme)
    return jnp.clip(codes, 0.0, float(levels)).astype(jnp.int32)


def dither_round_ref(x, *, scale, zero, bits, counter, seed, n_pulses):
    return quantize_codes_ref(
        x, scale=scale, zero=zero, bits=bits, scheme="dither",
        counter=counter, seed=seed, n_pulses=n_pulses,
    )


def stochastic_round_ref(x, *, scale, zero, bits, counter, seed):
    return quantize_codes_ref(
        x, scale=scale, zero=zero, bits=bits, scheme="stochastic",
        counter=counter, seed=seed, n_pulses=2,
    )


def dither_matmul_ref(
    a: jax.Array,
    b: jax.Array,
    *,
    bits: int,
    scheme: str = "dither",
    a_range=(0.0, 1.0),
    b_range=(0.0, 1.0),
    counter: int = 0,
    seed: int = 0,
    fmt: str = "spread",
) -> jax.Array:
    """Oracle for the fused quantise+matmul kernel (the §VIII 'separate' variant).

    Both operands are quantised once (A with seed, B with seed+1; dither
    N_pulses: N_A = b.shape[1], N_B = a.shape[0] per §VII), multiplied on the
    integer grid, and affinely mapped back to the real domain.
    """
    (p, q), (q2, r) = a.shape, b.shape
    assert q == q2
    levels = float((1 << bits) - 1)
    sa = levels / (a_range[1] - a_range[0])
    sb = levels / (b_range[1] - b_range[0])
    ca = quantize_codes_ref(
        a, scale=sa, zero=a_range[0], bits=bits, scheme=scheme,
        counter=counter, seed=seed, n_pulses=max(r, 2), fmt=fmt,
    ).astype(jnp.float32)
    cb = quantize_codes_ref(
        b, scale=sb, zero=b_range[0], bits=bits, scheme=scheme,
        counter=counter, seed=seed + 1, n_pulses=max(p, 2), fmt=fmt,
    ).astype(jnp.float32)
    cc = ca @ cb
    out = cc / (sa * sb)
    if a_range[0] != 0.0 or b_range[0] != 0.0:
        out = (
            out
            + a_range[0] * cb.sum(axis=0)[None, :] / sb
            + b_range[0] * ca.sum(axis=1)[:, None] / sa
            + q * a_range[0] * b_range[0]
        )
    return out
