"""Kernel backend registry + dispatch (DESIGN.md §3).

One entry point per hot-path kernel — ``matmul`` (the fused §VIII 'separate'
quantise+multiply), ``quantize`` (elementwise codes), and
``decode_attention`` (flash-decode over the serving ring KV cache, int8
dither codes consumed in-kernel) — routed to one of three interchangeable
backends:

* ``pallas-tpu``       — the compiled Pallas kernels (real TPU).
* ``pallas-interpret`` — the *same* kernel bodies evaluated in Pallas
  interpret mode; slow, but bit-exact with pallas-tpu, so CPU CI exercises
  the production code path (the parity tests in tests/test_dispatch.py).
* ``xla-ref``          — the pure-jnp oracles from kernels/ref.py lowered by
  XLA; the fast CPU path and the correctness anchor all backends must match.

Selection: an explicit ``backend=`` argument wins, then the
``REPRO_KERNEL_BACKEND`` environment variable, then platform detection
(TPU → pallas-tpu, anything else → xla-ref).  The aliases ``auto`` and
``pallas`` resolve the same way (``pallas`` insists on a Pallas backend:
interpret mode off-TPU).  All schemes share one PRNG contract — codes are a
stateless hash of (seed, element index, counter) — so switching backends
never changes results, only speed.

When no ``block`` is given, Pallas backends ask the autotuner: a cached
measured winner if one exists for (shape, dtype, bits, scheme, backend),
else the VMEM-budget model pick (kernels/autotune.py).
"""

from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ref
from repro.kernels import ops as kops
from repro.kernels.decode_attention import decode_attention_call

__all__ = [
    "KernelBackend", "register_backend", "available_backends",
    "resolve_backend", "resolve_policy_backend", "matmul", "quantize",
    "decode_attention", "DEFAULT_CPU_BACKEND",
]

ENV_VAR = "REPRO_KERNEL_BACKEND"
DEFAULT_CPU_BACKEND = "xla-ref"


@dataclass(frozen=True)
class KernelBackend:
    """A named implementation of the hot-path kernels.

    ``matmul(a, b, *, bits, scheme, counter, seed, a_range, b_range, fmt,
    block)`` → (M, N) f32;  ``quantize(x, *, bits, lo, hi, scheme, counter,
    seed, n_pulses, fmt, block)`` → (M, N) int32 codes;
    ``decode_attention(q, k, v, k_pos, pos, *, k_scale, v_scale, window,
    block)`` → (B, n_kv, group, hd) f32 flash-decode attention over the ring
    KV cache.  ``block`` may be ignored by backends without a tiling concept
    — except for ``decode_attention``, where the block *is* part of the
    split-K recurrence contract and every backend honours it (xla-ref
    defaults to one whole-cap block).
    """

    name: str
    matmul: Callable
    quantize: Callable
    decode_attention: Optional[Callable] = None


_REGISTRY: dict = {}


def register_backend(backend: KernelBackend) -> KernelBackend:
    _REGISTRY[backend.name] = backend
    return backend


def available_backends() -> tuple:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------


def _make_pallas(name: str, interpret: bool) -> KernelBackend:
    def _matmul(a, b, *, bits, scheme, counter, seed, a_range, b_range, fmt,
                block):
        return kops.dither_matmul(
            a, b, bits=bits, scheme=scheme, counter=counter, seed=seed,
            a_range=a_range, b_range=b_range, fmt=fmt, block=block,
            interpret=interpret)

    def _quantize(x, *, bits, lo, hi, scheme, counter, seed, n_pulses, fmt,
                  block):
        return kops.quantize_2d(
            x, bits=bits, lo=lo, hi=hi, scheme=scheme, counter=counter,
            seed=seed, n_pulses=n_pulses, fmt=fmt, block=block,
            interpret=interpret)

    def _decode_attention(q, k, v, k_pos, pos, *, k_scale, v_scale, window,
                          block):
        return decode_attention_call(
            q, k, v, k_pos, pos, k_scale, v_scale, window=window,
            block=tuple(block), interpret=interpret)

    return register_backend(
        KernelBackend(name, _matmul, _quantize, _decode_attention))


def _make_xla_ref() -> KernelBackend:
    # jit the oracle so xla-ref is the *fast* CPU path, not an eager one;
    # counter AND seed stay traced (the hash PRNG takes them as data), so
    # seed sweeps never recompile
    @functools.partial(jax.jit, static_argnames=(
        "bits", "scheme", "a_range", "b_range", "fmt"))
    def _matmul_jit(a, b, counter, seed, *, bits, scheme, a_range, b_range,
                    fmt):
        return ref.dither_matmul_ref(
            a.astype(jnp.float32), b.astype(jnp.float32), bits=bits,
            scheme=scheme, a_range=a_range, b_range=b_range,
            counter=counter, seed=seed, fmt=fmt)

    def _matmul(a, b, *, bits, scheme, counter, seed, a_range, b_range, fmt,
                block):
        del block  # XLA fuses; no explicit tiling
        return _matmul_jit(a, b, jnp.asarray(counter, jnp.int32),
                           jnp.asarray(seed, jnp.int32), bits=bits,
                           scheme=scheme, a_range=a_range, b_range=b_range,
                           fmt=fmt)

    @functools.partial(jax.jit, static_argnames=(
        "bits", "lo", "hi", "scheme", "n_pulses", "fmt"))
    def _quantize_jit(x, counter, seed, *, bits, lo, hi, scheme, n_pulses,
                      fmt):
        scale = ((1 << bits) - 1) / (hi - lo)
        return ref.quantize_codes_ref(
            x.astype(jnp.float32), scale=scale, zero=lo, bits=bits,
            scheme=scheme, counter=counter, seed=seed, n_pulses=n_pulses,
            fmt=fmt)

    def _quantize(x, *, bits, lo, hi, scheme, counter, seed, n_pulses, fmt,
                  block):
        del block
        return _quantize_jit(x, jnp.asarray(counter, jnp.int32),
                             jnp.asarray(seed, jnp.int32), bits=bits,
                             lo=lo, hi=hi, scheme=scheme, n_pulses=n_pulses,
                             fmt=fmt)

    @functools.partial(jax.jit, static_argnames=("window", "block"))
    def _decattn_jit(q, k, v, k_pos, pos, k_scale, v_scale, *, window, block):
        return ref.decode_attention_ref(
            q, k, v, k_pos, pos, k_scale, v_scale, window=window, block=block)

    def _decode_attention(q, k, v, k_pos, pos, *, k_scale, v_scale, window,
                          block):
        # the oracle honours `block` (it is part of the split-K contract);
        # None collapses to one whole-cap block — the fast XLA serving path
        return _decattn_jit(q, k, v, k_pos, jnp.asarray(pos, jnp.int32),
                            k_scale, v_scale, window=window,
                            block=None if block is None else tuple(block))

    return register_backend(
        KernelBackend("xla-ref", _matmul, _quantize, _decode_attention))


_make_pallas("pallas-tpu", interpret=False)
_make_pallas("pallas-interpret", interpret=True)
_make_xla_ref()


# ---------------------------------------------------------------------------
# selection
# ---------------------------------------------------------------------------


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(name: Optional[str] = None) -> KernelBackend:
    """Explicit name > $REPRO_KERNEL_BACKEND > platform detection.

    Aliases: ``auto`` → pallas-tpu on TPU else the fast CPU reference;
    ``pallas`` → pallas-tpu on TPU else pallas-interpret; ``ref`` → xla-ref.
    """
    if name is None or name == "auto":
        # 'auto' (and unset) defer to the environment before the platform
        # pick, so $REPRO_KERNEL_BACKEND redirects policy-driven call sites
        # (QuantPolicy.resolved passes 'auto' explicitly).
        name = os.environ.get(ENV_VAR) or "auto"
    if name == "auto":
        name = "pallas-tpu" if _on_tpu() else DEFAULT_CPU_BACKEND
    elif name == "pallas":
        name = "pallas-tpu" if _on_tpu() else "pallas-interpret"
    elif name == "ref":
        name = "xla-ref"
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel backend {name!r}; available: {available_backends()}"
        ) from None


def resolve_policy_backend(name: str) -> str:
    """QuantPolicy.backend resolution: 'jnp' (the unfused fake-quant path)
    passes through; everything else resolves to a concrete backend name."""
    if name == "jnp":
        return name
    return resolve_backend(name).name


# ---------------------------------------------------------------------------
# dispatch entry points
# ---------------------------------------------------------------------------


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bits: int,
    scheme: str = "dither",
    counter=0,
    seed: int = 0,
    a_range: tuple = (0.0, 1.0),
    b_range: tuple = (0.0, 1.0),
    fmt: str = "spread",
    block: Optional[tuple] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Quantised A @ B through the selected backend (§VIII 'separate')."""
    be = resolve_backend(backend)
    if block is None and be.name.startswith("pallas"):
        (m, k), (_, n) = a.shape, b.shape
        block = autotune.best_block("matmul", (m, k, n), str(a.dtype), bits,
                                    scheme, be.name)
    return be.matmul(a, b, bits=bits, scheme=scheme, counter=counter,
                     seed=seed, a_range=a_range, b_range=b_range, fmt=fmt,
                     block=block)


def quantize(
    x: jax.Array,
    *,
    bits: int,
    lo: float = 0.0,
    hi: float = 1.0,
    scheme: str = "dither",
    counter=0,
    seed: int = 0,
    n_pulses: int = 16,
    fmt: str = "spread",
    block: Optional[tuple] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """k-bit integer codes of ``x`` through the selected backend."""
    be = resolve_backend(backend)
    if block is None and be.name.startswith("pallas"):
        block = autotune.best_block("quantize", x.shape, str(x.dtype), bits,
                                    scheme, be.name)
    return be.quantize(x, bits=bits, lo=lo, hi=hi, scheme=scheme,
                       counter=counter, seed=seed, n_pulses=n_pulses, fmt=fmt,
                       block=block)


def decode_attention(
    q: jax.Array,        # (B, n_kv_heads, group, hd) — post-RoPE queries
    k: jax.Array,        # (B, cap, n_kv_heads, hd) int8 codes or bf16
    v: jax.Array,        # (B, cap, n_kv_heads, hd)
    k_pos: jax.Array,    # (B, cap) int32 absolute position per ring slot
    pos: jax.Array,      # (B,) int32 per-slot decode position
    *,
    k_scale: Optional[jax.Array] = None,   # (B, cap, n_kv_heads) f32 when int8
    v_scale: Optional[jax.Array] = None,
    window: int = 0,
    block: Optional[tuple] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Flash-decode attention over the ring KV cache → (B, n_kv, group, hd)
    f32, through the selected backend (DESIGN.md §2/§6).

    The int8 dither-quantised cache is consumed as codes — upcast tile-by-
    tile in VMEM, scales folded in after the dot — so the decode path never
    materialises a full-cap fp copy of the cache.  ``block=(bk,)`` is the
    cache-length tile of the split-K online-softmax recurrence; Pallas
    backends autotune it, xla-ref defaults to one whole-cap block.
    """
    be = resolve_backend(backend)
    if block is None and be.name.startswith("pallas"):
        b, cap, nkv, hd = k.shape
        group = q.shape[2]
        bits = 8 if k.dtype == jnp.int8 else 16
        block = autotune.best_block("decode_attention",
                                    (b, cap, nkv, group, hd), str(k.dtype),
                                    bits, "flash", be.name)
    return be.decode_attention(q, k, v, k_pos, pos, k_scale=k_scale,
                               v_scale=v_scale, window=window, block=block)
