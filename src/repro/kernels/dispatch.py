"""Kernel backend registry + dispatch (DESIGN.md §3).

One entry point per hot-path kernel — ``matmul`` (the fused §VIII 'separate'
quantise+multiply), ``quantize`` (elementwise codes), ``decode_attention``
(flash-decode over the serving ring KV cache, int8 dither codes consumed
in-kernel), ``paged_decode_attention`` (the same recurrence over the
paged block pool, gathered through a scalar-prefetched block table) and
their multi-token ``verify_attention`` / ``paged_verify_attention``
variants (k speculative query rows per slot, DESIGN.md §14) —
routed to one of three interchangeable backends:

* ``pallas-tpu``       — the compiled Pallas kernels (real TPU).
* ``pallas-interpret`` — the *same* kernel bodies evaluated in Pallas
  interpret mode; slow, but bit-exact with pallas-tpu, so CPU CI exercises
  the production code path (the parity tests in tests/test_dispatch.py).
* ``xla-ref``          — the pure-jnp oracles from kernels/ref.py lowered by
  XLA; the fast CPU path and the correctness anchor all backends must match.

Selection: an explicit ``backend=`` argument wins, then the
``REPRO_KERNEL_BACKEND`` environment variable, then platform detection
(TPU → pallas-tpu, anything else → xla-ref).  The aliases ``auto`` and
``pallas`` resolve the same way (``pallas`` insists on a Pallas backend:
interpret mode off-TPU).  All schemes share one PRNG contract — codes are a
stateless hash of (seed, element index, counter) — so switching backends
never changes results, only speed.

When no ``block`` is given, Pallas backends ask the autotuner: a cached
measured winner if one exists for (shape, dtype, bits, scheme, backend),
else the VMEM-budget model pick (kernels/autotune.py).

Sharded serving (DESIGN.md §9) calls every entry point *inside*
``shard_map``: the kernels see shard-local shapes — B/dp batch rows,
n_kv_heads/tp heads, the data shard's local block pool — and need no
mesh awareness of their own; per-shard results are bitwise the
single-device ones because batch rows and KV heads are embarrassingly
parallel dims of every kernel here.
"""

from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ref
from repro.kernels import ops as kops
from repro.kernels.decode_attention import (decode_attention_call,
                                            paged_decode_attention_call,
                                            paged_verify_attention_call,
                                            verify_attention_call)

__all__ = [
    "KernelBackend", "register_backend", "available_backends",
    "resolve_backend", "resolve_policy_backend", "matmul", "quantize",
    "decode_attention", "paged_decode_attention",
    "verify_attention", "paged_verify_attention", "DEFAULT_CPU_BACKEND",
]

ENV_VAR = "REPRO_KERNEL_BACKEND"
DEFAULT_CPU_BACKEND = "xla-ref"


@dataclass(frozen=True)
class KernelBackend:
    """A named implementation of the hot-path kernels.

    ``matmul(a, b, *, bits, scheme, counter, seed, a_range, b_range, fmt,
    block)`` → (M, N) f32;  ``quantize(x, *, bits, lo, hi, scheme, counter,
    seed, n_pulses, fmt, block)`` → (M, N) int32 codes;
    ``decode_attention(q, k, v, k_pos, pos, *, k_scale, v_scale, window,
    block)`` → (B, n_kv, group, hd) f32 flash-decode attention over the ring
    KV cache.  ``block`` may be ignored by backends without a tiling concept
    — except for ``decode_attention``, where the block *is* part of the
    split-K recurrence contract and every backend honours it (xla-ref
    defaults to one whole-cap block).  ``paged_decode_attention(q, k, v,
    block_tables, pos, *, k_scale, v_scale, window)`` is the paged-pool
    variant (DESIGN.md §6): the cache tile is pinned to the pool block size
    by the array layout, so it takes no ``block`` argument.
    """

    name: str
    matmul: Callable
    quantize: Callable
    decode_attention: Optional[Callable] = None
    paged_decode_attention: Optional[Callable] = None
    # multi-token verify variants (speculative decoding, DESIGN.md §14):
    # ``verify_attention(q, k, v, k_pos, pos, *, k_scale, v_scale, window,
    # block)`` scores (B, kq, n_kv, group, hd) draft queries; the paged
    # variant again takes no block (tile = pool block)
    verify_attention: Optional[Callable] = None
    paged_verify_attention: Optional[Callable] = None


_REGISTRY: dict = {}


def register_backend(backend: KernelBackend) -> KernelBackend:
    _REGISTRY[backend.name] = backend
    return backend


def available_backends() -> tuple:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------


def _make_pallas(name: str, interpret: bool) -> KernelBackend:
    def _matmul(a, b, *, bits, scheme, counter, seed, a_range, b_range, fmt,
                block):
        return kops.dither_matmul(
            a, b, bits=bits, scheme=scheme, counter=counter, seed=seed,
            a_range=a_range, b_range=b_range, fmt=fmt, block=block,
            interpret=interpret)

    def _quantize(x, *, bits, lo, hi, scheme, counter, seed, n_pulses, fmt,
                  block):
        return kops.quantize_2d(
            x, bits=bits, lo=lo, hi=hi, scheme=scheme, counter=counter,
            seed=seed, n_pulses=n_pulses, fmt=fmt, block=block,
            interpret=interpret)

    def _decode_attention(q, k, v, k_pos, pos, *, k_scale, v_scale, window,
                          block):
        return decode_attention_call(
            q, k, v, k_pos, pos, k_scale, v_scale, window=window,
            block=tuple(block), interpret=interpret)

    def _paged_decode_attention(q, k, v, block_tables, pos, *, k_scale,
                                v_scale, window):
        return paged_decode_attention_call(
            q, k, v, block_tables, pos, k_scale, v_scale, window=window,
            interpret=interpret)

    def _verify_attention(q, k, v, k_pos, pos, *, k_scale, v_scale, window,
                          block):
        return verify_attention_call(
            q, k, v, k_pos, pos, k_scale, v_scale, window=window,
            block=tuple(block), interpret=interpret)

    def _paged_verify_attention(q, k, v, block_tables, pos, *, k_scale,
                                v_scale, window):
        return paged_verify_attention_call(
            q, k, v, block_tables, pos, k_scale, v_scale, window=window,
            interpret=interpret)

    return register_backend(
        KernelBackend(name, _matmul, _quantize, _decode_attention,
                      _paged_decode_attention, _verify_attention,
                      _paged_verify_attention))


def _make_xla_ref() -> KernelBackend:
    # jit the oracle so xla-ref is the *fast* CPU path, not an eager one;
    # counter AND seed stay traced (the hash PRNG takes them as data), so
    # seed sweeps never recompile
    @functools.partial(jax.jit, static_argnames=(
        "bits", "scheme", "a_range", "b_range", "fmt"))
    def _matmul_jit(a, b, counter, seed, *, bits, scheme, a_range, b_range,
                    fmt):
        return ref.dither_matmul_ref(
            a.astype(jnp.float32), b.astype(jnp.float32), bits=bits,
            scheme=scheme, a_range=a_range, b_range=b_range,
            counter=counter, seed=seed, fmt=fmt)

    def _matmul(a, b, *, bits, scheme, counter, seed, a_range, b_range, fmt,
                block):
        del block  # XLA fuses; no explicit tiling
        return _matmul_jit(a, b, jnp.asarray(counter, jnp.int32),
                           jnp.asarray(seed, jnp.int32), bits=bits,
                           scheme=scheme, a_range=a_range, b_range=b_range,
                           fmt=fmt)

    @functools.partial(jax.jit, static_argnames=(
        "bits", "lo", "hi", "scheme", "n_pulses", "fmt"))
    def _quantize_jit(x, counter, seed, *, bits, lo, hi, scheme, n_pulses,
                      fmt):
        scale = ((1 << bits) - 1) / (hi - lo)
        return ref.quantize_codes_ref(
            x.astype(jnp.float32), scale=scale, zero=lo, bits=bits,
            scheme=scheme, counter=counter, seed=seed, n_pulses=n_pulses,
            fmt=fmt)

    def _quantize(x, *, bits, lo, hi, scheme, counter, seed, n_pulses, fmt,
                  block):
        del block
        return _quantize_jit(x, jnp.asarray(counter, jnp.int32),
                             jnp.asarray(seed, jnp.int32), bits=bits,
                             lo=lo, hi=hi, scheme=scheme, n_pulses=n_pulses,
                             fmt=fmt)

    @functools.partial(jax.jit, static_argnames=("window", "block"))
    def _decattn_jit(q, k, v, k_pos, pos, k_scale, v_scale, *, window, block):
        return ref.decode_attention_ref(
            q, k, v, k_pos, pos, k_scale, v_scale, window=window, block=block)

    def _decode_attention(q, k, v, k_pos, pos, *, k_scale, v_scale, window,
                          block):
        # the oracle honours `block` (it is part of the split-K contract);
        # None collapses to one whole-cap block — the fast XLA serving path
        return _decattn_jit(q, k, v, k_pos, jnp.asarray(pos, jnp.int32),
                            k_scale, v_scale, window=window,
                            block=None if block is None else tuple(block))

    @functools.partial(jax.jit, static_argnames=("window",))
    def _paged_jit(q, k, v, block_tables, pos, k_scale, v_scale, *, window):
        return ref.paged_decode_attention_ref(
            q, k, v, block_tables, pos, k_scale, v_scale, window=window)

    def _paged_decode_attention(q, k, v, block_tables, pos, *, k_scale,
                                v_scale, window):
        # the paged recurrence's tile is the pool block itself, so the
        # oracle runs the exact kernel recurrence — no whole-cap collapse
        return _paged_jit(q, k, v, block_tables,
                          jnp.asarray(pos, jnp.int32), k_scale, v_scale,
                          window=window)

    @functools.partial(jax.jit, static_argnames=("window", "block"))
    def _verify_jit(q, k, v, k_pos, pos, k_scale, v_scale, *, window, block):
        return ref.verify_attention_ref(
            q, k, v, k_pos, pos, k_scale, v_scale, window=window, block=block)

    def _verify_attention(q, k, v, k_pos, pos, *, k_scale, v_scale, window,
                          block):
        # same block semantics as decode: None collapses to one whole-cap
        # block, which is also what the serving decode path uses off-TPU —
        # keeping verify and decode on the same association order is what
        # makes the spec-decode stream bitwise ≡ plain decode (DESIGN.md §14)
        return _verify_jit(q, k, v, k_pos, jnp.asarray(pos, jnp.int32),
                           k_scale, v_scale, window=window,
                           block=None if block is None else tuple(block))

    @functools.partial(jax.jit, static_argnames=("window",))
    def _paged_verify_jit(q, k, v, block_tables, pos, k_scale, v_scale, *,
                          window):
        return ref.paged_verify_attention_ref(
            q, k, v, block_tables, pos, k_scale, v_scale, window=window)

    def _paged_verify_attention(q, k, v, block_tables, pos, *, k_scale,
                                v_scale, window):
        return _paged_verify_jit(q, k, v, block_tables,
                                 jnp.asarray(pos, jnp.int32), k_scale,
                                 v_scale, window=window)

    return register_backend(
        KernelBackend("xla-ref", _matmul, _quantize, _decode_attention,
                      _paged_decode_attention, _verify_attention,
                      _paged_verify_attention))


_make_pallas("pallas-tpu", interpret=False)
_make_pallas("pallas-interpret", interpret=True)
_make_xla_ref()


# ---------------------------------------------------------------------------
# selection
# ---------------------------------------------------------------------------


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(name: Optional[str] = None) -> KernelBackend:
    """Explicit name > $REPRO_KERNEL_BACKEND > platform detection.

    Aliases: ``auto`` → pallas-tpu on TPU else the fast CPU reference;
    ``pallas`` → pallas-tpu on TPU else pallas-interpret; ``ref`` → xla-ref.
    """
    if name is None or name == "auto":
        # 'auto' (and unset) defer to the environment before the platform
        # pick, so $REPRO_KERNEL_BACKEND redirects policy-driven call sites
        # (QuantPolicy.resolved passes 'auto' explicitly).
        name = os.environ.get(ENV_VAR) or "auto"
    if name == "auto":
        name = "pallas-tpu" if _on_tpu() else DEFAULT_CPU_BACKEND
    elif name == "pallas":
        name = "pallas-tpu" if _on_tpu() else "pallas-interpret"
    elif name == "ref":
        name = "xla-ref"
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel backend {name!r}; available: {available_backends()}"
        ) from None


def resolve_policy_backend(name: str) -> str:
    """QuantPolicy.backend resolution: 'jnp' (the unfused fake-quant path)
    passes through; everything else resolves to a concrete backend name."""
    if name == "jnp":
        return name
    return resolve_backend(name).name


# ---------------------------------------------------------------------------
# dispatch entry points
# ---------------------------------------------------------------------------


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bits: int,
    scheme: str = "dither",
    counter=0,
    seed: int = 0,
    a_range: tuple = (0.0, 1.0),
    b_range: tuple = (0.0, 1.0),
    fmt: str = "spread",
    block: Optional[tuple] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Quantised A @ B through the selected backend (§VIII 'separate')."""
    be = resolve_backend(backend)
    if block is None and be.name.startswith("pallas"):
        (m, k), (_, n) = a.shape, b.shape
        block = autotune.best_block("matmul", (m, k, n), str(a.dtype), bits,
                                    scheme, be.name)
    return be.matmul(a, b, bits=bits, scheme=scheme, counter=counter,
                     seed=seed, a_range=a_range, b_range=b_range, fmt=fmt,
                     block=block)


def quantize(
    x: jax.Array,
    *,
    bits: int,
    lo: float = 0.0,
    hi: float = 1.0,
    scheme: str = "dither",
    counter=0,
    seed: int = 0,
    n_pulses: int = 16,
    fmt: str = "spread",
    block: Optional[tuple] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """k-bit integer codes of ``x`` through the selected backend."""
    be = resolve_backend(backend)
    if block is None and be.name.startswith("pallas"):
        block = autotune.best_block("quantize", x.shape, str(x.dtype), bits,
                                    scheme, be.name)
    return be.quantize(x, bits=bits, lo=lo, hi=hi, scheme=scheme,
                       counter=counter, seed=seed, n_pulses=n_pulses, fmt=fmt,
                       block=block)


def decode_attention(
    q: jax.Array,        # (B, n_kv_heads, group, hd) — post-RoPE queries
    k: jax.Array,        # (B, cap, n_kv_heads, hd) int8 codes or bf16
    v: jax.Array,        # (B, cap, n_kv_heads, hd)
    k_pos: jax.Array,    # (B, cap) int32 absolute position per ring slot
    pos: jax.Array,      # (B,) int32 per-slot decode position
    *,
    k_scale: Optional[jax.Array] = None,   # (B, cap, n_kv_heads) f32 when int8
    v_scale: Optional[jax.Array] = None,
    window: int = 0,
    block: Optional[tuple] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Flash-decode attention over the ring KV cache → (B, n_kv, group, hd)
    f32, through the selected backend (DESIGN.md §2/§6).

    The int8 dither-quantised cache is consumed as codes — upcast tile-by-
    tile in VMEM, scales folded in after the dot — so the decode path never
    materialises a full-cap fp copy of the cache.  ``block=(bk,)`` is the
    cache-length tile of the split-K online-softmax recurrence; Pallas
    backends autotune it, xla-ref defaults to one whole-cap block.
    """
    be = resolve_backend(backend)
    if block is None and be.name.startswith("pallas"):
        b, cap, nkv, hd = k.shape
        group = q.shape[2]
        bits = 8 if k.dtype == jnp.int8 else 16
        block = autotune.best_block("decode_attention",
                                    (b, cap, nkv, group, hd), str(k.dtype),
                                    bits, "flash", be.name)
    return be.decode_attention(q, k, v, k_pos, pos, k_scale=k_scale,
                               v_scale=v_scale, window=window, block=block)


def paged_decode_attention(
    q: jax.Array,        # (B, n_kv_heads, group, hd) — post-RoPE queries
    k: jax.Array,        # (n_blocks, bs, n_kv_heads, hd) int8 codes or bf16
    v: jax.Array,        # (n_blocks, bs, n_kv_heads, hd)
    block_tables: jax.Array,  # (B, nbmax) int32 physical block per logical
    pos: jax.Array,      # (B,) int32 per-slot decode position
    *,
    k_scale: Optional[jax.Array] = None,  # (n_blocks, bs, n_kv) f32 when int8
    v_scale: Optional[jax.Array] = None,
    window: int = 0,
    backend: Optional[str] = None,
) -> jax.Array:
    """Paged flash-decode attention over the block-pool KV cache →
    (B, n_kv, group, hd) f32, through the selected backend (DESIGN.md §6).

    The split-K tile is the pool block itself (``bs = k.shape[1]``, chosen
    at pool-creation time from ``autotune.best_block('paged_attention',
    ...)``), so unlike the ring entry point there is no per-call ``block``:
    every backend runs the same per-block recurrence, and ``xla-ref`` is
    the bit-exact oracle rather than a whole-cap collapse.  The Pallas
    backends gather cache tiles through the scalar-prefetched block table,
    which is what makes refcount-shared prefix blocks readable by several
    requests at once without any copy.
    """
    be = resolve_backend(backend)
    return be.paged_decode_attention(q, k, v, block_tables, pos,
                                     k_scale=k_scale, v_scale=v_scale,
                                     window=window)


def verify_attention(
    q: jax.Array,        # (B, kq, n_kv_heads, group, hd) — draft queries
    k: jax.Array,        # (B, cap, n_kv_heads, hd) int8 codes or bf16
    v: jax.Array,        # (B, cap, n_kv_heads, hd)
    k_pos: jax.Array,    # (B, cap) int32 absolute position per ring slot
    pos: jax.Array,      # (B,) int32 per-slot base (first-row) position
    *,
    k_scale: Optional[jax.Array] = None,   # (B, cap, n_kv_heads) f32
    v_scale: Optional[jax.Array] = None,
    window: int = 0,
    block: Optional[tuple] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Multi-token verify attention over the ring KV cache →
    (B, kq, n_kv, group, hd) f32, through the selected backend
    (DESIGN.md §14).

    Query row t of slot b attends as if decoding at position ``pos[b]+t``
    (per-row causal mask and processed-block freeze), so accepted draft
    rows reproduce sequential decode's attention bitwise on the same tile.
    Pallas backends autotune ``block=(bk,)`` under the kq·group-row working
    set; xla-ref defaults to one whole-cap block — the same association
    order as its one-token decode path, which is what the engine's
    spec-decode stream-parity contract relies on off-TPU.
    """
    be = resolve_backend(backend)
    if block is None and be.name.startswith("pallas"):
        b, cap, nkv, hd = k.shape
        kq, group = q.shape[1], q.shape[3]
        bits = 8 if k.dtype == jnp.int8 else 16
        block = autotune.best_block("verify_attention",
                                    (b, cap, nkv, kq, group, hd),
                                    str(k.dtype), bits, "flash", be.name)
    return be.verify_attention(q, k, v, k_pos, pos, k_scale=k_scale,
                               v_scale=v_scale, window=window, block=block)


def paged_verify_attention(
    q: jax.Array,        # (B, kq, n_kv_heads, group, hd) — draft queries
    k: jax.Array,        # (n_blocks, bs, n_kv_heads, hd) int8 codes or bf16
    v: jax.Array,        # (n_blocks, bs, n_kv_heads, hd)
    block_tables: jax.Array,  # (B, nbmax) int32 physical block per logical
    pos: jax.Array,      # (B,) int32 per-slot base (first-row) position
    *,
    k_scale: Optional[jax.Array] = None,  # (n_blocks, bs, n_kv) f32
    v_scale: Optional[jax.Array] = None,
    window: int = 0,
    backend: Optional[str] = None,
) -> jax.Array:
    """Paged multi-token verify attention → (B, kq, n_kv, group, hd) f32.

    The tile is the pool block (no per-call ``block``), so every backend
    runs the identical per-row recurrence and row t matches sequential
    paged decode at position pos+t bitwise — tile-pinned stream parity on
    every backend, not just xla-ref (DESIGN.md §14).
    """
    be = resolve_backend(backend)
    return be.paged_verify_attention(q, k, v, block_tables, pos,
                                     k_scale=k_scale, v_scale=v_scale,
                                     window=window)
