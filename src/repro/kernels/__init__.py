"""Pallas TPU kernels for the compute hot-spots (quantise, fused matmul,
flash-decode attention over the serving ring KV cache).

Each kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper),
ref.py (pure-jnp oracle, bit-exact).

``dispatch.py`` is the public entry layer: a backend registry (pallas-tpu /
pallas-interpret / xla-ref) with platform detection and explicit override,
fed tile shapes by the ``autotune.py`` block-size autotuner (DESIGN.md §3).
Callers — core/matmul, numerics/policy, train, serve — go through dispatch
rather than importing kernels directly.
"""
