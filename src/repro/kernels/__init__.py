"""Pallas TPU kernels for the compute hot-spots (quantise, fused matmul).

Each kernel: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper),
ref.py (pure-jnp oracle, bit-exact).
"""
