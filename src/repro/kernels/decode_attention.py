"""Pallas TPU kernels: flash-decode attention over the KV cache
(DESIGN.md §2/§3 — the serving hot path, one query token per slot), in two
cache layouts: the dense per-slot ring buffer (``decode_attention_call``)
and the paged block pool (``paged_decode_attention_call``, DESIGN.md §6).

One grid program per (batch slot, kv head, cache-length block):

  grid = (B, n_kv_heads, cap/bk), cache-length innermost (sequential);
  each step streams one (bk, hd) K tile and V tile through VMEM, computes
  the (group, bk) logit tile for the slot's GQA query group, and folds it
  into an online-softmax state (running max m, running sum s, f32 value
  accumulator) held in VMEM scratch — the classic split-K flash-decode
  recurrence, so the full (cap,) logit row is never materialised.

The int8 dither-quantised cache is consumed *as codes*: the K tile is
upcast int8→bf16 in registers (tile-sized, never the full cache), the dot
runs int8-codes·bf16-query with f32 accumulation, and the per-position
``k_scale``/``v_scale`` fold in *after* the dot — the paper's "compute on
the pulse-coded representation" argument applied to attention (the same
fold as the unary dot-products of arXiv:2307.03204).  Keeping the codes
un-dequantised in HBM is what preserves the §VII variance analysis
(arXiv:2207.10321) and cuts decode-attention HBM traffic from
O(cap·hd·4 B) fp to O(cap·hd·1 B) codes per head per token.

Masking is in-kernel: slot validity (``k_pos >= 0``), causality
(``k_pos <= pos``), and the sliding window (``k_pos > pos - window``) are
evaluated per K tile.  **Length-aware block skipping**: the per-slot
position array is a scalar-prefetch operand, so the K/V BlockSpec index
maps clamp the cache-block index to ``pos // bk`` — Pallas elides the
copy when the block index repeats, and a ``pl.when`` guard skips the
compute, so a slot at position p reads ceil((p+1)/bk) blocks instead of
all of cap.

Numerics contract: the recurrence (op order, f32 state, -1e30 mask) is
mirrored exactly by ``kernels/ref.decode_attention_ref`` — the
``xla-ref`` dispatcher backend — so ``pallas-interpret`` is bit-identical
to the oracle for the same ``block`` (tests/test_decode_attention.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_call", "paged_decode_attention_call",
           "verify_attention_call", "paged_verify_attention_call",
           "shrink_block"]

# renamed TPUCompilerParams -> CompilerParams across jax versions
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_NEG_BIG = -1e30  # matches the pre-kernel einsum path's mask value


def shrink_block(bk: int, cap: int) -> int:
    """Largest block size ≤ bk that divides cap (cap stays un-padded: ring
    slots are positional state, padding would invent phantom slots)."""
    bk = max(1, min(bk, cap))
    while cap % bk:
        bk -= 1
    return bk


def _attn_body(
    pos_ref,        # scalar prefetch: (B,) int32 per-slot absolute positions
    q_ref,          # (1, 1, group, hd)
    k_ref,          # (1, bk, 1, hd) int8 codes or bf16
    v_ref,          # (1, bk, 1, hd)
    ks_ref,         # (1, 1, bk) f32 — only when quantized
    vs_ref,         # (1, 1, bk) f32 — only when quantized
    kpos_ref,       # (1, bk) int32
    out_ref,        # (1, 1, group, hd) f32
    m_ref,          # scratch (group, 1) f32 — running max
    s_ref,          # scratch (group, 1) f32 — running sum of exp
    acc_ref,        # scratch (group, hd) f32 — value accumulator
    *,
    bk: int,
    group: int,
    hd: int,
    window: int,
    quantized: bool,
):
    b, j = pl.program_id(0), pl.program_id(2)
    nb = pl.num_programs(2)
    pos_b = pos_ref[b]
    last = pos_b // bk  # blocks past this hold only unwritten (k_pos=-1) slots

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full((group, 1), -jnp.inf, jnp.float32)
        s_ref[...] = jnp.zeros((group, 1), jnp.float32)
        acc_ref[...] = jnp.zeros((group, hd), jnp.float32)

    @pl.when(j <= last)
    def _accumulate():
        q = q_ref[...].reshape(group, hd)
        kc = k_ref[...].reshape(bk, hd).astype(q.dtype)  # int8→bf16 upcast, tile only
        logits = jax.lax.dot_general(
            q, kc, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * float(1.0 / math.sqrt(hd))                   # (group, bk)
        if quantized:
            # per-position key scales fold in after the codes dot
            logits = logits * (ks_ref[...].reshape(1, bk) * (1.0 / 127.0))
        kp = kpos_ref[...].reshape(1, bk)
        valid = (kp >= 0) & (kp <= pos_b)
        if window:
            valid = valid & (kp > pos_b - window)
        logits = jnp.where(valid, logits, _NEG_BIG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)                       # (group, bk)
        m_ref[...] = m_new
        s_ref[...] = s_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        if quantized:
            # per-position value scales attach to the (unnormalised) weights
            p = p * (vs_ref[...].reshape(1, bk) * (1.0 / 127.0))
        vc = v_ref[...].reshape(bk, hd).astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, vc, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(j == nb - 1)
    def _finish():
        out_ref[...] = (acc_ref[...] / s_ref[...]).reshape(1, 1, group, hd)


@functools.partial(
    jax.jit, static_argnames=("window", "block", "interpret"),
)
def decode_attention_call(
    q: jax.Array,        # (B, n_kv, group, hd) bf16/f32 — post-RoPE queries
    k: jax.Array,        # (B, cap, n_kv, hd) int8 codes or bf16
    v: jax.Array,        # (B, cap, n_kv, hd)
    k_pos: jax.Array,    # (B, cap) int32 — absolute position per ring slot
    pos: jax.Array,      # (B,) int32 — per-slot absolute decode position
    k_scale: jax.Array | None = None,   # (B, cap, n_kv) f32 when int8
    v_scale: jax.Array | None = None,
    *,
    window: int = 0,
    block: tuple = (512,),
    interpret: bool = True,
) -> jax.Array:
    """Flash-decode attention over the ring cache → (B, n_kv, group, hd) f32.

    ``block = (bk,)`` is the cache-length tile (shrunk to a divisor of cap).
    The f32 output is unprojected attention; callers cast and apply W_O.
    """
    bsz, cap, nkv, hd = k.shape
    group = q.shape[2]
    quantized = k_scale is not None
    (bk,) = block
    bk = shrink_block(bk, cap)
    nb = cap // bk

    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (bsz,))
    inputs = [q, k, v]
    in_specs = [
        pl.BlockSpec((1, 1, group, hd), lambda b, h, j, p_: (b, h, 0, 0)),
        pl.BlockSpec((1, bk, 1, hd),
                     lambda b, h, j, p_: (b, jnp.minimum(j, p_[b] // bk), h, 0)),
        pl.BlockSpec((1, bk, 1, hd),
                     lambda b, h, j, p_: (b, jnp.minimum(j, p_[b] // bk), h, 0)),
    ]
    body = _attn_body
    if quantized:
        # (B, cap, n_kv) → (B, n_kv, cap): the lane dimension must be the
        # tiled cache axis (layout change only — no float ops, so the oracle
        # parity is unaffected)
        inputs += [k_scale.transpose(0, 2, 1), v_scale.transpose(0, 2, 1)]
        in_specs += [
            pl.BlockSpec((1, 1, bk),
                         lambda b, h, j, p_: (b, h, jnp.minimum(j, p_[b] // bk))),
            pl.BlockSpec((1, 1, bk),
                         lambda b, h, j, p_: (b, h, jnp.minimum(j, p_[b] // bk))),
        ]
    else:
        def body(pos_ref, q_ref, k_ref, v_ref, kpos_ref, out_ref,
                 m_ref, s_ref, acc_ref, **kw):
            return _attn_body(pos_ref, q_ref, k_ref, v_ref, None, None,
                              kpos_ref, out_ref, m_ref, s_ref, acc_ref, **kw)
    inputs.append(k_pos)
    in_specs.append(
        pl.BlockSpec((1, bk), lambda b, h, j, p_: (b, jnp.minimum(j, p_[b] // bk)))
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, nkv, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda b, h, j, p_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(body, bk=bk, group=group, hd=hd, window=window,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, nkv, group, hd), jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pos, *inputs)


# ---------------------------------------------------------------------------
# multi-token verify variant: k query positions per slot (DESIGN.md §14)
# ---------------------------------------------------------------------------


def _verify_body(
    pos_ref,        # scalar prefetch: (B,) int32 base (first-row) positions
    q_ref,          # (1, kq, 1, group, hd)
    k_ref,          # (1, bk, 1, hd) int8 codes or bf16
    v_ref,          # (1, bk, 1, hd)
    ks_ref,         # (1, 1, bk) f32 — only when quantized
    vs_ref,         # (1, 1, bk) f32 — only when quantized
    kpos_ref,       # (1, bk) int32
    out_ref,        # (1, kq, 1, group, hd) f32
    m_ref,          # scratch (kq*group, 1) f32 — running max
    s_ref,          # scratch (kq*group, 1) f32 — running sum of exp
    acc_ref,        # scratch (kq*group, hd) f32 — value accumulator
    *,
    bk: int,
    kq: int,
    group: int,
    hd: int,
    window: int,
    quantized: bool,
):
    """``_attn_body``'s split-K online-softmax recurrence run for kq query
    rows per slot at positions pos_b .. pos_b+kq-1 (speculative verify,
    DESIGN.md §14).  The row loop is a *static Python* loop so each row
    runs the exact (group, bk) dot shapes, op order and mask of the
    one-token kernel at position pos_b+t — a fused (kq·group, bk) logit
    tile would change the float-summation shape and break the bitwise
    stream-parity contract (batched dots are not row-pure across M on
    every backend).  Row t freezes on blocks ``j > (pos_b+t)//bk``, the
    per-row analogue of ``_attn_body``'s ``j <= last`` guard, so its
    processed-block set matches sequential decode exactly."""
    b, j = pl.program_id(0), pl.program_id(2)
    nb = pl.num_programs(2)
    pos_b = pos_ref[b]
    rows = kq * group
    last = (pos_b + kq - 1) // bk  # deepest block any query row can touch

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full((rows, 1), -jnp.inf, jnp.float32)
        s_ref[...] = jnp.zeros((rows, 1), jnp.float32)
        acc_ref[...] = jnp.zeros((rows, hd), jnp.float32)

    @pl.when(j <= last)
    def _accumulate():
        qs = q_ref[...].reshape(kq, group, hd)
        kc = k_ref[...].reshape(bk, hd)
        vc = v_ref[...].reshape(bk, hd).astype(jnp.float32)
        kp = kpos_ref[...].reshape(1, bk)
        for t in range(kq):
            sl = slice(t * group, (t + 1) * group)
            q = qs[t]                                     # (group, hd)
            logits = jax.lax.dot_general(
                q, kc.astype(q.dtype),  # int8→bf16 upcast, tile only
                dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) * float(1.0 / math.sqrt(hd))                # (group, bk)
            if quantized:
                # per-position key scales fold in after the codes dot
                logits = logits * (ks_ref[...].reshape(1, bk) * (1.0 / 127.0))
            qp = pos_b + t                # this row's absolute query position
            valid = (kp >= 0) & (kp <= qp)
            if window:
                valid = valid & (kp > qp - window)
            logits = jnp.where(valid, logits, _NEG_BIG)

            m_prev, s_prev, acc_prev = m_ref[sl], s_ref[sl], acc_ref[sl]
            m_new = jnp.maximum(m_prev,
                                jnp.max(logits, axis=-1, keepdims=True))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(logits - m_new)                   # (group, bk)
            s_new = s_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
            if quantized:
                # per-position value scales attach to the weights
                p = p * (vs_ref[...].reshape(1, bk) * (1.0 / 127.0))
            acc_new = acc_prev * alpha + jax.lax.dot_general(
                p, vc, dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            act = j <= qp // bk           # row-t processed-block freeze
            m_ref[sl] = jnp.where(act, m_new, m_prev)
            s_ref[sl] = jnp.where(act, s_new, s_prev)
            acc_ref[sl] = jnp.where(act, acc_new, acc_prev)

    @pl.when(j == nb - 1)
    def _finish():
        out_ref[...] = (acc_ref[...] / s_ref[...]).reshape(
            1, kq, 1, group, hd)


@functools.partial(
    jax.jit, static_argnames=("window", "block", "interpret"),
)
def verify_attention_call(
    q: jax.Array,        # (B, kq, n_kv, group, hd) — post-RoPE draft queries
    k: jax.Array,        # (B, cap, n_kv, hd) int8 codes or bf16
    v: jax.Array,        # (B, cap, n_kv, hd)
    k_pos: jax.Array,    # (B, cap) int32 — absolute position per ring slot
    pos: jax.Array,      # (B,) int32 — per-slot base (first-row) position
    k_scale: jax.Array | None = None,   # (B, cap, n_kv) f32 when int8
    v_scale: jax.Array | None = None,
    *,
    window: int = 0,
    block: tuple = (512,),
    interpret: bool = True,
) -> jax.Array:
    """Multi-token verify attention over the ring cache →
    (B, kq, n_kv, group, hd) f32.

    Query row t of slot b attends as if decoding at absolute position
    ``pos[b] + t`` — the draft rows' K/V must already sit in the cache
    (the verify forward writes them before attending, mirroring the decode
    write-then-attend order).  ``block = (bk,)`` is the cache-length tile
    (shrunk to a divisor of cap), shared with the one-token kernel so the
    per-row recurrence matches it bit-for-bit.
    """
    bsz, cap, nkv, hd = k.shape
    kq, group = q.shape[1], q.shape[3]
    quantized = k_scale is not None
    (bk,) = block
    bk = shrink_block(bk, cap)
    nb = cap // bk

    def kv_clamp(j, p_, b):
        return jnp.minimum(j, (p_[b] + kq - 1) // bk)

    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (bsz,))
    inputs = [q, k, v]
    in_specs = [
        pl.BlockSpec((1, kq, 1, group, hd),
                     lambda b, h, j, p_: (b, 0, h, 0, 0)),
        pl.BlockSpec((1, bk, 1, hd),
                     lambda b, h, j, p_: (b, kv_clamp(j, p_, b), h, 0)),
        pl.BlockSpec((1, bk, 1, hd),
                     lambda b, h, j, p_: (b, kv_clamp(j, p_, b), h, 0)),
    ]
    body = _verify_body
    if quantized:
        # (B, cap, n_kv) → (B, n_kv, cap): lane dim = tiled cache axis
        inputs += [k_scale.transpose(0, 2, 1), v_scale.transpose(0, 2, 1)]
        in_specs += [
            pl.BlockSpec((1, 1, bk),
                         lambda b, h, j, p_: (b, h, kv_clamp(j, p_, b))),
            pl.BlockSpec((1, 1, bk),
                         lambda b, h, j, p_: (b, h, kv_clamp(j, p_, b))),
        ]
    else:
        def body(pos_ref, q_ref, k_ref, v_ref, kpos_ref, out_ref,
                 m_ref, s_ref, acc_ref, **kw):
            return _verify_body(pos_ref, q_ref, k_ref, v_ref, None, None,
                                kpos_ref, out_ref, m_ref, s_ref, acc_ref,
                                **kw)
    inputs.append(k_pos)
    in_specs.append(
        pl.BlockSpec((1, bk), lambda b, h, j, p_: (b, kv_clamp(j, p_, b)))
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, nkv, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, kq, 1, group, hd),
                               lambda b, h, j, p_: (b, 0, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kq * group, 1), jnp.float32),
            pltpu.VMEM((kq * group, 1), jnp.float32),
            pltpu.VMEM((kq * group, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(body, bk=bk, kq=kq, group=group, hd=hd,
                          window=window, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, kq, nkv, group, hd),
                                       jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pos, *inputs)


# ---------------------------------------------------------------------------
# paged variant: block-table gather over the shared block pool (DESIGN.md §6)
# ---------------------------------------------------------------------------


def _paged_attn_body(
    pos_ref,        # scalar prefetch: (B,) int32 per-slot decode positions
    bt_ref,         # scalar prefetch: (B, nbmax) int32 physical block ids
    q_ref,          # (1, 1, group, hd)
    k_ref,          # (1, bs, 1, hd) int8 codes or bf16 — one pool block
    v_ref,          # (1, bs, 1, hd)
    ks_ref,         # (1, 1, bs) f32 — only when quantized
    vs_ref,         # (1, 1, bs) f32 — only when quantized
    out_ref,        # (1, 1, group, hd) f32
    m_ref,          # scratch (group, 1) f32 — running max
    s_ref,          # scratch (group, 1) f32 — running sum of exp
    acc_ref,        # scratch (group, hd) f32 — value accumulator
    *,
    bs: int,
    group: int,
    hd: int,
    window: int,
    quantized: bool,
):
    """Same split-K online-softmax recurrence as ``_attn_body``, over pool
    blocks instead of ring tiles.  The key position of slot t in *logical*
    block j is implicit — ``j·bs + t`` (the pool is append-only, never a
    ring) — so no k_pos tile is fetched; the block-table gather happened in
    the BlockSpec index maps via the scalar-prefetched table."""
    b, j = pl.program_id(0), pl.program_id(2)
    nb = pl.num_programs(2)
    pos_b = pos_ref[b]
    last = pos_b // bs   # logical blocks past this are unallocated

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full((group, 1), -jnp.inf, jnp.float32)
        s_ref[...] = jnp.zeros((group, 1), jnp.float32)
        acc_ref[...] = jnp.zeros((group, hd), jnp.float32)

    @pl.when(j <= last)
    def _accumulate():
        q = q_ref[...].reshape(group, hd)
        kc = k_ref[...].reshape(bs, hd).astype(q.dtype)
        logits = jax.lax.dot_general(
            q, kc, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * float(1.0 / math.sqrt(hd))                   # (group, bs)
        if quantized:
            logits = logits * (ks_ref[...].reshape(1, bs) * (1.0 / 127.0))
        # implicit key positions of this logical block
        kp = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        valid = kp <= pos_b
        if window:
            valid = valid & (kp > pos_b - window)
        logits = jnp.where(valid, logits, _NEG_BIG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)                       # (group, bs)
        m_ref[...] = m_new
        s_ref[...] = s_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        if quantized:
            p = p * (vs_ref[...].reshape(1, bs) * (1.0 / 127.0))
        vc = v_ref[...].reshape(bs, hd).astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, vc, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(j == nb - 1)
    def _finish():
        out_ref[...] = (acc_ref[...] / s_ref[...]).reshape(1, 1, group, hd)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention_call(
    q: jax.Array,        # (B, n_kv, group, hd) bf16/f32 — post-RoPE queries
    k: jax.Array,        # (n_blocks, bs, n_kv, hd) int8 codes or bf16 pool
    v: jax.Array,        # (n_blocks, bs, n_kv, hd)
    block_tables: jax.Array,  # (B, nbmax) int32 physical block per logical
    pos: jax.Array,      # (B,) int32 — per-slot absolute decode position
    k_scale: jax.Array | None = None,   # (n_blocks, bs, n_kv) f32 when int8
    v_scale: jax.Array | None = None,
    *,
    window: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """Paged flash-decode attention → (B, n_kv, group, hd) f32.

    The cache-length tile IS the pool block (bs = ``k.shape[1]``): the K/V
    BlockSpec index maps gather physical block ``block_tables[b, min(j,
    pos[b]//bs)]`` via the scalar-prefetched table, so a slot at position p
    reads its own ceil((p+1)/bs) blocks wherever they live in the pool —
    and shared prefix blocks are fetched from the same physical tiles for
    every request that holds them.  For bs == bk the recurrence is
    step-for-step the ring kernel's, so the two layouts are bit-identical
    on the same token stream (tests/test_paged_attention.py).
    """
    nblk, bs, nkv, hd = k.shape
    bsz = q.shape[0]
    nbmax = block_tables.shape[1]
    group = q.shape[2]
    quantized = k_scale is not None

    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (bsz,))
    block_tables = jnp.asarray(block_tables, jnp.int32)

    def kv_map(b, h, j, p_, bt_):
        return (bt_[b, jnp.minimum(j, p_[b] // bs)], 0, h, 0)

    inputs = [q, k, v]
    in_specs = [
        pl.BlockSpec((1, 1, group, hd), lambda b, h, j, p_, bt_: (b, h, 0, 0)),
        pl.BlockSpec((1, bs, 1, hd), kv_map),
        pl.BlockSpec((1, bs, 1, hd), kv_map),
    ]
    body = _paged_attn_body
    if quantized:
        # (n_blocks, bs, n_kv) → (n_blocks, n_kv, bs): the lane dimension
        # must be the tiled in-block axis (layout change only)
        inputs += [k_scale.transpose(0, 2, 1), v_scale.transpose(0, 2, 1)]
        in_specs += [
            pl.BlockSpec((1, 1, bs),
                         lambda b, h, j, p_, bt_:
                         (bt_[b, jnp.minimum(j, p_[b] // bs)], h, 0)),
            pl.BlockSpec((1, 1, bs),
                         lambda b, h, j, p_, bt_:
                         (bt_[b, jnp.minimum(j, p_[b] // bs)], h, 0)),
        ]
    else:
        def body(pos_ref, bt_ref, q_ref, k_ref, v_ref, out_ref,
                 m_ref, s_ref, acc_ref, **kw):
            return _paged_attn_body(pos_ref, bt_ref, q_ref, k_ref, v_ref,
                                    None, None, out_ref, m_ref, s_ref,
                                    acc_ref, **kw)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, nkv, nbmax),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda b, h, j, p_, bt_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(body, bs=bs, group=group, hd=hd, window=window,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, nkv, group, hd), jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pos, block_tables, *inputs)


def _paged_verify_body(
    pos_ref,        # scalar prefetch: (B,) int32 base (first-row) positions
    bt_ref,         # scalar prefetch: (B, nbmax) int32 physical block ids
    q_ref,          # (1, kq, 1, group, hd)
    k_ref,          # (1, bs, 1, hd) int8 codes or bf16 — one pool block
    v_ref,          # (1, bs, 1, hd)
    ks_ref,         # (1, 1, bs) f32 — only when quantized
    vs_ref,         # (1, 1, bs) f32 — only when quantized
    out_ref,        # (1, kq, 1, group, hd) f32
    m_ref,          # scratch (kq*group, 1) f32 — running max
    s_ref,          # scratch (kq*group, 1) f32 — running sum of exp
    acc_ref,        # scratch (kq*group, hd) f32 — value accumulator
    *,
    bs: int,
    kq: int,
    group: int,
    hd: int,
    window: int,
    quantized: bool,
):
    """``_verify_body`` over pool blocks: implicit key positions
    ``j·bs + t`` (no k_pos tile), block-table gather in the index maps,
    per-row ``j <= (pos_b+t)//bs`` freezing.  The static per-row loop runs
    the exact (group, bs) dot shapes of ``_paged_attn_body`` at position
    pos_b+t, so each row is bit-identical to sequential paged decode on
    the same pool block (see ``_verify_body`` on why a fused row tile
    would break that)."""
    b, j = pl.program_id(0), pl.program_id(2)
    nb = pl.num_programs(2)
    pos_b = pos_ref[b]
    rows = kq * group
    last = (pos_b + kq - 1) // bs  # deepest logical block any row can touch

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full((rows, 1), -jnp.inf, jnp.float32)
        s_ref[...] = jnp.zeros((rows, 1), jnp.float32)
        acc_ref[...] = jnp.zeros((rows, hd), jnp.float32)

    @pl.when(j <= last)
    def _accumulate():
        qs = q_ref[...].reshape(kq, group, hd)
        kc = k_ref[...].reshape(bs, hd)
        vc = v_ref[...].reshape(bs, hd).astype(jnp.float32)
        # implicit key positions of this logical block
        kp = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        for t in range(kq):
            sl = slice(t * group, (t + 1) * group)
            q = qs[t]                                     # (group, hd)
            logits = jax.lax.dot_general(
                q, kc.astype(q.dtype),
                dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            ) * float(1.0 / math.sqrt(hd))                # (group, bs)
            if quantized:
                logits = logits * (ks_ref[...].reshape(1, bs) * (1.0 / 127.0))
            qp = pos_b + t                # this row's absolute query position
            valid = kp <= qp
            if window:
                valid = valid & (kp > qp - window)
            logits = jnp.where(valid, logits, _NEG_BIG)

            m_prev, s_prev, acc_prev = m_ref[sl], s_ref[sl], acc_ref[sl]
            m_new = jnp.maximum(m_prev,
                                jnp.max(logits, axis=-1, keepdims=True))
            alpha = jnp.exp(m_prev - m_new)
            p = jnp.exp(logits - m_new)                   # (group, bs)
            s_new = s_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
            if quantized:
                p = p * (vs_ref[...].reshape(1, bs) * (1.0 / 127.0))
            acc_new = acc_prev * alpha + jax.lax.dot_general(
                p, vc, dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            act = j <= qp // bs           # row-t processed-block freeze
            m_ref[sl] = jnp.where(act, m_new, m_prev)
            s_ref[sl] = jnp.where(act, s_new, s_prev)
            acc_ref[sl] = jnp.where(act, acc_new, acc_prev)

    @pl.when(j == nb - 1)
    def _finish():
        out_ref[...] = (acc_ref[...] / s_ref[...]).reshape(
            1, kq, 1, group, hd)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_verify_attention_call(
    q: jax.Array,        # (B, kq, n_kv, group, hd) — post-RoPE draft queries
    k: jax.Array,        # (n_blocks, bs, n_kv, hd) int8 codes or bf16 pool
    v: jax.Array,        # (n_blocks, bs, n_kv, hd)
    block_tables: jax.Array,  # (B, nbmax) int32 physical block per logical
    pos: jax.Array,      # (B,) int32 — per-slot base (first-row) position
    k_scale: jax.Array | None = None,   # (n_blocks, bs, n_kv) f32 when int8
    v_scale: jax.Array | None = None,
    *,
    window: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """Paged multi-token verify attention → (B, kq, n_kv, group, hd) f32.

    The cache tile is the pool block (bs = ``k.shape[1]``) — the same tile
    the one-token paged kernel uses — so each query row's recurrence is
    bit-identical to sequential paged decode at position ``pos[b] + t``
    regardless of backend or autotuning (the pool pins the association
    order).  The engine must have allocated blocks covering every row it
    intends to accept; deeper rows read whatever the (clamped) table gather
    returns and their output is discarded host-side."""
    nblk, bs, nkv, hd = k.shape
    bsz = q.shape[0]
    nbmax = block_tables.shape[1]
    kq, group = q.shape[1], q.shape[3]
    quantized = k_scale is not None

    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (bsz,))
    block_tables = jnp.asarray(block_tables, jnp.int32)

    def bt_clamp(j, p_, bt_, b):
        return bt_[b, jnp.minimum(j, (p_[b] + kq - 1) // bs)]

    def kv_map(b, h, j, p_, bt_):
        return (bt_clamp(j, p_, bt_, b), 0, h, 0)

    inputs = [q, k, v]
    in_specs = [
        pl.BlockSpec((1, kq, 1, group, hd),
                     lambda b, h, j, p_, bt_: (b, 0, h, 0, 0)),
        pl.BlockSpec((1, bs, 1, hd), kv_map),
        pl.BlockSpec((1, bs, 1, hd), kv_map),
    ]
    body = _paged_verify_body
    if quantized:
        # (n_blocks, bs, n_kv) → (n_blocks, n_kv, bs): lane dim in-block
        inputs += [k_scale.transpose(0, 2, 1), v_scale.transpose(0, 2, 1)]
        in_specs += [
            pl.BlockSpec((1, 1, bs),
                         lambda b, h, j, p_, bt_:
                         (bt_clamp(j, p_, bt_, b), h, 0)),
            pl.BlockSpec((1, 1, bs),
                         lambda b, h, j, p_, bt_:
                         (bt_clamp(j, p_, bt_, b), h, 0)),
        ]
    else:
        def body(pos_ref, bt_ref, q_ref, k_ref, v_ref, out_ref,
                 m_ref, s_ref, acc_ref, **kw):
            return _paged_verify_body(pos_ref, bt_ref, q_ref, k_ref, v_ref,
                                      None, None, out_ref, m_ref, s_ref,
                                      acc_ref, **kw)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, nkv, nbmax),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, kq, 1, group, hd),
                               lambda b, h, j, p_, bt_: (b, 0, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kq * group, 1), jnp.float32),
            pltpu.VMEM((kq * group, 1), jnp.float32),
            pltpu.VMEM((kq * group, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(body, bs=bs, kq=kq, group=group, hd=hd,
                          window=window, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, kq, nkv, group, hd),
                                       jnp.float32),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pos, block_tables, *inputs)
