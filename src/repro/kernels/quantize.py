"""Pallas TPU kernel: tiled elementwise quantisation with dither /
stochastic / deterministic rounding (paper §VII).

The kernel quantises a 2-D f32 tensor to k-bit integer codes, tile by tile
(BlockSpec VMEM tiling).  Per element it evaluates the counter-indexed dither
pulse lazily — LCG permutation slot + murmur-hash Bernoulli tail — i.e. pure
VPU integer math; no pulse sequences are materialised (DESIGN.md §2).

Layout notes (TPU target):
  * blocks default to (256, 256) f32 — 256 KiB in, 256 KiB out (int32), well
    under the ~16 MiB VMEM budget, multiples of the (8, 128) f32 tile.
  * the counter is a (1, 1) int32 operand so that advancing i_s between
    steps does NOT retrace/recompile; everything else is compile-time static.
  * validated on CPU via interpret=True against kernels/ref.py (bit-exact).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import rounding

__all__ = ["quantize_kernel_call"]


def _quantize_body(
    counter_ref,
    x_ref,
    out_ref,
    *,
    scale: float,
    zero: float,
    bits: int,
    scheme: str,
    seed: int,
    n_pulses: int,
    fmt: str,
    n_cols: int,
    block: tuple,
):
    """One (bm, bn) tile: codes = clip(round((x - zero)·scale), 0, 2^k−1)."""
    bm, bn = block
    pid_m = pl.program_id(0)
    pid_n = pl.program_id(1)
    counter = counter_ref[0, 0].astype(jnp.uint32)

    x = x_ref[...]
    scaled = (x - zero) * scale
    fl = jnp.floor(scaled)
    f = scaled - fl

    # Global flattened (row-major) element index — matches the ref oracle.
    row = pid_m * bm + jax.lax.broadcasted_iota(jnp.uint32, (bm, bn), 0)
    col = pid_n * bn + jax.lax.broadcasted_iota(jnp.uint32, (bm, bn), 1)
    idx = row * jnp.uint32(n_cols) + col

    if scheme == "deterministic":
        codes = jnp.floor(scaled + 0.5)
    elif scheme == "stochastic":
        u = rounding.hash_uniform(seed, idx, counter)
        codes = fl + (u < f).astype(jnp.float32)
    elif scheme == "dither":
        slot = rounding.slot_index(counter, idx, n_pulses, seed=seed, fmt=fmt)
        u = rounding.hash_uniform(seed ^ 0xD1CE, idx, counter)
        codes = fl + rounding.dither_bit(f, slot, u, n_pulses)
    else:
        raise ValueError(scheme)

    levels = float((1 << bits) - 1)
    out_ref[...] = jnp.clip(codes, 0.0, levels).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "zero", "bits", "scheme", "seed", "n_pulses", "fmt", "block",
        "interpret",
    ),
)
def quantize_kernel_call(
    x: jax.Array,
    counter: jax.Array,
    *,
    scale: float,
    zero: float,
    bits: int,
    scheme: str = "dither",
    seed: int = 0,
    n_pulses: int = 16,
    fmt: str = "spread",
    block: tuple = (256, 256),
    interpret: bool = True,
) -> jax.Array:
    """Tiled quantisation.  x: (M, N) f32, counter: (1, 1) int32 → (M, N) int32.

    M, N must be divisible by the block shape (callers pad; the ops.py
    wrapper handles padding/unpadding automatically).
    """
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0, (x.shape, block)
    counter = counter.reshape(1, 1).astype(jnp.int32)

    body = functools.partial(
        _quantize_body,
        scale=scale, zero=zero, bits=bits, scheme=scheme, seed=seed,
        n_pulses=n_pulses, fmt=fmt, n_cols=n, block=(bm, bn),
    )
    return pl.pallas_call(
        body,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),  # counter (scalar)
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(counter, x)
