"""jit'd public wrappers for the Pallas kernels (padding, dtype, dispatch).

``interpret`` defaults to auto: real TPU → compiled kernel, anything else →
interpret mode (Python evaluation of the same kernel body), so tests/CI on
CPU exercise identical code paths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.dither_matmul import dither_matmul_kernel_call
from repro.kernels.quantize import quantize_kernel_call

__all__ = ["quantize_2d", "dither_matmul", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad2(x: jax.Array, bm: int, bn: int, value: float = 0.0) -> jax.Array:
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)), constant_values=value)
    return x


def quantize_2d(
    x: jax.Array,
    *,
    bits: int,
    lo: float = 0.0,
    hi: float = 1.0,
    scheme: str = "dither",
    counter=0,
    seed: int = 0,
    n_pulses: int = 16,
    fmt: str = "spread",
    block: tuple = (256, 256),
    interpret: bool | None = None,
) -> jax.Array:
    """Quantise a 2-D f32 array to k-bit int32 codes via the Pallas kernel."""
    if interpret is None:
        interpret = not on_tpu()
    m, n = x.shape
    scale = ((1 << bits) - 1) / (hi - lo)
    xp = _pad2(x.astype(jnp.float32), *block, value=lo)
    counter = jnp.asarray(counter, jnp.int32).reshape(1, 1)
    # NOTE: padding changes n_cols → flat indices differ from the unpadded
    # oracle only in the padded region, because the kernel derives n_cols
    # from the padded width.  We therefore pass the padded width to ref in
    # tests; statistically the index is just a PRNG stream id.
    codes = quantize_kernel_call(
        xp, counter, scale=scale, zero=lo, bits=bits, scheme=scheme,
        seed=seed, n_pulses=n_pulses, fmt=fmt, block=block, interpret=interpret,
    )
    return codes[:m, :n]


def dither_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    bits: int,
    scheme: str = "dither",
    counter=0,
    seed: int = 0,
    a_range: tuple = (0.0, 1.0),
    b_range: tuple = (0.0, 1.0),
    fmt: str = "spread",
    block: tuple = (256, 256, 512),
    interpret: bool | None = None,
) -> jax.Array:
    """Fused k-bit quantised matmul (§VIII 'separate'), padded to blocks.

    Zero-padding is exact: padding A/B with the range zero-point contributes
    code 0 … but code 0 maps back to `lo`, so instead we pad with `lo` and
    slice the result — cross terms from padded K rows would bias the output
    when lo ≠ 0, so K padding pads A with a_lo-equivalent zeros AND masks by
    padding B's rows with b's zero-point.  To keep the kernel exact we
    require K % bk == 0 after choosing bk = gcd-friendly block; the wrapper
    shrinks bk to a divisor of K when needed.
    """
    if interpret is None:
        interpret = not on_tpu()
    (m, k), (_, n) = a.shape, b.shape
    bm, bn, bk = block
    # exact K handling: shrink bk to a divisor of K (no K padding ⇒ no bias)
    bk = min(bk, k)
    while k % bk:
        bk -= 1
    ap = _pad2(a.astype(jnp.float32), bm, bk, value=a_range[0])
    bp = _pad2(b.astype(jnp.float32), bk, bn, value=b_range[0])
    counter = jnp.asarray(counter, jnp.int32).reshape(1, 1)
    out = dither_matmul_kernel_call(
        ap, bp, counter, bits=bits, scheme=scheme, seed=seed,
        a_range=a_range, b_range=b_range, fmt=fmt, block=(bm, bn, bk),
        interpret=interpret, true_shape=(m, k, n),
    )
    return out[:m, :n]
