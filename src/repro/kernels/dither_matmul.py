"""Pallas TPU kernel: fused dither-quantised matmul (paper §VIII 'separate'
variant, the production path — DESIGN.md §2 "AND-gate multiply → MXU matmul").

C = dequant( Q_dither(A) @ Q_dither(B) ) computed tile-by-tile:

  grid = (M/bm, N/bn, K/bk), K innermost (sequential accumulation);
  A tile (bm, bk) and B tile (bk, bn) are quantised to k-bit codes *in VMEM*
  (recomputed per grid step — rounding is a stateless hash of
  (seed, element, counter), so requantisation is free of statistical cost),
  multiplied on the MXU, accumulated in an f32 VMEM scratch.  Affine-zero
  cross terms are accumulated alongside via row/col code sums so signed
  ranges ([-1, 1] weights) are exact.

Default tiles (bm, bn, bk) = (256, 256, 512): A 512 KiB + B 512 KiB +
acc 256 KiB + sums ≈ 1.3 MiB VMEM — fits v5e VMEM with double buffering.
All dims multiples of (8, 128) f32 tiling and the 128×128 MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rounding

__all__ = ["dither_matmul_kernel_call"]

# renamed TPUCompilerParams -> CompilerParams across jax versions
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _quantize_tile(x, row0, col0, n_cols, *, scale, zero, bits, scheme, seed, n_pulses, fmt, counter):
    """Quantise one VMEM tile to codes (f32-valued integers, clipped)."""
    bm, bn = x.shape
    scaled = (x - zero) * scale
    fl = jnp.floor(scaled)
    f = scaled - fl
    row = row0 + jax.lax.broadcasted_iota(jnp.uint32, (bm, bn), 0)
    col = col0 + jax.lax.broadcasted_iota(jnp.uint32, (bm, bn), 1)
    idx = row * jnp.uint32(n_cols) + col
    if scheme == "deterministic":
        codes = jnp.floor(scaled + 0.5)
    elif scheme == "stochastic":
        u = rounding.hash_uniform(seed, idx, counter)
        codes = fl + (u < f).astype(jnp.float32)
    elif scheme == "dither":
        slot = rounding.slot_index(counter, idx, n_pulses, seed=seed, fmt=fmt)
        u = rounding.hash_uniform(seed ^ 0xD1CE, idx, counter)
        codes = fl + rounding.dither_bit(f, slot, u, n_pulses)
    else:
        raise ValueError(scheme)
    return jnp.clip(codes, 0.0, float((1 << bits) - 1))


def _matmul_body(
    counter_ref,
    a_ref,
    b_ref,
    out_ref,
    acc_ref,
    rowsum_ref,
    colsum_ref,
    *,
    bits: int,
    scheme: str,
    seed: int,
    sa: float,
    sb: float,
    a_zero: float,
    b_zero: float,
    k_total: int,
    a_cols: int,
    b_cols: int,
    n_pulses_a: int,
    n_pulses_b: int,
    fmt: str,
    block: tuple,
):
    bm, bn, bk = block
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)
    counter = counter_ref[0, 0].astype(jnp.uint32)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        rowsum_ref[...] = jnp.zeros_like(rowsum_ref)
        colsum_ref[...] = jnp.zeros_like(colsum_ref)

    ca = _quantize_tile(
        a_ref[...], i * bm, k * bk, a_cols,
        scale=sa, zero=a_zero, bits=bits, scheme=scheme, seed=seed,
        n_pulses=n_pulses_a, fmt=fmt, counter=counter,
    )
    cb = _quantize_tile(
        b_ref[...], k * bk, j * bn, b_cols,
        scale=sb, zero=b_zero, bits=bits, scheme=scheme, seed=seed + 1,
        n_pulses=n_pulses_b, fmt=fmt, counter=counter,
    )
    acc_ref[...] += jax.lax.dot(
        ca, cb, precision=jax.lax.Precision.HIGHEST,
        preferred_element_type=jnp.float32,
    )
    # cross-term accumulators for affine zeros (Σ_j codes along K)
    rowsum_ref[...] += jnp.sum(ca, axis=1, keepdims=True)
    colsum_ref[...] += jnp.sum(cb, axis=0, keepdims=True)

    @pl.when(k == nk - 1)
    def _finish():
        out = acc_ref[...] / (sa * sb)
        out += a_zero * colsum_ref[...] / sb
        out += b_zero * rowsum_ref[...] / sa
        out += float(k_total) * a_zero * b_zero
        out_ref[...] = out


@functools.partial(
    jax.jit,
    static_argnames=(
        "bits", "scheme", "seed", "a_range", "b_range", "fmt", "block",
        "interpret", "true_shape",
    ),
)
def dither_matmul_kernel_call(
    a: jax.Array,
    b: jax.Array,
    counter: jax.Array,
    *,
    bits: int,
    scheme: str = "dither",
    seed: int = 0,
    a_range: tuple = (0.0, 1.0),
    b_range: tuple = (0.0, 1.0),
    fmt: str = "spread",
    block: tuple = (256, 256, 512),
    interpret: bool = True,
    true_shape: tuple | None = None,
) -> jax.Array:
    """Fused quantise+matmul.  a: (M, K) f32, b: (K, N) f32 → (M, N) f32.

    Dither pulse counts follow §VII: N_A = N (each A element reused per
    output column), N_B = M.  Shapes must divide the block (ops.py pads).
    ``true_shape=(m, k, n)`` gives the pre-padding dims so the PRNG element
    indices and pulse counts are identical to the unpadded oracle.
    """
    (m, k), (k2, n) = a.shape, b.shape
    assert k == k2
    tm, tk, tn = true_shape or (m, k, n)
    bm, bn, bk = min(block[0], m), min(block[1], n), min(block[2], k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, block)
    levels = float((1 << bits) - 1)
    sa = levels / (a_range[1] - a_range[0])
    sb = levels / (b_range[1] - b_range[0])
    counter = counter.reshape(1, 1).astype(jnp.int32)

    body = functools.partial(
        _matmul_body,
        bits=bits, scheme=scheme, seed=seed, sa=sa, sb=sb,
        a_zero=a_range[0], b_zero=b_range[0], k_total=tk,
        a_cols=tk, b_cols=tn,
        n_pulses_a=max(tn, 2), n_pulses_b=max(tm, 2),
        fmt=fmt, block=(bm, bn, bk),
    )
    return pl.pallas_call(
        body,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, 1), jnp.float32),
            pltpu.VMEM((1, bn), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(counter, a, b)
