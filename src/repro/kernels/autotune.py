"""Block-size autotuner for the Pallas kernels (DESIGN.md §3).

Two modes, both keyed by ``(kind, M, K, N, dtype, bits, scheme, backend)``:

* **model-driven pick** (``best_block``) — no execution: enumerate (bm, bn,
  bk) candidates aligned to the TPU f32 tile (8, 128) and the 128×128 MXU,
  reject those whose working set exceeds the VMEM budget (double-buffered
  operand tiles + f32 accumulator + cross-term sums), and pick the largest
  surviving tile (fewest grid steps → best MXU occupancy).  This is what the
  dispatcher uses when no measurement is cached, so the hot path never pays
  a tuning cost it didn't ask for.
* **measured sweep** (``autotune_matmul`` / ``autotune_quantize``) — time
  each candidate via a caller-supplied runner and cache the winner, in
  memory and (when ``REPRO_AUTOTUNE_CACHE`` names a JSON file) on disk, so
  one tuning run amortises across processes.  ``benchmarks/kernel_bench.py``
  is the normal driver and emits the sweep as a JSON perf artifact.

The runner indirection keeps this module free of a dispatch import (dispatch
imports us for ``best_block``).
"""

from __future__ import annotations

import json
import os
import time
import weakref
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "VMEM_BUDGET_BYTES",
    "matmul_vmem_bytes", "quantize_vmem_bytes", "decode_attention_vmem_bytes",
    "verify_attention_vmem_bytes",
    "matmul_candidates", "quantize_candidates", "decode_attention_candidates",
    "paged_attention_candidates", "verify_attention_candidates",
    "best_block", "autotune_matmul", "autotune_quantize",
    "autotune_decode_attention", "autotune_paged_attention",
    "autotune_verify_attention",
    "cache_key", "load_cache", "save_cache", "clear_cache",
    "register_observer",
]

# Observability (DESIGN.md §13): tracers register here so winner-cache
# hits/misses and measured recompute sweeps show up on the serving timeline
# instead of as mystery gaps.  WeakSet: a dropped tracer unregisters itself,
# so short-lived engines never pin observers.  Observers are duck-typed —
# anything with an ``autotune_event(kind, **fields)`` method.
_OBSERVERS: "weakref.WeakSet" = weakref.WeakSet()


def register_observer(obs) -> None:
    """Register an object (held weakly) whose ``autotune_event`` method
    receives autotuner cache events: ``autotune_cache_hit``,
    ``autotune_model_pick``, ``autotune_sweep``."""
    _OBSERVERS.add(obs)


def _notify(kind: str, **fields) -> None:
    for obs in list(_OBSERVERS):
        try:
            obs.autotune_event(kind, **fields)
        except Exception:  # noqa: BLE001 — observability must not gate tuning
            pass

# v5e VMEM is ~16 MiB/core; leave headroom for the compiler's own buffers.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024
_VMEM_USABLE_FRACTION = 0.75

# TPU f32 native tile and MXU edge (pallas_guide: sublane×lane = 8×128).
_SUBLANE, _LANE = 8, 128

_F32 = 4


def matmul_vmem_bytes(block: Tuple[int, int, int]) -> int:
    """Working-set model for the fused matmul kernel at one grid step:
    double-buffered A (bm, bk) and B (bk, bn) input tiles, the f32
    accumulator + output tile (bm, bn), and the affine-zero cross-term rows
    and columns.  Quantised codes are produced in registers (f32-valued),
    modelled as one extra copy of each operand tile."""
    bm, bn, bk = block
    a_tile = bm * bk * _F32
    b_tile = bk * bn * _F32
    acc = bm * bn * _F32
    out = bm * bn * _F32
    sums = (bm + bn) * _F32
    codes = a_tile + b_tile
    return 2 * (a_tile + b_tile) + acc + out + sums + codes


def quantize_vmem_bytes(block: Tuple[int, int]) -> int:
    """Elementwise kernel: double-buffered f32 input and int32 output tiles."""
    bm, bn = block
    return 2 * (bm * bn * _F32) * 2


def decode_attention_vmem_bytes(block: Tuple[int], *, hd: int, group: int,
                                quantized: bool) -> int:
    """Working-set model for the flash-decode kernel at one grid step:
    double-buffered K and V cache tiles (bk, hd) in their storage dtype
    (int8 codes or bf16), their register upcasts (modelled as one f32 copy
    each), the (group, bk) logit/weight tiles, per-position scale and k_pos
    rows, and the online-softmax state (acc + m + s) plus the query tile."""
    (bk,) = block
    elem = 1 if quantized else 2
    kv_tiles = 2 * 2 * bk * hd * elem          # double-buffered K and V
    upcast = 2 * bk * hd * _F32                # in-register f32 working copies
    logits = 2 * group * bk * _F32             # logit + weight tiles
    scales = (2 * 2 * bk * _F32) if quantized else 0
    kpos = 2 * bk * 4
    state = group * (hd + 2) * _F32            # acc, m, s scratch
    q_tile = group * hd * _F32
    return kv_tiles + upcast + logits + scales + kpos + state + q_tile


def verify_attention_vmem_bytes(block: Tuple[int], *, hd: int, kq: int,
                                group: int, quantized: bool) -> int:
    """Working-set model for the multi-token verify kernel: the flash-decode
    model with the logit/weight tiles and softmax state widened from
    ``group`` rows to the ``kq·group`` query rows scored per grid step (the
    K/V tiles, scales and k_pos rows are shared across rows)."""
    return decode_attention_vmem_bytes(block, hd=hd, group=kq * group,
                                       quantized=quantized)


def _tile_sizes(dim: int, quantum: int, ceiling: int) -> List[int]:
    """Power-of-two multiples of ``quantum`` up to min(dim, ceiling), falling
    back to the (smaller) dim itself so CPU-scale shapes stay tunable."""
    sizes = []
    t = quantum
    while t <= min(dim, ceiling):
        sizes.append(t)
        t *= 2
    if not sizes:
        sizes.append(dim)
    return sizes


def matmul_candidates(m: int, k: int, n: int) -> List[Tuple[int, int, int]]:
    """(bm, bn, bk) candidates under the VMEM budget, MXU/f32-tile aligned
    when the shape allows it."""
    budget = VMEM_BUDGET_BYTES * _VMEM_USABLE_FRACTION
    cands = []
    for bm in _tile_sizes(m, _SUBLANE * 4, 512):
        for bn in _tile_sizes(n, _LANE, 512):
            for bk in _tile_sizes(k, _LANE, 1024):
                if matmul_vmem_bytes((bm, bn, bk)) <= budget:
                    cands.append((bm, bn, bk))
    return cands


def quantize_candidates(m: int, n: int) -> List[Tuple[int, int]]:
    budget = VMEM_BUDGET_BYTES * _VMEM_USABLE_FRACTION
    return [
        (bm, bn)
        for bm in _tile_sizes(m, _SUBLANE * 4, 1024)
        for bn in _tile_sizes(n, _LANE, 1024)
        if quantize_vmem_bytes((bm, bn)) <= budget
    ]


def decode_attention_candidates(cap: int, *, hd: int, group: int,
                                quantized: bool) -> List[Tuple[int]]:
    """(bk,) cache-length tile candidates under the VMEM budget.  Lane-quantum
    multiples up to the cap; tiny caps (CPU-scale serving tests) fall back to
    the cap itself so every shape stays tunable."""
    budget = VMEM_BUDGET_BYTES * _VMEM_USABLE_FRACTION
    cands = [
        (bk,)
        for bk in _tile_sizes(cap, _LANE, 4096)
        if decode_attention_vmem_bytes((bk,), hd=hd, group=group,
                                       quantized=quantized) <= budget
    ]
    return cands or [(cap,)]


def verify_attention_candidates(cap: int, *, hd: int, kq: int, group: int,
                                quantized: bool) -> List[Tuple[int]]:
    """(bk,) cache-length tile candidates for the verify kernel: the decode
    candidate grid filtered through the widened ``kq·group``-row working
    set, so deep drafts shrink the tile instead of blowing VMEM."""
    budget = VMEM_BUDGET_BYTES * _VMEM_USABLE_FRACTION
    cands = [
        (bk,)
        for bk in _tile_sizes(cap, _LANE, 4096)
        if verify_attention_vmem_bytes((bk,), hd=hd, kq=kq, group=group,
                                       quantized=quantized) <= budget
    ]
    return cands or [(cap,)]


def paged_attention_candidates(max_len: int, *, hd: int, group: int,
                               quantized: bool) -> List[Tuple[int]]:
    """(bs,) pool-block-size candidates for the paged KV cache.

    Unlike the ring kernel's per-call cache tile, the paged split-K tile is
    the pool block itself — fixed when the pool is allocated, because the
    block is both the kernel's gather granularity *and* the allocator's
    unit of capacity/prefix-sharing (serve/kvpool.py).  Candidates are
    sublane-quantum multiples: small enough that a short request wastes
    little of its last block, large enough that the per-block VMEM tile
    keeps the MXU fed; the same working-set model as the ring kernel
    rejects oversized blocks."""
    budget = VMEM_BUDGET_BYTES * _VMEM_USABLE_FRACTION
    cands = [
        (bs,)
        for bs in _tile_sizes(max_len, _SUBLANE, 1024)
        if bs <= max_len
        and decode_attention_vmem_bytes((bs,), hd=hd, group=group,
                                        quantized=quantized) <= budget
    ]
    return cands or [(max(1, max_len),)]


# ---------------------------------------------------------------------------
# winner cache: in-memory dict, optionally persisted to a JSON file
# ---------------------------------------------------------------------------

_CACHE: Dict[str, tuple] = {}
_CACHE_LOADED_FROM: Optional[str] = None


def cache_key(kind: str, shape: tuple, dtype, bits: int, scheme: str,
              backend: str) -> str:
    return "|".join([kind, "x".join(map(str, shape)), str(dtype), str(bits),
                     scheme, backend])


def _cache_path() -> Optional[str]:
    return os.environ.get("REPRO_AUTOTUNE_CACHE") or None


def load_cache(path: Optional[str] = None) -> Dict[str, tuple]:
    """Merge the JSON winner cache at ``path`` (or $REPRO_AUTOTUNE_CACHE)
    into the in-memory cache.  Missing/corrupt files are treated as empty."""
    global _CACHE_LOADED_FROM
    path = path or _cache_path()
    if path and os.path.exists(path):
        try:
            with open(path) as f:
                _CACHE.update({k: tuple(v) for k, v in json.load(f).items()})
            _CACHE_LOADED_FROM = path
        except (OSError, ValueError):
            pass
    return _CACHE


def save_cache(path: Optional[str] = None) -> Optional[str]:
    path = path or _cache_path()
    if not path:
        return None
    # merge-write: winners persisted by other processes survive, this
    # process's entries win on key conflicts
    merged: Dict[str, list] = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged.update(json.load(f))
        except (OSError, ValueError):
            pass
    merged.update({k: list(v) for k, v in _CACHE.items()})
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # atomic rename: parallel bench/CI runs each write a complete temp file
    # and swap it in, so a concurrent reader/writer never sees a truncated
    # cache (last swap wins; its content includes the merge above)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump(dict(sorted(merged.items())), f, indent=1)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            try:
                os.remove(tmp)
            except OSError:
                pass
    return path


def clear_cache() -> None:
    _CACHE.clear()


def best_block(kind: str, shape: tuple, dtype, bits: int, scheme: str,
               backend: str):
    """Cached winner if a sweep ran for this key; otherwise the model-driven
    pick: the largest candidate under the VMEM budget (ties → larger bk for
    matmul, i.e. fewest sequential grid steps per output tile)."""
    if _cache_path() and _CACHE_LOADED_FROM != _cache_path():
        load_cache()
    key = cache_key(kind, shape, dtype, bits, scheme, backend)
    hit = _CACHE.get(key)
    if hit is not None:
        _notify("autotune_cache_hit", key=key, block=list(hit))
        return tuple(hit)
    _notify("autotune_model_pick", key=key)
    if kind == "matmul":
        m, k, n = shape
        cands = matmul_candidates(m, k, n)
        return max(cands, key=lambda b: (b[0] * b[1] * b[2], b[2]))
    if kind == "quantize":
        m, n = shape
        return max(quantize_candidates(m, n), key=lambda b: b[0] * b[1])
    if kind == "decode_attention":
        _b, cap, _nkv, group, hd = shape
        cands = decode_attention_candidates(
            cap, hd=hd, group=group, quantized="int8" in str(dtype))
        # largest tile = fewest sequential cache blocks per (slot, head);
        # length-aware skipping still prunes at this granularity
        return max(cands, key=lambda b: b[0])
    if kind == "verify_attention":
        _b, cap, _nkv, kq, group, hd = shape
        cands = verify_attention_candidates(
            cap, hd=hd, kq=kq, group=group, quantized="int8" in str(dtype))
        # same pick rule as decode: largest tile = fewest sequential cache
        # blocks per (slot, head); the per-row freeze still prunes reads
        return max(cands, key=lambda b: b[0])
    if kind == "paged_attention":
        _b, max_len, _nkv, group, hd = shape
        cands = paged_attention_candidates(
            max_len, hd=hd, group=group, quantized="int8" in str(dtype))
        # the pool block is also the allocation/prefix-sharing unit, so the
        # model pick balances kernel tile size against granularity: the
        # largest candidate that still gives a full-length request ≥ 4
        # blocks (falls back to the smallest candidate for tiny max_len)
        fitting = [c for c in cands if c[0] * 4 <= max_len]
        return (max(fitting, key=lambda b: b[0]) if fitting
                else min(cands, key=lambda b: b[0]))
    raise ValueError(f"unknown kernel kind {kind!r}")


# ---------------------------------------------------------------------------
# measured sweeps
# ---------------------------------------------------------------------------


def _time_block(run: Callable[[tuple], object], block: tuple,
                repeats: int) -> float:
    run(block)  # compile / warm up outside the timed region
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = run(block)
        getattr(out, "block_until_ready", lambda: None)()
        best = min(best, time.perf_counter() - t0)
    return best


def _sweep(kind: str, shape: tuple, dtype, bits: int, scheme: str,
           backend: str, candidates: List[tuple],
           run: Callable[[tuple], object], repeats: int):
    key = cache_key(kind, shape, dtype, bits, scheme, backend)
    recompute = key in _CACHE  # re-sweeping a key that already had a winner
    t0 = time.perf_counter()
    results = []
    for block in candidates:
        try:
            dt = _time_block(run, block, repeats)
        except Exception:  # noqa: BLE001 — an illegal tiling just loses the sweep
            continue
        results.append({"block": list(block), "seconds": dt})
    if not results:
        raise RuntimeError(f"no runnable {kind} block candidate for {shape}")
    results.sort(key=lambda r: r["seconds"])
    winner = tuple(results[0]["block"])
    _CACHE[key] = winner
    save_cache()
    _notify("autotune_sweep", key=key, winner=list(winner),
            candidates=len(results), recompute=recompute,
            sweep_s=time.perf_counter() - t0)
    return winner, results


def autotune_matmul(m: int, k: int, n: int, *, bits: int, scheme: str,
                    backend: str, run: Callable[[tuple], object],
                    dtype="float32", repeats: int = 2,
                    candidates: Optional[List[tuple]] = None):
    """Measure ``run(block)`` over the candidate set, cache and return the
    winner.  Returns (winner_block, per-candidate results sorted by time)."""
    cands = candidates or matmul_candidates(m, k, n)
    return _sweep("matmul", (m, k, n), dtype, bits, scheme, backend, cands,
                  run, repeats)


def autotune_quantize(m: int, n: int, *, bits: int, scheme: str, backend: str,
                      run: Callable[[tuple], object], dtype="float32",
                      repeats: int = 2,
                      candidates: Optional[List[tuple]] = None):
    cands = candidates or quantize_candidates(m, n)
    return _sweep("quantize", (m, n), dtype, bits, scheme, backend, cands,
                  run, repeats)


def autotune_decode_attention(b: int, cap: int, nkv: int, group: int, hd: int,
                              *, backend: str, run: Callable[[tuple], object],
                              dtype="int8", repeats: int = 2,
                              candidates: Optional[List[tuple]] = None):
    """Measured (bk,) sweep for the flash-decode attention kernel.  ``dtype``
    is the cache storage dtype ('int8' or 'bfloat16'); bits follow from it."""
    quantized = "int8" in str(dtype)
    cands = candidates or decode_attention_candidates(
        cap, hd=hd, group=group, quantized=quantized)
    return _sweep("decode_attention", (b, cap, nkv, group, hd), dtype,
                  8 if quantized else 16, "flash", backend, cands, run,
                  repeats)


def autotune_verify_attention(b: int, cap: int, nkv: int, kq: int,
                              group: int, hd: int, *, backend: str,
                              run: Callable[[tuple], object],
                              dtype="int8", repeats: int = 2,
                              candidates: Optional[List[tuple]] = None):
    """Measured (bk,) sweep for the multi-token verify kernel.  ``kq`` (the
    draft depth) is part of the key — the logit tile is kq·group rows, so
    winners don't transfer across depths."""
    quantized = "int8" in str(dtype)
    cands = candidates or verify_attention_candidates(
        cap, hd=hd, kq=kq, group=group, quantized=quantized)
    return _sweep("verify_attention", (b, cap, nkv, kq, group, hd), dtype,
                  8 if quantized else 16, "flash", backend, cands, run,
                  repeats)


def autotune_paged_attention(b: int, max_len: int, nkv: int, group: int,
                             hd: int, *, backend: str,
                             run: Callable[[tuple], object],
                             dtype="int8", repeats: int = 2,
                             candidates: Optional[List[tuple]] = None):
    """Measured (bs,) pool-block-size sweep for paged decode attention.

    ``run((bs,))`` must build a pool with that block size and time a decode
    pass — the block size is baked into the pool layout, so unlike the ring
    sweep each candidate re-allocates the cache.  The winner is what
    ``serve/kvpool.py`` (and the engine's ``kv_layout='paged'`` path) picks
    up when no explicit ``block_size`` is given."""
    quantized = "int8" in str(dtype)
    cands = candidates or paged_attention_candidates(
        max_len, hd=hd, group=group, quantized=quantized)
    return _sweep("paged_attention", (b, max_len, nkv, group, hd), dtype,
                  8 if quantized else 16, "flash", backend, cands, run,
                  repeats)
