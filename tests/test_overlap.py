"""Overlapped engine (DESIGN.md §11): windowed multi-tick decode and
chunked piggyback prefill are *stream-preserving*.

Contracts pinned here:

* **N-tick ≡ N single ticks, bitwise** — the fused decode window scans the
  exact per-tick ops of the ``decode_ticks=1`` engine (same sampler hash,
  same KV writes, dead rows frozen), so every per-request token stream and
  finish reason is bit-identical for any window length, including under
  temperature sampling, for ring/paged × bf16/int8 KV.
* **chunked prefill ≡ whole-prompt prefill at stream level (greedy)** — the
  dither KV codes key on absolute position + per-request offset, so a
  chunk writes the codes whole-prompt prefill would have written; the
  chunk's history join re-associates the softmax reduction (split
  softmax), which perturbs logits at bf16 epsilon — the same documented
  drift as the paged prefix join — so the pinned invariant is greedy
  token-stream equality, the repo's standard parity currency.
* chunk/block **boundary edges**: prompt length at / one-below / one-above
  the chunk and block sizes, prefix-cache hits that end mid-chunk, empty
  prompts, oversized chunks (clamped), and preempt-resume of a
  half-prefilled request.
* the same parity on a (1, 1) mesh in tier-1, and (2, 1)/(1, 2)/(2, 2)
  under CI's forced-4-device step.
"""

import jax
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_serve_mesh
from repro.models import registry
from repro.serve.engine import Engine, Request
from repro.serve.sampling import SamplingParams

N_DEV = len(jax.devices())
needs4 = pytest.mark.skipif(
    N_DEV < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=4)")

CFG = get_config("smollm_135m").reduced()
PARAMS = registry.init_model(jax.random.PRNGKey(0), CFG)

BLOCK = 8                                  # paged pool block size under test


def _serve(prompts, *, max_new=6, temperature=0.0, batch=2, max_len=48,
           **eng_kw):
    """Serve ``prompts`` on a fresh engine; return the canonical stream
    fingerprint [(rid, tokens, finish_reason), ...] plus the engine."""
    if eng_kw.get("kv_layout") == "paged":
        eng_kw.setdefault("block_size", BLOCK)
    eng = Engine(PARAMS, CFG, batch=batch, max_len=max_len, **eng_kw)
    for r, prompt in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=list(prompt),
                           sampling=SamplingParams(
                               temperature=temperature, max_new=max_new,
                               seed=r, eos_id=11, stop_ids=(77,),
                               counter_offset=500 * r)))
    done = eng.run(ticks=400)
    assert len(done) == len(prompts)
    return sorted((d.rid, tuple(d.out), d.finish_reason) for d in done), eng


def _mix(n=6):
    # Fixture chosen tie-free: greedy argmax margins stay clear of the
    # split-softmax / prefix-join bf16 drift for every layout × kv_quant ×
    # chunk × decode_ticks combination below (conftest.assert_argmax_margin
    # philosophy — near-tie fixtures get reseeded, not worked around).
    return [[(13 * r + i) % (CFG.vocab_size - 1) + 1
             for i in range(6 + 3 * r)] for r in range(n)]


_BASE = {}


def _baseline(kv_layout, kv_quant, temperature=0.0):
    key = (kv_layout, kv_quant, temperature)
    if key not in _BASE:
        _BASE[key], _ = _serve(_mix(), kv_layout=kv_layout,
                               kv_quant=kv_quant, temperature=temperature)
    return _BASE[key]


# ---------------------------------------------------------------------------
# multi-tick fused decode ≡ single ticks (bitwise)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_quant", [False, True], ids=["bf16", "int8"])
@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
@pytest.mark.parametrize("n", [3, 4])
def test_fused_window_matches_single_ticks(kv_layout, kv_quant, n):
    got, eng = _serve(_mix(), kv_layout=kv_layout, kv_quant=kv_quant,
                      decode_ticks=n)
    assert got == _baseline(kv_layout, kv_quant)
    if eng.pools:
        assert eng.pool_stats()["live"] == 0


@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
def test_fused_window_bitwise_under_temperature(kv_layout):
    """The window is bitwise even for sampled decoding: the sampler hash
    keys on (seed, counter = offset + emitted), both of which the fused
    scan advances exactly as N single ticks do."""
    want = _baseline(kv_layout, False, temperature=0.8)
    got, _ = _serve(_mix(), kv_layout=kv_layout, temperature=0.8,
                    decode_ticks=4)
    assert got == want


def test_fused_window_under_pool_pressure():
    """A pool too small to cover full windows caps per-window budgets
    (_paged_cap) instead of changing behaviour: streams still match the
    one-tick engine, and preempted requests still resume correctly."""
    want, _ = _serve(_mix(), kv_layout="paged", num_blocks=12)
    got, eng = _serve(_mix(), kv_layout="paged", num_blocks=12,
                      decode_ticks=4)
    assert got == want
    assert eng.pool_stats()["live"] == 0


def test_decode_ticks_validation():
    with pytest.raises(ValueError):
        Engine(PARAMS, CFG, batch=2, max_len=16, decode_ticks=0)


# ---------------------------------------------------------------------------
# chunked prefill ≡ whole-prompt prefill (greedy stream level)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_quant", [False, True], ids=["bf16", "int8"])
@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
def test_chunked_prefill_matches_whole_prompt(kv_layout, kv_quant):
    got, eng = _serve(_mix(), kv_layout=kv_layout, kv_quant=kv_quant,
                      prefill_chunk=5)
    assert got == _baseline(kv_layout, kv_quant)
    if eng.pools:
        assert eng.pool_stats()["live"] == 0


@pytest.mark.parametrize("kv_quant", [False, True], ids=["bf16", "int8"])
@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
def test_chunked_prefill_with_fused_windows(kv_layout, kv_quant):
    """The full overlapped configuration — piggyback chunks admitted
    between 4-tick decode windows — still reproduces the unoverlapped
    engine's streams."""
    got, _ = _serve(_mix(), kv_layout=kv_layout, kv_quant=kv_quant,
                    prefill_chunk=5, decode_ticks=4)
    assert got == _baseline(kv_layout, kv_quant)


# ---------------------------------------------------------------------------
# chunk / block boundary edges
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_layout,chunk", [("ring", 5), ("paged", BLOCK)])
def test_prompt_lengths_straddling_boundaries(kv_layout, chunk):
    """Prompt length exactly at / one below / one above the chunk size and
    the block size (and multiples) — the partial-final-chunk and
    full-final-chunk paths must agree with whole-prompt prefill."""
    lens = sorted({chunk - 1, chunk, chunk + 1,
                   2 * chunk - 1, 2 * chunk, 2 * chunk + 1, 1})
    prompts = [[(23 * r + i) % (CFG.vocab_size - 1) + 1 for i in range(n)]
               for r, n in enumerate(lens)]
    want, _ = _serve(prompts, kv_layout=kv_layout)
    got, _ = _serve(prompts, kv_layout=kv_layout, prefill_chunk=chunk)
    assert got == want


def test_empty_prompt_and_oversized_chunk():
    """Empty prompts take the BOS substitution through the chunked path,
    and a chunk larger than max_len is clamped (ring) / the whole prompt
    lands in one wave — both degenerate to whole-prompt prefill."""
    prompts = [[], [5, 6, 7], []]
    want, _ = _serve(prompts)
    for chunk in (2, 10 ** 6):
        got, _ = _serve(prompts, prefill_chunk=chunk)
        assert got == want


def test_paged_chunk_rounds_to_block_multiple():
    eng = Engine(PARAMS, CFG, batch=2, max_len=32, kv_layout="paged",
                 block_size=BLOCK, prefill_chunk=BLOCK + 3)
    assert eng.prefill_chunk == BLOCK                 # rounded down
    eng2 = Engine(PARAMS, CFG, batch=2, max_len=32, kv_layout="paged",
                  block_size=BLOCK, prefill_chunk=1)
    assert eng2.prefill_chunk == BLOCK                # floor one block


def test_prefix_hit_ending_mid_chunk():
    """A prefix-cache hit hands the request a block-aligned start; the
    remaining suffix here is shorter than one chunk, so the first (only)
    chunk is a partial one riding the prefix-join path.  The warm stream
    must equal the cold stream."""
    p_long = [(3 * i) % (CFG.vocab_size - 1) + 1 for i in range(2 * BLOCK)]
    p_warm = p_long[:2 * BLOCK - 3] + [401, 402]      # shares 1 full block+
    cold, _ = _serve([p_warm], kv_layout="paged", prefill_chunk=BLOCK)

    eng = Engine(PARAMS, CFG, batch=2, max_len=48, kv_layout="paged",
                 block_size=BLOCK, prefill_chunk=BLOCK)
    eng.submit(Request(rid=0, prompt=p_long,
                       sampling=SamplingParams(max_new=6, seed=0,
                                               counter_offset=0)))
    eng.run(ticks=100)
    eng.submit(Request(rid=1, prompt=p_warm,
                       sampling=SamplingParams(max_new=6, seed=0, eos_id=11,
                                               stop_ids=(77,),
                                               counter_offset=0)))
    done = eng.run(ticks=200)
    warm = [d for d in done if d.rid == 1][0]
    assert eng.stats["prefix_hit_tokens"] >= BLOCK    # the hit happened
    assert (0, tuple(warm.out), warm.finish_reason) == cold[0]


def test_preempt_resume_half_prefilled():
    """White-box: preempt a request mid-prefill (state == 'prefilling',
    blocks intact) and let admission resume it — it must rejoin the chunk
    waves at its _pf_pos and finish with the undisturbed engine's exact
    stream."""
    prompts = _mix(3)
    prompts[0] = [(5 * i) % (CFG.vocab_size - 1) + 1 for i in range(4 * BLOCK)]
    want, _ = _serve(prompts, kv_layout="paged", prefill_chunk=BLOCK)

    eng = Engine(PARAMS, CFG, batch=2, max_len=48, kv_layout="paged",
                 block_size=BLOCK, prefill_chunk=BLOCK)
    for r, prompt in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=list(prompt),
                           sampling=SamplingParams(
                               max_new=6, seed=r, eos_id=11, stop_ids=(77,),
                               counter_offset=500 * r)))
    preempted = False
    for _ in range(400):
        if not preempted:
            for i, s in enumerate(eng.slots):
                if s is not None and s.state == "prefilling" \
                        and 0 < s._pf_pos < len(s.prompt):
                    eng._preempt_requeue(i, s)
                    preempted = True
                    break
        eng.step()
        if not len(eng.scheduler) and all(s is None for s in eng.slots):
            break
    assert preempted, "fixture never caught a half-prefilled slot"
    got = sorted((d.rid, tuple(d.out), d.finish_reason) for d in eng.finished)
    assert got == want
    assert eng.stats["preemptions"] >= 1
    assert eng.pool_stats()["live"] == 0


# ---------------------------------------------------------------------------
# mesh parity: (1,1) in tier-1; 4-device shapes under CI's forced step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
def test_mesh_1x1_overlap_parity(kv_layout):
    """The shard_map fused-window + chunked-prefill path on a trivial
    (1, 1) mesh is stream-identical to the unmeshed one-tick engine."""
    got, _ = _serve(_mix(), kv_layout=kv_layout, decode_ticks=4,
                    prefill_chunk=5 if kv_layout == "ring" else BLOCK,
                    mesh=make_serve_mesh(1, 1), batch=2)
    assert got == _baseline(kv_layout, False)


_BASE4 = {}


@needs4
@pytest.mark.parametrize("dp,tp", [(2, 1), (1, 2), (2, 2)])
@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
def test_mesh_overlap_parity(kv_layout, dp, tp):
    """Windowed decode + chunked prefill sharded on (data, model) meshes
    reproduces the unmeshed single-tick streams (CI forces 4 devices)."""
    if kv_layout not in _BASE4:
        _BASE4[kv_layout], _ = _serve(_mix(), kv_layout=kv_layout, batch=4)
    got, _ = _serve(_mix(), kv_layout=kv_layout, decode_ticks=4,
                    prefill_chunk=5 if kv_layout == "ring" else BLOCK,
                    mesh=make_serve_mesh(dp, tp), batch=4)
    assert got == _BASE4[kv_layout]
