"""MoE dispatch correctness: scatter-based grouped matmul vs a brute-force
dense-expert reference, plus capacity-drop semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe
from repro.models.config import ModelConfig


def _cfg(e=4, k=2, cf=8.0):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=32, vocab_size=64, n_experts=e,
        n_experts_active=k, capacity_factor=cf,
    )


def _dense_reference(params, cfg, x):
    """Compute MoE output exactly: every token through its top-k experts."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = np.asarray(jnp.matmul(xf.astype(jnp.float32), params["router"]))
    probs = np.exp(logits - logits.max(1, keepdims=True))
    probs /= probs.sum(1, keepdims=True)
    k = cfg.n_experts_active
    out = np.zeros((t, d), np.float32)
    for ti in range(t):
        top = np.argsort(-probs[ti])[:k]
        w = probs[ti][top] / probs[ti][top].sum()
        for e_i, wi in zip(top, w):
            h = np.asarray(xf[ti]).astype(np.float32)
            g = h @ np.asarray(params["wg"][e_i], np.float32)
            u = h @ np.asarray(params["wu"][e_i], np.float32)
            act = (g / (1 + np.exp(-g))) * u
            out[ti] += wi * (act @ np.asarray(params["wd"][e_i], np.float32))
    return out.reshape(b, s, d)


def test_moe_matches_dense_reference():
    cfg = _cfg(cf=8.0)  # generous capacity → no drops
    key = jax.random.PRNGKey(0)
    params = moe.init_moe(key, cfg)
    # f32 params for a tight comparison
    params = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16), jnp.float32)
    got = np.asarray(moe.moe_ffn(params, cfg, x))
    want = _dense_reference(params, cfg, x)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-3)


def test_moe_capacity_drops_tokens():
    """With capacity factor ≪ 1 most assignments drop → output much smaller."""
    cfg_lo = _cfg(cf=0.05)
    cfg_hi = _cfg(cf=8.0)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg_lo)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16), jnp.float32)
    params = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    lo = np.abs(np.asarray(moe.moe_ffn(params, cfg_lo, x))).mean()
    hi = np.abs(np.asarray(moe.moe_ffn(params, cfg_hi, x))).mean()
    assert lo < hi * 0.6, (lo, hi)


def test_moe_capacity_formula():
    cfg = _cfg(e=8, k=2, cf=1.25)
    assert moe.moe_capacity(64, cfg) == 20  # ceil(64·2·1.25/8)


def test_shared_expert_path():
    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=32, vocab_size=64, n_experts=4,
        n_experts_active=2, shared_d_ff=24,
    )
    params = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 16), jnp.bfloat16)
    out = moe.moe_ffn(params, cfg, x)
    assert out.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(out)))
