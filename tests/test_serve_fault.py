"""Serve-path fault tolerance: deadlines, shedding, degradation, and
bitwise crash recovery (DESIGN.md §12).

The load-bearing pin is the **chaos recovery contract**: an engine crashed
by the serve-phase ``FailureInjector`` at any of its five crash points and
restored from its snapshot emits token streams *bitwise-identical* to an
uninterrupted run — for ring and paged layouts × bf16/int8 KV × greedy and
temperature sampling — with zero slot/block leaks and FCFS-within-priority
preserved across the restart.  This only works because the paper's
determinism carries to serving: dither KV codes are a pure function of
(value, absolute position + offset, element index) and the sampler is a
stateless hash of (seed, counter), so re-prefilling the prompt region and
teacher-forced-replaying the generated region rebuilds the device cache
bit-for-bit.  A stochastic-rounded cache has no such replay.

Engines are cached per configuration (jit closures are per-Engine);
``Engine.restore`` works in place, so the crash tests restore into the
cached engine rather than recompiling a fresh one.  The
``run_serve_with_restarts`` test builds genuinely fresh engines to prove
the cross-process recovery shape.  Hypothesis parts skip cleanly when
hypothesis is absent (tests/_hypothesis_compat.py).
"""

import itertools
import json
import time

import jax
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.dist.fault_tolerance import (FailureInjector, InjectedFailure,
                                        SERVE_PHASES, StragglerWatchdog,
                                        run_serve_with_restarts)
from repro.models import registry
from repro.serve.engine import Engine, Request
from repro.serve.sampling import SamplingParams

CFG = get_config("smollm_135m").reduced()
PARAMS = registry.init_model(jax.random.PRNGKey(0), CFG)

MAX_LEN = 32
EOS = 11

# the acceptance matrix: ring/paged × bf16/int8, exercised below at both
# greedy and temperature sampling
CONFIGS = {
    "ring-bf16": dict(decode_ticks=2),
    "ring-int8": dict(decode_ticks=2, kv_quant=True),
    "paged-bf16": dict(kv_layout="paged", block_size=8, decode_ticks=2),
    "paged-int8": dict(kv_layout="paged", block_size=8, decode_ticks=2,
                       kv_quant=True),
}
_ENGINES = {}
_RID = itertools.count()


def _engine(name):
    if name not in _ENGINES:
        _ENGINES[name] = Engine(PARAMS, CFG, batch=2, max_len=MAX_LEN,
                                scheduler="priority", **CONFIGS[name])
    eng = _ENGINES[name]
    eng.finished = []
    eng.injector = None
    eng.snapshot_path = None
    eng.reset_stats()
    return eng


def _request(rid, key=None, temperature=0.0, max_new=5, prompt_len=None,
             **kw):
    """Build a request whose *content* (prompt, seed, priority, counter
    offset) is a pure function of ``key`` — parity tests run the same
    keyed workload under different rid ranges on a shared engine."""
    key = rid if key is None else key
    prompt_len = 4 + key % 3 if prompt_len is None else prompt_len
    prompt = [(7 * key + i) % (CFG.vocab_size - 1) + 1
              for i in range(prompt_len)]
    return Request(rid=rid, prompt=prompt, priority=key % 2,
                   sampling=SamplingParams(temperature=temperature, seed=key,
                                           max_new=max_new, eos_id=EOS,
                                           counter_offset=100 * key), **kw)


def _streams(engine):
    return {r.rid: (list(r.out), r.finish_reason) for r in engine.finished}


def _assert_no_leaks(engine):
    assert all(s is None for s in engine.slots)
    assert len(engine.scheduler) == 0
    if engine.pools:
        assert sum(p.live_blocks for p in engine.pools) == 0


def _assert_fcfs_within_priority(reqs):
    for prio in {r.priority for r in reqs}:
        admits = [r.t_admit for r in reqs
                  if r.priority == prio and r.t_admit is not None]
        assert admits == sorted(admits)


# -------------------------------------------------------------- deadlines


def test_deadline_expires_queued_request():
    """A queued request past its deadline finishes 'deadline' without ever
    touching a slot; the expiry scan runs before admission, so a zero
    deadline is deterministic."""
    eng = _engine("ring-bf16")
    eng.submit(_request(next(_RID), max_new=6))
    eng.submit(_request(next(_RID), max_new=6))
    expired = _request(next(_RID), deadline_s=0.0)
    eng.submit(expired)
    eng.run(200)
    assert expired.finish_reason == "deadline"
    assert expired.out == [] and expired.t_admit is None
    assert eng.metrics.counters["finish_deadline"] == 1
    _assert_no_leaks(eng)


def test_queue_ttl_expires_stale_queue():
    eng = Engine(PARAMS, CFG, batch=1, max_len=MAX_LEN, queue_ttl_s=30.0)
    eng.submit(_request(0, max_new=4))
    eng.submit(_request(1, max_new=4))
    eng.step()                                  # admits rid 0; rid 1 queued
    eng._now = lambda: time.time() + 60.0       # everything is now stale
    done = {r.rid: r for r in eng.run(200)}
    assert done[1].finish_reason == "deadline" and done[1].out == []
    # the running request has no deadline_s — TTL only bounds queue wait
    assert done[0].finish_reason == "length"
    _assert_no_leaks(eng)


def test_deadline_cancels_running_request_and_releases_blocks():
    eng = _engine("paged-bf16")
    victim = _request(next(_RID), deadline_s=5.0, max_new=30)
    eng.submit(victim)
    eng.submit(_request(next(_RID), max_new=4))
    for _ in range(2):
        eng.step()
    assert victim.state == "active" and victim.out
    clock = eng._now
    try:
        eng._now = lambda: time.time() + 100.0
        eng.run(200)
    finally:
        eng._now = clock
    assert victim.finish_reason == "deadline" and len(victim.out) > 0
    assert len(victim.out) < 30                 # cancelled, not drained
    _assert_no_leaks(eng)


# --------------------------------------------------------------- shedding


def test_shed_reject_new_bounds_the_queue():
    eng = Engine(PARAMS, CFG, batch=1, max_len=MAX_LEN, queue_cap=2)
    eng.submit(_request(0, max_new=6))
    eng.step()                                  # rid 0 occupies the slot
    kept = [_request(1), _request(2)]
    for r in kept:
        eng.submit(r)
    shed = _request(3)
    eng.submit(shed)
    assert shed.done and shed.finish_reason == "shed" and shed.out == []
    assert shed in eng.finished
    assert len(eng.scheduler) == 2
    eng.run(300)
    assert all(r.finish_reason in ("length", "eos") for r in kept)
    assert eng.metrics.counters["finish_shed"] == 1
    assert eng.metrics.counters["finished_requests"] == 4
    _assert_no_leaks(eng)


def test_shed_evict_lowest_priority_prefers_newcomer_rank():
    eng = Engine(PARAMS, CFG, batch=1, max_len=MAX_LEN, queue_cap=2,
                 shed_policy="evict-lowest-priority", scheduler="priority")
    eng.submit(_request(0, max_new=6))
    eng.step()
    low_old = _request(1)
    low_new = _request(2)
    for r in (low_old, low_new):
        r.priority = 0
        eng.submit(r)
    vip = _request(3)
    vip.priority = 5
    eng.submit(vip)           # evicts the lowest-priority *latest* arrival
    assert low_new.finish_reason == "shed"
    assert not low_old.done and not vip.done
    peer = _request(4)
    peer.priority = 0         # does not outrank the queue minimum
    eng.submit(peer)
    assert peer.finish_reason == "shed"
    eng.run(300)
    assert {r.rid for r in eng.finished} == {0, 1, 2, 3, 4}
    assert eng.metrics.counters["finish_shed"] == 2
    _assert_no_leaks(eng)


# ------------------------------------------------------------ degradation


def test_degradation_watermarks_have_hysteresis():
    """White-box: drive the live-block share across the watermarks via
    direct pool allocations and check the degraded flag flips with
    hysteresis — window drops to 1 tick, prefix insertion pauses, and both
    restore only after pressure clears the low watermark."""
    eng = Engine(PARAMS, CFG, batch=2, max_len=MAX_LEN, kv_layout="paged",
                 block_size=4, num_blocks=8, decode_ticks=4)
    pool, bs = eng.pool, 4
    assert eng._window_ticks() == 4
    pool.allocate(999, 8 * bs)                       # live share 1.0
    eng._update_pressure()
    assert eng._degraded and eng._window_ticks() == 1
    pool.release(999)
    pool.allocate(998, 7 * bs)                       # 0.875: between marks
    eng._update_pressure()
    assert eng._degraded, "must stay degraded between the watermarks"
    pool.release(998)
    pool.allocate(997, 4 * bs)                       # 0.5 <= degrade_low
    eng._update_pressure()
    assert not eng._degraded and eng._window_ticks() == 4
    pool.allocate(996, 3 * bs)                       # 0.875 again, from below
    eng._update_pressure()
    assert not eng._degraded, "must stay clear until the high watermark"
    assert eng.metrics.counters["degrade_events"] == 1
    pool.release(997)
    pool.release(996)


def test_degraded_engine_streams_are_unchanged():
    """Degradation is stream-preserving: a forced-degraded run emits the
    same tokens as a normal one (window length is bitwise-invariant and
    sealing is only an availability optimisation)."""
    eng = _engine("paged-int8")
    reqs = [_request(next(_RID), key=k, temperature=0.8) for k in range(3)]
    rid0 = reqs[0].rid
    for r in reqs:
        eng.submit(r)
    eng.run(300)
    ref = {r.rid - rid0: (list(r.out), r.finish_reason)
           for r in eng.finished}

    eng = _engine("paged-int8")
    eng._degraded = True
    eng.degrade_low = -1.0          # unreachable: stays degraded throughout
    try:
        reqs = [_request(next(_RID), key=k, temperature=0.8)
                for k in range(3)]
        rid0 = reqs[0].rid
        for r in reqs:
            eng.submit(r)
        eng.run(300)
        got = {r.rid - rid0: (list(r.out), r.finish_reason)
               for r in eng.finished}
    finally:
        eng._degraded = False
        eng.degrade_low = 0.70
    assert got == ref
    _assert_no_leaks(eng)


# --------------------------------------------------- snapshot/restore pins


@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_snapshot_restore_is_bitwise(name, temperature):
    """The §12 acceptance pin: stop an engine mid-flight, serialize it
    through real JSON, restore, continue — streams, finish reasons, FCFS
    order and pool accounting all match the uninterrupted run exactly."""
    def workload(rid0):
        return [_request(rid0 + k, key=k, temperature=temperature,
                         max_new=5 + k % 2) for k in range(4)]

    eng = _engine(name)
    rid0 = next(_RID)
    for _ in range(3):
        next(_RID)
    ref_reqs = workload(rid0)
    for r in ref_reqs:
        eng.submit(r)
    eng.run(300)
    ref = {r.rid - rid0: (list(r.out), r.finish_reason)
           for r in eng.finished}
    _assert_fcfs_within_priority(ref_reqs)

    eng = _engine(name)
    rid0 = next(_RID)
    for _ in range(3):
        next(_RID)
    reqs = {r.rid: r for r in workload(rid0)}
    for r in reqs.values():
        eng.submit(r)
    for _ in range(3):
        eng.step()                        # mid-flight: slots busy, queue live
    snap = json.loads(json.dumps(eng.snapshot()))      # prove JSON-able
    eng.restore(snap)                     # in place: fresh device cache
    eng.run(300)
    got = {r.rid - rid0: (list(r.out), r.finish_reason)
           for r in eng.finished}
    assert got == ref
    # restored Request objects replace the submitted ones; FCFS must hold
    # across the restore boundary on the engine's own records
    by_rid = {r.rid: r for r in eng.finished}
    _assert_fcfs_within_priority([by_rid[rid] for rid in sorted(by_rid)])
    assert eng.metrics.counters["recoveries"] == 1
    _assert_no_leaks(eng)


def test_snapshot_restores_into_fresh_engine_from_file(tmp_path):
    """Cold-process shape: snapshot to disk, build a new Engine, restore,
    and re-attach streaming callbacks by rid."""
    kw = dict(batch=2, max_len=MAX_LEN, kv_layout="paged", block_size=8,
              decode_ticks=2)
    ref = Engine(PARAMS, CFG, **kw)
    for r in range(4):
        ref.submit(_request(r))
    ref.run(300)
    expected = _streams(ref)

    eng = Engine(PARAMS, CFG, snapshot_path=str(tmp_path / "snap.json"), **kw)
    for r in range(4):
        eng.submit(_request(r))
    for _ in range(2):
        eng.step()
    del eng                                     # "crash": engine object gone

    tokens = {r: [] for r in range(4)}
    streams = {r: (lambda req, tok, _r=r: tokens[_r].append(tok))
               for r in range(4)}
    fresh = Engine(PARAMS, CFG, **kw)
    with open(tmp_path / "snap.json") as fh:
        fresh.restore(json.load(fh), streams=streams)
    fresh.run(300)
    assert _streams(fresh) == expected
    # callbacks resumed mid-stream: every post-restore token reached its
    # stream, and each stream is a suffix of the request's full output
    assert any(tokens.values())
    for r in fresh.finished:
        got = tokens[r.rid]
        if got:
            assert r.out[-len(got):] == got
    _assert_no_leaks(fresh)


def test_restore_rejects_layout_mismatch():
    eng = _engine("paged-bf16")
    snap = eng.snapshot()
    other = _engine("ring-bf16")
    with pytest.raises(ValueError, match="kv_layout"):
        other.restore(snap)


# ------------------------------------------------------- injector + driver


def test_injector_crash_points_recover_bitwise(tmp_path):
    """Every serve crash phase, driven through ``run_serve_with_restarts``
    with genuinely fresh engines per restart: recovery is bitwise, the
    injector fires exactly once, and nothing leaks."""
    kw = dict(batch=2, max_len=MAX_LEN, kv_layout="paged", block_size=8,
              decode_ticks=2)
    ref = Engine(PARAMS, CFG, **kw)
    for r in range(4):
        ref.submit(_request(r, temperature=0.8))
    ref.run(300)
    expected = _streams(ref)

    for phase in SERVE_PHASES:
        snap_path = str(tmp_path / f"snap_{phase}.json")
        injector = FailureInjector(crash_at={2: phase})

        def make_engine():
            return Engine(PARAMS, CFG, injector=injector,
                          snapshot_path=snap_path, **kw)

        def submit(engine):
            for r in range(4):
                engine.submit(_request(r, temperature=0.8))

        eng = run_serve_with_restarts(make_engine, submit,
                                      snapshot_path=snap_path, ticks=300)
        assert _streams(eng) == expected, phase
        assert injector.fired == {(2, phase)}
        assert eng.metrics.counters["recoveries"] == 1
        _assert_no_leaks(eng)


def test_injector_unrecoverable_after_max_restarts(tmp_path):
    """A crash point that always re-fires (fresh injector per engine)
    exhausts max_restarts and surfaces as the chained RuntimeError."""
    snap_path = str(tmp_path / "snap.json")

    def make_engine():
        return Engine(PARAMS, CFG, batch=1, max_len=MAX_LEN,
                      injector=FailureInjector(crash_at={0: "pre_admit"}),
                      snapshot_path=snap_path)

    def submit(engine):
        engine.submit(_request(0))

    with pytest.raises(RuntimeError, match="after 1 restarts"):
        run_serve_with_restarts(make_engine, submit,
                                snapshot_path=snap_path, ticks=50,
                                max_restarts=1)


# ---------------------------------------------------------------- watchdog


class _ListSink:
    def __init__(self):
        self.records = []

    def write(self, records):
        self.records.extend(records)

    def close(self):
        pass


def test_watchdog_flags_slow_windows_through_the_sink():
    """Since PR 9 the lifecycle *events* (slow_window, degraded/restored)
    travel on the tracer's feed (DESIGN.md §13); the metrics stream keeps
    the counter and the per-window wall-time gauge.  Both sinks are
    attached here to pin which stream carries what."""
    msink, tsink = _ListSink(), _ListSink()
    eng = Engine(PARAMS, CFG, batch=1, max_len=MAX_LEN, metrics=msink,
                 trace=tsink,
                 watchdog=StragglerWatchdog(threshold=0.0, warmup=1))
    eng.submit(_request(0, max_new=6))
    eng.run(100)
    eng.metrics.flush()
    eng.trace.flush()
    slow = eng.metrics.counters["slow_windows"]
    assert slow > 0
    events = [r for r in tsink.records
              if r.get("kind") == "event" and r.get("name") == "slow_window"]
    assert len(events) == slow
    assert all("window_s" in e and "tick" in e for e in events)
    assert not any(r.get("event") == "slow_window" for r in msink.records)
    ticks = [r for r in msink.records if "queue_depth" in r]
    assert all("window_s" in r for r in ticks)   # per-window wall-time gauge


def test_watchdog_defaults_on_and_quiet():
    eng = _engine("ring-bf16")
    assert isinstance(eng.watchdog, StragglerWatchdog)
    off = Engine(PARAMS, CFG, batch=1, max_len=MAX_LEN, watchdog=False)
    assert off.watchdog is None


# --------------------------------------------------------- hypothesis soak


crash_st = st.tuples(
    st.integers(0, 10),                       # crash window index
    st.sampled_from(SERVE_PHASES),
)


@settings(max_examples=8, deadline=None)
@given(crashes=st.lists(crash_st, min_size=1, max_size=3, unique=True),
       temperature=st.sampled_from([0.0, 0.8]),
       n_reqs=st.integers(2, 5))
def test_random_crash_soak_recovers_bitwise(crashes, temperature, n_reqs):
    """Hypothesis-chosen crash ticks/phases (possibly several per run): the
    cached engine crashes, restores in place from its last snapshot file,
    and must still finish every request with streams bitwise-equal to an
    uninterrupted run and no leaks."""
    import os
    import tempfile

    name = "paged-int8"
    eng = _engine(name)
    rid0 = next(_RID)
    for _ in range(n_reqs - 1):
        next(_RID)
    reqs = [_request(rid0 + k, key=k, temperature=temperature)
            for k in range(n_reqs)]
    for r in reqs:
        eng.submit(r)
    eng.run(300)
    ref = {r.rid - rid0: (list(r.out), r.finish_reason)
           for r in eng.finished}

    eng = _engine(name)
    rid0 = next(_RID)
    for _ in range(n_reqs - 1):
        next(_RID)
    reqs = [_request(rid0 + k, key=k, temperature=temperature)
            for k in range(n_reqs)]
    fd, snap_path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    os.unlink(snap_path)
    try:
        # windows advance the tick by decode_ticks; key crashes on the
        # ticks the windows actually start at
        n = eng.decode_ticks
        eng.injector = FailureInjector(
            crash_at={w * n: phase for w, phase in crashes})
        eng.snapshot_path = snap_path
        for r in reqs:
            eng.submit(r)
        # recovery point for a crash that lands before the first on-disk
        # snapshot: the pristine just-submitted state
        snap0 = eng.snapshot()
        for _ in range(len(crashes) + 1):
            try:
                eng.run(300)
                break
            except InjectedFailure:
                if os.path.exists(snap_path):
                    with open(snap_path) as fh:
                        eng.restore(json.load(fh))
                else:
                    eng.restore(snap0)
        got = {r.rid - rid0: (list(r.out), r.finish_reason)
               for r in eng.finished}
        assert got == ref
        _assert_no_leaks(eng)
    finally:
        eng.injector = None
        eng.snapshot_path = None
        if os.path.exists(snap_path):
            os.unlink(snap_path)
