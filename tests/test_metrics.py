"""Engine metrics surface (DESIGN.md §10): histogram counts equal finished
counts, per-tick gauges agree with ``Engine.stats`` / ``pool_stats`` across
ring/paged layouts and the (1,1) mesh, sink crashes never reach serving, and
``reset_stats`` round-trips the metrics surface."""

import json

import jax
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_serve_mesh
from repro.models import registry
from repro.serve import (Engine, Histogram, JsonlSink, Metrics, NullSink,
                         Request, SamplingParams, StdoutSink, make_sink)

CFG = get_config("smollm_135m").reduced()
PARAMS = registry.init_model(jax.random.PRNGKey(0), CFG)


def _run_engine(n_requests=4, max_new=4, metrics=None, **eng_kw):
    eng = Engine(PARAMS, CFG, batch=2, max_len=32, metrics=metrics, **eng_kw)
    for r in range(n_requests):
        eng.submit(Request(
            rid=r, prompt=[1 + r, 2, 3],
            sampling=SamplingParams(max_new=max_new, seed=r,
                                    counter_offset=100 * r)))
    done = eng.run(ticks=n_requests * (max_new + 4) + 20)
    return eng, done


# ---------------------------------------------------------------------------
# Histogram
# ---------------------------------------------------------------------------


def test_histogram_counts_exact_percentiles_approximate():
    h = Histogram()
    vals = [0.001 * (i + 1) for i in range(100)]        # 1ms .. 100ms
    for v in vals:
        h.record(v)
    s = h.summary()
    assert s["count"] == 100                            # counts are exact
    assert s["max"] == pytest.approx(0.1)
    assert s["mean"] == pytest.approx(sum(vals) / 100)
    # log-bucket percentiles: ≈21% bucket ratio → generous relative band
    assert s["p50"] == pytest.approx(0.0505, rel=0.25)
    assert s["p99"] == pytest.approx(0.099, rel=0.25)


def test_histogram_underflow_overflow_and_empty():
    h = Histogram(lo=1e-3, hi=1.0, n_buckets=8)
    assert h.summary()["p50"] == 0.0                    # empty histogram
    h.record(1e-9)                                      # underflow
    h.record(100.0)                                     # overflow
    assert h.count == 2
    assert h.max == 100.0
    assert h.percentile(1) <= h.lo                      # lands in underflow
    # overflow bucket interpolates between hi and the recorded max
    assert h.hi <= h.percentile(99) <= h.max


def test_histogram_percentile_extremes():
    h = Histogram()
    assert h.percentile(0.0) == 0.0 == h.percentile(100.0)  # empty
    h.record(0.05)                                      # single sample
    lo_edge = h._edge(h._bucket(0.05) - 1)
    hi_edge = h._edge(h._bucket(0.05))
    # every percentile of a single sample lands inside its bucket
    for q in (0.0, 1.0, 50.0, 99.0, 100.0):
        assert lo_edge <= h.percentile(q) <= hi_edge
    assert h.summary()["max"] == 0.05


def test_histogram_clamps_out_of_range_values():
    h = Histogram(lo=1e-2, hi=1.0, n_buckets=4)
    for v in (0.0, 1e-6, 5.0, 100.0):
        h.record(v)
    assert h.count == 4
    assert h.counts[0] == 2                             # underflow bucket
    assert h.counts[-1] == 2                            # overflow bucket
    assert 0.0 <= h.percentile(1) <= h.lo
    assert h.hi <= h.percentile(99) <= h.max == 100.0
    assert h.sum == pytest.approx(105.000001)           # sums stay exact


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------


def test_make_sink_specs(tmp_path):
    assert isinstance(make_sink(None), NullSink)
    assert isinstance(make_sink("null"), NullSink)
    assert isinstance(make_sink("stdout"), StdoutSink)
    assert isinstance(make_sink(f"jsonl:{tmp_path}/m.jsonl"), JsonlSink)
    assert isinstance(make_sink(str(tmp_path / "m.jsonl")), JsonlSink)
    sink = NullSink()
    assert make_sink(sink) is sink                      # objects pass through
    with pytest.raises(ValueError):
        make_sink("csv:/tmp/x")
    with pytest.raises(TypeError):
        make_sink(42)


def test_jsonl_sink_streams_every_tick(tmp_path):
    path = tmp_path / "ticks.jsonl"
    eng, done = _run_engine(metrics=f"jsonl:{path}")
    eng.metrics.close()
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(lines) == eng.metrics.ticks              # one record per tick
    assert [l["tick"] for l in lines] == list(range(len(lines)))
    assert all("queue_depth" in l and "batch_occupancy" in l for l in lines)
    assert lines[-1]["finished_total"] == len(done)


def test_jsonl_sink_close_fsyncs_and_reopen_repairs_torn_tail(tmp_path):
    """Durability contract: close() leaves every record on disk, and a
    reopening writer truncates a torn final line (crash mid-write) back to
    the last complete record before appending."""
    path = tmp_path / "m.jsonl"
    sink = JsonlSink(str(path))
    sink.write([{"a": 1}, {"a": 2}])
    sink.close()
    assert sink._fh is None                             # idempotent close
    sink.close()
    with open(path, "a") as fh:
        fh.write('{"a": 3, "torn')                      # no trailing newline

    reopened = JsonlSink(str(path))
    reopened.write([{"a": 4}])
    reopened.close()
    recs = [json.loads(l) for l in path.read_text().splitlines()]
    assert [r["a"] for r in recs] == [1, 2, 4]          # torn record gone


def test_jsonl_sink_repairs_file_with_no_complete_line(tmp_path):
    path = tmp_path / "m.jsonl"
    path.write_text('{"partial": ')                     # newline-free tail
    sink = JsonlSink(str(path))
    sink.write([{"a": 1}])
    sink.close()
    assert [json.loads(l)["a"] for l in path.read_text().splitlines()] == [1]


def test_sink_crash_isolation():
    """A sink that raises on every write must not disturb serving: the run
    completes, the error is counted once, and the sink degrades to a
    NullSink (the wandblog idiom — observability is best-effort)."""

    class BoomSink:
        def write(self, records):
            raise IOError("disk full")

        def close(self):
            pass

    m = Metrics(sink=BoomSink(), flush_every=1)
    eng, done = _run_engine(metrics=m)
    assert len(done) == 4
    assert all(r.finish_reason == "length" for r in done)
    assert eng.metrics.sink_errors == 1                 # first flush only
    assert isinstance(eng.metrics.sink, NullSink)
    # token stream is unchanged vs a clean engine
    _, done_clean = _run_engine()
    assert ([r.out for r in sorted(done, key=lambda r: r.rid)]
            == [r.out for r in sorted(done_clean, key=lambda r: r.rid)])


# ---------------------------------------------------------------------------
# engine consistency: metrics ≡ stats/pool_stats, layouts × (1,1) mesh
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("eng_kw", [
    {},                                                  # dense ring
    {"kv_layout": "paged", "block_size": 8},             # paged pool
    {"mesh": "1x1"},                                     # (1,1) mesh, ring
    {"kv_layout": "paged", "block_size": 8, "mesh": "1x1"},
], ids=["ring", "paged", "ring-mesh11", "paged-mesh11"])
def test_metrics_consistent_with_engine_stats(eng_kw):
    eng_kw = dict(eng_kw)
    if eng_kw.get("mesh") == "1x1":
        eng_kw["mesh"] = make_serve_mesh(1, 1)
    eng, done = _run_engine(**eng_kw)
    assert len(done) == 4
    ms = eng.metrics.summary()

    # histogram counts == finished-request accounting (exact, no bucketing)
    n_first = sum(1 for r in done if r.ttft is not None)
    assert ms["ttft_s"]["count"] == n_first == len(done)
    assert ms["itl_s"]["count"] == sum(len(r.itl) for r in done)
    assert ms["counters"]["finished_requests"] == len(done)
    assert ms["counters"]["finish_length"] == len(done)

    # last-tick gauges == the engine's own cumulative stats
    g = ms["gauges"]
    assert g["finished_total"]["last"] == len(done)
    assert g["prefill_tokens"]["last"] == eng.stats["prefill_tokens"]
    assert g["decode_tokens"]["last"] == eng.stats["decode_tokens"]
    assert g["prefix_hit_tokens"]["last"] == eng.stats["prefix_hit_tokens"]
    assert g["preemptions"]["last"] == eng.stats["preemptions"]
    assert 0.0 <= g["batch_occupancy"]["mean"] <= 1.0
    assert ms["ticks"] > 0

    if eng.pools:                                        # paged-only gauges
        ps = eng.pool_stats()
        assert g["live_blocks"]["last"] == ps["live"]
        assert g["cached_blocks"]["last"] == ps["cached"]
        assert (g["free_blocks"]["last"]
                == sum(p.free_blocks for p in eng.pools))
    else:
        assert "live_blocks" not in g


@pytest.mark.parametrize("eng_kw", [
    {},
    {"kv_layout": "paged", "block_size": 8},
], ids=["ring", "paged"])
def test_itl_attribution_consistent_across_decode_ticks(eng_kw):
    """A fused window drains m tokens per host visit; the engine attributes
    drain_interval / m to each (DESIGN.md §11), so the itl histogram keeps
    one observation **per completed token** — decode_ticks=4 and
    decode_ticks=1 must report identical itl counts and identical
    per-request itl list lengths, not one observation per drain."""
    eng1, done1 = _run_engine(**eng_kw)
    eng4, done4 = _run_engine(decode_ticks=4, **eng_kw)
    assert len(done1) == len(done4) == 4

    by_rid1 = {r.rid: r for r in done1}
    by_rid4 = {r.rid: r for r in done4}
    for rid in by_rid1:
        r1, r4 = by_rid1[rid], by_rid4[rid]
        assert r1.out == r4.out                          # streams bitwise
        # one inter-token latency per token after the first — regardless of
        # how many host drains produced them
        assert len(r4.itl) == len(r1.itl) == len(r1.out) - 1
        assert all(v >= 0.0 for v in r4.itl)
        # max_new=4 ⇒ the 3 decode tokens drain in a single 4-tick window,
        # so every one carries the same drain_interval / m share (equal up
        # to float64 epoch-timestamp subtraction noise, ~µs)
        assert all(v == pytest.approx(r4.itl[0], abs=1e-5) for v in r4.itl)

    m1, m4 = eng1.metrics.summary(), eng4.metrics.summary()
    want = sum(len(r.out) - 1 for r in done1)
    assert m1["itl_s"]["count"] == want
    assert m4["itl_s"]["count"] == want                  # per token, per drain
    assert m1["ttft_s"]["count"] == m4["ttft_s"]["count"] == 4
    # the fused engine made fewer decode dispatches to emit the same tokens
    assert eng4.stats["decode_tokens"] == eng1.stats["decode_tokens"]
    assert eng4.stats["decode_calls"] < eng1.stats["decode_calls"]


def test_rejected_requests_are_counted():
    eng = Engine(PARAMS, CFG, batch=1, max_len=8)
    eng.submit(Request(rid=0, prompt=list(range(1, 20)), max_new=4))
    done = eng.run(10)
    assert done[0].finish_reason == "rejected"
    ms = eng.metrics.summary()
    assert ms["counters"]["finished_requests"] == 1
    assert ms["counters"]["finish_rejected"] == 1
    assert ms["ttft_s"]["count"] == 0                   # never emitted


def test_reset_stats_roundtrips_metrics():
    """benchmarks reset between waves: the histograms and counters must
    describe only the post-reset wave (serve_bench's v5 fields ride on
    this), while the sink plumbing stays alive."""
    eng, done = _run_engine()
    assert eng.metrics.ticks > 0
    eng.reset_stats()
    ms = eng.metrics.summary()
    assert ms["ticks"] == 0 and ms["counters"] == {}
    assert ms["ttft_s"]["count"] == 0 and ms["itl_s"]["count"] == 0
    assert ms["gauges"] == {}

    eng.finished = []
    for r in range(2):
        eng.submit(Request(rid=100 + r, prompt=[1 + r, 2, 3],
                           sampling=SamplingParams(max_new=3, seed=r)))
    done2 = eng.run(60)
    ms = eng.metrics.summary()
    assert ms["ttft_s"]["count"] == len(done2) == 2     # second wave only
    assert ms["counters"]["finished_requests"] == 2
    assert ms["gauges"]["finished_total"]["last"] == 2
