"""Per-request tracing and latency attribution (DESIGN.md §13).

The load-bearing pins:

* **bitwise invisibility** — tracing is host-only (timestamps are taken
  only where the engine already synchronises), so a traced engine's token
  streams are bitwise those of an untraced one across ring/paged ×
  bf16/int8 with fused windows and chunked prefill on.
* **attribution by construction** — each request's phase segments exactly
  partition [t_submit, t_finish], so ``explain()`` shares sum to 100% for
  every finished request of a mixed workload (chunked prefill, preemption,
  deadline expiry, degradation).
* **export consistency** — the Perfetto JSON and the jsonl feed describe
  the same per-request spans one-to-one.
* **crash continuity** — timelines carried through snapshot/restore stay
  contiguous: spans open at the crash close with a recovery marker and a
  ``recovery`` segment bridges crash → resume.
"""

import itertools
import json

import jax
import pytest

from repro.configs import get_config
from repro.dist.fault_tolerance import FailureInjector, run_serve_with_restarts
from repro.kernels import autotune
from repro.models import registry
from repro.serve import (Engine, JsonlSink, NullSink, Request, SamplingParams,
                         Tracer, format_explain)
from repro.serve.trace import CATEGORIES

CFG = get_config("smollm_135m").reduced()
PARAMS = registry.init_model(jax.random.PRNGKey(0), CFG)

MAX_LEN = 32
EOS = 11

# the acceptance matrix: ring/paged × bf16/int8, fused windows + chunked
# piggyback prefill on — the paths where the tracer hooks are densest
CONFIGS = {
    "ring-bf16": dict(decode_ticks=4, prefill_chunk=2),
    "ring-int8": dict(decode_ticks=4, prefill_chunk=2, kv_quant=True),
    "paged-bf16": dict(kv_layout="paged", block_size=8, decode_ticks=4,
                       prefill_chunk=8),
    "paged-int8": dict(kv_layout="paged", block_size=8, decode_ticks=4,
                       prefill_chunk=8, kv_quant=True),
}
_ENGINES = {}
_RID = itertools.count()


def _engine(name, traced):
    key = (name, traced)
    if key not in _ENGINES:
        _ENGINES[key] = Engine(PARAMS, CFG, batch=2, max_len=MAX_LEN,
                               trace="mem" if traced else None,
                               **CONFIGS[name])
    eng = _ENGINES[key]
    eng.finished = []
    eng.reset_stats()
    return eng


def _request(rid, key=None, temperature=0.0, max_new=5, **kw):
    key = rid if key is None else key
    prompt = [(7 * key + i) % (CFG.vocab_size - 1) + 1
              for i in range(4 + key % 3)]
    return Request(rid=rid, prompt=prompt, priority=key % 2,
                   sampling=SamplingParams(temperature=temperature, seed=key,
                                           max_new=max_new, eos_id=EOS,
                                           counter_offset=100 * key), **kw)


def _assert_contiguous(report):
    segs = report["segments"]
    assert segs, "finished request with no segments"
    for a, b in zip(segs, segs[1:]):
        assert b["t0"] == pytest.approx(a["t1"]), "timeline gap"
    assert sum(report["shares"].values()) == pytest.approx(1.0, abs=0.01)


# --------------------------------------------------------------- unit layer


def test_from_spec_parsing(tmp_path):
    assert Tracer.from_spec(None).enabled is False
    t = Tracer.from_spec("mem")
    assert t.enabled and isinstance(t.sink, NullSink) and t._retain
    assert Tracer.from_spec(t) is t                    # tracer passes through

    combo = Tracer.from_spec(f"perfetto:{tmp_path}/t.json,"
                             f"jsonl:{tmp_path}/t.jsonl")
    assert combo.perfetto_path == f"{tmp_path}/t.json"
    assert isinstance(combo.sink, JsonlSink) and combo._retain

    feed_only = Tracer.from_spec(str(tmp_path / "feed.jsonl"))
    assert isinstance(feed_only.sink, JsonlSink)
    assert not feed_only._retain                       # pure stream: no memory

    class Sink:
        def write(self, records):
            pass

    sink = Sink()
    assert Tracer.from_spec(sink).sink is sink
    with pytest.raises(ValueError):
        Tracer.from_spec("csv:/tmp/x")
    with pytest.raises(TypeError):
        Tracer.from_spec(42)


def test_phase_segments_partition_wall_exactly():
    """The attribution invariant, driven by hand: every transition closes at
    t and reopens at t, degradation rotates the open span, and explain()
    decomposes the wall exactly."""
    tr = Tracer()
    tr.begin(0, 10.0, priority=1)
    tr.phase(0, "prefill", 10.5, slot=0)
    tr.phase(0, "decode", 11.0, slot=0)
    tr.set_degraded(True, 11.25)
    tr.set_degraded(False, 11.75)
    tr.finish(0, 12.0, "length")
    rep = tr.explain(0)
    assert rep["done"] and rep["finish_reason"] == "length"
    assert rep["wall_s"] == pytest.approx(2.0)
    assert rep["seconds"]["queue"] == pytest.approx(0.5)
    assert rep["seconds"]["prefill"] == pytest.approx(0.5)
    assert rep["seconds"]["decode"] == pytest.approx(0.5)
    assert rep["seconds"]["degraded"] == pytest.approx(0.5)
    _assert_contiguous(rep)
    assert rep["segments"][0]["t0"] == 10.0
    assert rep["segments"][-1]["t1"] == 12.0

    line = format_explain(rep)
    assert line.startswith("req 0:") and "[length]" in line
    assert "degraded=25.0%" in line


def test_tracer_snapshot_restore_bridges_open_spans():
    tr = Tracer()
    tr.begin(7, 1.0)
    tr.phase(7, "decode", 1.2, slot=0)
    snap = json.loads(json.dumps(tr.snapshot(1.4)))    # prove JSON-able

    tr2 = Tracer()
    tr2.restore(snap, t=1.9)
    tr2.finish(7, 2.0, "length")
    rep = tr2.explain(7)
    phases = [(s["phase"], round(s["t1"] - s["t0"], 6))
              for s in rep["segments"]]
    assert phases == [("queued", 0.2), ("decode", 0.2),
                      ("recovery", 0.5), ("decode", 0.1)]
    _assert_contiguous(rep)
    # the pre-crash decode span carries the recovery mark on the feed
    marked = [r for r in tr2.records()
              if r.get("kind") == "span" and r.get("recovery") == 1]
    assert len(marked) == 1 and marked[0]["name"] == "decode"


def test_explain_live_request_attributes_up_to_now():
    tr = Tracer()
    tr.begin(3, 5.0)
    rep = tr.explain(3, now=7.0)
    assert not rep["done"]
    assert rep["wall_s"] == pytest.approx(2.0)
    assert rep["shares"]["queue"] == pytest.approx(1.0)
    assert "live" in format_explain(rep)


# ------------------------------------------------------- engine integration


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_tracing_is_bitwise_invisible(name):
    """Acceptance pin: tracing on vs off changes no token stream, and every
    finished request explains to shares summing to 100%."""
    def serve(traced):
        eng = _engine(name, traced)
        rid0 = next(_RID)
        for _ in range(3):
            next(_RID)
        for k in range(4):
            eng.submit(_request(rid0 + k, key=k, temperature=0.8))
        eng.run(300)
        return eng, {r.rid - rid0: (list(r.out), r.finish_reason)
                     for r in eng.finished}

    _, want = serve(False)
    eng, got = serve(True)
    assert got == want
    for r in eng.finished:
        rep = eng.explain(r.rid)
        assert rep["done"] and rep["finish_reason"] == r.finish_reason
        assert rep["seconds"]["decode"] > 0.0 or r.out == []
        _assert_contiguous(rep)


def test_explain_requires_an_enabled_tracer():
    eng = _engine("ring-bf16", traced=False)
    with pytest.raises(RuntimeError, match="trace"):
        eng.explain(0)


def test_explain_shares_sum_on_mixed_workload():
    """The ISSUE acceptance workload: chunked prefill + pool pressure
    (preemptions) + a deadline expiry, all on one traced engine — every
    finished request's shares sum to 100% ± 1%."""
    eng = Engine(PARAMS, CFG, batch=2, max_len=MAX_LEN, kv_layout="paged",
                 block_size=4, num_blocks=5, prefix_cache=False,
                 decode_ticks=2, prefill_chunk=4, scheduler="priority",
                 trace="mem")
    reqs = [_request(r, key=r, max_new=8) for r in range(3)]
    reqs.append(_request(3, key=3, deadline_s=0.0))    # expires in queue
    for r in reqs:
        eng.submit(r)
    done = eng.run(400)
    assert len(done) == 4
    assert eng.stats["preemptions"] >= 1               # pressure was real
    reasons = {r.rid: r.finish_reason for r in done}
    assert reasons[3] == "deadline"

    saw_stall = False
    for r in done:
        rep = eng.explain(r.rid)
        assert rep["done"] and rep["finish_reason"] == reasons[r.rid]
        _assert_contiguous(rep)
        saw_stall = saw_stall or rep["seconds"]["preempt_stall"] > 0.0
    assert saw_stall, "a preempted request must show preempt_stall time"
    # the expired request never left the queue: 100% queue share
    rep = eng.explain(3)
    assert rep["dominant"] == "queue"
    assert rep["shares"]["queue"] == pytest.approx(1.0, abs=0.01)


def test_queue_and_pool_provenance_events_reach_the_feed():
    eng = Engine(PARAMS, CFG, batch=2, max_len=MAX_LEN, kv_layout="paged",
                 block_size=4, num_blocks=5, prefix_cache=False,
                 trace="mem")
    for r in range(3):
        eng.submit(_request(r, key=r, max_new=8))
    eng.run(300)
    events = {r["name"] for r in eng.trace.records()
              if r.get("kind") == "event"}
    assert {"submit", "finish", "queue_enter"} <= events
    # pool pressure (num_blocks=5) forces preempts → requeue provenance
    assert "queue_requeue" in events
    waves = [r for r in eng.trace.records()
             if r.get("kind") == "span" and r.get("cat") == "wave"]
    assert any(r["name"] == "prefill_wave" and r["rid"] is None
               for r in waves)
    assert any(r["name"] == "decode_window" and r["rid"] is None
               for r in waves)
    # engine wave spans are mirrored by per-request detail spans
    assert any(r["rid"] is not None and r["name"].startswith("decode[")
               for r in waves)


def test_deadlock_breaker_emits_reprefill_event():
    """The last-resort block reclamation (DESIGN.md §6 deadlock breaker)
    shows up on the feed: a pool too small for two growing requests forces
    a queued preempted holder to give its blocks back and re-prefill."""
    eng = Engine(PARAMS, CFG, batch=2, max_len=16, kv_layout="paged",
                 block_size=4, num_blocks=3, prefix_cache=False,
                 trace="mem")
    for r in range(2):
        eng.submit(Request(rid=r, prompt=[1 + r, 2, 3], max_new=10))
    done = eng.run(300)
    assert len(done) == 2
    events = [r for r in eng.trace.records() if r.get("kind") == "event"]
    reprefills = [e for e in events if e["name"] == "reprefill"]
    assert reprefills and all("pos" in e and "rid" in e for e in reprefills)
    for r in done:
        _assert_contiguous(eng.explain(r.rid))


# ----------------------------------------------------------------- exports


def test_perfetto_export_matches_jsonl_feed(tmp_path):
    """Acceptance pin: the Perfetto export and the jsonl feed agree
    one-to-one on per-request spans (same (rid, name, duration) multiset)."""
    pf_path = tmp_path / "trace.json"
    feed_path = tmp_path / "trace.jsonl"
    eng = Engine(PARAMS, CFG, batch=2, max_len=MAX_LEN, decode_ticks=2,
                 trace=f"perfetto:{pf_path},jsonl:{feed_path}")
    for r in range(3):
        eng.submit(_request(r, key=r))
    eng.run(300)
    eng.trace.close()

    feed = [json.loads(l) for l in feed_path.read_text().splitlines()]
    feed_spans = sorted(
        (r["rid"], r["name"], round(1e6 * (r["t1"] - r["t0"])))
        for r in feed if r.get("kind") == "span" and r.get("rid") is not None)
    pf = json.loads(pf_path.read_text())
    pf_spans = sorted(
        (e["tid"], e["name"], round(e["dur"]))
        for e in pf["traceEvents"] if e["ph"] == "X" and e["pid"] == 1)
    assert pf_spans == feed_spans and feed_spans
    # request tracks are named, engine track exists
    names = [e for e in pf["traceEvents"] if e["ph"] == "M"]
    assert any(e["args"].get("name") == "engine" for e in names)
    assert any(e["args"].get("name") == "req 0" for e in names)
    # engine-track spans (waves) land on pid 0
    assert any(e["ph"] == "X" and e["pid"] == 0
               for e in pf["traceEvents"])
    # counters sampled every tick
    assert any(e["ph"] == "C" for e in pf["traceEvents"])


def test_trace_sink_crash_is_isolated():
    """The SinkBuffer contract holds for the trace feed too: a raising sink
    degrades to NullSink without disturbing serving."""

    class BoomSink:
        def write(self, records):
            raise IOError("disk full")

        def close(self):
            pass

    eng = Engine(PARAMS, CFG, batch=2, max_len=MAX_LEN,
                 trace=Tracer(sink=BoomSink(), flush_every=1))
    for r in range(2):
        eng.submit(_request(r, key=r))
    done = eng.run(200)
    assert len(done) == 2
    assert all(r.finish_reason in ("length", "eos") for r in done)
    assert eng.trace.sink_errors == 1
    assert isinstance(eng.trace.sink, NullSink)


# --------------------------------------------------------- crash continuity


def test_trace_continuity_across_injected_crash(tmp_path):
    """A mid-window crash + restart keeps every timeline contiguous: spans
    open at the crash close with a recovery marker, a recovery segment
    bridges to resume, shares still sum to 100%, and streams stay bitwise
    those of an uninterrupted (untraced) run."""
    kw = dict(batch=2, max_len=MAX_LEN, kv_layout="paged", block_size=8,
              decode_ticks=2)
    ref = Engine(PARAMS, CFG, **kw)
    for r in range(4):
        ref.submit(_request(r, key=r, temperature=0.8))
    ref.run(300)
    want = {r.rid: (list(r.out), r.finish_reason) for r in ref.finished}

    snap_path = str(tmp_path / "snap.json")
    injector = FailureInjector(crash_at={2: "mid_window"})

    def make_engine():
        return Engine(PARAMS, CFG, injector=injector,
                      snapshot_path=snap_path, trace="mem", **kw)

    def submit(engine):
        for r in range(4):
            engine.submit(_request(r, key=r, temperature=0.8))

    eng = run_serve_with_restarts(make_engine, submit,
                                  snapshot_path=snap_path, ticks=300)
    assert injector.fired == {(2, "mid_window")}
    assert {r.rid: (list(r.out), r.finish_reason)
            for r in eng.finished} == want

    bridged = 0
    for r in eng.finished:
        rep = eng.explain(r.rid)
        assert rep["done"]
        _assert_contiguous(rep)
        if any(s["phase"] == "recovery" for s in rep["segments"]):
            bridged += 1
    assert bridged > 0, "spans open at the crash must get a recovery bridge"
    recs = eng.trace.records()
    assert any(r.get("kind") == "event" and r.get("name") == "recovery"
               for r in recs)
    # pre-crash history was re-injected for the post-restore export
    assert any(r.get("carried") == 1 for r in recs)


# --------------------------------------------------------- autotune events


def test_autotune_observer_feeds_cache_events():
    tr = Tracer()
    autotune.clear_cache()
    shape = (2, 64, 3, 3, 64)
    block = autotune.best_block("decode_attention", shape, "int8", 8,
                                "flash", "unit-test")
    key = autotune.cache_key("decode_attention", shape, "int8", 8, "flash",
                             "unit-test")
    autotune._CACHE[key] = block                       # a sweep ran
    autotune.best_block("decode_attention", shape, "int8", 8, "flash",
                        "unit-test")
    del autotune._CACHE[key]
    events = [r for r in tr.records() if r.get("kind") == "event"]
    assert [e["name"] for e in events] == ["autotune_model_pick",
                                           "autotune_cache_hit"]
    assert events[0]["key"] == key
    assert tuple(events[1]["block"]) == tuple(block)


def test_dropped_tracer_unregisters_from_autotune():
    import weakref

    tr = Tracer()
    ref = weakref.ref(tr)
    assert tr in autotune._OBSERVERS
    del tr
    assert ref() is None and all(o is not None
                                 for o in autotune._OBSERVERS)
