"""Property soak for the overlapped engine (DESIGN.md §6, §11).

Hypothesis drives randomized request mixes (prompt lengths, priorities,
budgets, temperatures, eos/stop collisions, oversized prompts) through the
engine at every overlap setting — single-tick, fused windows, chunked
prefill, tight paged pools — and checks the invariants that must hold for
*any* workload, not just the pinned parity fixtures in tests/test_overlap.py:

* **drain leaves nothing behind** — every submitted request finishes, all
  slots free, queue empty, and the paged pool holds zero live blocks.
* **FCFS within priority, preemption included** — among equal-priority
  requests, first admission order follows submission order (a requeued
  victim keeps its original ``_arrival``, so it never loses its place).
* **finish reasons are valid and consistent** with the emitted stream
  (eos ⇒ last token is ``eos_id``; stop ⇒ last token in ``stop_ids``;
  length ⇒ budget exhausted; rejected ⇒ nothing emitted).
* **stats ≡ metrics** — the histogram counts and counters the metrics
  surface reports match the per-request ground truth on the Request
  objects and ``Engine.stats``.

Engines are cached per overlap configuration (the jitted serve fns
recompile per Engine), so each example only pays a serve run.  Skips when
hypothesis is absent (tests/_hypothesis_compat.py).
"""

import itertools

import jax
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import registry
from repro.serve.engine import Engine, Request
from repro.serve.sampling import SamplingParams

CFG = get_config("smollm_135m").reduced()
PARAMS = registry.init_model(jax.random.PRNGKey(0), CFG)

MAX_LEN = 32
EOS, STOP = 11, 77

# one engine per overlap configuration, built lazily and reused across
# examples (jit closures are per-Engine; recompiling per example would
# dominate the soak).  The last one runs a pool small enough to preempt.
CONFIGS = {
    "ring-plain": dict(),
    "ring-window": dict(decode_ticks=4, prefill_chunk=5),
    "paged-plain": dict(kv_layout="paged", block_size=8),
    "paged-window": dict(kv_layout="paged", block_size=8, decode_ticks=2,
                         prefill_chunk=8),
    "paged-tight": dict(kv_layout="paged", block_size=8, num_blocks=12,
                        decode_ticks=4, prefill_chunk=8, kv_quant=True),
}
_ENGINES = {}
_RID = itertools.count()


def _engine(name):
    if name not in _ENGINES:
        _ENGINES[name] = Engine(PARAMS, CFG, batch=2, max_len=MAX_LEN,
                                scheduler="priority", **CONFIGS[name])
    eng = _ENGINES[name]
    eng.finished = []
    eng.reset_stats()
    return eng


req_st = st.tuples(
    st.integers(0, 40),                     # prompt length: 0 = BOS path,
                                            # > max_len = rejection path
    st.integers(0, 2 ** 31 - 1),            # prompt content seed
    st.integers(1, 2),                      # priority class
    st.integers(1, 6),                      # max_new
    st.sampled_from([0.0, 0.8]),            # greedy / sampled
)


def _submit(eng, draws):
    reqs = []
    for n, seed, prio, max_new, temp in draws:
        rid = next(_RID)
        prompt = [(seed + 7 * i) % (CFG.vocab_size - 1) + 1 for i in range(n)]
        req = Request(rid=rid, prompt=prompt, priority=prio,
                      sampling=SamplingParams(
                          temperature=temp, max_new=max_new, seed=seed,
                          eos_id=EOS, stop_ids=(STOP,),
                          counter_offset=(rid % 7) * 100))
        eng.submit(req)
        reqs.append(req)
    return reqs


def _check_invariants(eng, reqs):
    done = {r.rid: r for r in eng.finished}

    # -- nothing left behind
    assert sorted(done) == sorted(r.rid for r in reqs)
    assert all(s is None for s in eng.slots)
    assert len(eng.scheduler) == 0
    if eng.pools:
        assert eng.pool_stats()["live"] == 0

    # -- finish reasons valid and consistent with the stream
    for r in reqs:
        assert r.done and r.state == "done"
        assert r.finish_reason in {"eos", "stop", "length", "preempted",
                                   "rejected", "deadline", "shed"}
        budget = r.effective_max_new()
        assert len(r.out) <= budget
        if r.finish_reason == "eos":
            assert r.out[-1] == EOS
        elif r.finish_reason == "stop":
            assert r.out[-1] == STOP
        elif r.finish_reason == "length":
            assert (len(r.out) == budget
                    or len(r.prompt) + len(r.out) >= MAX_LEN)
        elif r.finish_reason == "rejected":
            assert r.out == [] and r.t_first is None
        elif r.finish_reason in ("shed", "deadline"):
            # shed/expired before ever reaching a slot ⇒ nothing emitted;
            # a preempted block-holder shed/expired from the queue (or a
            # running slot cancelled by its deadline) keeps what it
            # generated — either way the stream obeys the budget above
            if r.t_first is None:
                assert r.out == []
        if r.out:
            assert all(v >= 0.0 for v in r.itl)
            assert len(r.itl) == len(r.out) - 1

    # -- FCFS within priority: first admission follows submission order
    for prio in {r.priority for r in reqs}:
        cls = [r for r in reqs if r.priority == prio and r.t_admit is not None]
        admits = [r.t_admit for r in cls]       # reqs is in submission order
        assert admits == sorted(admits)

    # -- stats ≡ metrics
    ms = eng.metrics.summary()
    assert ms["counters"].get("finished_requests", 0) == len(reqs)
    assert ms["ttft_s"]["count"] == sum(
        1 for r in reqs if r.t_first is not None)
    assert ms["itl_s"]["count"] == sum(len(r.itl) for r in reqs)
    assert eng.stats["decode_tokens"] >= sum(
        len(r.out) - 1 for r in reqs if r.out)
    for reason in {r.finish_reason for r in reqs}:
        assert ms["counters"][f"finish_{reason}"] == sum(
            1 for r in reqs if r.finish_reason == reason)


@pytest.mark.parametrize("name", sorted(CONFIGS))
@settings(max_examples=8, deadline=None)
@given(draws=st.lists(req_st, min_size=1, max_size=6))
def test_engine_invariants_hold_for_any_workload(name, draws):
    eng = _engine(name)
    reqs = _submit(eng, draws)
    eng.run(ticks=600)
    _check_invariants(eng, reqs)


@pytest.mark.parametrize("name", ["ring-window", "paged-plain"])
@settings(max_examples=6, deadline=None)
@given(draws=st.lists(req_st, min_size=2, max_size=6),
       expire=st.lists(st.booleans(), min_size=6, max_size=6),
       cap=st.integers(1, 3),
       policy=st.sampled_from(["reject-new", "evict-lowest-priority"]))
def test_invariants_hold_under_shedding_and_deadlines(name, draws, expire,
                                                      cap, policy):
    """The bounded queue and deadline expiry keep every invariant: shed and
    expired requests still land in ``finished`` with consistent metrics,
    nothing leaks, and survivors keep FCFS-within-priority.  A zero
    deadline expires deterministically (the expiry scan runs before
    admission), so which requests reach a slot stays reproducible."""
    eng = _engine(name)
    eng.queue_cap, eng.shed_policy = cap, policy
    try:
        reqs = []
        for k, d in enumerate(draws):
            reqs.extend(_submit(eng, [d]))
            if expire[k % len(expire)]:
                reqs[-1].deadline_s = 0.0
        eng.run(ticks=600)
        _check_invariants(eng, reqs)
        for r in reqs:
            if r.deadline_s == 0.0 and r.finish_reason != "shed":
                assert r.finish_reason == "deadline" and r.out == []
    finally:
        eng.queue_cap, eng.shed_policy = None, "reject-new"


SPEC_CONFIGS = {name: dict(cfg, spec_decode=True, draft_k=4)
                for name, cfg in CONFIGS.items()}


def _spec_engine(name):
    key = "spec-" + name
    if key not in _ENGINES:
        _ENGINES[key] = Engine(PARAMS, CFG, batch=2, max_len=MAX_LEN,
                               scheduler="priority", **SPEC_CONFIGS[name])
    eng = _ENGINES[key]
    eng.finished = []
    eng.reset_stats()
    return eng


@pytest.mark.parametrize("name", sorted(SPEC_CONFIGS))
@settings(max_examples=6, deadline=None)
@given(draws=st.lists(req_st, min_size=1, max_size=6))
def test_spec_engine_invariants_and_accept_accounting(name, draws):
    """Speculative decode under every overlap configuration keeps the full
    invariant set, and its accept counters reconcile exactly with the
    emitted streams: every post-prefill token flows through a spec window
    (``spec_emitted_tokens`` equals the decode-token ground truth), the
    accepted count never exceeds the drafted count, and since each slot a
    window serves emits its accepted prefix plus one sampled token,
    ``emitted - accepted`` is the number of slot servings — bounded by
    [windows, windows * batch]."""
    eng = _spec_engine(name)
    reqs = _submit(eng, draws)
    eng.run(ticks=600)
    _check_invariants(eng, reqs)
    ms = eng.metrics.summary()["counters"]
    drafted = ms.get("spec_draft_tokens", 0)
    accepted = ms.get("spec_accepted_tokens", 0)
    emitted = ms.get("spec_emitted_tokens", 0)
    windows = ms.get("spec_windows", 0)
    decode_emitted = sum(len(r.out) - 1 for r in reqs if r.out)
    assert emitted == decode_emitted
    assert 0 <= accepted <= drafted
    if decode_emitted:
        assert windows >= 1
        servings = emitted - accepted
        assert windows <= servings <= windows * eng.batch
    else:
        assert (windows, drafted, accepted) == (0, 0, 0)


@settings(max_examples=6, deadline=None)
@given(draws=st.lists(req_st, min_size=2, max_size=6),
       victim=st.integers(0, 5))
def test_invariants_survive_mid_run_preemption(draws, victim):
    """White-box soak: forcibly preempt an occupied paged slot partway
    through serving (mid-prefill or mid-decode) — the requeued victim must
    still finish, keep its place within its priority class, and leak no
    blocks."""
    eng = _engine("paged-window")
    reqs = _submit(eng, draws)
    kicked = False
    for _ in range(600):
        if not kicked:
            i = victim % eng.batch
            s = eng.slots[i]
            if s is not None and s.state in ("prefilling", "active"):
                eng._preempt_requeue(i, s)
                kicked = True
        eng.step()
        if not len(eng.scheduler) and all(s is None for s in eng.slots):
            break
    _check_invariants(eng, reqs)
