"""End-to-end system tests: training convergence, serving engine, data
pipeline determinism, gradient compression, schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.launch.train import run_training
from repro.models import registry
from repro.numerics.policy import QuantPolicy
from repro.optim import grad_compress
from repro.optim.schedules import cosine_schedule, wsd_schedule
from repro.serve.engine import Engine, Request


def test_training_reduces_loss():
    cfg = get_config("smollm_135m").reduced()
    _, losses = run_training(cfg, steps=60, batch=8, seq=32, peak_lr=3e-3,
                             log=lambda *a: None)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.8, (first, last)


def test_training_with_dither_policy_converges():
    """The paper's feature end-to-end: int8 dither-rounded matmuls still learn."""
    cfg = get_config("smollm_135m").reduced()
    _, losses = run_training(cfg, steps=60, batch=8, seq=32, peak_lr=3e-3,
                             policy=QuantPolicy(scheme="dither", bits=8),
                             log=lambda *a: None)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5


def test_serving_engine_completes_requests():
    cfg = get_config("smollm_135m").reduced()
    params = registry.init_model(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, batch=2, max_len=64)
    for r in range(4):
        eng.submit(Request(rid=r, prompt=[1, 2, 3], max_new=4))
    done = eng.run(ticks=200)
    assert len(done) == 4
    assert all(len(r.out) == 4 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.out)


def test_data_pipeline_deterministic_and_shaped():
    cfg = get_config("internvl2_1b").reduced()
    d = DataConfig(batch=4, seq=32, seed=7)
    b1 = synthetic_batch(cfg, d, 3)
    b2 = synthetic_batch(cfg, d, 3)
    b3 = synthetic_batch(cfg, d, 4)
    assert jnp.array_equal(b1["tokens"], b2["tokens"])
    assert not jnp.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert b1["embeds"].shape == (4, cfg.n_frontend_tokens, cfg.d_model)
    assert int(b1["tokens"].max()) < cfg.vocab_size


def test_grad_compress_unbiased():
    pol = QuantPolicy(scheme="dither", bits=8)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    outs = jnp.stack([
        grad_compress.compress_grads(g, pol, c)["w"] for c in range(32)
    ])
    rel = float(jnp.abs(outs.mean(0) - g["w"]).max() / jnp.abs(g["w"]).max())
    assert rel < 0.02, rel


def test_schedules():
    cos = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(cos(jnp.int32(0))) == 0.0
    assert abs(float(cos(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(cos(jnp.int32(100))) < 2e-4
    wsd = wsd_schedule(1e-3, warmup=10, stable=50, decay=40)
    assert abs(float(wsd(jnp.int32(30))) - 1e-3) < 1e-9   # plateau
    assert float(wsd(jnp.int32(100))) < 1e-3               # decaying
