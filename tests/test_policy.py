"""QuantPolicy / qmatmul: STE gradients, unbiasedness, counter semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.numerics.policy import QuantPolicy, dense, fake_quant, qmatmul


def test_policy_none_is_plain_matmul():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 3))
    assert jnp.allclose(dense(x, w, None), x @ w)


def test_qmatmul_ste_gradients():
    """Backward = full-precision grads (straight-through)."""
    pol = QuantPolicy(scheme="dither", bits=8)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 3))

    def loss_q(x, w):
        return jnp.sum(qmatmul(x, w, pol, 0, jnp.float32(0)) ** 2) * 0 + \
               jnp.sum(qmatmul(x, w, pol, 0, jnp.float32(0)))

    gx, gw = jax.grad(loss_q, argnums=(0, 1))(x, w)
    # STE: d(sum(xq@wq))/dx = ones @ w.T exactly (full precision w)
    np.testing.assert_allclose(np.asarray(gx),
                               np.asarray(jnp.ones((4, 3)) @ w.T), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gw),
                               np.asarray(x.T @ jnp.ones((4, 3))), rtol=1e-5)


def test_dither_policy_unbiased_over_counters():
    """Averaging the quantised matmul over a pulse period recovers x@w."""
    pol = QuantPolicy(scheme="dither", bits=4, n_pulses=16)
    x = jax.random.uniform(jax.random.PRNGKey(2), (16, 32))
    w = jax.random.uniform(jax.random.PRNGKey(3), (32, 8), minval=-1, maxval=1)
    outs = jnp.stack([
        qmatmul(x, w, pol, 0, jnp.float32(c)) for c in range(64)
    ])
    err = float(jnp.max(jnp.abs(outs.mean(0) - x @ w))) / float(jnp.abs(x @ w).max())
    assert err < 0.05, err


def test_counter_changes_rounding_but_not_scale():
    pol = QuantPolicy(scheme="dither", bits=6)
    x = jax.random.uniform(jax.random.PRNGKey(4), (8, 8))
    a = fake_quant(x, pol, counter=0)
    b = fake_quant(x, pol, counter=1)
    assert not jnp.allclose(a, b)
    assert float(jnp.max(jnp.abs(a - x))) < 0.05  # stays near the grid


def test_fake_quant_levels():
    pol = QuantPolicy(scheme="deterministic", bits=2)
    x = jnp.linspace(-1, 1, 100)
    q = fake_quant(x, pol)
    assert len(np.unique(np.asarray(q).round(5))) <= 4
