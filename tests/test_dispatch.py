"""Kernel dispatch + autotune subsystem (kernels/dispatch.py, autotune.py).

The load-bearing contract: every backend computes the *same* codes — the
Pallas interpret backend (the TPU kernel body, evaluated on CPU) must be
bit-identical to the pure-XLA reference for all three rounding schemes in
both pulse formats, and the fused matmuls must agree to float tolerance.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.core.matmul import quantized_matmul
from repro.kernels import autotune, dispatch, ref
from repro.numerics.policy import QuantPolicy, qmatmul

SCHEMES = ["deterministic", "stochastic", "dither"]
FORMATS = ["unary", "spread"]


# ---------------------------------------------------------------------------
# backend parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("scheme", SCHEMES)
def test_quantize_codes_bit_identical_across_backends(scheme, fmt):
    x = jax.random.uniform(jax.random.PRNGKey(0), (48, 96), minval=-1, maxval=1)
    kw = dict(bits=8, lo=-1.0, hi=1.0, scheme=scheme, counter=7, seed=3,
              n_pulses=16, fmt=fmt)
    codes_ref = dispatch.quantize(x, backend="xla-ref", **kw)
    codes_pal = dispatch.quantize(x, backend="pallas-interpret",
                                  block=(32, 32), **kw)
    assert jnp.array_equal(codes_ref, codes_pal)


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("scheme", SCHEMES)
def test_matmul_outputs_match_across_backends(scheme, fmt):
    a = jax.random.uniform(jax.random.PRNGKey(1), (33, 64))
    b = jax.random.uniform(jax.random.PRNGKey(2), (64, 50), minval=-1, maxval=1)
    kw = dict(bits=6, scheme=scheme, counter=2, seed=9,
              a_range=(0.0, 1.0), b_range=(-1.0, 1.0), fmt=fmt)
    out_ref = dispatch.matmul(a, b, backend="xla-ref", **kw)
    out_pal = dispatch.matmul(a, b, backend="pallas-interpret",
                              block=(32, 32, 32), **kw)
    assert float(jnp.max(jnp.abs(out_ref - out_pal))) < 1e-4


def test_unary_and_spread_formats_differ_but_both_unbiased():
    """The two σ formats are different permutations of the same pulses:
    codes differ at fixed counter, but averaging over a full period
    recovers x for both (§VII time-averaged unbiasedness)."""
    x = jax.random.uniform(jax.random.PRNGKey(3), (32, 32))
    n = 16
    per_fmt = {}
    for fmt in FORMATS:
        codes = [dispatch.quantize(x, bits=4, scheme="dither", counter=c,
                                   n_pulses=n, fmt=fmt, backend="xla-ref")
                 for c in range(n)]
        per_fmt[fmt] = codes
        mean = jnp.stack(codes).astype(jnp.float32).mean(0) / 15.0
        assert float(jnp.max(jnp.abs(mean - x))) < 0.1
    assert not all(
        bool(jnp.array_equal(a, b))
        for a, b in zip(per_fmt["unary"], per_fmt["spread"])
    )


# ---------------------------------------------------------------------------
# selection / override
# ---------------------------------------------------------------------------


def test_registry_lists_builtin_backends():
    names = dispatch.available_backends()
    for expected in ("pallas-tpu", "pallas-interpret", "xla-ref"):
        assert expected in names


def test_resolve_platform_default_and_aliases():
    on_tpu = jax.default_backend() == "tpu"
    assert dispatch.resolve_backend().name == (
        "pallas-tpu" if on_tpu else dispatch.DEFAULT_CPU_BACKEND)
    assert dispatch.resolve_backend("pallas").name == (
        "pallas-tpu" if on_tpu else "pallas-interpret")
    assert dispatch.resolve_backend("ref").name == "xla-ref"
    with pytest.raises(ValueError, match="unknown kernel backend"):
        dispatch.resolve_backend("nonesuch")


def test_env_override(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_VAR, "pallas-interpret")
    assert dispatch.resolve_backend().name == "pallas-interpret"
    # 'auto' defers to the environment too — QuantPolicy.resolved passes it
    # explicitly, and the env var must still redirect policy call sites
    assert dispatch.resolve_backend("auto").name == "pallas-interpret"
    assert (QuantPolicy(scheme="dither", backend="auto").resolved().backend
            == "pallas-interpret")
    # an explicit concrete backend beats the environment
    assert dispatch.resolve_backend("xla-ref").name == "xla-ref"


def test_policy_backend_resolution():
    assert dispatch.resolve_policy_backend("jnp") == "jnp"
    resolved = QuantPolicy(scheme="dither", backend="auto").resolved()
    assert resolved.backend in dispatch.available_backends()


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------


def test_candidates_respect_vmem_budget():
    budget = autotune.VMEM_BUDGET_BYTES
    cands = autotune.matmul_candidates(4096, 8192, 4096)
    assert cands
    for blk in cands:
        assert autotune.matmul_vmem_bytes(blk) <= budget
    # model pick = a candidate, and usable for real shapes
    blk = autotune.best_block("matmul", (4096, 8192, 4096), "float32", 8,
                              "dither", "pallas-tpu")
    assert blk in cands


def test_best_block_small_shapes_stay_runnable():
    blk = autotune.best_block("matmul", (32, 64, 48), "float32", 8, "dither",
                              "pallas-interpret")
    out = dispatch.matmul(
        jax.random.uniform(jax.random.PRNGKey(4), (32, 64)),
        jax.random.uniform(jax.random.PRNGKey(5), (64, 48)),
        bits=8, block=blk, backend="pallas-interpret")
    assert out.shape == (32, 48)


def test_measured_sweep_caches_winner(tmp_path, monkeypatch):
    cache_file = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache_file))
    autotune.clear_cache()
    a = jax.random.uniform(jax.random.PRNGKey(6), (32, 32))
    b = jax.random.uniform(jax.random.PRNGKey(7), (32, 32))

    def run(block):
        return dispatch.matmul(a, b, bits=8, scheme="dither",
                               block=tuple(block), backend="pallas-interpret")

    winner, results = autotune.autotune_matmul(
        32, 32, 32, bits=8, scheme="dither", backend="pallas-interpret",
        run=run, repeats=1, candidates=[(32, 32, 32), (16, 16, 16)])
    assert len(results) == 2
    assert tuple(results[0]["block"]) == winner

    # persisted and re-loaded: best_block now returns the measured winner
    assert json.loads(cache_file.read_text())
    autotune.clear_cache()
    got = autotune.best_block("matmul", (32, 32, 32), "float32", 8, "dither",
                              "pallas-interpret")
    assert got == winner
    autotune.clear_cache()


def test_paged_attention_candidates_and_model_pick():
    """(bs,) pool-block candidates stay under the VMEM budget; the model
    pick balances granularity — a full-length request spans ≥ 4 blocks
    whenever a candidate allows it, and tiny caps stay servable."""
    cands = autotune.paged_attention_candidates(4096, hd=64, group=4,
                                                quantized=True)
    assert cands
    for (bs,) in cands:
        assert autotune.decode_attention_vmem_bytes(
            (bs,), hd=64, group=4, quantized=True) \
            <= autotune.VMEM_BUDGET_BYTES
    pick = autotune.best_block("paged_attention", (8, 4096, 8, 4, 64),
                               "int8", 8, "flash", "pallas-tpu")
    assert pick in cands and pick[0] * 4 <= 4096
    tiny = autotune.best_block("paged_attention", (2, 8, 2, 2, 32),
                               "bfloat16", 16, "flash", "pallas-interpret")
    assert 1 <= tiny[0] <= 8


def test_save_cache_atomic_merge_survives_concurrent_writers(tmp_path,
                                                             monkeypatch):
    """The winner-cache write is merge + atomic rename: entries persisted
    by another process survive, ours win on conflicts, no temp files are
    left behind, and the file is never observable half-written (satellite:
    parallel bench/CI runs must not truncate each other)."""
    cache_file = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache_file))
    autotune.clear_cache()
    # "another process" wrote first
    cache_file.write_text(json.dumps({"matmul|1x1x1|f32|8|dither|x": [4, 4, 4],
                                      "shared|key": [1]}))
    autotune._CACHE["shared|key"] = (2,)
    autotune._CACHE["quantize|8x8|f32|8|dither|x"] = (8, 8)
    autotune.save_cache()
    merged = json.loads(cache_file.read_text())
    assert merged["matmul|1x1x1|f32|8|dither|x"] == [4, 4, 4]  # theirs kept
    assert merged["shared|key"] == [2]                         # ours wins
    assert merged["quantize|8x8|f32|8|dither|x"] == [8, 8]
    assert not list(tmp_path.glob("*.tmp.*"))                  # swap cleaned up
    autotune.clear_cache()


# ---------------------------------------------------------------------------
# call-site wiring
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scheme", SCHEMES)
def test_quantized_matmul_separate_backend_parity(scheme):
    a = jax.random.uniform(jax.random.PRNGKey(8), (24, 32))
    b = jax.random.uniform(jax.random.PRNGKey(9), (32, 20))
    c_ref = quantized_matmul(a, b, bits=8, scheme=scheme, variant="separate",
                             backend="xla-ref")
    c_pal = quantized_matmul(a, b, bits=8, scheme=scheme, variant="separate",
                             backend="pallas-interpret")
    assert float(jnp.max(jnp.abs(c_ref - c_pal))) < 1e-4


def test_qmatmul_fused_backend_matches_unfused():
    """The policy's fused dispatcher path lands on the same quantisation
    grid as the unfused fake-quant path (different pulse counts → different
    draws, but both within the same quantisation error of x@w)."""
    x = jax.random.uniform(jax.random.PRNGKey(10), (16, 32), minval=-1, maxval=1)
    w = jax.random.uniform(jax.random.PRNGKey(11), (32, 8), minval=-1, maxval=1)
    tol = 32 * (2.0 / 255) * 2  # K × grid step, generous
    exact = x @ w
    for backend in ["jnp", "xla-ref", "pallas-interpret"]:
        pol = QuantPolicy(scheme="dither", bits=8, backend=backend)
        out = qmatmul(x, w, pol, 0, jnp.float32(3))
        assert float(jnp.max(jnp.abs(out - exact))) < tol, backend


def test_qmatmul_fused_ste_gradients():
    pol = QuantPolicy(scheme="dither", bits=8, backend="xla-ref")
    x = jax.random.uniform(jax.random.PRNGKey(12), (8, 16))
    w = jax.random.uniform(jax.random.PRNGKey(13), (16, 4))
    gx, gw = jax.grad(
        lambda x, w: jnp.sum(qmatmul(x, w, pol, 0, jnp.float32(0))),
        argnums=(0, 1))(x, w)
    assert jnp.allclose(gx, jnp.ones((8, 4)) @ w.T, rtol=1e-5, atol=1e-6)
    assert jnp.allclose(gw, x.T @ jnp.ones((8, 4)), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("variant", ["separate", "round_a_once", "per_partial"])
def test_quantized_matmul_counter_advances_all_variants(variant):
    """The global step counter i_s phase-shifts every variant ("rounding in
    time"), not just the dispatcher-backed separate path."""
    a = jax.random.uniform(jax.random.PRNGKey(16), (12, 16))
    b = jax.random.uniform(jax.random.PRNGKey(17), (16, 8))
    c0 = quantized_matmul(a, b, bits=3, scheme="dither", variant=variant,
                          counter=0)
    c1 = quantized_matmul(a, b, bits=3, scheme="dither", variant=variant,
                          counter=1)
    assert float(jnp.max(jnp.abs(c0 - c1))) > 0.0


def test_matmul_counter_advances_on_every_backend():
    a = jax.random.uniform(jax.random.PRNGKey(14), (32, 32))
    b = jax.random.uniform(jax.random.PRNGKey(15), (32, 32))
    for backend in ["xla-ref", "pallas-interpret"]:
        c0 = dispatch.matmul(a, b, bits=3, scheme="dither", counter=0,
                             block=(32, 32, 32), backend=backend)
        c1 = dispatch.matmul(a, b, bits=3, scheme="dither", counter=1,
                             block=(32, 32, 32), backend=backend)
        assert float(jnp.max(jnp.abs(c0 - c1))) > 0.0, backend
