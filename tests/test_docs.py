"""Docs-consistency gate (ISSUE 5 satellite): documentation references must
point at things that exist.

Three failure classes this pins, all of which have actually happened here:

1. **stale section cites** — a docstring says "DESIGN.md §N" but DESIGN.md
   has no §N header (PRs renumber sections; module docstrings fossilise);
2. **dangling doc files** — code cites an ALL-CAPS markdown file (e.g. the
   pre-PR-5 ``EXPERIMENTS.md §Perf it.N`` cites) that is not in the repo;
3. **dead relative links** — README/DESIGN/docs markdown links to paths
   that moved or never landed.

Pure text checks — no jax import — so this file is cheap enough for every
tier-1 run, and CI runs it as an explicit docs-consistency step.
"""

import re
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# documentation trees whose markdown links must resolve
DOC_FILES = [ROOT / "README.md", ROOT / "DESIGN.md",
             ROOT / "benchmarks" / "README.md",
             *sorted((ROOT / "docs").glob("*.md"))]

# code trees audited for doc references
CODE_DIRS = ["src", "tests", "benchmarks", "examples"]


def _code_files():
    for d in CODE_DIRS:
        for p in sorted((ROOT / d).rglob("*.py")):
            if p.name != "test_docs.py":    # this file cites rot as examples
                yield p


def _design_sections():
    text = (ROOT / "DESIGN.md").read_text()
    return {m.group(1) for m in re.finditer(r"^##\s+§(\d+)\b", text,
                                            re.MULTILINE)}


def test_design_section_references_exist():
    """Every `DESIGN.md §N` mention in code or docs names a real section."""
    sections = _design_sections()
    assert sections, "DESIGN.md has no '## §N' headers?"
    bad = []
    for path in [*_code_files(), *DOC_FILES]:
        for m in re.finditer(r"DESIGN\.md\s+§(\d+)", path.read_text()):
            if m.group(1) not in sections:
                bad.append(f"{path.relative_to(ROOT)}: DESIGN.md §{m.group(1)}")
    assert not bad, ("stale DESIGN.md section references "
                     f"(have §{sorted(sections)}):\n" + "\n".join(bad))


def test_referenced_doc_files_exist():
    """ALL-CAPS markdown files cited from code must exist in the repo —
    the check that catches EXPERIMENTS.md-style rot."""
    bad = []
    for path in _code_files():
        for m in re.finditer(r"\b([A-Z][A-Z_]+\.md)\b", path.read_text()):
            name = m.group(1)
            if not ((ROOT / name).exists()
                    or (path.parent / name).exists()):
                bad.append(f"{path.relative_to(ROOT)}: {name}")
    assert not bad, "dangling doc-file references:\n" + "\n".join(bad)


def test_relative_markdown_links_resolve():
    """Relative links in the documentation tree point at real files."""
    bad = []
    for doc in DOC_FILES:
        assert doc.exists(), f"missing doc file {doc}"
        for m in re.finditer(r"\]\(([^)\s]+)\)", doc.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "#", "mailto:")):
                continue
            target = target.split("#")[0]
            if target and not (doc.parent / target).exists():
                bad.append(f"{doc.relative_to(ROOT)}: ({m.group(1)})")
    assert not bad, "dead relative markdown links:\n" + "\n".join(bad)


def test_design_sections_are_contiguous():
    """§ numbering has no gaps — a gap means a renumbering sweep missed
    DESIGN.md itself."""
    sections = sorted(int(s) for s in _design_sections())
    assert sections == list(range(1, len(sections) + 1)), sections
