"""Property tests for §II-C / §VII rounding schemes."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, st

from repro.core import rounding

FLOATS = st.floats(-100.0, 100.0, allow_nan=False, allow_infinity=False, width=32)


@given(x=FLOATS, n=st.sampled_from([4, 16, 64]))
def test_dither_round_output_on_grid(x, n):
    """d(α, i) ∈ {⌊α⌋, ⌊α⌋+1} always."""
    out = rounding.dither_round(jnp.float32(x)[None], 3, 7, n)
    fl = np.floor(np.float32(x))
    assert float(out[0]) in (fl, fl + 1.0)


@given(x=st.floats(0.0, 10.0, allow_nan=False, width=32))
def test_dither_round_unbiased_over_period(x):
    """Averaging over a full pulse period + seeds recovers α with O(1/N) error."""
    n = 16
    xs = jnp.full((64,), x, jnp.float32)
    outs = jnp.stack([rounding.dither_round(xs, c, 11, n) for c in range(4 * n)])
    err = abs(float(outs.mean()) - float(np.float32(x)))
    assert err < 0.08, err


def test_dither_vs_stochastic_time_averaged_mse():
    """§VII: dither rounding in time converges faster than stochastic."""
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (2000,)) * 8.0
    n = 16
    d = jnp.stack([rounding.dither_round(x, c, 5, n) for c in range(64)]).mean(0)
    s = jnp.stack([rounding.stochastic_round(x, 5, c) for c in range(64)]).mean(0)
    mse_d = float(jnp.mean((d - x) ** 2))
    mse_s = float(jnp.mean((s - x) ** 2))
    assert mse_d < mse_s / 2.0, (mse_d, mse_s)


@given(seed=st.integers(0, 2**31 - 1), counter=st.integers(0, 10000))
def test_hash_uniform_range_and_determinism(seed, counter):
    idx = jnp.arange(128, dtype=jnp.uint32)
    u1 = rounding.hash_uniform(seed, idx, counter)
    u2 = rounding.hash_uniform(seed, idx, counter)
    assert jnp.all(u1 == u2)
    assert float(u1.min()) >= 0.0 and float(u1.max()) < 1.0


@given(n=st.sampled_from([3, 8, 16, 60, 257]))
def test_lcg_slot_is_permutation(n):
    """Over one period the slot sequence visits every slot exactly once."""
    slots = np.asarray(
        rounding.lcg_slot(jnp.arange(n, dtype=jnp.uint32), 42, n, seed=9))
    assert sorted(slots.tolist()) == list(range(n))


def test_deterministic_round_half_up():
    assert float(rounding.deterministic_round(jnp.float32(0.5))) == 1.0
    assert float(rounding.deterministic_round(jnp.float32(-0.5))) == 0.0
    assert float(rounding.deterministic_round(jnp.float32(2.49))) == 2.0


def test_stochastic_round_mean():
    x = jnp.full((4000,), 1.25, jnp.float32)
    out = rounding.stochastic_round(x, 3, 0)
    assert abs(float(out.mean()) - 1.25) < 0.03
