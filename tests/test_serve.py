"""Serving-layer tests (DESIGN.md §6): batched prefill vs token-by-token
cache equivalence (policy on and off), scheduler admission / preemption /
EOS, per-request sampling, and restart determinism of the per-request
dither counters."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_argmax_margin

from repro.configs import get_config
from repro.models import registry
from repro.numerics.policy import QuantPolicy
from repro.serve import Engine, Request, SamplingParams, Scheduler, make_serve_fns
from repro.serve.sampling import sample_tokens

CFG = get_config("smollm_135m").reduced()
PARAMS = registry.init_model(jax.random.PRNGKey(0), CFG)


def _prompts(seed, n, length):
    key = jax.random.PRNGKey(seed)
    return np.asarray(
        jax.random.randint(key, (n, length), 1, CFG.vocab_size)).tolist()


def _ref_generate(params, cfg, prompts, max_new, policy=None, kv_quant=False,
                  max_len=32, margin_floor=None):
    """The pre-rebuild engine's path: equal-length prompts admitted together
    and fed token-by-token through ``decode_step``, then greedy decode.
    ``margin_floor`` additionally asserts every greedy pick is decided by at
    least that top-1/top-2 logit gap — the parity tests below pin exact
    token equality, which is only a meaningful check when no step's argmax
    sits on a float coin-flip (see conftest.assert_argmax_margin)."""
    toks = jnp.asarray(prompts, jnp.int32)
    b, s = toks.shape
    cache = registry.make_cache(params, cfg, b, max_len, kv_quant=kv_quant)
    for t in range(s):
        logits, cache = registry.apply_decode(params, cfg, toks[:, t], cache,
                                              policy=policy)
    outs = [[] for _ in range(b)]
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    for step in range(max_new):
        if margin_floor is not None:
            assert_argmax_margin(logits, min_margin=margin_floor,
                                 context=f"greedy step {step}")
        for i in range(b):
            outs[i].append(int(cur[i]))
        logits, cache = registry.apply_decode(params, cfg, cur, cache,
                                              policy=policy)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
    return outs


def _engine_generate(prompts, max_new, policy=None, kv_quant=False,
                     max_len=32, **req_kw):
    eng = Engine(PARAMS, CFG, batch=len(prompts), max_len=max_len,
                 policy=policy, kv_quant=kv_quant)
    for r, p in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=list(p), max_new=max_new, **req_kw))
    done = sorted(eng.run(40 + 4 * max_new), key=lambda r: r.rid)
    return [r.out for r in done]


# ---------------------------------------------------------------------------
# prefill ≡ token-by-token
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [1, 2])
def test_engine_prefill_matches_token_by_token_policy_off(seed):
    """Acceptance: the batched-prefill engine emits exactly the tokens the
    old per-token prompt feeding produced (greedy, full precision).
    (Re-pinned from [0, 1]: the margin assertion below surfaced that seed
    0's chain contains an *exact* top-2 logit tie — the bf16 logit grid
    makes every margin either 0 or ≥ 2⁻⁸ — so its token parity only held
    because both paths broke the tie identically, which no numerics
    guarantee protects.)"""
    prompts = _prompts(seed, 2, 5)
    ref = _ref_generate(PARAMS, CFG, prompts, 6, margin_floor=1e-3)
    assert _engine_generate(prompts, 6) == ref


@pytest.mark.parametrize("seed", [1, 7])
def test_engine_prefill_matches_token_by_token_policy_dither(seed):
    """Same check with int8 dither-rounded matmuls switched on.  (The
    rounding element indices differ between a (B·S, d) prefill matmul and a
    (B, d) decode matmul, so logits agree only to rounding noise — these
    seeds are argmax-stable under the flash-decode attention path's f32
    value accumulation and the outputs are identical.  Re-pinned from
    [0, 1] when PR 3 routed decode attention through the kernel dispatcher:
    the old einsum path rounded logits and probabilities to bf16, and seed
    0's chain included exact logit ties that only survived by luck.)"""
    pol = QuantPolicy(scheme="dither", bits=8)
    prompts = _prompts(seed, 2, 5)
    ref = _ref_generate(PARAMS, CFG, prompts, 6, policy=pol,
                        margin_floor=1e-3)
    assert _engine_generate(prompts, 6, policy=pol) == ref


def test_prefill_cache_equals_decode_cache():
    """prefill_with_cache writes the same bf16 K/V ring layout (per-slot
    positions included) that token-by-token decode would have written —
    variable prompt lengths, right-padded.  The first layer sees identical
    inputs either way, so its K/V must match bit-for-bit; deeper layers'
    inputs pass through attention — full-sequence einsum in prefill vs the
    flash-decode kernel path in decode (f32 value accumulation, PR 3) — so
    their bf16 K/V agree to rounding (≤ a couple of bf16 ULPs), exactly as
    the int8-cache variant below has always documented."""
    toks = jnp.asarray(_prompts(4, 3, 8), jnp.int32)
    lengths = jnp.array([8, 5, 3], jnp.int32)
    toks = toks * (jnp.arange(8)[None, :] < lengths[:, None])
    _, cache = registry.apply_prefill(PARAMS, CFG, toks, lengths, 16)

    ref = registry.make_cache(PARAMS, CFG, 3, 16)
    for t in range(8):
        _, new = registry.apply_decode(PARAMS, CFG, toks[:, t], ref)
        # freeze rows whose prompt already ended (what the engine's slot
        # lifecycle guarantees)
        ref = registry.merge_prefill(CFG, ref, new, t < lengths)

    assert jnp.array_equal(cache["pos"], lengths)
    got0, want0 = cache["layers"][0], ref["layers"][0]
    for name in ("k", "v", "k_pos"):
        assert jnp.array_equal(got0[name][0], want0[name][0]), name
    for (path, got), (_, want) in zip(
            jax.tree_util.tree_leaves_with_path(cache),
            jax.tree_util.tree_leaves_with_path(ref)):
        assert got.shape == want.shape
        if got.dtype == jnp.int32:          # pos / k_pos stay exact
            assert jnp.array_equal(got, want), path
        else:
            assert jnp.allclose(got.astype(jnp.float32),
                                want.astype(jnp.float32),
                                rtol=2e-2, atol=2e-2), path


def test_prefill_quantised_cache_first_layer_bit_exact():
    """int8 KV path: the first layer sees identical inputs either way, so
    its dither-quantised codes must match bit-for-bit (same counter = the
    absolute position).  Deeper layers differ by design: batched prefill
    computes prompt attention in full precision and quantises only for
    storage, while token-by-token decode re-reads quantised KV."""
    toks = jnp.asarray(_prompts(5, 2, 6), jnp.int32)
    lengths = jnp.full((2,), 6, jnp.int32)
    _, cache = registry.apply_prefill(PARAMS, CFG, toks, lengths, 16,
                                      kv_quant=True)
    ref = registry.make_cache(PARAMS, CFG, 2, 16, kv_quant=True)
    for t in range(6):
        _, ref = registry.apply_decode(PARAMS, CFG, toks[:, t], ref)

    got, want = cache["layers"][0], ref["layers"][0]
    for name in ("k", "v", "k_pos"):
        assert jnp.array_equal(got[name][0], want[name][0]), name
    assert jnp.allclose(got["k_scale"][0], want["k_scale"][0], rtol=1e-6)


def test_make_serve_fns_prefill_then_decode():
    """The two jit-able entry points compose: prefill seeds the cache at
    pos = lengths and decode continues from it."""
    prefill_step, decode_step = make_serve_fns(CFG, None, max_len=16)
    toks = jnp.asarray(_prompts(6, 2, 4), jnp.int32)
    lengths = jnp.full((2,), 4, jnp.int32)
    last_logits, cache = jax.jit(prefill_step)(PARAMS, toks, lengths)
    assert last_logits.shape == (2, CFG.vocab_size)
    assert jnp.array_equal(cache["pos"], lengths)
    tok = jnp.argmax(last_logits, -1).astype(jnp.int32)
    logits, cache = jax.jit(decode_step)(PARAMS, tok, cache)
    assert logits.shape == (2, CFG.vocab_size)
    assert jnp.array_equal(cache["pos"], lengths + 1)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_scanned_prefill_fallback_matches_token_by_token():
    """Recurrent architectures (no batched prefill) use the scanned
    on-device fallback — same decode math, so greedy outputs are identical
    to per-token prompt feeding."""
    cfg = get_config("mamba2_370m").reduced()
    params = registry.init_model(jax.random.PRNGKey(0), cfg)
    assert not registry.supports_batched_prefill(cfg)
    prompts = _prompts(7, 2, 5)
    ref = _ref_generate(params, cfg, prompts, 5)
    eng = Engine(params, cfg, batch=2, max_len=32)
    for r, p in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=list(p), max_new=5))
    done = sorted(eng.run(60), key=lambda r: r.rid)
    assert [r.out for r in done] == ref


# ---------------------------------------------------------------------------
# scheduler: admission order, preemption, EOS/stop
# ---------------------------------------------------------------------------


def test_scheduler_fcfs_and_priority_order():
    sched = Scheduler("fcfs")
    reqs = [Request(rid=r, prompt=[1], priority=p)
            for r, p in enumerate([0, 5, 5])]
    for r in reqs:
        sched.submit(r)
    assert [r.rid for r in sched.admit(3)] == [0, 1, 2]

    sched = Scheduler("priority")
    for r in reqs:
        sched.submit(r)
    assert [r.rid for r in sched.admit(2)] == [1, 2]   # ties stay FCFS
    assert [r.rid for r in sched.admit(2)] == [0]
    with pytest.raises(ValueError):
        Scheduler("sjf")


def test_engine_priority_admission_order():
    """batch=1 engine: the high-priority latecomer is served first."""
    eng = Engine(PARAMS, CFG, batch=1, max_len=32, scheduler="priority")
    for rid, prio in [(0, 0), (1, 5), (2, 5)]:
        eng.submit(Request(rid=rid, prompt=[1 + rid, 2], max_new=2,
                           priority=prio))
    done = eng.run(60)
    assert [r.rid for r in done] == [1, 2, 0]
    assert all(r.finish_reason == "length" for r in done)


def test_engine_preempts_slot_on_max_len():
    """A request that would overflow its slot's ring cache is preempted and
    the slot recycled for the next queued request."""
    eng = Engine(PARAMS, CFG, batch=1, max_len=8)
    eng.submit(Request(rid=0, prompt=[1, 2, 3, 4], max_new=100))
    eng.submit(Request(rid=1, prompt=[5, 6], max_new=2))
    done = eng.run(60)
    assert [r.rid for r in done] == [0, 1]
    assert done[0].finish_reason == "preempted"
    # prefill emits 1 token at pos=4; decode fills pos 5..8 → 5 tokens total
    assert len(done[0].out) == 8 - 4 + 1
    assert done[1].finish_reason == "length" and len(done[1].out) == 2


def test_engine_rejects_overlong_prompt():
    eng = Engine(PARAMS, CFG, batch=1, max_len=8)
    eng.submit(Request(rid=0, prompt=list(range(1, 20)), max_new=4))
    done = eng.run(10)
    assert done[0].finish_reason == "rejected" and done[0].out == []


def test_engine_eos_and_stop_tokens():
    """EOS/stop matching: replay a greedy run with eos_id / stop_ids set to
    a token it is known to emit."""
    prompts = _prompts(0, 1, 4)
    base = _engine_generate(prompts, 6)[0]
    eos = base[1]

    outs = _engine_generate(prompts, 6,
                            sampling=SamplingParams(eos_id=eos, max_new=6))
    eng_done = outs[0]
    assert eng_done == base[:2]

    eng = Engine(PARAMS, CFG, batch=1, max_len=32)
    eng.submit(Request(rid=0, prompt=list(prompts[0]),
                       sampling=SamplingParams(stop_ids=(eos,), max_new=6)))
    req = eng.run(40)[0]
    assert req.finish_reason == "stop" and req.out == base[:2]


def test_engine_streaming_and_timing():
    got = []
    eng = Engine(PARAMS, CFG, batch=2, max_len=32)
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4,
                       stream=lambda r, t: got.append(t)))
    req = eng.run(40)[0]
    assert got == req.out
    assert req.ttft is not None and req.ttft >= 0
    assert len(req.itl) == len(req.out) - 1


# ---------------------------------------------------------------------------
# sampling + per-request dither counters
# ---------------------------------------------------------------------------


def test_sample_tokens_greedy_topk_temperature():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)),
                         jnp.float32)
    z = jnp.zeros((4,), jnp.int32)
    greedy = sample_tokens(logits, jnp.zeros((4,)), z, z, z)
    assert jnp.array_equal(greedy, jnp.argmax(logits, -1))
    # top_k=1 at any temperature is greedy
    t = sample_tokens(logits, jnp.full((4,), 2.0), jnp.ones((4,), jnp.int32),
                      z, z)
    assert jnp.array_equal(t, greedy)
    # sampling is deterministic in (seed, counter) and varies across them
    s1 = sample_tokens(logits, jnp.full((4,), 1.0), z, z, z)
    s2 = sample_tokens(logits, jnp.full((4,), 1.0), z, z, z)
    assert jnp.array_equal(s1, s2)
    draws = [sample_tokens(logits, jnp.full((4,), 5.0), z, z,
                           jnp.full((4,), c, jnp.int32))
             for c in range(8)]
    assert len({tuple(np.asarray(d)) for d in draws}) > 1
    # top-k masking really restricts support
    topk = [int(x) for c in range(16) for x in np.asarray(
        sample_tokens(logits, jnp.full((4,), 5.0),
                      jnp.full((4,), 2, jnp.int32), z,
                      jnp.full((4,), c, jnp.int32)))]
    top2 = np.argsort(np.asarray(logits), axis=-1)[:, -2:]
    for c in range(16):
        for row in range(4):
            assert topk[4 * c + row] in top2[row]


def test_per_request_counter_offsets_decorrelate_streams():
    """Two concurrent requests with the same prompt and seed: identical
    counter offsets → identical sampled streams; distinct offsets →
    distinct streams (independent pulse walks, DESIGN.md §6)."""
    prompt = [3, 1, 4, 1, 5]

    def run(offsets):
        eng = Engine(PARAMS, CFG, batch=2, max_len=32)
        for r, off in enumerate(offsets):
            eng.submit(Request(rid=r, prompt=list(prompt),
                               sampling=SamplingParams(
                                   temperature=1.0, seed=7, max_new=8,
                                   counter_offset=off)))
        return [r.out for r in sorted(eng.run(60), key=lambda r: r.rid)]

    same = run([0, 0])
    assert same[0] == same[1]
    diff = run([0, 1000])
    assert diff[0] != diff[1]


def test_restart_determinism_of_dither_counters():
    """A fresh engine replaying the same submissions reproduces every
    token: KV-quantiser counters are (position + per-request offset),
    sampling counters are (offset + emitted count), and the policy counter
    is the engine tick — none depend on wall clock or engine history."""
    pol = QuantPolicy(scheme="dither", bits=8)

    def run():
        eng = Engine(PARAMS, CFG, batch=2, max_len=32, policy=pol,
                     kv_quant=True)
        for r in range(4):
            eng.submit(Request(
                rid=r, prompt=[1 + r, 2, 3],
                sampling=SamplingParams(temperature=0.8, top_k=16, seed=r,
                                        max_new=5, counter_offset=100 * r)))
        return [(r.rid, tuple(r.out), r.finish_reason)
                for r in sorted(eng.run(80), key=lambda r: r.rid)]

    assert run() == run()
