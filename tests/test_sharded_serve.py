"""Sharded serving (DESIGN.md §9): a mesh-sharded engine must emit the
*bitwise* token stream of the single-device engine.

The serve layout is reduction-preserving — QKV column-parallel, attention
heads all-gathered before a replicated W_O, decode slots / paged pools
partitioned on 'data', KV heads on 'model' — so no f32 reduction is ever
re-associated by sharding, and the dither KV codes hash coordinates that
are independent of slot placement and shard count.  These tests pin that
contract over kv_layout ∈ {ring, paged} × KV dtype ∈ {bf16, int8}:

* the (1, 1) mesh runs everywhere (tier-1: single CPU device) and pins the
  shard_map path itself against the unmeshed engine;
* (2, 1) / (1, 2) / (2, 2) meshes run when ≥ 4 devices exist — CI forces
  them with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``;
* the GQA fallback (n_kv_heads % tp != 0 → fully replicated TP compute,
  mirroring dist/sharding's head-count guards) is pinned on a (1, 2) mesh.
"""

from dataclasses import replace

import jax
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_serve_mesh
from repro.models import registry
from repro.serve.engine import Engine, Request
from repro.serve.sampling import SamplingParams

N_DEV = len(jax.devices())
needs4 = pytest.mark.skipif(
    N_DEV < 4,
    reason="needs 4 devices (XLA_FLAGS=--xla_force_host_platform_device_count=4)")
needs2 = pytest.mark.skipif(N_DEV < 2, reason="needs 2 devices")

CFG = get_config("smollm_135m").reduced()      # 4 heads / 2 KV heads
CFG_MQA = replace(CFG, n_kv_heads=1)           # 1 % tp != 0 → GQA fallback
_PARAMS = {}


def _params(cfg):
    key = cfg.n_kv_heads
    if key not in _PARAMS:
        _PARAMS[key] = registry.init_model(jax.random.PRNGKey(0), cfg)
    return _PARAMS[key]


def _stream(cfg, mesh, kv_layout, kv_quant, *, temperature=0.0, spec=False):
    """Serve a fixed 6-request mix; return the full per-request streams."""
    eng = Engine(_params(cfg), cfg, batch=4, max_len=48, kv_quant=kv_quant,
                 kv_layout=kv_layout,
                 block_size=8 if kv_layout == "paged" else None, mesh=mesh,
                 **(dict(spec_decode=True, draft_k=4) if spec else {}))
    for r in range(6):
        prompt = [(7 * r + i) % (cfg.vocab_size - 1) + 1
                  for i in range(5 + r % 3)]
        eng.submit(Request(rid=r, prompt=prompt,
                           sampling=SamplingParams(temperature=temperature,
                                                   max_new=6, seed=r,
                                                   counter_offset=1000 * r)))
    done = eng.run(ticks=200)
    assert len(done) == 6
    if spec:
        assert eng.metrics.summary()["counters"].get("spec_windows", 0) > 0
    return sorted((r.rid, tuple(r.out), r.finish_reason) for r in done)


_BASE = {}


def _baseline(cfg, kv_layout, kv_quant):
    key = (cfg.n_kv_heads, kv_layout, kv_quant)
    if key not in _BASE:
        _BASE[key] = _stream(cfg, None, kv_layout, kv_quant)
    return _BASE[key]


@pytest.mark.parametrize("kv_quant", [False, True], ids=["bf16", "int8"])
@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
def test_mesh_1x1_parity(kv_layout, kv_quant):
    """The shard_map serve path on a trivial (1, 1) mesh is bitwise the
    unmeshed engine — runs in tier-1 on a single CPU device."""
    got = _stream(CFG, make_serve_mesh(1, 1), kv_layout, kv_quant)
    assert got == _baseline(CFG, kv_layout, kv_quant)


@needs4
@pytest.mark.parametrize("kv_quant", [False, True], ids=["bf16", "int8"])
@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
@pytest.mark.parametrize("mesh_shape", [(2, 1), (1, 2), (2, 2)],
                         ids=["dp2", "tp2", "dp2tp2"])
def test_mesh_parity(mesh_shape, kv_layout, kv_quant):
    """data-, model- and jointly-sharded streams are bitwise the
    single-device stream (the ISSUE-5 acceptance criterion)."""
    got = _stream(CFG, make_serve_mesh(*mesh_shape), kv_layout, kv_quant)
    assert got == _baseline(CFG, kv_layout, kv_quant)


@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
def test_spec_mesh_1x1_parity(kv_layout):
    """Speculative decode through the shard_map serve path on a (1, 1)
    mesh is bitwise the unmeshed *plain* engine — the spec window's verify
    and bulk-commit dispatches preserve the stream contract under mesh
    placement (tier-1, single CPU device)."""
    got = _stream(CFG, make_serve_mesh(1, 1), kv_layout, False, spec=True)
    assert got == _baseline(CFG, kv_layout, False)


@needs4
@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
@pytest.mark.parametrize("mesh_shape", [(2, 1), (1, 2), (2, 2)],
                         ids=["dp2", "tp2", "dp2tp2"])
def test_spec_mesh_parity(mesh_shape, kv_layout):
    """Speculative streams on data-, model- and jointly-sharded meshes are
    bitwise the single-device plain stream: dither KV codes hash absolute
    coordinates, so a bulk-committed window is placement-independent just
    like sequential decode (DESIGN.md §14)."""
    got = _stream(CFG, make_serve_mesh(*mesh_shape), kv_layout, False,
                  spec=True)
    assert got == _baseline(CFG, kv_layout, False)


@needs4
def test_mesh_parity_sampled():
    """Temperature sampling is per-row hash noise, so parity survives
    non-greedy decoding too (ring, int8 KV, (2, 2))."""
    base = _stream(CFG, None, "ring", True, temperature=0.8)
    got = _stream(CFG, make_serve_mesh(2, 2), "ring", True, temperature=0.8)
    assert got == base


@needs2
@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
def test_gqa_fallback_parity(kv_layout):
    """n_kv_heads=1 cannot split a 2-way model axis: the engine must fall
    back to replicated TP compute (heads_sharded False) and still match the
    single-device stream bitwise."""
    mesh = make_serve_mesh(1, 2)
    eng = Engine(_params(CFG_MQA), CFG_MQA, batch=4, max_len=48,
                 kv_layout=kv_layout,
                 block_size=8 if kv_layout == "paged" else None, mesh=mesh)
    assert eng.heads_sharded is False
    assert eng._cfg_local.n_kv_heads == CFG_MQA.n_kv_heads
    got = _stream(CFG_MQA, mesh, kv_layout, True)
    assert got == _baseline(CFG_MQA, kv_layout, True)


@needs2
def test_mesh_requires_batch_divisible():
    with pytest.raises(ValueError, match="multiple of the mesh's data axis"):
        Engine(_params(CFG), CFG, batch=3, max_len=32,
               mesh=make_serve_mesh(2, 1))


def test_mesh_rejects_recurrent_archs():
    cfg = get_config("mamba2_370m").reduced()
    with pytest.raises(ValueError, match="attention-only"):
        Engine(registry.init_model(jax.random.PRNGKey(0), cfg), cfg,
               batch=2, max_len=32, mesh=make_serve_mesh(1, 1))


@needs4
def test_paged_pool_partitioned_per_shard():
    """The paged pool splits into per-data-shard sub-pools: admission
    budget, trash id and block tables are shard-local (DESIGN.md §9)."""
    eng = Engine(_params(CFG), CFG, batch=4, max_len=48, kv_layout="paged",
                 block_size=8, num_blocks=12, mesh=make_serve_mesh(2, 2))
    assert len(eng.pools) == 2
    assert eng.num_blocks == 12 and eng._nb_local == 6
    assert all(p.trash == 6 for p in eng.pools)
    # device pool: 2 shards × (6 + 1 trash) blocks back to back
    assert eng.cache["layers"][0]["k"].shape[1] == 14
    for r in range(4):
        eng.submit(Request(rid=r, prompt=[r + 1] * 5,
                           sampling=SamplingParams(max_new=4)))
    done = eng.run(ticks=60)
    assert len(done) == 4
    assert {eng._slot_shard(i) for i in range(4)} == {0, 1}
    assert eng.pool_stats()["live"] == 0       # all released on finish
