"""Elastic restart: checkpoints written under one mesh shape restore onto a
different mesh (device count changes), in a subprocess with 8 virtual
devices so real resharding happens."""

import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.configs import get_config
    from repro.dist import sharding as shd
    from repro.models import registry

    cfg = get_config("smollm_135m").reduced()
    params = registry.init_model(jax.random.PRNGKey(0), cfg)

    # place on a (4, 2) mesh
    mesh_a = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    specs = shd.param_specs(params, cfg, mesh_a)
    sh_a = jax.tree.map(lambda s: NamedSharding(mesh_a, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
    params_a = jax.tree.map(lambda x, s: jax.device_put(x, s), params, sh_a)

    ck = Checkpointer(sys.argv[1])
    ck.save(1, params_a)

    # restore on a DIFFERENT mesh: (2, 2) submesh — "two hosts died"
    mesh_b = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    specs_b = shd.param_specs(params, cfg, mesh_b)
    sh_b = jax.tree.map(lambda s: NamedSharding(mesh_b, s), specs_b,
                        is_leaf=lambda x: isinstance(x, P))
    restored = ck.restore(1, params_a, shardings=sh_b)

    for a, b in zip(jax.tree.leaves(params_a), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a).astype(np.float32),
                                      np.asarray(b).astype(np.float32))
    emb = jax.tree.leaves(restored)[0]
    print("OK", len(jax.tree.leaves(restored)))
""")


def test_elastic_restore_across_mesh_shapes(tmp_path):
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(tmp_path)],
        capture_output=True, text=True, timeout=420,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
