"""Host-side paged-pool allocator + scheduler edge cases (DESIGN.md §6).

KVPool: free-list accounting, chained-hash prefix matching, refcounted
sharing with LRU eviction of cached blocks, copy-on-write, and the
release/forget split.  Scheduler: priority ties stay FCFS, requeued
(preempted) requests keep their place in line, and admission succeeds with
exactly one free block (satellite coverage for PR 4)."""

import pytest

from repro.serve import Engine, Request, Scheduler
from repro.serve.kvpool import KVPool

# ---------------------------------------------------------------------------
# KVPool
# ---------------------------------------------------------------------------


def test_pool_allocate_and_release_accounting():
    pool = KVPool(num_blocks=8, block_size=4)
    assert pool.free_blocks == 8 and pool.trash == 8
    table = pool.allocate(rid=1, n_tokens=9)          # 3 blocks
    assert len(table) == 3 and pool.free_blocks == 5
    assert pool.live_blocks == 3 and pool.holders == 1
    grown = pool.append_block(1)
    assert grown is not None and pool.table(1) == table + [grown]
    pool.release(1)
    # nothing sealed → everything back on the free list, nothing cached
    assert pool.free_blocks == 8 and pool.cached_blocks == 0


def test_pool_allocation_failure_leaves_state_unchanged():
    pool = KVPool(num_blocks=2, block_size=4)
    assert pool.allocate(rid=1, n_tokens=12) is None   # needs 3 > 2
    assert pool.free_blocks == 2 and pool.holders == 0
    assert pool.allocate(rid=1, n_tokens=8) is not None
    assert pool.append_block(1) is None                # exhausted


def test_pool_prefix_match_caps_below_full_prompt():
    """A full-prompt hit is capped: at least one token must remain to
    prefill (its logits seed sampling), so the match walks at most
    (len-1)//bs blocks even when every block is cached."""
    pool = KVPool(num_blocks=8, block_size=4)
    toks = list(range(8))
    pool.allocate(1, len(toks))
    pool.seal_block(1, 0, toks[:4])
    pool.seal_block(1, 1, toks[4:])
    pool.release(1)
    assert pool.cached_blocks == 2
    hits, _ = pool.match_prefix(toks)                 # exactly the cached seq
    assert len(hits) == 1                             # capped at (8-1)//4
    hits, _ = pool.match_prefix(toks + [99])
    assert len(hits) == 2                             # proper prefix → both
    # a different offset seed namespaces the chain (int8 code streams)
    hits, _ = pool.match_prefix(toks + [99], seed=1000)
    assert hits == []


def test_pool_shared_refcounts_and_lru_eviction():
    pool = KVPool(num_blocks=3, block_size=2)
    pool.allocate(1, 4)
    seq = [5, 6, 7, 8]
    pool.seal_block(1, 0, seq[:2])
    pool.seal_block(1, 1, seq[2:])
    pool.release(1)
    assert pool.cached_blocks == 2 and pool.free_blocks == 3
    # a second request hits the chain and shares the physical blocks
    hits, chain = pool.match_prefix(seq + [9, 10])
    assert len(hits) == 2
    t2 = pool.allocate(2, 6, shared=hits, chain=chain)
    assert t2[:2] == hits and pool.live_blocks == 3
    assert pool.cached_blocks == 0                    # shared ≠ evictable
    # pool is full; a cold request must fail, not evict referenced blocks
    assert pool.allocate(3, 4) is None
    pool.release(2)
    # now eviction can reclaim the LRU cached block for a cold allocation
    t3 = pool.allocate(3, 6)
    assert t3 is not None and pool.stats["evicted"] >= 1
    # the evicted block's hash is gone from the lookup
    hits2, _ = pool.match_prefix(seq + [9])
    assert len(hits2) < 2


def test_pool_shared_cached_blocks_are_not_fresh_capacity():
    """Regression: the allocation guard must not count the matched prefix
    blocks themselves as capacity for the fresh blocks — acquiring them
    removes them from the evictable set, so a hit whose shared blocks are
    the only 'free' space must fail cleanly (state unchanged), not trip an
    assert mid-allocation and leak the acquired references."""
    pool = KVPool(num_blocks=3, block_size=4)
    toks = list(range(8))
    pool.allocate(1, len(toks))
    pool.seal_block(1, 0, toks[:4])
    pool.seal_block(1, 1, toks[4:])
    pool.release(1)                              # 2 cached, 1 free
    assert pool.allocate(2, 4) is not None       # 3rd block now held
    hits, chain = pool.match_prefix(toks + [9])
    assert len(hits) == 2                        # both hits are cached-only
    # needs 1 fresh block; free_blocks == 2 but both ARE the shared blocks
    assert pool.allocate(3, 9, shared=hits, chain=chain) is None
    # state intact: nothing leaked, the cached chain still matches
    assert pool.free_blocks == 2 and pool.holders == 1
    assert len(pool.match_prefix(toks + [9])[0]) == 2
    pool.release(2)
    # with the holder gone the same request fits (eviction supplies fresh)
    assert pool.allocate(3, 9, shared=hits, chain=chain) is not None


def test_pool_copy_on_write():
    pool = KVPool(num_blocks=4, block_size=2)
    pool.allocate(1, 4)
    pool.seal_block(1, 0, [1, 2])
    hits, chain = pool.match_prefix([1, 2, 3])
    pool.allocate(2, 3, shared=hits, chain=chain)
    # request 2's logical block 0 is shared → a write must copy it first
    phys, copied = pool.ensure_writable(2, 0)
    assert copied and phys != hits[0]
    assert pool.table(2)[0] == phys
    assert pool.stats["cow_copies"] == 1
    # request 1 still owns the original; its own write needs no copy
    p1, c1 = pool.ensure_writable(1, 0)
    assert p1 == hits[0] and not c1


def test_pool_forget_drops_prefix_cache_entries():
    pool = KVPool(num_blocks=4, block_size=2)
    pool.allocate(1, 4)
    pool.seal_block(1, 0, [1, 2])
    pool.forget(1)
    assert pool.free_blocks == 4 and pool.cached_blocks == 0
    hits, _ = pool.match_prefix([1, 2, 3])
    assert hits == []


# ---------------------------------------------------------------------------
# Scheduler edge cases (satellite)
# ---------------------------------------------------------------------------


def test_priority_ties_admit_fcfs_within_class():
    sched = Scheduler("priority")
    reqs = [Request(rid=r, prompt=[1], priority=p)
            for r, p in enumerate([3, 5, 3, 5, 5])]
    for r in reqs:
        sched.submit(r)
    assert [r.rid for r in sched.admit(5)] == [1, 3, 4, 0, 2]


@pytest.mark.parametrize("policy", ["fcfs", "priority"])
def test_requeue_preserves_arrival_order(policy):
    """A preempted request re-enters *ahead* of later arrivals in its
    priority class — preemption must not cost it its place in line."""
    sched = Scheduler(policy)
    reqs = [Request(rid=r, prompt=[1]) for r in range(4)]
    for r in reqs[:3]:
        sched.submit(r)
    victim = sched.admit(1)[0]
    assert victim.rid == 0
    sched.submit(reqs[3])                  # arrives after the preemption
    sched.requeue(victim)
    assert [r.rid for r in sched.admit(4)] == [0, 1, 2, 3]


def test_requeue_respects_priority_classes():
    sched = Scheduler("priority")
    lo = Request(rid=0, prompt=[1], priority=0)
    sched.submit(lo)
    victim = sched.admit(1)[0]
    hi = Request(rid=1, prompt=[1], priority=9)
    sched.submit(hi)
    sched.requeue(victim)
    # the requeued low-priority victim still yields to higher priority
    assert [r.rid for r in sched.admit(2)] == [1, 0]


def test_peek_then_pop_matches_admit_order():
    sched = Scheduler("priority")
    for r, p in enumerate([1, 7, 7]):
        sched.submit(Request(rid=r, prompt=[1], priority=p))
    head = sched.peek()
    assert head.rid == 1
    sched.pop(head)
    assert sched.peek().rid == 2 and len(sched) == 2


# ---------------------------------------------------------------------------
# Engine admission at exactly one free block (satellite)
# ---------------------------------------------------------------------------


def test_engine_admission_with_exactly_one_free_block():
    """Token-budget admission boundary: with one free block, a one-block
    request admits and a two-block request must wait (head-of-line), then
    admit once the first finishes and releases."""
    import jax

    from repro.configs import get_config
    from repro.models import registry

    cfg = get_config("smollm_135m").reduced()
    params = registry.init_model(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, batch=2, max_len=8, kv_layout="paged",
                 block_size=8, num_blocks=1, prefix_cache=False)
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4))   # 1 block
    eng.submit(Request(rid=1, prompt=[4, 5, 6], max_new=4))   # must wait
    eng.step()       # rid 0 admitted (sole block); rid 1 head-of-line waits
    assert eng.slots[0] is not None and eng.slots[0].rid == 0
    assert eng.slots[1] is None and len(eng.scheduler) == 1
    done = sorted(eng.run(40), key=lambda r: r.rid)
    assert [r.rid for r in done] == [0, 1]
    assert all(len(r.out) == 4 for r in done)
