"""hlo_cost parser vs XLA cost_analysis on scan-free graphs, and loop
weighting on scanned graphs."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import hlo_cost


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_unrolled_matches_cost_analysis():
    def f(x, w):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = _compile(f, x, x)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    got = hlo_cost(compiled.as_text(), loop_factor=1)["dot_flops"]
    want = float(ca.get("flops", 0.0))
    assert want > 0
    assert abs(got - want) / want < 0.05, (got, want)


def test_scan_loop_weighting():
    """A scan of R matmuls must count R× the single-body flops."""
    R = 7

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=R)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = _compile(f, x, x)
    got = hlo_cost(compiled.as_text(), loop_factor=R)["dot_flops"]
    one_matmul = 2 * 128 * 128 * 128
    assert abs(got - R * one_matmul) / (R * one_matmul) < 0.05, got


def test_stream_bytes_nonzero_and_bounded():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    compiled = _compile(f, a, a)
    res = hlo_cost(compiled.as_text(), loop_factor=1)
    # one matmul: ~3 × 1 MiB traffic
    assert 2e6 < res["stream_bytes"] < 2e7, res
