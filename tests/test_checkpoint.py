"""Checkpointer: roundtrip, atomicity, async, retention, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nest": {"b": jnp.arange(10, dtype=jnp.int32),
                 "c": jnp.float32(3.5)},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(5, t)
    out = ck.restore(5, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(1, _tree(1))
    ck.save_async(2, _tree(2))
    ck.wait()
    assert ck.latest_step() == 2
    step, out = ck.restore_latest(_tree())
    assert step == 2


def test_retention_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in range(5):
        ck.save(s, _tree(s))
    assert ck.all_steps() == [3, 4]


def test_no_partial_checkpoint_visible(tmp_path):
    """tmp dirs are never listed as valid steps."""
    ck = Checkpointer(str(tmp_path))
    os.makedirs(tmp_path / "step_00000009.tmp.123.456")
    assert ck.all_steps() == []
    ck.save(1, _tree())
    assert ck.all_steps() == [1]


def test_elastic_restore_dtype_and_placement(tmp_path):
    """Restore casts to the reference dtype and accepts shardings=None
    (mesh-shape-agnostic numpy storage → any future mesh)."""
    ck = Checkpointer(str(tmp_path))
    t = {"w": jnp.ones((4, 4), jnp.float32)}
    ck.save(1, t)
    like = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    out = ck.restore(1, like)
    assert out["w"].dtype == jnp.bfloat16
    assert float(out["w"].sum()) == 16.0


def test_mismatched_structure_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    with pytest.raises(AssertionError):
        ck.restore(1, {"only": jnp.zeros(3)})
