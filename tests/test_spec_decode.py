"""Speculative decoding over the dither KV cache (DESIGN.md §14).

Three test layers pin the draft-and-verify path:

* **Bulk-commit stream parity** — the spec engine's emitted token stream is
  bitwise the plain engine's for every ring/paged × bf16/int8-KV ×
  greedy/temperature configuration, including the accept-all edge (a replay
  oracle drafter: every window commits ``draft_k`` tokens) and the
  reject-at-every-position edge (an anti-replay drafter: every window
  commits exactly row 0).  This is the position-purity consequence the
  design leans on: a dither KV code is a function of (value, absolute
  position, element index) only — never of *when* or *how many at a time*
  the write happened — so a bulk commit of k accepted tokens writes the
  exact bytes sequential decode would have.

* **Verify-kernel backend parity** — ``verify_attention`` /
  ``paged_verify_attention`` are bit-identical between ``pallas-interpret``
  and the ``xla-ref`` oracle across kv_quant × GQA group × window, and the
  oracle's row ``t`` is bitwise the one-token decode oracle evaluated at
  ``pos + t`` over the same cache (rows drafted beyond ``pos + t`` are
  masked to exp() = 0.0 contributions at the same slot locations sequential
  decode leaves empty — identical association order, identical sums).

* **Rejected-suffix rollback** — after windows whose drafts all reject, the
  spec engine's cache bytes (and, paged, the pool's refcounts, free list
  and prefix-cache index) are identical to a never-drafted engine's at the
  same emitted length: ``spec_commit`` scrubs stale draft slots back to
  init values and ``KVPool.truncate`` exactly reverses ``append_block``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import dispatch
from repro.models import registry
from repro.serve import Engine, Request, SamplingParams
from repro.serve.draft import (Drafter, FixedDrafter, PromptLookupDrafter,
                               ReplayDrafter)

CFG = get_config("smollm_135m").reduced()
PARAMS = registry.init_model(jax.random.PRNGKey(0), CFG)


def _prompts(n, length=6):
    return [[(7 * r + i) % (CFG.vocab_size - 1) + 1 for i in range(length)]
            for r in range(n)]


class AntiReplayDrafter(Drafter):
    """Proposes the *wrong* token at every position: the recorded stream's
    token shifted by one in vocab space.  Guarantees reject-at-every-
    position (each verify window commits exactly row 0), which is the
    harness for the rollback tests — the engine still makes sequential
    progress, but every window exercises the scrub + truncate path."""

    def __init__(self, streams):
        self.replay = ReplayDrafter(streams)

    def propose(self, context, k):
        good = self.replay.propose(context, k)
        return [(t + 1) % CFG.vocab_size for t in good]


def _serve(*, spec, drafter=None, kv_layout="ring", kv_quant=False,
           temperature=0.0, max_new=8, requests=2, max_len=32, batch=2,
           draft_k=4):
    kw = {}
    if kv_layout == "paged":
        kw = dict(kv_layout="paged", block_size=4)
    eng = Engine(PARAMS, CFG, batch=batch, max_len=max_len, kv_quant=kv_quant,
                 spec_decode=spec, draft_k=draft_k if spec else 4,
                 drafter=drafter, **kw)
    for r, p in enumerate(_prompts(requests)):
        eng.submit(Request(rid=r, prompt=p,
                           sampling=SamplingParams(temperature=temperature,
                                                   top_k=8 if temperature else 0,
                                                   seed=r, max_new=max_new,
                                                   counter_offset=1000 * r)))
    done = eng.run(ticks=requests * (max_new + 6) + 20)
    return {r.rid: list(r.out) for r in done}, eng


# ---------------------------------------------------------------------------
# bulk-commit stream parity: spec ≡ plain, bitwise, across the engine grid
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.8], ids=["greedy", "temp"])
@pytest.mark.parametrize("kv_quant", [False, True], ids=["bf16", "int8"])
@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
def test_spec_stream_bitwise_equals_plain(kv_layout, kv_quant, temperature):
    """The headline contract: speculation changes *when* tokens are
    computed, never *which* — acceptance is exact token match against the
    engine's own stateless sampler, so greedy and temperature streams are
    both bitwise invariant."""
    plain, _ = _serve(spec=False, kv_layout=kv_layout, kv_quant=kv_quant,
                      temperature=temperature)
    spec, eng = _serve(spec=True, drafter=PromptLookupDrafter(),
                       kv_layout=kv_layout, kv_quant=kv_quant,
                       temperature=temperature)
    assert spec == plain
    mc = eng.metrics.summary()["counters"]
    assert mc.get("spec_windows", 0) > 0
    # every token after each request's prefill-emitted first one came
    # through a spec window
    assert mc.get("spec_emitted_tokens", 0) == sum(
        len(o) - 1 for o in spec.values())


@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
def test_accept_all_edge_commits_full_windows(kv_layout):
    """Replay-oracle drafting: every draft matches, so every window commits
    its whole budget and the accept counters saturate — the bulk-commit
    fast path where the scrub mask is empty."""
    plain, _ = _serve(spec=False, kv_layout=kv_layout)
    streams = {tuple(p): plain[r] for r, p in enumerate(_prompts(2))}
    spec, eng = _serve(spec=True, drafter=ReplayDrafter(streams),
                       kv_layout=kv_layout)
    assert spec == plain
    mc = eng.metrics.summary()["counters"]
    assert mc["spec_accepted_tokens"] == mc["spec_draft_tokens"] > 0
    # full accept: both slots decode in lockstep — 7 post-prefill tokens
    # per request in windows of budget 4 then 3 → 2 engine windows total
    assert mc["spec_windows"] == 2
    assert mc["spec_emitted_tokens"] == 14


@pytest.mark.parametrize("kv_layout", ["ring", "paged"])
def test_reject_every_position_edge_still_progresses(kv_layout):
    """Anti-replay drafting: every draft is wrong, so every window commits
    exactly row 0 (plain decode's tick) — wrong drafts cost latency, never
    correctness or progress."""
    plain, _ = _serve(spec=False, kv_layout=kv_layout)
    streams = {tuple(p): plain[r] for r, p in enumerate(_prompts(2))}
    spec, eng = _serve(spec=True, drafter=AntiReplayDrafter(streams),
                       kv_layout=kv_layout)
    assert spec == plain
    mc = eng.metrics.summary()["counters"]
    assert mc["spec_accepted_tokens"] == 0
    assert mc["spec_draft_tokens"] > 0
    # one token per slot per window, both slots in lockstep: 7 windows
    # emit the 14 post-prefill tokens
    assert mc["spec_windows"] == 7
    assert mc["spec_emitted_tokens"] == 14


def test_empty_and_short_proposals_pad_safely():
    """A drafter may return fewer than ``draft_k - 1`` tokens (or none):
    the window pads with zeros, scores them anyway, and the stream is still
    bitwise plain — padding rows only commit on an exact match."""
    plain, _ = _serve(spec=False)
    spec, _ = _serve(spec=True, drafter=FixedDrafter([3]))
    assert spec == plain


# ---------------------------------------------------------------------------
# verify-kernel backend parity + the per-row sequential-equivalence oracle
# ---------------------------------------------------------------------------


def _ring_verify_inputs(seed, *, b=2, cap=32, nkv=2, group=2, hd=16, kq=3,
                        quantized=False, pos_vals=(5, 20)):
    """A ring snapshot mid-verify: slots hold positions up to
    ``pos + kq - 1`` (base row + drafted rows already scattered); unwritten
    slots carry k_pos = -1 and arbitrary codes that masking must hide."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, kq, nkv, group, hd)), jnp.bfloat16)
    pos = jnp.asarray(pos_vals[:b], jnp.int32)
    kpos = np.full((b, cap), -1, np.int64)
    for i in range(b):
        for p in range(int(pos_vals[i]) + kq):
            kpos[i, p % cap] = p
    k_pos = jnp.asarray(kpos, jnp.int32)
    if quantized:
        k = jnp.asarray(rng.integers(-127, 128, size=(b, cap, nkv, hd)), jnp.int8)
        v = jnp.asarray(rng.integers(-127, 128, size=(b, cap, nkv, hd)), jnp.int8)
        ks = jnp.asarray(rng.uniform(0.1, 2.0, size=(b, cap, nkv)), jnp.float32)
        vs = jnp.asarray(rng.uniform(0.1, 2.0, size=(b, cap, nkv)), jnp.float32)
    else:
        k = jnp.asarray(rng.normal(size=(b, cap, nkv, hd)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(b, cap, nkv, hd)), jnp.bfloat16)
        ks = vs = None
    return q, k, v, k_pos, pos, ks, vs


@pytest.mark.parametrize("quantized", [False, True], ids=["bf16", "int8"])
@pytest.mark.parametrize("window", [0, 16], ids=["full", "window16"])
@pytest.mark.parametrize("group", [1, 2, 4])
def test_verify_interpret_bit_identical_to_xla_ref(quantized, window, group):
    """The Pallas verify kernel mirrors the oracle's per-row recurrence
    op-for-op: bit-identical across kv_quant × window × GQA group for
    every split-K block size."""
    q, k, v, k_pos, pos, ks, vs = _ring_verify_inputs(
        group, group=group, quantized=quantized)
    for bk in (8, 32):
        out_i = dispatch.verify_attention(
            q, k, v, k_pos, pos, k_scale=ks, v_scale=vs, window=window,
            block=(bk,), backend="pallas-interpret")
        out_r = dispatch.verify_attention(
            q, k, v, k_pos, pos, k_scale=ks, v_scale=vs, window=window,
            block=(bk,), backend="xla-ref")
        assert out_i.dtype == jnp.float32
        assert jnp.array_equal(out_i, out_r), (quantized, window, group, bk)


@pytest.mark.parametrize("quantized", [False, True], ids=["bf16", "int8"])
@pytest.mark.parametrize("group", [1, 2])
def test_paged_verify_interpret_bit_identical_to_xla_ref(quantized, group):
    """Paged verify: the tile is the pool block on every backend, so
    interpret must match the oracle bit-for-bit (including junk rows in
    partially-filled and out-of-table blocks, which masking hides).
    group == 1 pins allclose-at-ulp instead, inheriting the one-token
    paged kernel's documented GEMV-shape association caveat
    (tests/test_paged_attention.py) — the verify body runs the exact same
    per-row dot shapes, so it deviates exactly where decode does."""
    rng = np.random.default_rng(11 + group)
    b, bs, nbmax, nblocks, nkv, hd, kq = 2, 4, 6, 16, 2, 16, 3
    q = jnp.asarray(rng.normal(size=(b, kq, nkv, group, hd)), jnp.bfloat16)
    pos = jnp.asarray([5, 13], jnp.int32)
    bt = jnp.asarray(rng.permutation(nblocks - 1)[:b * nbmax].reshape(b, nbmax),
                     jnp.int32)
    if quantized:
        k = jnp.asarray(rng.integers(-127, 128, size=(nblocks, bs, nkv, hd)),
                        jnp.int8)
        v = jnp.asarray(rng.integers(-127, 128, size=(nblocks, bs, nkv, hd)),
                        jnp.int8)
        ks = jnp.asarray(rng.uniform(0.1, 2.0, size=(nblocks, bs, nkv)),
                         jnp.float32)
        vs = jnp.asarray(rng.uniform(0.1, 2.0, size=(nblocks, bs, nkv)),
                         jnp.float32)
    else:
        k = jnp.asarray(rng.normal(size=(nblocks, bs, nkv, hd)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(nblocks, bs, nkv, hd)), jnp.bfloat16)
        ks = vs = None
    out_i = dispatch.paged_verify_attention(
        q, k, v, bt, pos, k_scale=ks, v_scale=vs, backend="pallas-interpret")
    out_r = dispatch.paged_verify_attention(
        q, k, v, bt, pos, k_scale=ks, v_scale=vs, backend="xla-ref")
    assert out_i.dtype == jnp.float32
    if group == 1:
        np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_r),
                                   rtol=1e-6, atol=1e-7)
    else:
        assert jnp.array_equal(out_i, out_r), (quantized, group)


@pytest.mark.parametrize("quantized", [False, True], ids=["bf16", "int8"])
def test_verify_row_equals_sequential_decode_row(quantized):
    """The stream-parity linchpin, at the kernel level: verify row ``t``
    over a cache holding drafted positions up to ``pos + kq - 1`` is
    bitwise the one-token decode oracle at ``pos + t`` over the *same*
    cache.  Drafted-but-future slots contribute exp() = 0.0 terms at the
    slot locations sequential decode leaves empty — same association
    order, same sums — so acceptance implies bitwise logits row by row."""
    q, k, v, k_pos, pos, ks, vs = _ring_verify_inputs(7, quantized=quantized)
    kq = q.shape[1]
    ver = dispatch.verify_attention(q, k, v, k_pos, pos, k_scale=ks,
                                    v_scale=vs, backend="xla-ref")
    for t in range(kq):
        one = dispatch.decode_attention(q[:, t], k, v, k_pos, pos + t,
                                        k_scale=ks, v_scale=vs,
                                        backend="xla-ref")
        assert jnp.array_equal(ver[:, t], one), t


# ---------------------------------------------------------------------------
# rejected-suffix rollback: cache bytes + pool state ≡ never-drafted
# ---------------------------------------------------------------------------


def _lockstep_engines(kv_layout, kv_quant=False, max_new=10):
    """A spec engine whose drafts all reject (1 token per window) and a
    plain engine, stepped in lockstep at full batch occupancy.  Full
    occupancy matters for the byte comparison: the plain fused-decode path
    eagerly writes (deterministic, never-read) junk into *dead* ring rows
    each tick, while the verify path's write gate drops dead-row scatters
    entirely — both harmless, but their bytes differ, so the
    byte-identity contract is over rows a request can actually read."""
    plain, _ = _serve(spec=False, kv_layout=kv_layout, kv_quant=kv_quant,
                      requests=2, max_new=max_new)
    streams = {tuple(p): plain[r] for r, p in enumerate(_prompts(2))}
    kw = {}
    if kv_layout == "paged":
        kw = dict(kv_layout="paged", block_size=4)
    engs = []
    for spec in (True, False):
        eng = Engine(PARAMS, CFG, batch=2, max_len=32, kv_quant=kv_quant,
                     spec_decode=spec, draft_k=4,
                     drafter=AntiReplayDrafter(streams) if spec else None,
                     **kw)
        for r, p in enumerate(_prompts(2)):
            eng.submit(Request(rid=r, prompt=p,
                               sampling=SamplingParams(max_new=max_new,
                                                       seed=r,
                                                       counter_offset=1000 * r)))
        engs.append(eng)
    return engs[0], engs[1]


def _readable_paged_bytes(eng):
    """Paged cache bytes a request can actually read: for each slot, the
    rows of its table's blocks at positions below ``_slot_pos``.  Rows at
    or past a slot's position are write targets, not state — plain prefill
    leaves deterministic pad junk in the tail of a partial block, which the
    verify path overwrites with draft K/V and then scrubs back to init — and
    the trash block plus free-list blocks are never read at all.  Pool
    *bookkeeping* (refcounts, free-list order, tables) is still compared
    exactly in the test body.  Leaves without a block axis (``pos``) are
    returned whole."""
    nbp = eng.num_blocks + 1
    bs = eng.block_size
    out = []
    for leaf in jax.tree_util.tree_leaves(
            {k: v for k, v in eng.cache.items() if k != "block_tables"}):
        a = np.asarray(leaf)
        bax = next((i for i, d in enumerate(a.shape) if d == nbp), None)
        if bax is None:
            out.append(a)
            continue
        for slot, req in enumerate(eng.slots):
            if req is None:
                continue
            table = eng.pool._tables[req.rid]
            pos = int(eng._slot_pos[slot])
            for li, phys in enumerate(table):
                rows = min(max(pos - li * bs, 0), bs)
                blk = np.take(a, phys, axis=bax)
                out.append(np.take(blk, range(rows), axis=bax))
    return out


@pytest.mark.parametrize("kv_quant", [False, True], ids=["bf16", "int8"])
def test_rollback_ring_cache_bytes_equal_never_drafted(kv_quant):
    """After every window of an all-reject run, the ring cache is byte-
    identical to the never-drafted engine's at the same position: the
    commit scrub restores rejected draft slots to exact init values (zero
    codes, zero scales, k_pos = -1), and accepted-prefix bytes need no
    touch-up at all (position-purity)."""
    spec_eng, plain_eng = _lockstep_engines("ring", kv_quant=kv_quant)
    for _ in range(14):
        spec_eng.step()
        plain_eng.step()
        assert list(spec_eng._slot_pos) == list(plain_eng._slot_pos)
        a = jax.tree_util.tree_leaves(spec_eng.cache)
        b = jax.tree_util.tree_leaves(plain_eng.cache)
        assert len(a) == len(b)
        for la, lb in zip(a, b):
            assert np.array_equal(np.asarray(la), np.asarray(lb))


def test_rollback_paged_pool_state_equal_never_drafted():
    """Paged all-reject run: at every window the pool's refcounts, free
    list (order included — ``truncate`` exactly reverses ``append_block``),
    block tables and prefix-cache index match the never-drafted engine's,
    and every readable pool row (positions below each slot's ``_slot_pos``,
    through its own table) is byte-identical."""
    spec_eng, plain_eng = _lockstep_engines("paged")
    for _ in range(14):
        spec_eng.step()
        plain_eng.step()
        assert list(spec_eng._slot_pos) == list(plain_eng._slot_pos)
        ps, pp = spec_eng.pool, plain_eng.pool
        assert ps._ref == pp._ref
        assert ps._free == pp._free
        assert list(ps._cached.keys()) == list(pp._cached.keys())
        assert {r: t for r, t in ps._tables.items()} == \
               {r: t for r, t in pp._tables.items()}
        sl = _readable_paged_bytes(spec_eng)
        pl = _readable_paged_bytes(plain_eng)
        assert len(sl) == len(pl)
        for la, lb in zip(sl, pl):
            assert la.shape == lb.shape
            assert np.array_equal(la, lb)


# ---------------------------------------------------------------------------
# guard rails: configs speculation must refuse
# ---------------------------------------------------------------------------


def test_spec_rejects_draft_k_one():
    with pytest.raises(ValueError):
        Engine(PARAMS, CFG, batch=2, max_len=32, spec_decode=True, draft_k=1)


def test_spec_rejects_quant_policy():
    """Policy fake-quant scales are tensor-global (absmax over the whole
    activation), so a (B, K) verify activation quantises differently from a
    (B,) decode activation — not row-pure, so speculation refuses it."""
    from repro.numerics.policy import QuantPolicy
    with pytest.raises(ValueError):
        Engine(PARAMS, CFG, batch=2, max_len=32, spec_decode=True,
               policy=QuantPolicy(scheme="dither"))


def test_spec_rejects_effective_sliding_window():
    """A ring cap below max_len means verify rows would overwrite slots
    earlier rows still attend to — speculation requires the full ring."""
    import dataclasses
    wcfg = dataclasses.replace(CFG, window=8)
    wparams = registry.init_model(jax.random.PRNGKey(0), wcfg)
    with pytest.raises(ValueError):
        Engine(wparams, wcfg, batch=2, max_len=32, spec_decode=True)


def test_spec_rejects_moe():
    """MoE capacity ranks are a cumsum over *all* dispatched tokens, so a
    verify row competes with its own future draft rows — not row-pure."""
    mcfg = get_config("granite_moe_1b_a400m").reduced()
    mparams = registry.init_model(jax.random.PRNGKey(0), mcfg)
    assert not registry.supports_spec_decode(mcfg)
    with pytest.raises(ValueError):
        Engine(mparams, mcfg, batch=2, max_len=32, spec_decode=True)
