"""Per-architecture smoke tests: reduced config, one forward + one train step
+ one decode step on CPU, asserting shapes and no NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.models import registry
from repro.numerics.policy import QuantPolicy
from repro.optim.adamw import AdamW
from repro.train import trainer


def _batch(cfg, b=2, s=16):
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32)}
    if cfg.frontend == "vit_stub":
        batch["embeds"] = jnp.zeros((b, cfg.n_frontend_tokens, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = jnp.zeros((b, cfg.n_enc_tokens, cfg.d_model),
                                    jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_decode(arch):
    cfg = get_config(arch).reduced()
    params = registry.init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits = registry.apply_model(params, cfg, batch, remat=False)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert not bool(jnp.any(jnp.isnan(logits)))

    cache = registry.make_cache(params, cfg, 2, 32, frames=batch.get("frames"))
    lg, cache = registry.apply_decode(params, cfg, jnp.ones((2,), jnp.int32), cache)
    lg2, cache = registry.apply_decode(params, cfg, jnp.ones((2,), jnp.int32), cache)
    assert lg.shape == (2, cfg.vocab_size)
    # per-slot positions (serving slots decode independently, DESIGN.md §6)
    assert cache["pos"].shape == (2,)
    assert [int(p) for p in cache["pos"]] == [2, 2]
    assert not bool(jnp.any(jnp.isnan(lg2)))


@pytest.mark.parametrize("arch", ["smollm_135m", "granite_moe_1b_a400m",
                                  "mamba2_370m", "recurrentgemma_9b",
                                  "whisper_small"])
def test_train_step(arch):
    """One family per kind: dense, MoE, SSM, hybrid, enc-dec."""
    cfg = get_config(arch).reduced()
    state = trainer.init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(trainer.make_train_step(cfg, AdamW(lr=1e-3)))
    batch = synthetic_batch(cfg, DataConfig(batch=2, seq=16), 0)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert jnp.isfinite(m1["loss"]) and jnp.isfinite(m2["loss"])
    assert int(state["counter"]) == 2


def test_train_step_with_dither_policy():
    cfg = get_config("smollm_135m").reduced()
    state = trainer.init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(trainer.make_train_step(
        cfg, AdamW(lr=1e-3), policy=QuantPolicy(scheme="dither", bits=8),
        grad_policy=QuantPolicy(scheme="dither", bits=8)))
    batch = synthetic_batch(cfg, DataConfig(batch=2, seq=16), 0)
    state, m = step(state, batch)
    assert jnp.isfinite(m["loss"])


def test_decode_matches_forward_full_attention():
    """Teacher-forced decode logits ≈ full forward logits (cache correctness)."""
    cfg = get_config("smollm_135m").reduced()
    params = registry.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    full = registry.apply_model(params, cfg, {"tokens": toks}, remat=False)
    cache = registry.make_cache(params, cfg, 1, 16)
    outs = []
    for t in range(8):
        lg, cache = registry.apply_decode(params, cfg, toks[:, t], cache)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    diff = jnp.max(jnp.abs(jax.nn.log_softmax(full) - jax.nn.log_softmax(dec)))
    assert float(diff) < 0.08, float(diff)


def test_param_count_sane():
    """Declared param counts are within 20% of actual initialised params."""
    for arch in ["smollm_135m", "qwen2_1_5b"]:
        cfg = get_config(arch)
        est = cfg.param_count()
        # actual from shapes (eval_shape, no allocation)
        shapes = jax.eval_shape(lambda k: registry.init_model(k, cfg),
                                jax.random.PRNGKey(0))
        actual = sum(int(jnp.prod(jnp.array(l.shape)))
                     for l in jax.tree.leaves(shapes))
        assert 0.8 < est / actual < 1.25, (arch, est, actual)


def test_windowed_ring_decode_matches_forward():
    """Sliding-window ring cache: decode logits ≈ full forward with the same
    window mask (recurrentgemma's local-attention layers)."""
    from repro.configs import get_config as _gc
    cfg = _gc("recurrentgemma_9b").reduced()  # window reduced to 64
    assert cfg.window and cfg.window >= 16
    params = registry.init_model(jax.random.PRNGKey(3), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 12), 0, cfg.vocab_size)
    full = registry.apply_model(params, cfg, {"tokens": toks}, remat=False)
    cache = registry.make_cache(params, cfg, 1, 32)
    outs = []
    for t in range(12):
        lg, cache = registry.apply_decode(params, cfg, toks[:, t], cache)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    diff = jnp.max(jnp.abs(jax.nn.log_softmax(full[:, :, :dec.shape[-1]])
                           - jax.nn.log_softmax(dec)))
    assert float(diff) < 0.1, float(diff)


def test_kv_quant_decode_close_to_full_precision():
    """Dither-quantised int8 KV cache (beyond-paper, DESIGN.md §6): decode
    logits stay close to the bf16-cache decode."""
    cfg = get_config("smollm_135m").reduced()
    params = registry.init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab_size)
    c_ref = registry.make_cache(params, cfg, 2, 16)
    c_q8 = registry.make_cache(params, cfg, 2, 16, kv_quant=True)
    assert c_q8["layers"][0]["k"].dtype == jnp.int8
    diffs = []
    for t in range(10):
        lr, c_ref = registry.apply_decode(params, cfg, toks[:, t], c_ref)
        lq, c_q8 = registry.apply_decode(params, cfg, toks[:, t], c_q8)
        d = jnp.max(jnp.abs(jax.nn.log_softmax(lr) - jax.nn.log_softmax(lq)))
        diffs.append(float(d))
    assert max(diffs) < 0.6, diffs
    assert not any(jnp.isnan(jnp.asarray(diffs)))
