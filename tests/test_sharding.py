"""Sharding rules: every generated spec must evenly divide its dim on the
production mesh, for every assigned architecture (param + cache trees)."""

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.dist import sharding as shd
from repro.models import registry

def _abstract_mesh(shape, names):
    try:
        return AbstractMesh(shape, names)  # jax >= 0.5 signature
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH_MP = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_size(mesh, entry):
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
    return n


def _check(tree_shapes, specs, mesh):
    flat_s, _ = jax.tree_util.tree_flatten(tree_shapes)
    flat_p = jax.tree_util.tree_flatten(specs, is_leaf=lambda x: isinstance(x, P))[0]
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_s, flat_p):
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            size = _axis_size(mesh, entry)
            assert leaf.shape[i] % size == 0, (leaf.shape, spec, i)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["single", "multi"])
def test_param_specs_divide(arch, mesh):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: registry.init_model(k, cfg),
                            jax.random.PRNGKey(0))
    specs = shd.param_specs(shapes, cfg, mesh)
    _check(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "recurrentgemma_9b",
                                  "mamba2_370m", "whisper_small"])
def test_cache_specs_divide(arch):
    cfg = get_config(arch)
    params = jax.eval_shape(lambda k: registry.init_model(k, cfg),
                            jax.random.PRNGKey(0))
    frames = (jax.ShapeDtypeStruct((128, cfg.n_enc_tokens, cfg.d_model),
                                   "bfloat16") if cfg.is_encdec else None)
    cache = jax.eval_shape(
        lambda p, f: registry.make_cache(p, cfg, 128, 32768, frames=f),
        params, frames)
    specs = shd.cache_specs(cache, cfg, MESH)
    _check(cache, specs, MESH)


def test_attention_sharding_respects_head_counts():
    """wq shards only when n_heads % tp == 0; wk/wv only when kv does."""
    cfg = get_config("granite_3_8b")  # 32 q heads (÷16 ✓), 8 kv heads (✗)
    shapes = jax.eval_shape(lambda k: registry.init_model(k, cfg),
                            jax.random.PRNGKey(0))
    specs = shd.param_specs(shapes, cfg, MESH)
    blk = specs["blocks"][0]["attn"]
    assert blk["wq"] == P(None, None, "model")
    assert blk["wk"] == P(None, None, None)   # 8 kv heads can't split 16 ways
    assert blk["wo"] == P(None, "model", None)
