"""Sharding rules: every generated spec must evenly divide its dim on the
production mesh, for every assigned architecture (param + cache trees)."""

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.dist import sharding as shd
from repro.models import registry

def _abstract_mesh(shape, names):
    try:
        return AbstractMesh(shape, names)  # jax >= 0.5 signature
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH_MP = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_size(mesh, entry):
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
    return n


def _check(tree_shapes, specs, mesh):
    flat_s, _ = jax.tree_util.tree_flatten(tree_shapes)
    flat_p = jax.tree_util.tree_flatten(specs, is_leaf=lambda x: isinstance(x, P))[0]
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_s, flat_p):
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            size = _axis_size(mesh, entry)
            assert leaf.shape[i] % size == 0, (leaf.shape, spec, i)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["single", "multi"])
def test_param_specs_divide(arch, mesh):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: registry.init_model(k, cfg),
                            jax.random.PRNGKey(0))
    specs = shd.param_specs(shapes, cfg, mesh)
    _check(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "recurrentgemma_9b",
                                  "mamba2_370m", "whisper_small"])
def test_cache_specs_divide(arch):
    cfg = get_config(arch)
    params = jax.eval_shape(lambda k: registry.init_model(k, cfg),
                            jax.random.PRNGKey(0))
    frames = (jax.ShapeDtypeStruct((128, cfg.n_enc_tokens, cfg.d_model),
                                   "bfloat16") if cfg.is_encdec else None)
    cache = jax.eval_shape(
        lambda p, f: registry.make_cache(p, cfg, 128, 32768, frames=f),
        params, frames)
    specs = shd.cache_specs(cache, cfg, MESH)
    _check(cache, specs, MESH)


def test_attention_sharding_respects_head_counts():
    """wq shards only when n_heads % tp == 0; wk/wv only when kv does."""
    cfg = get_config("granite_3_8b")  # 32 q heads (÷16 ✓), 8 kv heads (✗)
    shapes = jax.eval_shape(lambda k: registry.init_model(k, cfg),
                            jax.random.PRNGKey(0))
    specs = shd.param_specs(shapes, cfg, MESH)
    blk = specs["blocks"][0]["attn"]
    assert blk["wq"] == P(None, None, "model")
    assert blk["wk"] == P(None, None, None)   # 8 kv heads can't split 16 ways
    assert blk["wo"] == P(None, "model", None)


# ---------------------------------------------------------------------------
# sharded serving (DESIGN.md §9): paged cache specs + the serve param subset
# ---------------------------------------------------------------------------

SERVE_MESHES = {
    (1, 1): _abstract_mesh((1, 1), ("data", "model")),
    (2, 1): _abstract_mesh((2, 1), ("data", "model")),
    (1, 2): _abstract_mesh((1, 2), ("data", "model")),
}


def _paged_cache_shapes(cfg, batch=4, max_len=64, bs=8, dp=1, kv_quant=True):
    params = jax.eval_shape(lambda k: registry.init_model(k, cfg),
                            jax.random.PRNGKey(0))
    return jax.eval_shape(
        lambda p: registry.make_cache(p, cfg, batch, max_len,
                                      kv_quant=kv_quant, kv_layout="paged",
                                      block_size=bs, data_shards=dp),
        params)


@pytest.mark.parametrize("mesh_shape", sorted(SERVE_MESHES), ids=str)
def test_cache_specs_paged_divide(mesh_shape):
    """Paged cache specs (pools, block tables, pos) stay legal on every
    serve mesh — block axis on 'data', KV heads on 'model'."""
    cfg = get_config("smollm_135m").reduced()     # 2 KV heads
    dp, tp = mesh_shape
    mesh = SERVE_MESHES[mesh_shape]
    cache = _paged_cache_shapes(cfg, dp=dp)
    specs = shd.cache_specs(cache, cfg, mesh)
    _check(cache, specs, mesh)
    ent = specs["layers"][0]          # stacked entry: leading repeat axis
    assert specs["pos"] == (P("data") if dp > 1 else P(None))
    assert specs["block_tables"] == P("data" if dp > 1 else None, None)
    # repeat axis never shards; the pool-block axis carries 'data'
    assert ent["k"][0] is None
    assert ent["k"][1] == ("data" if dp > 1 else None)
    assert ent["k"][3] == ("model" if tp > 1 else None)
    assert ent["k_scale"][3] == ("model" if tp > 1 else None)


def test_cache_specs_paged_gqa_fallback():
    """n_kv_heads % tp != 0 → the head dim stays replicated (the same
    guard the engine's replicated-TP fallback mirrors)."""
    cfg = get_config("smollm_135m").reduced()
    from dataclasses import replace
    cfg = replace(cfg, n_kv_heads=1)              # MQA: 1 % 2 != 0
    mesh = SERVE_MESHES[(1, 2)]
    cache = _paged_cache_shapes(cfg)
    specs = shd.cache_specs(cache, cfg, mesh)
    _check(cache, specs, mesh)
    ent = specs["layers"][0]
    assert ent["k"][3] is None
    assert ent["v"][3] is None
    assert ent["k_scale"][3] is None
    assert not shd.serve_heads_shardable(cfg, 2)


def test_cache_specs_ring_stack_axis_not_data_sharded():
    """Stacked ring entries carry the scan repeat axis first: the batch
    rule must target axis 1, never the repeat axis (regression — the
    pre-§9 rule sharded axis 0 of stacked entries on 'data')."""
    cfg = get_config("smollm_135m").reduced()
    params = jax.eval_shape(lambda k: registry.init_model(k, cfg),
                            jax.random.PRNGKey(0))
    cache = jax.eval_shape(
        lambda p: registry.make_cache(p, cfg, 4, 64, kv_quant=True), params)
    specs = shd.cache_specs(cache, cfg, SERVE_MESHES[(2, 1)])
    ent = specs["layers"][0]
    assert ent["k"][0] is None and ent["k"][1] == "data"
    assert ent["k_pos"][0] is None and ent["k_pos"][1] == "data"


def test_serve_param_specs_reduction_preserving():
    """Serve params shard only the QKV projections (column-parallel,
    head-guarded); W_O / MLP / embeddings stay replicated so no f32
    contraction is ever split (the bitwise stream-parity contract)."""
    cfg = get_config("smollm_135m").reduced()     # 4 heads / 2 KV heads
    params = jax.eval_shape(lambda k: registry.init_model(k, cfg),
                            jax.random.PRNGKey(0))
    specs = shd.serve_param_specs(params, cfg, SERVE_MESHES[(1, 2)])
    blk = specs["blocks"][0]["attn"]
    assert blk["wq"] == P(None, None, "model")
    assert blk["wk"] == P(None, None, "model")
    assert blk["wo"] == P(None, None, None)       # replicated, all-gathered in
    mlp = specs["blocks"][0]["mlp"]
    assert all(e is None for leaf in mlp.values() for e in leaf)
    assert all(e is None for e in specs["embed"])
    # GQA fallback: nothing shards
    from dataclasses import replace
    mqa = replace(cfg, n_kv_heads=1)
    specs = shd.serve_param_specs(params, mqa, SERVE_MESHES[(1, 2)])
    assert specs["blocks"][0]["attn"]["wq"] == P(None, None, None)
