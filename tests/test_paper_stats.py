"""Property-based statistical pins of the paper's §II claims (Table 1),
matching what benchmarks/repr_emse.py and benchmarks/table1_asymptotics.py
measure:

* dither computing is **unbiased** with EMSE ≤ 2/N² (Θ(1/N²)),
* stochastic computing is unbiased but EMSE = Θ(1/N) — bounded *below*,
  so the 1/N² rate is genuinely dither's improvement, not shared,
* the deterministic variant's EMSE is ~1/(12N²) (bias-dominated).

The checkers are plain functions pinned at fixed seeds (they always run,
hypothesis installed or not); thin ``@given`` wrappers re-run them across
drawn (seed, N) in CI via tests/_hypothesis_compat.py.  Every bound carries
CLT-sized slack (≥6σ) so arbitrary drawn seeds cannot flake."""

import math

import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import HAVE_HYPOTHESIS, given, st

from benchmarks.common import loglog_slope
from repro.core.representations import (decode, deterministic_encode,
                                        dither_encode, stochastic_encode)

TRIALS = 256
# off-lattice x grid: not commensurate with any benchmarked N, so the
# deterministic rounding error and the dither residual δ are both exercised
XS = jnp.linspace(0.013, 0.987, 33)


def _errors(scheme: str, seed: int, n: int):
    """decode(encode(x)) − x over TRIALS iid encodings of the x grid."""
    xt = jnp.broadcast_to(XS, (TRIALS, XS.shape[0]))
    key = jax.random.PRNGKey(seed)
    if scheme == "dither":
        pulses = dither_encode(key, xt, n)
    elif scheme == "stochastic":
        pulses = stochastic_encode(key, xt, n)
    elif scheme == "deterministic":
        pulses = deterministic_encode(xt, n)
    else:
        raise ValueError(scheme)
    return decode(pulses) - xt


def check_dither_unbiased(seed: int, n: int):
    """Paper §II-D: E[X_s] = x.  The empirical bias over TRIALS×|XS|
    samples is CLT-bounded by the variance bound Var ≤ 2/N²: 8σ slack."""
    err = _errors("dither", seed, n)
    bias = float(jnp.mean(err))
    tol = 8.0 * math.sqrt(2.0) / (n * math.sqrt(err.size))
    assert abs(bias) <= tol, (seed, n, bias, tol)


def check_dither_emse_n2_bounded(seed: int, n: int):
    """Paper §II-D / Table 1: EMSE ≤ 2/N², i.e. MSE·N² ≤ 2 in expectation
    (×1.5 sampling slack on ~8k squared-error samples)."""
    err = _errors("dither", seed, n)
    mse_n2 = float(jnp.mean(err ** 2)) * n * n
    assert mse_n2 <= 3.0, (seed, n, mse_n2)


def check_stochastic_emse_n_bounded_below(seed: int, n: int):
    """Paper §II-A / Table 1: stochastic EMSE = x(1−x)/N, whose mean over
    x~U(0,1) is 1/(6N) — so MSE·N concentrates near 1/6 and is bounded
    *below*: stochastic computing cannot reach the 1/N² dither rate."""
    err = _errors("stochastic", seed, n)
    mse_n = float(jnp.mean(err ** 2)) * n
    assert 0.08 <= mse_n <= 0.30, (seed, n, mse_n)


def check_asymptotic_slopes(seed: int):
    """table1_asymptotics.py's headline, as a test: the log-log slope of
    EMSE vs N is ≈ −2 for dither and the deterministic variant, ≈ −1 for
    stochastic (the N² vs N separation that is the paper's point)."""
    ns = [8, 16, 32, 64]
    mses = {s: [float(jnp.mean(_errors(s, seed, n) ** 2)) for n in ns]
            for s in ("dither", "stochastic", "deterministic")}
    assert -2.7 <= loglog_slope(ns, mses["dither"]) <= -1.6
    assert -1.35 <= loglog_slope(ns, mses["stochastic"]) <= -0.7
    assert -2.6 <= loglog_slope(ns, mses["deterministic"]) <= -1.5
    # and at every N the dither EMSE beats stochastic outright
    for d, s in zip(mses["dither"], mses["stochastic"]):
        assert d < s


# -- fixed-seed pins: always run, hypothesis or not -------------------------


@pytest.mark.parametrize("seed,n", [(0, 16), (1, 32), (2, 64)])
def test_dither_unbiased(seed, n):
    check_dither_unbiased(seed, n)


@pytest.mark.parametrize("seed,n", [(0, 16), (1, 32), (2, 64)])
def test_dither_emse_n2_bounded(seed, n):
    check_dither_emse_n2_bounded(seed, n)


@pytest.mark.parametrize("seed,n", [(0, 16), (1, 32), (2, 64)])
def test_stochastic_emse_n_bounded_below(seed, n):
    check_stochastic_emse_n_bounded_below(seed, n)


@pytest.mark.parametrize("seed", [0, 3])
def test_asymptotic_slopes(seed):
    check_asymptotic_slopes(seed)


# -- property layer: drawn (seed, N) in CI ----------------------------------

_SEEDS = st.integers(min_value=0, max_value=2 ** 20)
_NS = st.sampled_from([16, 24, 32, 48, 64])


@given(seed=_SEEDS, n=_NS)
def test_dither_unbiased_property(seed, n):
    check_dither_unbiased(seed, n)


@given(seed=_SEEDS, n=_NS)
def test_dither_emse_n2_bounded_property(seed, n):
    check_dither_emse_n2_bounded(seed, n)


@given(seed=_SEEDS, n=_NS)
def test_stochastic_emse_n_bounded_below_property(seed, n):
    check_stochastic_emse_n_bounded_below(seed, n)


@given(seed=_SEEDS)
def test_asymptotic_slopes_property(seed):
    check_asymptotic_slopes(seed)


def test_property_layer_active_or_skipped():
    """Self-description: when hypothesis is installed the property layer
    really runs (CI installs it via requirements-dev.txt); when absent the
    wrappers above skip rather than silently pass."""
    if HAVE_HYPOTHESIS:
        import hypothesis
        assert hypothesis.settings().max_examples >= 1
    else:
        assert test_dither_unbiased_property.__name__  # shim kept the name
