"""Property-based statistical pins of the paper's §II claims (Table 1),
matching what benchmarks/repr_emse.py and benchmarks/table1_asymptotics.py
measure:

* dither computing is **unbiased** with EMSE ≤ 2/N² (Θ(1/N²)),
* stochastic computing is unbiased but EMSE = Θ(1/N) — bounded *below*,
  so the 1/N² rate is genuinely dither's improvement, not shared,
* the deterministic variant's EMSE is ~1/(12N²) (bias-dominated).

The checkers are plain functions pinned at fixed seeds (they always run,
hypothesis installed or not); thin ``@given`` wrappers re-run them across
drawn (seed, N) in CI via tests/_hypothesis_compat.py.  Every bound carries
CLT-sized slack (≥6σ) so arbitrary drawn seeds cannot flake."""

import math

import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import HAVE_HYPOTHESIS, given, st

from benchmarks.common import loglog_slope
from repro.core.representations import (decode, deterministic_encode,
                                        dither_encode, stochastic_encode)

TRIALS = 256
# off-lattice x grid: not commensurate with any benchmarked N, so the
# deterministic rounding error and the dither residual δ are both exercised
XS = jnp.linspace(0.013, 0.987, 33)


def _errors(scheme: str, seed: int, n: int):
    """decode(encode(x)) − x over TRIALS iid encodings of the x grid."""
    xt = jnp.broadcast_to(XS, (TRIALS, XS.shape[0]))
    key = jax.random.PRNGKey(seed)
    if scheme == "dither":
        pulses = dither_encode(key, xt, n)
    elif scheme == "stochastic":
        pulses = stochastic_encode(key, xt, n)
    elif scheme == "deterministic":
        pulses = deterministic_encode(xt, n)
    else:
        raise ValueError(scheme)
    return decode(pulses) - xt


def check_dither_unbiased(seed: int, n: int):
    """Paper §II-D: E[X_s] = x.  The empirical bias over TRIALS×|XS|
    samples is CLT-bounded by the variance bound Var ≤ 2/N²: 8σ slack."""
    err = _errors("dither", seed, n)
    bias = float(jnp.mean(err))
    tol = 8.0 * math.sqrt(2.0) / (n * math.sqrt(err.size))
    assert abs(bias) <= tol, (seed, n, bias, tol)


def check_dither_emse_n2_bounded(seed: int, n: int):
    """Paper §II-D / Table 1: EMSE ≤ 2/N², i.e. MSE·N² ≤ 2 in expectation
    (×1.5 sampling slack on ~8k squared-error samples)."""
    err = _errors("dither", seed, n)
    mse_n2 = float(jnp.mean(err ** 2)) * n * n
    assert mse_n2 <= 3.0, (seed, n, mse_n2)


def check_stochastic_emse_n_bounded_below(seed: int, n: int):
    """Paper §II-A / Table 1: stochastic EMSE = x(1−x)/N, whose mean over
    x~U(0,1) is 1/(6N) — so MSE·N concentrates near 1/6 and is bounded
    *below*: stochastic computing cannot reach the 1/N² dither rate."""
    err = _errors("stochastic", seed, n)
    mse_n = float(jnp.mean(err ** 2)) * n
    assert 0.08 <= mse_n <= 0.30, (seed, n, mse_n)


def check_asymptotic_slopes(seed: int):
    """table1_asymptotics.py's headline, as a test: the log-log slope of
    EMSE vs N is ≈ −2 for dither and the deterministic variant, ≈ −1 for
    stochastic (the N² vs N separation that is the paper's point)."""
    ns = [8, 16, 32, 64]
    mses = {s: [float(jnp.mean(_errors(s, seed, n) ** 2)) for n in ns]
            for s in ("dither", "stochastic", "deterministic")}
    assert -2.7 <= loglog_slope(ns, mses["dither"]) <= -1.6
    assert -1.35 <= loglog_slope(ns, mses["stochastic"]) <= -0.7
    assert -2.6 <= loglog_slope(ns, mses["deterministic"]) <= -1.5
    # and at every N the dither EMSE beats stochastic outright
    for d, s in zip(mses["dither"], mses["stochastic"]):
        assert d < s


def check_kv_bulk_quantise_equals_sequential(seed: int, k: int):
    """Speculative bulk commit's statistical footing (DESIGN.md §14):
    dither-quantising a length-k span of K/V values in one shot produces
    *bitwise* the int8 codes and scales of k sequential single-position
    quantisations — the codes are a pure function of (value, absolute
    position, element index), never of write width or path."""
    from repro.models.transformer import _kv_elem_idx, _kv_q8
    nkv, hd, pos0 = 2, 16, 37
    key = jax.random.PRNGKey(seed)
    t = jax.random.normal(key, (2, k, nkv, hd), jnp.float32)
    idx = _kv_elem_idx(nkv, hd)
    ctr = (pos0 + jnp.arange(k)).reshape(1, k, 1, 1)
    bulk_c, bulk_s = _kv_q8(t, ctr, idx, seed)
    for j in range(k):
        cj, sj = _kv_q8(t[:, j:j + 1],
                        jnp.full((1, 1, 1, 1), pos0 + j, jnp.int32),
                        idx, seed)
        assert jnp.array_equal(bulk_c[:, j:j + 1], cj), (seed, k, j)
        assert jnp.array_equal(bulk_s[:, j:j + 1], sj), (seed, k, j)


def check_kv_quant_window_unbiased_emse(seed: int, pos0: int):
    """The KV quantiser is the paper's N=16 dither rounder on the int8
    lattice: over any 16 consecutive absolute positions each element's LCG
    permutation visits every slot exactly once, so the windowed average of
    the code residual is unbiased with EMSE ≤ 2/N² (§II-D / §VII) — at any
    window start, which is why a spec window can land anywhere in the
    stream.  Rows carry distinct counter offsets (the per-request
    ``counter_offset`` pattern) so their hash draws are independent."""
    from repro.models.transformer import _kv_elem_idx, _kv_q8
    rows, nkv, hd, N = 8, 2, 16, 16
    key = jax.random.PRNGKey(seed)
    t = jnp.broadcast_to(
        jax.random.normal(key, (rows, 1, nkv, hd), jnp.float32),
        (rows, N, nkv, hd))
    idx = _kv_elem_idx(nkv, hd)
    ctr = (pos0 + 997 * jnp.arange(rows)[:, None] +
           jnp.arange(N)[None, :]).reshape(rows, N, 1, 1)
    codes, scale = _kv_q8(t, ctr, idx, seed)
    scaled = t / scale[..., None] * 127.0 + 128.0
    resid = codes.astype(jnp.float32) + 128.0 - scaled     # lattice units
    avg = jnp.mean(resid, axis=1)                          # N-window average
    bias = float(jnp.mean(avg))
    # per-window σ ≤ √2/N (the §II-D variance bound), 8σ CLT slack over
    # rows·nkv·hd independent windows
    tol = 8.0 * math.sqrt(2.0) / (N * math.sqrt(avg.size))
    assert abs(bias) <= tol, (seed, pos0, bias, tol)
    emse_n2 = float(jnp.mean(avg ** 2)) * N * N
    assert emse_n2 <= 3.0, (seed, pos0, emse_n2)


# -- fixed-seed pins: always run, hypothesis or not -------------------------


@pytest.mark.parametrize("seed,n", [(0, 16), (1, 32), (2, 64)])
def test_dither_unbiased(seed, n):
    check_dither_unbiased(seed, n)


@pytest.mark.parametrize("seed,n", [(0, 16), (1, 32), (2, 64)])
def test_dither_emse_n2_bounded(seed, n):
    check_dither_emse_n2_bounded(seed, n)


@pytest.mark.parametrize("seed,n", [(0, 16), (1, 32), (2, 64)])
def test_stochastic_emse_n_bounded_below(seed, n):
    check_stochastic_emse_n_bounded_below(seed, n)


@pytest.mark.parametrize("seed", [0, 3])
def test_asymptotic_slopes(seed):
    check_asymptotic_slopes(seed)


@pytest.mark.parametrize("seed,k", [(0, 2), (1, 4), (2, 6)])
def test_kv_bulk_quantise_equals_sequential(seed, k):
    check_kv_bulk_quantise_equals_sequential(seed, k)


@pytest.mark.parametrize("seed,pos0", [(0, 0), (1, 5), (2, 1000)])
def test_kv_quant_window_unbiased_emse(seed, pos0):
    check_kv_quant_window_unbiased_emse(seed, pos0)


# -- property layer: drawn (seed, N) in CI ----------------------------------

_SEEDS = st.integers(min_value=0, max_value=2 ** 20)
_NS = st.sampled_from([16, 24, 32, 48, 64])


@given(seed=_SEEDS, n=_NS)
def test_dither_unbiased_property(seed, n):
    check_dither_unbiased(seed, n)


@given(seed=_SEEDS, n=_NS)
def test_dither_emse_n2_bounded_property(seed, n):
    check_dither_emse_n2_bounded(seed, n)


@given(seed=_SEEDS, n=_NS)
def test_stochastic_emse_n_bounded_below_property(seed, n):
    check_stochastic_emse_n_bounded_below(seed, n)


@given(seed=_SEEDS)
def test_asymptotic_slopes_property(seed):
    check_asymptotic_slopes(seed)


@given(seed=_SEEDS, k=st.integers(min_value=2, max_value=8))
def test_kv_bulk_quantise_equals_sequential_property(seed, k):
    check_kv_bulk_quantise_equals_sequential(seed, k)


@given(seed=_SEEDS, pos0=st.integers(min_value=0, max_value=2 ** 16))
def test_kv_quant_window_unbiased_emse_property(seed, pos0):
    check_kv_quant_window_unbiased_emse(seed, pos0)


def test_property_layer_active_or_skipped():
    """Self-description: when hypothesis is installed the property layer
    really runs (CI installs it via requirements-dev.txt); when absent the
    wrappers above skip rather than silently pass."""
    if HAVE_HYPOTHESIS:
        import hypothesis
        assert hypothesis.settings().max_examples >= 1
    else:
        assert test_dither_unbiased_property.__name__  # shim kept the name
