"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype/scheme
sweeps with exact (codes) and tight-allclose (matmul) assertions."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops as kops, ref

SCHEMES = ["deterministic", "stochastic", "dither"]


@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("shape,block", [
    ((32, 64), (32, 64)),
    ((64, 128), (32, 64)),
    ((128, 256), (64, 128)),
])
def test_quantize_kernel_bit_exact(scheme, shape, block):
    x = jax.random.uniform(jax.random.PRNGKey(1), shape, minval=-1, maxval=1)
    codes_k = kops.quantize_2d(x, bits=8, lo=-1, hi=1, scheme=scheme,
                               counter=5, seed=3, n_pulses=16, block=block)
    codes_r = ref.quantize_codes_ref(x, scale=255 / 2, zero=-1, bits=8,
                                     scheme=scheme, counter=5, seed=3, n_pulses=16)
    assert jnp.array_equal(codes_k, codes_r)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quantize_kernel_bits_sweep(bits):
    x = jax.random.uniform(jax.random.PRNGKey(2), (64, 64))
    codes_k = kops.quantize_2d(x, bits=bits, scheme="dither", block=(32, 32))
    codes_r = ref.quantize_codes_ref(
        x, scale=float((1 << bits) - 1), zero=0.0, bits=bits, scheme="dither",
        counter=0, seed=0, n_pulses=16)
    assert jnp.array_equal(codes_k, codes_r)
    assert int(codes_k.max()) <= (1 << bits) - 1


@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("mkn,block", [
    ((32, 64, 48), (32, 32, 32)),
    ((48, 96, 80), (32, 32, 32)),     # M/N padding path
    ((33, 64, 50), (32, 32, 32)),     # ragged everything
])
def test_matmul_kernel_matches_oracle(scheme, mkn, block):
    m, k, n = mkn
    a = jax.random.uniform(jax.random.PRNGKey(3), (m, k))
    b = jax.random.uniform(jax.random.PRNGKey(4), (k, n), minval=-1, maxval=1)
    ck = kops.dither_matmul(a, b, bits=6, scheme=scheme, counter=2, seed=9,
                            a_range=(0., 1.), b_range=(-1., 1.), block=block)
    cr = ref.dither_matmul_ref(a, b, bits=6, scheme=scheme,
                               a_range=(0., 1.), b_range=(-1., 1.),
                               counter=2, seed=9)
    assert float(jnp.max(jnp.abs(ck - cr))) < 1e-4


def test_matmul_kernel_counter_advances_rounding():
    a = jax.random.uniform(jax.random.PRNGKey(5), (32, 32))
    b = jax.random.uniform(jax.random.PRNGKey(6), (32, 32))
    c0 = kops.dither_matmul(a, b, bits=3, scheme="dither", counter=0, block=(32, 32, 32))
    c1 = kops.dither_matmul(a, b, bits=3, scheme="dither", counter=1, block=(32, 32, 32))
    assert float(jnp.max(jnp.abs(c0 - c1))) > 0.0


def test_matmul_kernel_f32_vs_bf16_input():
    a = jax.random.uniform(jax.random.PRNGKey(7), (32, 32)).astype(jnp.bfloat16)
    b = jax.random.uniform(jax.random.PRNGKey(8), (32, 32)).astype(jnp.bfloat16)
    out = kops.dither_matmul(a, b, bits=8, scheme="dither", block=(32, 32, 32))
    assert out.dtype == jnp.float32
    assert not bool(jnp.any(jnp.isnan(out)))
