"""Optional-hypothesis shim.

``requirements-dev.txt`` installs hypothesis, but the tier-1 suite must also
collect (and run its non-property tests) in environments where it is absent.
When hypothesis is missing, ``@given(...)``-decorated tests become skips and
the ``st`` strategy namespace degrades to inert placeholders, so module-level
strategy definitions still evaluate.
"""

import pytest

try:
    from hypothesis import given, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Any ``st.<name>(...)`` call returns an inert placeholder."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        def decorate(_fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pass

            skipped.__name__ = _fn.__name__
            skipped.__doc__ = _fn.__doc__
            return skipped

        return decorate
