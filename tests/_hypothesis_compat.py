"""Optional-hypothesis shim.

``requirements-dev.txt`` installs hypothesis, but the tier-1 suite must also
collect (and run its non-property tests) in environments where it is absent.
When hypothesis is missing, ``@given(...)``-decorated tests become skips and
the ``st`` strategy namespace degrades to inert placeholders, so module-level
strategy definitions still evaluate.
"""

import functools

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def settings(*_args, **_kwargs):
        """No-op ``@settings(...)`` decorator factory."""
        return lambda fn: fn

    class _StrategyStub:
        """Any ``st.<name>(...)`` call returns an inert placeholder."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _StrategyStub()

    def given(*_args, **_kwargs):
        def decorate(_fn):
            # functools.wraps preserves the signature, so stacked
            # @pytest.mark.parametrize decorators still find their argument
            # names at collection; the skip mark is evaluated before fixture
            # resolution, so the strategy-bound parameters are never looked
            # up as fixtures.
            @pytest.mark.skip(reason="hypothesis not installed")
            @functools.wraps(_fn)
            def skipped(*args, **kwargs):
                pass

            return skipped

        return decorate
