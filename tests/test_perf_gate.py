"""Perf gate (benchmarks/perf_gate.py): passes against itself, fails on a
synthetic 30% tok/s regression and on schema mismatch, normalises by the
machine calibration row, and never fails on advisory (latency) metrics."""

import copy
import json
import os

import pytest

from benchmarks.perf_gate import (artifact_kind, compare_artifacts,
                                  gate_directories, main, row_key)

ARTIFACTS_DIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                             "artifacts")


def _serve_artifact(decode_tok_s=1000.0, calib_us=100.0, version=9):
    return {
        "version": version,
        "calibration": {"probe": "matmul_f32_256", "repeats": 5,
                        "best_us": calib_us},
        "results": [{
            "arch": "smollm_135m", "policy": "none", "kernel_backend": None,
            "kv_layout": "ring", "kv_quant": False, "mesh": None,
            "batch": 2, "max_len": 32, "prompt_len": 8, "max_new": 4,
            "requests": 3, "waves": 3, "block_size": None,
            "decode_ticks": 1, "prefill_chunk": None,
            "decode_tok_s": decode_tok_s, "prefill_tok_s": 4 * decode_tok_s,
            "completed": 9, "preemptions": 0, "prefix_hit_rate": 0.0,
            "attn_bytes_per_token": 123456,
            "collective_bytes_per_token": 0,
            "ttft_ms": {"p50": 10.0, "p95": 20.0},
            "itl_ms": {"p50": 5.0, "p95": 9.0},
            "ttft_hist_ms": {"count": 3, "p50": 10.0, "p95": 20.0,
                             "p99": 21.0, "max": 22.0},
            "itl_hist_ms": {"count": 9, "p50": 5.0, "p95": 9.0,
                            "p99": 9.5, "max": 10.0},
            "deadline_expired": 0, "shed": 0, "recoveries": 0,
        }],
    }


def _kernel_artifact(tok_s=5000.0, calib_us=100.0, version=3):
    return {
        "version": version,
        "calibration": {"probe": "matmul_f32_256", "repeats": 5,
                        "best_us": calib_us},
        "results": [
            {"kernel": "decode_attention", "backend": "pallas-interpret",
             "shape": [2, 256, 2, 2, 64], "cap": 256, "block": [64],
             "us": 2 * 1e6 / tok_s, "tok_s": tok_s,
             "bytes_per_token": 99000, "bytes_per_token_einsum": 400000,
             "max_abs_err_vs_ref": 1e-6},
            {"kernel": "quantize", "backend": "pallas-interpret",
             "shape": [256, 256], "bits": 8, "scheme": "dither",
             "block": None, "us": 100.0, "codes_exact_vs_ref": True},
        ],
    }


def _write(dirpath, name, artifact):
    os.makedirs(dirpath, exist_ok=True)
    with open(os.path.join(dirpath, name), "w") as f:
        json.dump(artifact, f)


def _fails(findings):
    return [f for f in findings if f.severity == "fail"]


def _dirs(tmp_path, ref_serve, cand_serve):
    ref, cand = str(tmp_path / "ref"), str(tmp_path / "cand")
    _write(ref, "serve_bench.json", ref_serve)
    _write(cand, "serve_bench.json", cand_serve)
    return ref, cand


# ---------------------------------------------------------------------------


def test_gate_passes_vs_self(tmp_path):
    ref, cand = _dirs(tmp_path, _serve_artifact(), _serve_artifact())
    _write(ref, "kernel_bench.json", _kernel_artifact())
    _write(cand, "kernel_bench.json", _kernel_artifact())
    findings = gate_directories(ref, cand)
    assert not _fails(findings)
    assert main(["--reference", ref, "--candidate", cand]) == 0


def test_gate_fails_on_30pct_tok_s_regression(tmp_path):
    """The gate's contract: decode_tok_s carries a 25% band, so a 30%
    regression on the same machine (same calibration) must fail."""
    ref, cand = _dirs(tmp_path, _serve_artifact(decode_tok_s=1000.0),
                      _serve_artifact(decode_tok_s=700.0))
    findings = gate_directories(ref, cand)
    bad = _fails(findings)
    assert any(f.metric == "decode_tok_s" for f in bad)
    assert main(["--reference", ref, "--candidate", cand]) == 1


def test_gate_passes_inside_tolerance_band(tmp_path):
    ref, cand = _dirs(tmp_path, _serve_artifact(decode_tok_s=1000.0),
                      _serve_artifact(decode_tok_s=900.0))   # -10%: noise
    assert not _fails(gate_directories(ref, cand))


def test_calibration_normalizes_slower_machine(tmp_path):
    """Half the throughput on a machine the calibration probe shows to be
    half as fast is *not* a regression — the same raw 500 tok/s without the
    calibration excuse is."""
    ref, cand = _dirs(
        tmp_path, _serve_artifact(decode_tok_s=1000.0, calib_us=100.0),
        _serve_artifact(decode_tok_s=500.0, calib_us=200.0))
    assert not _fails(gate_directories(ref, cand))

    ref, cand = _dirs(
        tmp_path, _serve_artifact(decode_tok_s=1000.0, calib_us=100.0),
        _serve_artifact(decode_tok_s=500.0, calib_us=100.0))
    assert any(f.metric == "decode_tok_s"
               for f in _fails(gate_directories(ref, cand)))


def test_gate_fails_on_schema_mismatch(tmp_path):
    ref, cand = _dirs(tmp_path, _serve_artifact(),
                      _serve_artifact(version=4))
    bad = _fails(gate_directories(ref, cand))
    assert any(f.metric == "version" for f in bad)
    # a v4 *reference* (stale committed artifact) is equally fatal
    ref, cand = _dirs(tmp_path, _serve_artifact(version=4),
                      _serve_artifact())
    assert any(f.metric == "version"
               for f in _fails(gate_directories(ref, cand)))


def test_advisory_metrics_never_fail(tmp_path):
    """Latency percentiles are advisory: a 10× TTFT blow-up is reported but
    does not gate (CPU smoke percentiles are noise-dominated)."""
    cand = _serve_artifact()
    row = cand["results"][0]
    row["ttft_ms"] = {"p50": 100.0, "p95": 200.0}
    row["itl_ms"] = {"p50": 50.0, "p95": 90.0}
    row["ttft_hist_ms"]["p95"] = 200.0
    ref, cand_dir = _dirs(tmp_path, _serve_artifact(), cand)
    findings = gate_directories(ref, cand_dir)
    assert not _fails(findings)
    assert any(f.severity == "advisory" and f.metric == "ttft_ms.p50"
               for f in findings)


def test_exact_and_bool_metrics_have_no_band(tmp_path):
    cand = _serve_artifact()
    cand["results"][0]["attn_bytes_per_token"] += 8      # analytic drift
    cand["results"][0]["ttft_hist_ms"]["count"] = 2      # lost a request
    ref, cand_dir = _dirs(tmp_path, _serve_artifact(), cand)
    bad = {f.metric for f in _fails(gate_directories(ref, cand_dir))}
    assert {"attn_bytes_per_token", "ttft_hist_ms.count"} <= bad

    k_cand = _kernel_artifact()
    k_cand["results"][1]["codes_exact_vs_ref"] = False   # correctness flip
    ref_d, cand_d = str(tmp_path / "kref"), str(tmp_path / "kcand")
    _write(ref_d, "kernel_bench.json", _kernel_artifact())
    _write(cand_d, "kernel_bench.json", k_cand)
    assert any(f.metric == "codes_exact_vs_ref"
               for f in _fails(gate_directories(ref_d, cand_d)))


def test_fault_counters_gate_exactly(tmp_path):
    """Schema v7: the fault-tolerance counters are exact metrics — the
    bench workload never expires, sheds or restarts, so a single stray
    count on the benchmark path fails the gate."""
    cand = _serve_artifact()
    cand["results"][0]["shed"] = 1
    cand["results"][0]["recoveries"] = 2
    ref, cand_dir = _dirs(tmp_path, _serve_artifact(), cand)
    bad = {f.metric for f in _fails(gate_directories(ref, cand_dir))}
    assert {"shed", "recoveries"} <= bad


def test_lost_row_and_missing_file_fail(tmp_path):
    cand = _serve_artifact()
    cand["results"] = []                                 # coverage lost
    ref, cand_dir = _dirs(tmp_path, _serve_artifact(), cand)
    assert any("coverage" in f.message
               for f in _fails(gate_directories(ref, cand_dir)))

    os.remove(os.path.join(cand_dir, "serve_bench.json"))
    assert any("candidate artifact missing" in f.message
               for f in _fails(gate_directories(ref, cand_dir)))


def test_new_candidate_rows_are_info_not_fail(tmp_path):
    cand = _serve_artifact()
    extra = copy.deepcopy(cand["results"][0])
    extra["policy"] = "dither"
    cand["results"].append(extra)
    ref, cand_dir = _dirs(tmp_path, _serve_artifact(), cand)
    findings = gate_directories(ref, cand_dir)
    assert not _fails(findings)
    assert any("new candidate row" in f.message for f in findings)


def test_tick_sweep_rows_gate_speedup_and_identity(tmp_path):
    """Schema v6: decode_ticks/prefill_chunk are identity keys — a 4-tick
    row never matches a 1-tick row — and the fused-window speedup ratio
    ``tick_speedup_vs_1`` is a gated (non-advisory) metric."""
    def with_sweep(speedup):
        art = _serve_artifact()
        row = copy.deepcopy(art["results"][0])
        row.update(workload="tick_sweep", decode_ticks=4, prefill_chunk=4,
                   tick_speedup_vs_1=speedup)
        art["results"].append(row)
        return art

    a = with_sweep(1.5)["results"][1]
    assert row_key("serve", a) != row_key("serve", _serve_artifact()["results"][0])

    ref, cand = _dirs(tmp_path, with_sweep(1.5), with_sweep(1.45))
    assert not _fails(gate_directories(ref, cand))      # inside the band
    ref, cand = _dirs(tmp_path, with_sweep(1.5), with_sweep(1.0))
    assert any(f.metric == "tick_speedup_vs_1"
               for f in _fails(gate_directories(ref, cand)))


def test_trace_overhead_gates_against_absolute_ceiling(tmp_path):
    """Schema v8: ``trace_overhead_pct`` uses the reference-independent
    ceiling mode — 2.5% fails even when the reference also reads 2.5%
    (no drift ratchet), and the bitwise/span-count pins are frozen."""
    def with_trace(pct, bitwise=True, spans=63):
        art = _serve_artifact()
        row = copy.deepcopy(art["results"][0])
        row.update(workload="trace_overhead", decode_ticks=4, prefill_chunk=4,
                   max_new=16, trace_overhead_pct=pct,
                   decode_tok_s_untraced=1000.0,
                   streams_bitwise_equal=bitwise, trace_phase_spans=spans)
        art["results"].append(row)
        return art

    ref, cand = _dirs(tmp_path, with_trace(0.0), with_trace(1.9))
    assert not _fails(gate_directories(ref, cand))       # under the ceiling

    ref, cand = _dirs(tmp_path, with_trace(2.5), with_trace(2.5))
    assert any(f.metric == "trace_overhead_pct"          # ceiling is absolute:
               for f in _fails(gate_directories(ref, cand)))  # ref ≡ cand still fails

    ref, cand = _dirs(tmp_path, with_trace(0.0),
                      with_trace(0.0, bitwise=False, spans=60))
    bad = {f.metric for f in _fails(gate_directories(ref, cand))}
    assert {"streams_bitwise_equal", "trace_phase_spans"} <= bad


def test_spec_decode_rows_gate_against_absolute_floor(tmp_path):
    """Schema v9: ``spec_speedup_vs_plain`` uses the reference-independent
    floor mode (the ceiling's dual) — 1.4× fails even when the reference
    also reads 1.4× (no drift erosion), draft_k is an identity key, and
    the accept counters plus the bitwise pin are frozen."""
    def with_spec(speedup, bitwise=True, accepted=33):
        art = _serve_artifact()
        row = copy.deepcopy(art["results"][0])
        row.update(workload="spec_decode", draft_k=4, max_new=16,
                   spec_speedup_vs_plain=speedup,
                   decode_tok_s_plain=1000.0,
                   streams_bitwise_equal=bitwise,
                   spec_windows=8, spec_draft_tokens=33,
                   spec_accepted_tokens=accepted, spec_emitted_tokens=45,
                   spec_accept_rate=accepted / 33.0,
                   spec_accept_rate_prompt_lookup=0.01)
        art["results"].append(row)
        return art

    a = with_spec(2.6)["results"][1]
    assert row_key("serve", a) != row_key("serve", dict(a, draft_k=8))

    ref, cand = _dirs(tmp_path, with_spec(2.6), with_spec(2.2))
    assert not _fails(gate_directories(ref, cand))       # band + above floor

    ref, cand = _dirs(tmp_path, with_spec(1.4), with_spec(1.4))
    assert any(f.metric == "spec_speedup_vs_plain"       # floor is absolute:
               for f in _fails(gate_directories(ref, cand)))  # ref ≡ cand still fails

    ref, cand = _dirs(tmp_path, with_spec(2.6),
                      with_spec(2.6, bitwise=False, accepted=30))
    bad = {f.metric for f in _fails(gate_directories(ref, cand))}
    assert {"streams_bitwise_equal", "spec_accepted_tokens",
            "spec_accept_rate"} <= bad


def test_row_key_and_kind_mapping():
    assert artifact_kind("kernel_bench.json") == "kernel"
    assert artifact_kind("serve_bench_paged.json") == "serve"
    with pytest.raises(ValueError):
        artifact_kind("roofline.json")
    a = _serve_artifact()["results"][0]
    b = dict(a, decode_tok_s=1.0)                        # metrics ≠ identity
    assert row_key("serve", a) == row_key("serve", b)
    assert row_key("serve", a) != row_key("serve", dict(a, policy="dither"))


def test_committed_artifacts_gate_green_vs_themselves():
    """The acceptance criterion 'gate green against the committed
    artifacts': every committed artifact must parse at the expected schema
    version and pass the gate when compared with itself."""
    names = sorted(f for f in os.listdir(ARTIFACTS_DIR)
                   if f.startswith(("kernel_bench", "serve_bench")))
    assert {"kernel_bench.json", "serve_bench.json", "serve_bench_paged.json",
            "serve_bench_mesh.json"} <= set(names)
    findings = gate_directories(ARTIFACTS_DIR, ARTIFACTS_DIR, files=names)
    assert not _fails(findings)
