"""§III multiplication and §IV scaled-addition behaviour per scheme."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import ops


@pytest.mark.parametrize("scheme", ["stochastic", "deterministic", "dither"])
def test_multiply_converges(scheme):
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(jax.random.PRNGKey(1), (500,))
    y = jax.random.uniform(jax.random.PRNGKey(2), (500,))
    outs = [ops.multiply_estimate(jax.random.fold_in(key, t), x, y, 128, scheme)
            for t in range(1 if scheme == "deterministic" else 10)]
    e = jnp.stack(outs)
    emse = float(jnp.mean((e - x * y) ** 2))
    assert emse < 5e-3, emse


@pytest.mark.parametrize("scheme", ["stochastic", "deterministic", "dither"])
def test_scaled_add_converges(scheme):
    key = jax.random.PRNGKey(3)
    x = jax.random.uniform(jax.random.PRNGKey(4), (500,))
    y = jax.random.uniform(jax.random.PRNGKey(5), (500,))
    outs = [ops.scaled_add_pulses(jax.random.fold_in(key, t), x, y, 128, scheme)
            for t in range(1 if scheme == "deterministic" else 10)]
    e = jnp.stack(outs)
    emse = float(jnp.mean((e - (x + y) / 2) ** 2))
    assert emse < 5e-3, emse


def test_orderings_match_table1():
    """dither EMSE ≪ stochastic EMSE; dither |bias| ≪ deterministic |bias|."""
    key = jax.random.PRNGKey(6)
    x = jax.random.uniform(jax.random.PRNGKey(7), (800,))
    y = jax.random.uniform(jax.random.PRNGKey(8), (800,))
    n = 64
    res = {}
    for scheme in ["stochastic", "deterministic", "dither"]:
        outs = [ops.multiply_estimate(jax.random.fold_in(key, t), x, y, n, scheme)
                for t in range(1 if scheme == "deterministic" else 20)]
        e = jnp.stack(outs)
        res[scheme] = (float(jnp.mean((e - x * y) ** 2)),
                       float(jnp.abs(jnp.mean(e - x * y))))
    assert res["dither"][0] < res["stochastic"][0] / 3
    assert res["dither"][1] < res["deterministic"][1] / 3


def test_control_sequence_properties():
    w = ops.control_sequence(jax.random.PRNGKey(0), (2000,), 64, "dither")
    # each sequence is one of the two alternating phases
    alt = jnp.abs(jnp.diff(w, axis=-1)).min()
    assert float(alt) == 1.0
    assert abs(float(w.mean()) - 0.5) < 0.05
