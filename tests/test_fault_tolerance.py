"""Fault tolerance: injected crashes + restart-from-checkpoint completes
training with the same final state as an uninterrupted run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.dist.fault_tolerance import (FailureInjector, StragglerWatchdog,
                                        run_with_restarts)
from repro.launch.train import run_training


def test_straggler_watchdog():
    w = StragglerWatchdog(threshold=3.0)
    for i in range(10):
        w.observe(i, 0.1)
    assert w.observe(10, 1.0) is True
    assert 10 in w.flagged
    assert w.observe(11, 0.11) is False


def test_injected_crash_restarts_and_completes(tmp_path):
    cfg = get_config("smollm_135m").reduced()
    injector = FailureInjector(crash_at={7: "before_save"})
    calls = []

    def loop(restart_idx):
        calls.append(restart_idx)
        steps, losses = run_training(
            cfg, steps=12, batch=2, seq=16, ckpt_dir=str(tmp_path),
            ckpt_every=5, injector=injector, log=lambda *a: None)
        return steps

    final = run_with_restarts(loop)
    assert final == 12
    assert len(calls) == 2  # crashed once, resumed once
    # resumed run must restart from step 5's checkpoint
    from repro.checkpoint.checkpointer import Checkpointer
    assert Checkpointer(str(tmp_path)).latest_step() == 12


def test_resume_equals_uninterrupted(tmp_path):
    """Checkpoint/restart reproduces the uninterrupted loss trajectory
    (deterministic data from step index + exact state restore)."""
    cfg = get_config("smollm_135m").reduced()
    _, losses_ref = run_training(cfg, steps=8, batch=2, seq=16,
                                 ckpt_dir=None, log=lambda *a: None)
    d1 = tmp_path / "a"
    _, l1 = run_training(cfg, steps=4, batch=2, seq=16, ckpt_dir=str(d1),
                         ckpt_every=4, log=lambda *a: None)
    _, l2 = run_training(cfg, steps=8, batch=2, seq=16, ckpt_dir=str(d1),
                         ckpt_every=4, log=lambda *a: None)
    # l2 resumed from step 4 — its first losses continue the trajectory
    np.testing.assert_allclose(losses_ref[4:], l2, rtol=2e-2)


def test_max_restarts_enforced():
    injector = FailureInjector(crash_at={i: "before_save" for i in range(99)})

    def loop(_):
        injector.fired.clear()
        injector.maybe_fail(0, "before_save")
        return 0

    with pytest.raises(Exception):
        run_with_restarts(loop, max_restarts=2)
