import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # Property-based tests skip themselves via tests/_hypothesis_compat.py;
    # everything else must still collect and run (requirements-dev.txt
    # installs hypothesis for the full suite).
    pass
else:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
