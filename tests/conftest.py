import os
import sys

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only launch/dryrun.py forces 512 host devices.
_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(_ROOT, "src"))
if _ROOT not in sys.path:        # the `benchmarks` package (perf-gate tests)
    sys.path.insert(0, _ROOT)


def assert_argmax_margin(logits, axis=-1, min_margin=1e-4, context=""):
    """Assert greedy argmax over ``logits`` is decided by a real gap, not a
    float coin-flip.  Tests that pin "engine output == token-by-token
    reference" implicitly assume the top-1 logit isn't in a near-tie with
    the runner-up — otherwise a benign kernel reassociation could flip the
    argmax and the parity test would report a correctness bug that isn't
    one.  This makes that assumption explicit: it fails (loudly, with the
    gap) when a fixture drifts into a tie, telling the author to reseed the
    test rather than chase a phantom numerics regression."""
    import numpy as np

    arr = np.asarray(logits, dtype=np.float64)
    arr = np.moveaxis(arr, axis, -1).reshape(-1, arr.shape[axis])
    top2 = np.sort(arr, axis=-1)[:, -2:]
    margin = float(np.min(top2[:, 1] - top2[:, 0]))
    assert margin >= min_margin, (
        f"near-tied argmax (margin {margin:.3e} < {min_margin:.0e})"
        f"{' in ' + context if context else ''}: greedy parity checks on "
        f"these logits are numerically fragile — reseed the fixture")

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # Property-based tests skip themselves via tests/_hypothesis_compat.py;
    # everything else must still collect and run (requirements-dev.txt
    # installs hypothesis for the full suite).
    pass
else:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
