"""Paged flash-decode parity + paged-engine equivalence (PR 4, DESIGN.md §6).

Three contracts:

1. **Backend parity** — ``dispatch.paged_decode_attention`` on
   ``pallas-interpret`` is bit-identical to the ``xla-ref`` oracle for
   every kv_quant × window × GQA-group configuration.
2. **Layout parity** — for the same token stream, the paged pool (blocks
   scattered anywhere, reached through the block table) produces output
   bit-identical to the dense ring path run with the same cache tile
   (bs == bk): the recurrence is step-for-step the same, which is the
   bit-reusability property that makes prefix blocks shareable.
3. **Engine equivalence** — the paged engine (continuous batching, block
   allocation, paged prefill) emits exactly the ring engine's tokens, and
   a prefix-cache hit produces the same logits/tokens as a cold prefill of
   the full prompt.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import dispatch
from repro.models import registry
from repro.serve import Engine, Request, SamplingParams

CFG = get_config("smollm_135m").reduced()
PARAMS = registry.init_model(jax.random.PRNGKey(0), CFG)


def _dual_layout_inputs(seed, *, b=3, bs=16, max_len=64, nkv=2, group=2,
                        hd=32, quantized=True, pos_vals=(5, 40, 63)):
    """One token stream materialised in BOTH cache layouts: the dense ring
    (k_pos-tracked) and the paged pool (blocks permuted through a block
    table, plus a trash block holding poison)."""
    rng = np.random.default_rng(seed)
    nbmax = max_len // bs
    num_blocks = b * nbmax
    q = jnp.asarray(rng.normal(size=(b, nkv, group, hd)), jnp.bfloat16)
    pos = jnp.asarray(pos_vals[:b], jnp.int32)

    if quantized:
        def draw_kv():
            return rng.integers(-127, 128, size=(2, nkv, hd))
        kdt, sdt = np.int8, np.float32
    else:
        def draw_kv():
            return rng.normal(size=(2, nkv, hd))
        kdt, sdt = np.float32, np.float32

    ring = {n: np.zeros((b, max_len, nkv, hd), kdt) for n in ("k", "v")}
    ring_s = {n: np.zeros((b, max_len, nkv), sdt) for n in ("ks", "vs")}
    kpos = np.full((b, max_len), -1, np.int64)
    pool = {n: np.zeros((num_blocks + 1, bs, nkv, hd), kdt) for n in ("k", "v")}
    pool_s = {n: np.zeros((num_blocks + 1, bs, nkv), sdt) for n in ("ks", "vs")}
    # poison the trash block: it must never be read (unallocated entries)
    for n in ("k", "v"):
        pool[n][num_blocks] = 111 if quantized else 1e4
    bt = np.full((b, nbmax), num_blocks, np.int32)
    perm = rng.permutation(num_blocks)
    nalloc = 0
    for i in range(b):
        for p in range(int(pos_vals[i]) + 1):
            kv = draw_kv()
            sc = rng.uniform(0.1, 2.0, size=(2, nkv))
            ring["k"][i, p], ring["v"][i, p] = kv
            ring_s["ks"][i, p], ring_s["vs"][i, p] = sc
            kpos[i, p] = p
            j, t = p // bs, p % bs
            if t == 0:
                bt[i, j] = perm[nalloc]
                nalloc += 1
            phys = bt[i, j]
            pool["k"][phys, t], pool["v"][phys, t] = kv
            pool_s["ks"][phys, t], pool_s["vs"][phys, t] = sc

    cast = jnp.int8 if quantized else jnp.bfloat16
    out = dict(
        q=q, pos=pos,
        ring_k=jnp.asarray(ring["k"], cast), ring_v=jnp.asarray(ring["v"], cast),
        k_pos=jnp.asarray(kpos, jnp.int32),
        pool_k=jnp.asarray(pool["k"], cast), pool_v=jnp.asarray(pool["v"], cast),
        bt=jnp.asarray(bt),
    )
    if quantized:
        out.update(ring_ks=jnp.asarray(ring_s["ks"]),
                   ring_vs=jnp.asarray(ring_s["vs"]),
                   pool_ks=jnp.asarray(pool_s["ks"]),
                   pool_vs=jnp.asarray(pool_s["vs"]))
    else:
        out.update(ring_ks=None, ring_vs=None, pool_ks=None, pool_vs=None)
    return out


# ---------------------------------------------------------------------------
# 1+2: backend parity and layout parity, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quantized", [False, True], ids=["bf16", "int8"])
@pytest.mark.parametrize("window", [0, 16], ids=["full", "window16"])
@pytest.mark.parametrize("group", [1, 2, 4])
def test_paged_interpret_bit_identical_to_xla_ref(quantized, window, group):
    """group ≥ 2 (GQA) is asserted bitwise.  group == 1 degenerates the
    per-block dots to single-row (GEMV-shaped) contractions, where XLA's
    CPU lowering may associate the f32 accumulation differently from the
    interpret-mode GEMM — a ≲ 4e-8 deviation the *ring* kernel shares on
    the same data (its PR-3 suite just never drew inputs exposing it), so
    group == 1 pins allclose-at-ulp here while the ring↔paged layout
    parity below stays exact per backend."""
    d = _dual_layout_inputs(group, group=group, quantized=quantized)
    out_i = dispatch.paged_decode_attention(
        d["q"], d["pool_k"], d["pool_v"], d["bt"], d["pos"],
        k_scale=d["pool_ks"], v_scale=d["pool_vs"], window=window,
        backend="pallas-interpret")
    out_r = dispatch.paged_decode_attention(
        d["q"], d["pool_k"], d["pool_v"], d["bt"], d["pos"],
        k_scale=d["pool_ks"], v_scale=d["pool_vs"], window=window,
        backend="xla-ref")
    assert out_i.dtype == jnp.float32
    if group == 1:
        np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_r),
                                   rtol=1e-6, atol=1e-7)
    else:
        assert jnp.array_equal(out_i, out_r), (quantized, window, group)


@pytest.mark.parametrize("quantized", [False, True], ids=["bf16", "int8"])
@pytest.mark.parametrize("window", [0, 16], ids=["full", "window16"])
def test_paged_bit_identical_to_ring_same_stream(quantized, window):
    """Acceptance: for the same token stream, paged output == ring output
    *bitwise* when the ring runs the pool's block size as its cache tile —
    the recurrences are step-for-step identical, so where a block lives
    (contiguous ring slot vs permuted pool block) cannot matter.  The
    paged trash block is poisoned, so this also proves unallocated table
    entries never leak in."""
    bs = 16
    d = _dual_layout_inputs(7, bs=bs, quantized=quantized)
    for backend in ("xla-ref", "pallas-interpret"):
        ring = dispatch.decode_attention(
            d["q"], d["ring_k"], d["ring_v"], d["k_pos"], d["pos"],
            k_scale=d["ring_ks"], v_scale=d["ring_vs"], window=window,
            block=(bs,), backend=backend)
        paged = dispatch.paged_decode_attention(
            d["q"], d["pool_k"], d["pool_v"], d["bt"], d["pos"],
            k_scale=d["pool_ks"], v_scale=d["pool_vs"], window=window,
            backend=backend)
        assert jnp.array_equal(ring, paged), (quantized, window, backend)


def test_paged_gqa_and_single_block_edge():
    """MQA-style group=4 with a cache exactly one block long (bs == max_len)
    — the recurrence degenerates to a single masked softmax pass."""
    d = _dual_layout_inputs(3, b=2, bs=32, max_len=32, group=4,
                            quantized=True, pos_vals=(0, 31))
    out_i = dispatch.paged_decode_attention(
        d["q"], d["pool_k"], d["pool_v"], d["bt"], d["pos"],
        k_scale=d["pool_ks"], v_scale=d["pool_vs"],
        backend="pallas-interpret")
    out_r = dispatch.paged_decode_attention(
        d["q"], d["pool_k"], d["pool_v"], d["bt"], d["pos"],
        k_scale=d["pool_ks"], v_scale=d["pool_vs"], backend="xla-ref")
    assert jnp.array_equal(out_i, out_r)
    assert not bool(jnp.any(jnp.isnan(out_r)))


# ---------------------------------------------------------------------------
# 3: engine equivalence (cold, prefix-hit, preemption-resume)
# ---------------------------------------------------------------------------


def _prompts(seed, n, length):
    key = jax.random.PRNGKey(seed)
    return np.asarray(
        jax.random.randint(key, (n, length), 1, CFG.vocab_size)).tolist()


def _run_engine(prompts, max_new, *, kv_layout="ring", max_len=32, **kw):
    eng = Engine(PARAMS, CFG, batch=len(prompts), max_len=max_len,
                 kv_layout=kv_layout, **kw)
    for r, p in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=list(p), max_new=max_new))
    done = sorted(eng.run(60 + 4 * max_new), key=lambda r: r.rid)
    return eng, [r.out for r in done]


@pytest.mark.parametrize("kv_quant", [False, True], ids=["bf16", "int8"])
def test_paged_engine_matches_ring_engine(kv_quant):
    """Cold paged serving (block-aligned scatter, paged prefill, paged
    flash-decode) emits exactly the ring engine's greedy tokens.  The ring
    cap equals the pool block size here, so both layouts run the identical
    single-block recurrence and the streams must match token for token."""
    prompts = _prompts(0, 2, 5)
    _, ring = _run_engine(prompts, 6, kv_layout="ring", kv_quant=kv_quant)
    _, paged = _run_engine(prompts, 6, kv_layout="paged", block_size=32,
                           kv_quant=kv_quant)
    assert paged == ring


@pytest.mark.parametrize("kv_quant", [False, True], ids=["bf16", "int8"])
def test_prefix_hit_matches_cold_prefill(kv_quant):
    """Acceptance: a prefix-cache hit produces the same tokens as a cold
    prefill of the full prompt — the shared blocks hold exactly the codes
    a cold prefill would write (counter = absolute position), and the
    suffix attends them through the pool gather."""
    shared = _prompts(11, 1, 8)[0]                # 2 full blocks at bs=4

    def serve(prefix_cache):
        eng = Engine(PARAMS, CFG, batch=2, max_len=32, kv_layout="paged",
                     block_size=4, kv_quant=kv_quant,
                     prefix_cache=prefix_cache)
        for r in range(4):
            eng.submit(Request(rid=r, prompt=shared + [10 + r, 30 + r],
                               max_new=5))
        done = sorted(eng.run(100), key=lambda r: r.rid)
        return eng, [r.out for r in done]

    hit_eng, hit = serve(True)
    cold_eng, cold = serve(False)
    assert hit == cold
    assert hit_eng.stats["prefix_hit_tokens"] > 0
    assert cold_eng.stats["prefix_hit_tokens"] == 0


def test_paged_preemption_resumes_not_reprefills():
    """A starved pool (fewer blocks than the active set needs) preempts a
    request back through the scheduler with its blocks intact; the resumed
    stream equals unconstrained serial execution — nothing re-prefilled,
    nothing lost (the PR-4 replacement for the ring 'preempted' finish)."""
    prompts = [[1 + r, 2, 3] for r in range(3)]
    eng = Engine(PARAMS, CFG, batch=2, max_len=32, kv_layout="paged",
                 block_size=4, num_blocks=5, prefix_cache=False)
    for r, p in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=list(p), max_new=8))
    done = sorted(eng.run(200), key=lambda r: r.rid)
    assert eng.stats["preemptions"] >= 1
    assert [r.finish_reason for r in done] == ["length"] * 3
    # serial ring reference: one slot, plenty of cache
    ref = Engine(PARAMS, CFG, batch=1, max_len=32)
    for r, p in enumerate(prompts):
        ref.submit(Request(rid=r, prompt=list(p), max_new=8))
    ref_done = sorted(ref.run(200), key=lambda r: r.rid)
    assert [r.out for r in done] == [r.out for r in ref_done]


def test_paged_deadlock_breaks_via_reprefill():
    """Every block held by preempted queued requests and nothing active:
    the engine flips victims to re-prefill mode and completes the whole
    wave.  Output lengths and finish reasons are exact; token values after
    a re-prefill resume are only rounding-equal to the uninterrupted run
    (deeper-layer KV re-enters through the batched prefill — the
    prefill≡decode divergence pinned since PR 2), so this pins liveness +
    budget, not the stream."""
    eng = Engine(PARAMS, CFG, batch=2, max_len=16, kv_layout="paged",
                 block_size=4, num_blocks=3, prefix_cache=False)
    for r in range(2):
        eng.submit(Request(rid=r, prompt=[1 + r, 2, 3], max_new=10))
    done = sorted(eng.run(300), key=lambda r: r.rid)
    assert [(r.rid, len(r.out), r.finish_reason) for r in done] == \
        [(0, 10, "length"), (1, 10, "length")]
    assert eng.stats["preemptions"] >= 2
    assert eng.pool.live_blocks == 0


def test_paged_pool_capacity_below_dense_ring():
    """The headline memory property: a pool sized well under
    batch × max_len serves a full wave whose *live* token demand fits,
    where the dense ring would have needed cap × slots up front."""
    eng = Engine(PARAMS, CFG, batch=4, max_len=64, kv_layout="paged",
                 block_size=8, num_blocks=12)   # 96 slots vs 256 dense
    for r in range(4):
        eng.submit(Request(rid=r, prompt=[1 + r, 2, 3, 4], max_new=6))
    done = sorted(eng.run(120), key=lambda r: r.rid)
    assert len(done) == 4
    assert all(len(r.out) == 6 for r in done)
    assert eng.pool.live_blocks == 0            # all released on finish


def test_paged_restart_determinism():
    """Replaying the same submissions on a fresh paged engine reproduces
    every token — counters are position-keyed, block placement is
    irrelevant to the math."""
    def run():
        eng = Engine(PARAMS, CFG, batch=2, max_len=32, kv_layout="paged",
                     block_size=4, kv_quant=True)
        for r in range(4):
            eng.submit(Request(
                rid=r, prompt=[1 + r, 2, 3, 4, 5],
                sampling=SamplingParams(temperature=0.8, top_k=16, seed=r,
                                        max_new=5, counter_offset=100 * r)))
        return [(r.rid, tuple(r.out), r.finish_reason)
                for r in sorted(eng.run(80), key=lambda r: r.rid)]

    assert run() == run()


def test_paged_rejects_unservable_requests():
    eng = Engine(PARAMS, CFG, batch=1, max_len=8, kv_layout="paged",
                 block_size=4, num_blocks=1)
    eng.submit(Request(rid=0, prompt=list(range(1, 20)), max_new=4))
    eng.submit(Request(rid=1, prompt=[1, 2, 3, 4, 5], max_new=4))  # > 1 block
    done = sorted(eng.run(20), key=lambda r: r.rid)
    assert [r.finish_reason for r in done] == ["rejected", "rejected"]
