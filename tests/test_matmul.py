"""§VII–§VIII quantised matmul variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.matmul import matmul_error, quantized_matmul

VARIANTS = ["per_partial", "round_a_once", "separate"]
SCHEMES = ["deterministic", "stochastic", "dither"]


@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("scheme", SCHEMES)
def test_high_bits_near_exact(variant, scheme):
    a = jax.random.uniform(jax.random.PRNGKey(0), (24, 32))
    b = jax.random.uniform(jax.random.PRNGKey(1), (32, 20))
    c = quantized_matmul(a, b, bits=12, scheme=scheme, variant=variant)
    assert float(matmul_error(a, b, c)) < 0.05


@pytest.mark.parametrize("variant", VARIANTS)
def test_signed_range_correction(variant):
    """The affine-zero cross terms must reconstruct exactly for lo ≠ 0."""
    a = jax.random.uniform(jax.random.PRNGKey(2), (16, 24), minval=-1, maxval=1)
    b = jax.random.uniform(jax.random.PRNGKey(3), (24, 12), minval=-1, maxval=1)
    c = quantized_matmul(a, b, bits=12, scheme="deterministic", variant=variant,
                         lo=-1.0, hi=1.0)
    assert float(jnp.max(jnp.abs(c - a @ b))) < 0.05


def test_dither_unbiased_per_partial():
    """E[Ĉ] = C for dither rounding (averaging over seeds)."""
    a = jax.random.uniform(jax.random.PRNGKey(4), (12, 60))
    b = jax.random.uniform(jax.random.PRNGKey(5), (60, 12))
    cs = jnp.stack([
        quantized_matmul(a, b, bits=2, scheme="dither", variant="per_partial",
                         seed=s)
        for s in range(40)
    ])
    # mean |bias| across output cells (max is noise-dominated at 40 seeds);
    # deterministic rounding's systematic bias at k=2 is ~10× larger.
    bias = float(jnp.mean(jnp.abs(cs.mean(0) - a @ b)))
    det = quantized_matmul(a, b, bits=2, scheme="deterministic",
                           variant="per_partial")
    det_bias = float(jnp.mean(jnp.abs(det - a @ b)))
    # 40-seed noise floor ≈ 0.13 of the 0.248 measured; det is systematic.
    assert bias < 0.3, bias
    assert bias < det_bias * 0.75, (bias, det_bias)


def test_dither_beats_deterministic_narrow_range():
    """Paper Fig 8 regime: entries in [0, 0.5), small k."""
    rs = np.random.RandomState(0)
    a = jnp.asarray(rs.rand(50, 50).astype(np.float32) * 0.5)
    b = jnp.asarray(rs.rand(50, 50).astype(np.float32) * 0.5)
    e = {}
    for scheme in SCHEMES:
        c = quantized_matmul(a, b, bits=1, scheme=scheme, variant="per_partial")
        e[scheme] = float(matmul_error(a, b, c))
    assert e["dither"] < e["deterministic"]
    assert e["stochastic"] < e["deterministic"]


def test_variant_rounding_counts_note():
    """separate == deterministic single-rounding for deterministic scheme."""
    a = jax.random.uniform(jax.random.PRNGKey(6), (8, 8))
    b = jax.random.uniform(jax.random.PRNGKey(7), (8, 8))
    c1 = quantized_matmul(a, b, bits=4, scheme="deterministic", variant="separate")
    c2 = quantized_matmul(a, b, bits=4, scheme="deterministic", variant="per_partial")
    # deterministic rounding is use-independent → variants agree exactly
    assert float(jnp.max(jnp.abs(c1 - c2))) < 1e-5
