"""Decode-attention kernel parity + single-dispatch engine tick (PR 3).

The dispatch contract for ``decode_attention`` is the split-K online-softmax
recurrence over cache-length blocks (kernels/ref.decode_attention_ref): the
``pallas-interpret`` kernel must be **bit-identical** to the ``xla-ref``
oracle for the same block across kv_quant on/off × sliding window on/off ×
GQA group sizes; the oracle itself must match the pre-kernel full-softmax
einsum path to float-association tolerance; and the engine's fused
``decode_and_sample`` tick must reproduce the PR-2 two-call
(decode_step + sample_tokens) token stream exactly."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import assert_argmax_margin

from repro.configs import get_config
from repro.kernels import dispatch, ref
from repro.kernels.decode_attention import shrink_block
from repro.models import registry
from repro.serve import Engine, Request, SamplingParams, make_serve_fns
from repro.serve.engine import make_decode_and_sample
from repro.serve.sampling import sample_tokens


def _ring_inputs(seed, *, b=3, cap=64, nkv=2, group=2, hd=32, quantized=False,
                 pos_vals=(5, 40, 63)):
    """A realistic ring-cache snapshot: slot s of row i holds the latest
    prompt/decode position p ≡ s (mod cap) with p ≤ pos_i; unwritten slots
    carry k_pos = -1 (and arbitrary codes — masking must hide them)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, nkv, group, hd)), jnp.bfloat16)
    pos = jnp.asarray(pos_vals[:b], jnp.int32)
    kpos = np.full((b, cap), -1, np.int64)
    for i in range(b):
        for p in range(int(pos_vals[i]) + 1):
            kpos[i, p % cap] = p
    k_pos = jnp.asarray(kpos, jnp.int32)
    if quantized:
        k = jnp.asarray(rng.integers(-127, 128, size=(b, cap, nkv, hd)), jnp.int8)
        v = jnp.asarray(rng.integers(-127, 128, size=(b, cap, nkv, hd)), jnp.int8)
        ks = jnp.asarray(rng.uniform(0.1, 2.0, size=(b, cap, nkv)), jnp.float32)
        vs = jnp.asarray(rng.uniform(0.1, 2.0, size=(b, cap, nkv)), jnp.float32)
    else:
        k = jnp.asarray(rng.normal(size=(b, cap, nkv, hd)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(b, cap, nkv, hd)), jnp.bfloat16)
        ks = vs = None
    return q, k, v, k_pos, pos, ks, vs


def _einsum_baseline(q, k, v, k_pos, pos, ks, vs, window):
    """The pre-PR-3 ``_attention_decode`` einsum path, f32 logits/probs (the
    old path additionally rounded logits and probabilities to bf16; f32 here
    isolates the association difference from that storage rounding)."""
    b, cap, nkv, hd = k.shape
    group = q.shape[2]
    logits = jnp.einsum("bhgd,bkhd->bhgk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    if ks is not None:
        logits = logits * (ks / 127.0).transpose(0, 2, 1)[:, :, None, :]
    posb = jnp.broadcast_to(pos, (b,))
    valid = (k_pos >= 0) & (k_pos <= posb[:, None])
    if window:
        valid = valid & (k_pos > posb[:, None] - window)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    if vs is not None:
        probs = probs * (vs / 127.0).transpose(0, 2, 1)[:, :, None, :]
    return jnp.einsum("bhgk,bkhd->bhgd", probs, v.astype(jnp.float32))


# ---------------------------------------------------------------------------
# backend parity: pallas-interpret ≡ xla-ref, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quantized", [False, True], ids=["bf16", "int8"])
@pytest.mark.parametrize("window", [0, 16], ids=["full", "window16"])
@pytest.mark.parametrize("group", [1, 2, 4])
def test_interpret_bit_identical_to_xla_ref(quantized, window, group):
    """The Pallas kernel body mirrors the oracle's recurrence op-for-op, so
    interpret mode is bit-identical for every kv_quant × window × GQA-group
    configuration and every block size."""
    q, k, v, k_pos, pos, ks, vs = _ring_inputs(
        group, group=group, quantized=quantized)
    for bk in (16, 64):
        out_i = dispatch.decode_attention(
            q, k, v, k_pos, pos, k_scale=ks, v_scale=vs, window=window,
            block=(bk,), backend="pallas-interpret")
        out_r = dispatch.decode_attention(
            q, k, v, k_pos, pos, k_scale=ks, v_scale=vs, window=window,
            block=(bk,), backend="xla-ref")
        assert out_i.dtype == jnp.float32
        assert jnp.array_equal(out_i, out_r), (quantized, window, group, bk)


def test_interpret_autotuned_block_matches_explicit():
    """block=None routes Pallas backends through the autotuner's VMEM-model
    pick; the result must equal the same explicitly-passed block."""
    from repro.kernels import autotune

    q, k, v, k_pos, pos, ks, vs = _ring_inputs(9, quantized=True)
    picked = autotune.best_block(
        "decode_attention", (3, 64, 2, 2, 32), "int8", 8, "flash",
        "pallas-interpret")
    auto = dispatch.decode_attention(q, k, v, k_pos, pos, k_scale=ks,
                                     v_scale=vs, backend="pallas-interpret")
    explicit = dispatch.decode_attention(q, k, v, k_pos, pos, k_scale=ks,
                                         v_scale=vs, block=tuple(picked),
                                         backend="pallas-interpret")
    assert jnp.array_equal(auto, explicit)


@pytest.mark.parametrize("quantized", [False, True], ids=["bf16", "int8"])
@pytest.mark.parametrize("window", [0, 16], ids=["full", "window16"])
def test_oracle_matches_full_softmax_einsum(quantized, window):
    """The split-K recurrence equals the full-softmax einsum path up to
    float-summation association (it is *more* precise than the retired
    in-model path, which stored logits and probabilities in bf16)."""
    q, k, v, k_pos, pos, ks, vs = _ring_inputs(11, quantized=quantized)
    out_r = ref.decode_attention_ref(q, k, v, k_pos, pos, ks, vs,
                                     window=window, block=(16,))
    base = _einsum_baseline(q, k, v, k_pos, pos, ks, vs, window)
    np.testing.assert_allclose(np.asarray(out_r), np.asarray(base),
                               rtol=2e-4, atol=2e-4)


def test_block_size_invariance_and_masked_slot_independence():
    """The recurrence result is block-size independent (to association
    noise), and slots hidden by the mask — unwritten, future, or outside
    the sliding window — cannot leak into the output even with poisoned
    codes."""
    q, k, v, k_pos, pos, ks, vs = _ring_inputs(13, quantized=True)
    outs = [ref.decode_attention_ref(q, k, v, k_pos, pos, ks, vs, window=16,
                                     block=(bk,)) for bk in (8, 16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-6)

    # poison every masked slot's codes and scales: masked logits become the
    # -1e30 sentinel either way and exp to exactly 0, so the output must be
    # *bitwise* unchanged
    posb = np.asarray(pos)[:, None]
    kp = np.asarray(k_pos)
    valid = jnp.asarray((kp >= 0) & (kp <= posb) & (kp > posb - 16))
    vm = valid[:, :, None, None]
    k_bad = jnp.where(vm, k, jnp.int8(127))
    v_bad = jnp.where(vm, v, jnp.int8(-128))
    ks_bad = jnp.where(valid[:, :, None], ks, 1e4)
    vs_bad = jnp.where(valid[:, :, None], vs, 1e4)
    clean = ref.decode_attention_ref(q, k, v, k_pos, pos, ks, vs, window=16,
                                     block=(16,))
    poisoned = ref.decode_attention_ref(q, k_bad, v_bad, k_pos, pos, ks_bad,
                                        vs_bad, window=16, block=(16,))
    assert jnp.array_equal(clean, poisoned)


def test_shrink_block_divides_cap():
    assert shrink_block(512, 64) == 64
    assert shrink_block(48, 64) == 32
    assert shrink_block(1, 7) == 1
    assert shrink_block(7, 7) == 7


# ---------------------------------------------------------------------------
# engine: fused decode_and_sample ≡ the PR-2 two-call tick
# ---------------------------------------------------------------------------

CFG = get_config("smollm_135m").reduced()
PARAMS = registry.init_model(jax.random.PRNGKey(0), CFG)


def test_decode_and_sample_matches_two_call_path():
    """One fused dispatch per tick emits exactly the tokens the PR-2 engine's
    separate jit(decode_step) + jit(sample_tokens) calls produced, over a
    multi-tick greedy + temperature mix on the int8 cache."""
    batch, max_len = 2, 32
    prefill_step, decode_step = make_serve_fns(
        CFG, None, max_len=max_len, kv_quant=True)
    fused = jax.jit(make_decode_and_sample(CFG, None))
    decode = jax.jit(decode_step)
    sample = jax.jit(sample_tokens)

    toks = jnp.asarray([[3, 1, 4, 1], [5, 9, 2, 6]], jnp.int32)
    lengths = jnp.full((batch,), 4, jnp.int32)
    offsets = jnp.asarray([0, 1000], jnp.int32)
    temps = jnp.asarray([0.0, 0.9], jnp.float32)
    topks = jnp.asarray([0, 8], jnp.int32)
    seeds = jnp.asarray([0, 7], jnp.int32)

    last_logits, cache_a = jax.jit(prefill_step)(PARAMS, toks, lengths,
                                                 offsets, 0)
    counters = offsets
    token = sample(last_logits, temps, topks, seeds, counters)
    counters = counters + 1
    cache_b = jax.tree.map(jnp.copy, cache_a)

    token_a = token_b = token
    ctr_a = ctr_b = counters
    for tick in range(6):
        token_a, ctr_a, cache_a = fused(PARAMS, token_a, cache_a, offsets,
                                        tick, temps, topks, seeds, ctr_a)
        logits, cache_b = decode(PARAMS, token_b, cache_b, offsets, tick)
        # slot 0 decodes greedily: its fused ≡ two-call parity assumes the
        # argmax isn't a float coin-flip between the two logit paths
        assert_argmax_margin(logits[0], min_margin=1e-3,
                             context=f"greedy slot 0, tick {tick}")
        token_b = sample(logits, temps, topks, seeds, ctr_b)
        ctr_b = ctr_b + 1
        assert jnp.array_equal(token_a, token_b), tick
        assert jnp.array_equal(ctr_a, ctr_b)


def test_engine_stream_matches_manual_two_call_loop():
    """Full engine (device-resident state, donated cache, fused tick) vs a
    hand-driven PR-2-style loop with the same single admission wave: every
    emitted token identical."""
    batch, max_len, max_new = 2, 32, 5
    prompts = [[3, 1, 4, 1, 5], [2, 7, 1, 8, 2]]
    sp = [SamplingParams(temperature=0.0, max_new=max_new),
          SamplingParams(temperature=1.1, top_k=16, seed=4, max_new=max_new,
                         counter_offset=500)]

    eng = Engine(PARAMS, CFG, batch=batch, max_len=max_len, kv_quant=True)
    for r, p in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=list(p), sampling=sp[r]))
    done = sorted(eng.run(40), key=lambda r: r.rid)
    got = [r.out for r in done]

    # manual PR-2-style loop: jitted prefill, then decode + sample per tick
    prefill_step, decode_step = make_serve_fns(
        CFG, None, max_len=max_len, kv_quant=True)
    prefill = jax.jit(prefill_step)
    decode = jax.jit(decode_step)
    sample = jax.jit(sample_tokens)
    toks = jnp.asarray(prompts, jnp.int32)
    lengths = jnp.full((batch,), 5, jnp.int32)
    offsets = jnp.asarray([s.counter_offset for s in sp], jnp.int32)
    temps = jnp.asarray([s.temperature for s in sp], jnp.float32)
    topks = jnp.asarray([s.top_k for s in sp], jnp.int32)
    seeds = jnp.asarray([s.seed for s in sp], jnp.int32)

    last_logits, cache = prefill(PARAMS, toks, lengths, offsets, 0)
    counters = offsets
    assert_argmax_margin(last_logits[0], min_margin=1e-3,
                         context="greedy slot 0, prefill logits")
    token = sample(last_logits, temps, topks, seeds, counters)
    counters = counters + 1
    want = [[int(token[i])] for i in range(batch)]
    for tick in range(max_new - 1):
        logits, cache = decode(PARAMS, token, cache, offsets, tick)
        assert_argmax_margin(logits[0], min_margin=1e-3,
                             context=f"greedy slot 0, tick {tick}")
        token = sample(logits, temps, topks, seeds, counters)
        counters = counters + 1
        for i in range(batch):
            want[i].append(int(token[i]))
    assert got == want
