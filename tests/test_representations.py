"""Property tests for the §II pulse representations (the paper's core claims)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core import representations as rep, theory

UNIT = st.floats(0.0, 1.0, allow_nan=False, allow_infinity=False, width=32)


@given(x=UNIT, n=st.sampled_from([4, 16, 64, 257]))
def test_dither_encode_unbiased_and_low_var(x, n):
    """§II-D: E[X_s] = x exactly; Var(X_s) ≤ 2/N²."""
    xs = jnp.full((256,), x, jnp.float32)
    pulses = rep.dither_encode(jax.random.PRNGKey(0), xs, n)
    est = rep.decode(pulses)
    mean = float(jnp.mean(est))
    var = float(jnp.var(est))
    # SEM of the mean over 256 draws with var ≤ 2/N²
    tol = 6.0 * np.sqrt(2.0 / n**2 / 256) + 1e-6
    assert abs(mean - x) < tol, (mean, x, tol)
    assert var <= 2.0 / n**2 + 1e-6


@given(x=UNIT, n=st.sampled_from([4, 16, 64]))
def test_deterministic_encode_bias_bound(x, n):
    """§II-B: |X_s − x| ≤ 1/(2N), zero variance."""
    est = float(rep.decode(rep.deterministic_encode(jnp.float32(x), n)))
    assert abs(est - x) <= 0.5 / n + 1e-6


@given(x=UNIT, n=st.sampled_from([8, 32]))
def test_stochastic_encode_unbiased(x, n):
    xs = jnp.full((512,), x, jnp.float32)
    est = rep.decode(rep.stochastic_encode(jax.random.PRNGKey(1), xs, n))
    sem = np.sqrt(x * (1 - x) / n / 512) + 1e-6
    assert abs(float(jnp.mean(est)) - x) < 6 * sem + 1e-3


@given(n=st.sampled_from([8, 16, 64]))
def test_pulse_counts_exact_for_grid_values(n):
    """x = m/N with m ≤ N/2 → exactly m deterministic 1-pulses, rest δ=0."""
    m = n // 4
    x = jnp.float32(m / n)
    pulses = rep.dither_encode(jax.random.PRNGKey(2), x[None], n)
    assert int(pulses.sum()) == m


def test_emse_orders_match_theory():
    """Sample EMSE within 2× of the closed forms (paper Figs 1–2)."""
    key = jax.random.PRNGKey(3)
    x = jax.random.uniform(key, (2000,))
    n = 64
    # stochastic
    est = rep.decode(rep.stochastic_encode(key, x, n))
    L = float(jnp.mean((est - x) ** 2))
    assert 0.5 < L / theory.emse_repr_stochastic(n) < 2.0
    # deterministic
    est = rep.decode(rep.deterministic_encode(x, n))
    L = float(jnp.mean((est - x) ** 2))
    assert 0.5 < L / theory.emse_repr_deterministic(n) < 2.0
    # dither: below the bound, above the global lower bound
    est = rep.decode(rep.dither_encode(key, x, n))
    L = float(jnp.mean((est - x) ** 2))
    assert theory.emse_lower_bound(n) * 0.5 < L <= theory.emse_repr_dither_bound(n)


def test_spread_ones_places_exact_count():
    for m in [0, 1, 5, 16]:
        bits = rep.spread_ones(jnp.float32(m)[None], 16)
        assert int(bits.sum()) == m
