"""Figs 9–10: classification accuracy (mean and variance over trials) with
deterministic / stochastic / dither rounding in the inference matmul.

Synthetic MNIST stand-in (offline container; DESIGN.md §7): 1-layer softmax
trained in float, inference matmul quantised per scheme at k bits with the
paper's per-partial-product rounding (Fig 7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.core.matmul import quantized_matmul
from repro.data.mnist_like import make_dataset


def train_softmax(x, y, steps=300, lr=0.5):
    n, d = x.shape
    w = np.zeros((d, 10), np.float32)
    b = np.zeros((10,), np.float32)
    for s in range(steps):
        logits = x @ w + b
        logits -= logits.max(1, keepdims=True)
        p = np.exp(logits)
        p /= p.sum(1, keepdims=True)
        p[np.arange(n), y] -= 1.0
        p /= n
        w -= lr * (x.T @ p)
        b -= lr * p.sum(0)
    return w, b


def quantized_accuracy(x, y, w, b, bits, scheme, variant, trials, seed=0):
    """The paper's §VII setup: weights scaled to [-1,1], inputs stay in
    [0,1], BOTH rescaled from the fixed [-1,1] interval to [0, 2^k−1] — the
    input only occupies the upper half of the quantizer range ("did not
    fully utilize the full range"), which is exactly the regime where
    deterministic rounding collapses for small k."""
    s = float(np.abs(w).max())
    ws = w / s
    accs = []
    for tr in range(1 if scheme == "deterministic" else trials):
        c = quantized_matmul(jnp.asarray(x), jnp.asarray(ws), bits=bits,
                             scheme=scheme, variant=variant,
                             seed=seed + 101 * tr, lo=-1.0, hi=1.0)
        pred = np.argmax(np.asarray(c) + b / s, axis=1)
        accs.append(float((pred == y).mean()))
    return float(np.mean(accs)), float(np.var(accs))


def run(full: bool = False, variant: str = "per_partial"):
    t = timer()
    n_tr, n_te = (6000, 1000) if full else (1500, 400)
    trials = 20 if full else 6
    # difficulty tuned for a ~0.92 float baseline (the paper's MNIST softmax)
    x_tr, y_tr, x_te, y_te = make_dataset(n_tr, n_te, noise=0.45, sharp=0.5)
    w, b = train_softmax(x_tr, y_tr)
    base = float((np.argmax(x_te @ w + b, 1) == y_te).mean())
    rows = [("fig9_baseline_acc", t(), f"{base:.3f}")]
    ks = [1, 2, 3, 4, 6] if full else [1, 2, 4]
    summary = {}
    for k in ks:
        accs = {}
        for scheme in ["deterministic", "stochastic", "dither"]:
            m, v = quantized_accuracy(x_te, y_te, w, b, k, scheme, variant, trials)
            accs[scheme] = (m, v)
        summary[k] = accs
        rows.append((f"fig9_acc_k{k}", t(),
                     " ".join(f"{s[:5]}={m:.3f}" for s, (m, v) in accs.items())))
        rows.append((f"fig10_var_k{k}", t(),
                     f"dith={accs['dither'][1]:.2e} stoch={accs['stochastic'][1]:.2e}"))
    k_small = ks[0]
    rows.append((
        "fig9_dither_beats_det_smallk", t(),
        str(summary[k_small]["dither"][0] > summary[k_small]["deterministic"][0]),
    ))
    return rows
