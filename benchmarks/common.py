"""Shared helpers for the paper-reproduction benchmarks."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

N_VALUES = [8, 16, 32, 64, 128, 256]


def timer():
    t0 = time.time()
    return lambda: (time.time() - t0) * 1e6  # µs


def loglog_slope(ns, ys):
    """Least-squares slope of log(y) vs log(N) — the asymptotic exponent."""
    ns = np.asarray(ns, float)
    ys = np.maximum(np.asarray(ys, float), 1e-30)
    return float(np.polyfit(np.log(ns), np.log(ys), 1)[0])


def sample_xy(n_pairs: int, seed: int = 0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(kx, (n_pairs,))
    y = jax.random.uniform(ky, (n_pairs,))
    return x, y
