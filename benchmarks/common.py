"""Shared helpers for the paper-reproduction benchmarks."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

N_VALUES = [8, 16, 32, 64, 128, 256]


def timer():
    t0 = time.time()
    return lambda: (time.time() - t0) * 1e6  # µs


def loglog_slope(ns, ys):
    """Least-squares slope of log(y) vs log(N) — the asymptotic exponent."""
    ns = np.asarray(ns, float)
    ys = np.maximum(np.asarray(ys, float), 1e-30)
    return float(np.polyfit(np.log(ns), np.log(ys), 1)[0])


def sample_xy(n_pairs: int, seed: int = 0):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(kx, (n_pairs,))
    y = jax.random.uniform(ky, (n_pairs,))
    return x, y


def machine_calibration(repeats: int = 5) -> dict:
    """The artifact *calibration row* (DESIGN.md §10): best-of-N wall time
    of one fixed jitted f32 256×256 matmul on this machine.

    Every perf artifact embeds this measurement at generation time;
    ``benchmarks/perf_gate.py`` divides the reference and candidate rows to
    get a machine-speed ratio and normalises wall-clock metrics (tok/s, µs,
    latency percentiles) by it — so a slower CI runner doesn't read as a
    perf regression, and a faster one doesn't mask a real one.  The probe
    is deliberately dumb: fixed shape, fixed dtype, no Pallas, no dispatch
    — it tracks raw machine speed, not any code path this repo owns."""
    a = jnp.asarray(np.linspace(-1.0, 1.0, 256 * 256, dtype=np.float32)
                    .reshape(256, 256))
    f = jax.jit(lambda x: x @ x)
    f(a).block_until_ready()                     # compile outside the timing
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        f(a).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return {"probe": "matmul_f32_256", "repeats": repeats,
            "best_us": best * 1e6}
