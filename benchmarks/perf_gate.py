"""CI perf-regression gate: diff fresh bench artifacts against committed ones.

Loads the committed reference artifacts under ``benchmarks/artifacts/``
(kernel_bench schema v3, serve_bench schema v9) and a candidate directory of
freshly generated artifacts from the same commands, matches result rows on
their identity keys (kernel × backend × shape × block; workload × policy ×
kv_quant × layout × mesh × shape), and checks every shared metric against a
per-metric tolerance band:

  * **higher/lower** — wall-clock rates and times, normalised by the machine
    calibration row first (see ``benchmarks.common.machine_calibration``):
    the candidate rate is scaled by ``cand_calib_us / ref_calib_us`` so a
    slower CI runner doesn't read as a regression.  ``decode_tok_s`` /
    ``prefill_tok_s`` carry a 25 % band — a 30 % throughput regression
    fails the gate.
  * **exact** — analytic byte counts, completion/preemption counts,
    histogram counts, prefix-hit rates: bit-deterministic host-side
    quantities; any drift is a behaviour change, not noise.
  * **bool** — correctness flags (``codes_exact_vs_ref``) must not flip.
  * **ceiling** — reference-*independent* absolute budgets
    (``trace_overhead_pct`` ≤ 2%): the bound is the contract, so the
    candidate is checked against ``abs_floor`` directly, with no
    machine normalisation and no drift band.
  * **advisory** — latency percentiles and single-call µs timings: reported
    in the gate output but never fail it (CPU smoke runs are too noisy for
    hard latency bands; the *rates* are best-of-waves and stable).

A schema-version mismatch, a reference row with no candidate match, or a
missing candidate file is a hard failure — silent coverage loss is itself a
regression.  Exits non-zero on any failure (DESIGN.md §10):

  PYTHONPATH=src python benchmarks/perf_gate.py \
      --reference benchmarks/artifacts --candidate /tmp/fresh_artifacts
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

EXPECTED_VERSIONS = {"kernel": 3, "serve": 9}

# Identity keys: the fields that *name* a row.  Everything else is a metric.
KERNEL_KEYS = ("kernel", "backend", "shape", "block", "cap", "bits", "scheme")
SERVE_KEYS = ("workload", "arch", "policy", "kernel_backend", "kv_layout",
              "kv_quant", "mesh", "batch", "max_len", "prompt_len",
              "prefix_len", "tail_len", "max_new", "requests", "waves",
              "block_size", "decode_ticks", "prefill_chunk", "draft_k")


@dataclasses.dataclass(frozen=True)
class Metric:
    """One gated metric: a dotted path into a result row plus its band.

    ``mode`` — 'higher' (regression = candidate below ref), 'lower'
    (regression = candidate above ref), 'exact' (must match to abs_floor),
    'bool' (must equal ref), 'ceiling' (candidate must not exceed
    ``abs_floor``) / 'floor' (candidate must not fall below ``abs_floor``;
    for both, the reference value is ignored — the budget itself is
    the contract).  ``normalize`` scales the candidate by the
    machine-speed ratio before comparing.  ``advisory`` reports but never
    fails.  The tolerance is ``max(rel_tol * |ref|, abs_floor)``."""
    path: str
    mode: str
    rel_tol: float = 0.0
    abs_floor: float = 0.0
    normalize: bool = False
    advisory: bool = False


@dataclasses.dataclass
class Finding:
    severity: str          # "fail" | "advisory" | "info"
    file: str
    row: str
    metric: str
    message: str

    def __str__(self):
        return (f"[{self.severity.upper():8s}] {self.file} :: {self.row} :: "
                f"{self.metric}: {self.message}")


KERNEL_METRICS = (
    # interpret-mode µs are relative numbers (DESIGN.md §3) — advisory; the
    # decode-attention tok/s trend is gated, with a wide band for interpret
    # overhead variance on shared CI hosts.
    Metric("tok_s", "higher", rel_tol=0.60, normalize=True),
    Metric("us", "lower", rel_tol=0.60, normalize=True, advisory=True),
    Metric("us_einsum_baseline", "lower", rel_tol=0.60, normalize=True,
           advisory=True),
    # analytic HBM models and oracle checks: deterministic, no band.
    Metric("bytes_per_token", "exact"),
    Metric("bytes_per_token_einsum", "exact"),
    Metric("max_abs_err_vs_ref", "lower", rel_tol=1.0, abs_floor=1e-3),
    Metric("codes_exact_vs_ref", "bool"),
)

SERVE_METRICS = (
    # headline rates: best-of-waves, machine-normalised, 25 % band — the
    # gate's contract is that a 30 % tok/s regression fails.
    Metric("decode_tok_s", "higher", rel_tol=0.25, normalize=True),
    Metric("prefill_tok_s", "higher", rel_tol=0.25, normalize=True),
    Metric("prefill_to_decode_ratio", "higher", rel_tol=0.5, advisory=True),
    Metric("per_shard_decode_tok_s", "higher", rel_tol=0.25, normalize=True,
           advisory=True),
    # schema v6: fused-window speedup over the sweep's own 1-tick row — a
    # same-machine ratio (normalisation cancels), so it gets a plain band.
    Metric("tick_speedup_vs_1", "higher", rel_tol=0.25),
    # deterministic host-side behaviour: exact.
    Metric("completed", "exact"),
    Metric("preemptions", "exact"),
    Metric("prefix_hit_rate", "exact", abs_floor=1e-9),
    Metric("prefix_hit_tokens", "exact"),
    Metric("attn_bytes_per_token", "exact"),
    Metric("collective_bytes_per_token", "exact"),
    Metric("kv_hbm_bytes_peak_live", "exact"),
    Metric("kv_hbm_bytes_dense_ring", "exact"),
    Metric("ttft_hist_ms.count", "exact"),
    Metric("itl_hist_ms.count", "exact"),
    # schema v7: fault-tolerance counters (DESIGN.md §12).  The bench
    # workload sets no deadlines or queue cap and never crashes — all three
    # must be exactly zero, so any expiry/shed/restart on the benchmark
    # path is a behaviour regression, not noise.
    Metric("deadline_expired", "exact"),
    Metric("shed", "exact"),
    Metric("recoveries", "exact"),
    Metric("attn_full_cap_fp32_upcast", "bool"),
    Metric("heads_sharded", "bool"),
    # schema v8: per-request tracing (DESIGN.md §13).  The overhead pct is
    # an absolute budget, not a drift band — tracing must cost ≤ 2% of the
    # smoke decode rate on *any* machine, so it gates against the ceiling
    # rather than the reference.  The bitwise flag pins the host-only
    # contract (tracing never perturbs a token stream) and the span count
    # pins instrumentation coverage.
    Metric("trace_overhead_pct", "ceiling", abs_floor=2.0),
    Metric("streams_bitwise_equal", "bool"),
    Metric("trace_phase_spans", "exact"),
    Metric("decode_tok_s_untraced", "higher", rel_tol=0.25, normalize=True,
           advisory=True),
    # schema v9: speculative decode (DESIGN.md §14).  The speedup is a
    # same-machine spec/plain ratio at the replay-oracle accept ceiling —
    # banded against the reference *and* held to the ≥1.5× absolute
    # contract (the workload's reason to exist).  Accept rates and window
    # counters are deterministic on the greedy smoke workload — exact, so
    # drafter-quality or acceptance-walk drift gates as a behaviour change.
    Metric("spec_speedup_vs_plain", "higher", rel_tol=0.25),
    Metric("spec_speedup_vs_plain", "floor", abs_floor=1.5),
    Metric("decode_tok_s_plain", "higher", rel_tol=0.25, normalize=True,
           advisory=True),
    Metric("spec_accept_rate", "exact", abs_floor=1e-9),
    Metric("spec_accept_rate_prompt_lookup", "exact", abs_floor=1e-9),
    Metric("spec_windows", "exact"),
    Metric("spec_draft_tokens", "exact"),
    Metric("spec_accepted_tokens", "exact"),
    Metric("spec_emitted_tokens", "exact"),
    # latency percentiles: CPU-noise-dominated at smoke shapes — advisory.
    Metric("ttft_ms.p50", "lower", rel_tol=1.0, normalize=True,
           advisory=True),
    Metric("ttft_ms.p90", "lower", rel_tol=1.0, normalize=True,
           advisory=True),
    Metric("ttft_ms.p95", "lower", rel_tol=1.0, normalize=True,
           advisory=True),
    Metric("itl_ms.p50", "lower", rel_tol=1.0, normalize=True,
           advisory=True),
    Metric("itl_ms.p95", "lower", rel_tol=1.0, normalize=True,
           advisory=True),
    Metric("ttft_hist_ms.p95", "lower", rel_tol=1.0, normalize=True,
           advisory=True),
    Metric("itl_hist_ms.p95", "lower", rel_tol=1.0, normalize=True,
           advisory=True),
    Metric("ttft_ms_hit.p50", "lower", rel_tol=1.0, normalize=True,
           advisory=True),
    Metric("ttft_ms_cold.p50", "lower", rel_tol=1.0, normalize=True,
           advisory=True),
    Metric("queue_depth_mean", "lower", rel_tol=1.0, advisory=True),
    Metric("batch_occupancy_mean", "higher", rel_tol=0.5, advisory=True),
    Metric("kv_hbm_live_to_dense", "lower", rel_tol=0.25, advisory=True),
)

_MISSING = object()


def artifact_kind(filename: str) -> str:
    base = os.path.basename(filename)
    if base.startswith("kernel_bench"):
        return "kernel"
    if base.startswith("serve_bench"):
        return "serve"
    raise ValueError(f"unknown artifact kind for {filename!r}")


def load_artifact(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def row_key(kind: str, row: dict) -> str:
    keys = KERNEL_KEYS if kind == "kernel" else SERVE_KEYS
    ident = {k: row.get(k, "grid" if k == "workload" else None) for k in keys}
    return json.dumps(ident, sort_keys=True)


def lookup(row: dict, path: str):
    cur = row
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return _MISSING
        cur = cur[part]
    return cur


def speed_ratio(ref_art: dict, cand_art: dict) -> float:
    """cand/ref machine-speed ratio from the calibration rows (> 1 = the
    candidate machine is slower, so its rates get scaled up and its wall
    times scaled down before band checks)."""
    ref_us = float(ref_art["calibration"]["best_us"])
    cand_us = float(cand_art["calibration"]["best_us"])
    return cand_us / ref_us


def check_metric(m: Metric, ref_row: dict, cand_row: dict,
                 ratio: float, file: str, key: str):
    ref_v = lookup(ref_row, m.path)
    cand_v = lookup(cand_row, m.path)
    if ref_v is _MISSING and cand_v is _MISSING:
        return None                     # metric not applicable to this row
    sev = "advisory" if m.advisory else "fail"
    if ref_v is _MISSING or cand_v is _MISSING:
        side = "reference" if ref_v is _MISSING else "candidate"
        return Finding("fail", file, key, m.path,
                       f"missing from {side} row (schema drift)")
    if m.mode == "bool":
        if bool(cand_v) != bool(ref_v):
            return Finding(sev, file, key, m.path,
                           f"flipped {ref_v} -> {cand_v}")
        return None
    if m.mode == "ceiling":
        if float(cand_v) > m.abs_floor:
            return Finding(sev, file, key, m.path,
                           f"{float(cand_v):g} > {m.abs_floor:g} "
                           f"absolute ceiling")
        return None
    if m.mode == "floor":
        if float(cand_v) < m.abs_floor:
            return Finding(sev, file, key, m.path,
                           f"{float(cand_v):g} < {m.abs_floor:g} "
                           f"absolute floor")
        return None
    ref_v, cand_v = float(ref_v), float(cand_v)
    if m.mode == "exact":
        tol = max(m.abs_floor, m.rel_tol * abs(ref_v))
        if abs(cand_v - ref_v) > tol:
            return Finding(sev, file, key, m.path,
                           f"{cand_v:g} != {ref_v:g} (exact metric)")
        return None
    norm = cand_v
    if m.normalize and ratio != 1.0:
        norm = cand_v * ratio if m.mode == "higher" else cand_v / ratio
    tol = max(m.abs_floor, m.rel_tol * abs(ref_v))
    if m.mode == "higher" and norm < ref_v - tol:
        return Finding(sev, file, key, m.path,
                       f"{norm:g} (raw {cand_v:g}) < {ref_v:g} "
                       f"- {100 * m.rel_tol:.0f}% band")
    if m.mode == "lower" and norm > ref_v + tol:
        return Finding(sev, file, key, m.path,
                       f"{norm:g} (raw {cand_v:g}) > {ref_v:g} "
                       f"+ {100 * m.rel_tol:.0f}% band")
    return None


def compare_artifacts(filename: str, ref_art: dict,
                      cand_art: dict) -> list:
    """All findings from diffing one candidate artifact against its
    reference.  Schema mismatch short-circuits — rows aren't comparable
    across schema versions."""
    kind = artifact_kind(filename)
    want = EXPECTED_VERSIONS[kind]
    findings = []
    for side, art in (("reference", ref_art), ("candidate", cand_art)):
        if art.get("version") != want:
            findings.append(Finding(
                "fail", filename, "-", "version",
                f"{side} schema v{art.get('version')} != expected v{want}"))
    if findings:
        return findings
    for side, art in (("reference", ref_art), ("candidate", cand_art)):
        if "calibration" not in art:
            findings.append(Finding("fail", filename, "-", "calibration",
                                    f"{side} artifact has no calibration row"))
    if findings:
        return findings
    ratio = speed_ratio(ref_art, cand_art)
    if not 0.01 < ratio < 100.0:
        findings.append(Finding(
            "fail", filename, "-", "calibration",
            f"implausible machine-speed ratio {ratio:g}"))
        return findings
    findings.append(Finding(
        "info", filename, "-", "calibration",
        f"machine-speed ratio cand/ref = {ratio:.2f}"))

    metrics = KERNEL_METRICS if kind == "kernel" else SERVE_METRICS
    cand_rows = {row_key(kind, r): r for r in cand_art["results"]}
    matched = set()
    for ref_row in ref_art["results"]:
        key = row_key(kind, ref_row)
        cand_row = cand_rows.get(key)
        if cand_row is None:
            findings.append(Finding(
                "fail", filename, key, "-",
                "reference row has no candidate match (coverage lost)"))
            continue
        matched.add(key)
        for m in metrics:
            f = check_metric(m, ref_row, cand_row, ratio, filename, key)
            if f is not None:
                findings.append(f)
    for key in cand_rows:
        if key not in matched:
            findings.append(Finding(
                "info", filename, key, "-",
                "new candidate row (not in reference — commit a refreshed "
                "artifact to start gating it)"))
    return findings


def gate_directories(ref_dir: str, cand_dir: str, files=None) -> list:
    """Diff every gated artifact in ``ref_dir`` against ``cand_dir``."""
    if files is None:
        files = sorted(f for f in os.listdir(ref_dir)
                       if f.endswith(".json"))
        files = [f for f in files
                 if f.startswith(("kernel_bench", "serve_bench"))]
    findings = []
    if not files:
        findings.append(Finding("fail", ref_dir, "-", "-",
                                "no reference artifacts to gate against"))
    for name in files:
        ref_path = os.path.join(ref_dir, name)
        cand_path = os.path.join(cand_dir, name)
        if not os.path.exists(ref_path):
            findings.append(Finding("fail", name, "-", "-",
                                    f"reference artifact missing: {ref_path}"))
            continue
        if not os.path.exists(cand_path):
            findings.append(Finding("fail", name, "-", "-",
                                    f"candidate artifact missing: {cand_path}"))
            continue
        findings += compare_artifacts(name, load_artifact(ref_path),
                                      load_artifact(cand_path))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reference",
                    default=os.path.join(os.path.dirname(
                        os.path.abspath(__file__)), "artifacts"),
                    help="committed reference artifact directory")
    ap.add_argument("--candidate", required=True,
                    help="directory of freshly generated artifacts")
    ap.add_argument("--files", nargs="*", default=None,
                    help="artifact filenames to gate (default: every "
                         "kernel_bench*/serve_bench* JSON in --reference)")
    args = ap.parse_args(argv)

    findings = gate_directories(args.reference, args.candidate,
                                files=args.files)
    fails = [f for f in findings if f.severity == "fail"]
    advisories = [f for f in findings if f.severity == "advisory"]
    for f in findings:
        print(f)
    print(f"perf gate: {len(fails)} failure(s), {len(advisories)} "
          f"advisory, {len(findings) - len(fails) - len(advisories)} info")
    if fails:
        print("PERF GATE: FAIL")
        return 1
    print("PERF GATE: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
