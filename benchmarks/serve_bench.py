"""Serving benchmark: two-phase engine throughput / latency, JSON artifact.

Drives the ``serve/`` engine (batched prefill → batched decode, DESIGN.md
§6) over policy ∈ {none, dither, stochastic, deterministic} × kv_quant ∈
{off, on} and records, per configuration: prefill vs decode tokens/s,
time-to-first-token (TTFT) and inter-token latency (ITL) percentiles.  A
warm-up wave runs first so jit compile time stays out of the measured
rates.  The headline check is ``prefill_to_decode_ratio``: batched prefill
pushes prompt tokens at a multiple of the decode rate because a prompt
costs one forward pass instead of O(prompt_len) decode ticks.

``--kv-layout paged`` runs the same grid over the paged block-pool cache
(PR 4), and attention-only archs additionally get a **prefix-reuse
workload**: every request shares a block-aligned system prompt, served
once with prefix caching on and once off — reporting the prefix-hit rate,
TTFT with vs without reuse, and the pool's peak *live* KV HBM footprint
against what the dense ring would have reserved up front.

Standalone CLI (emits the perf artifact future PRs diff against, alongside
``kernel_bench.json``):

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke     # CI: tiny
      # config; quantised policies run the Pallas interpret backend
  PYTHONPATH=src python benchmarks/serve_bench.py [--full] \
      [--kv-layout ring|paged] [--arch smollm_135m] \
      [--out benchmarks/artifacts/serve_bench.json]

The artifact schema is documented in benchmarks/README.md.  CPU numbers are
relative; they track the serving path's perf trajectory across PRs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ is None or __package__ == "":  # `python benchmarks/serve_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_root, os.path.join(_root, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import machine_calibration
from repro.configs import get_config
from repro.kernels import autotune, dispatch
from repro.models import registry
from repro.numerics.policy import QuantPolicy
from repro.serve import Engine, Request, SamplingParams

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "artifacts", "serve_bench.json")

ARTIFACT_VERSION = 9

POLICIES = ("none", "dither", "stochastic", "deterministic")


def _mesh_profile(cfg, engine=None) -> dict:
    """Schema-v4 mesh dimensions, read from the *engine's resolved layout*
    (never re-derived — the artifact must describe what actually ran),
    plus the analytic decode-time collective bytes per generated token per
    slot.  The serve layout's only decode collective is the all-gather of
    attention-head activations before the replicated W_O (DESIGN.md §9):
    each model shard receives the other shards' (n_heads/tp)·hd bf16 slices
    once per attention layer per token; 0 when tp == 1, under the GQA
    replicated fallback, or off-mesh (``engine`` None = single-device
    workload rows)."""
    if engine is None or engine.mesh is None:
        return {"mesh": None, "data_shards": 1, "model_shards": 1,
                "heads_sharded": False, "collective_bytes_per_token": 0}
    dp, tp, heads_sharded = engine.dp, engine.tp, engine.heads_sharded
    per_layer = ((tp - 1) * (cfg.n_heads // tp) * cfg.hd() * 2
                 if heads_sharded else 0)
    return {"mesh": [dp, tp], "data_shards": dp, "model_shards": tp,
            "heads_sharded": heads_sharded,
            "collective_bytes_per_token": int(_n_attn(cfg) * per_layer)}


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, float), q)) if xs else 0.0


def _metrics_fields(engine) -> dict:
    """Schema-v5 engine-metrics fields, read from the engine's metrics
    surface (DESIGN.md §10) after the *last measured wave* (``reset_stats``
    re-zeros the histograms per wave, so these describe one steady wave,
    not warm-up).  ``*_hist_ms`` percentiles come from the log-bucket
    histograms — ≈20% bucket resolution, and their ``count`` fields are
    exact (the perf gate checks them against the request count)."""
    ms = engine.metrics.summary()
    g = ms["gauges"]

    def hist_ms(h):
        return {"count": h["count"], "p50": 1e3 * h["p50"],
                "p95": 1e3 * h["p95"], "p99": 1e3 * h["p99"],
                "max": 1e3 * h["max"]}

    return {
        "queue_depth_mean": g.get("queue_depth", {}).get("mean", 0.0),
        "batch_occupancy_mean": g.get("batch_occupancy", {}).get("mean", 0.0),
        "ttft_hist_ms": hist_ms(ms["ttft_s"]),
        "itl_hist_ms": hist_ms(ms["itl_s"]),
    }


def _n_attn(cfg) -> int:
    return sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn")


def _kv_bytes_per_block(cfg, block_size: int, kv_quant: bool) -> int:
    """HBM bytes one pool block costs across every attention layer."""
    nkv, hd = cfg.n_kv_heads, cfg.hd()
    elem = 1 if kv_quant else 2
    per_layer = 2 * block_size * nkv * hd * elem
    if kv_quant:
        per_layer += 2 * block_size * nkv * 4
    return _n_attn(cfg) * per_layer


def _kv_bytes_dense_ring(cfg, batch: int, max_len: int,
                         kv_quant: bool) -> int:
    """What the dense per-slot ring reserves up front (slots × cap)."""
    cap = min(cfg.window, max_len) if cfg.window else max_len
    nkv, hd = cfg.n_kv_heads, cfg.hd()
    elem = 1 if kv_quant else 2
    per_layer = batch * (2 * cap * nkv * hd * elem + cap * 4)
    if kv_quant:
        per_layer += batch * 2 * cap * nkv * 4
    return _n_attn(cfg) * per_layer


def _attn_profile(cfg, max_len: int, kv_quant: bool, batch: int,
                  kv_layout: str = "ring", block_size=None):
    """How decode attention runs for this config: the dispatcher backend the
    engine's traced decode step embeds, its cache-length block, and the
    analytic steady-state attention HBM bytes per generated token per slot
    (sum over attention layers, cache at full occupancy).  Since PR 3 the
    int8 cache is consumed as codes in-kernel — never upcast to a full-cap
    fp tensor — so there is no fp-upcast term.  The paged layout's block is
    the pool block size; its per-token read replaces the ring's k_pos rows
    with the (tiny) block-table fetch."""
    backend = dispatch.resolve_backend(None).name
    cap = min(cfg.window, max_len) if cfg.window else max_len
    nkv, hd = cfg.n_kv_heads, cfg.hd()
    group = max(1, cfg.n_heads // max(1, nkv))
    elem = 1 if kv_quant else 2
    if kv_layout == "paged":
        bs = int(block_size)
        block = [bs]
        nbmax = -(-max_len // bs)
        per_layer = nkv * 2 * max_len * hd * elem + nbmax * 4
        if kv_quant:
            per_layer += nkv * 2 * max_len * 4
    else:
        if backend.startswith("pallas"):
            dtype = "int8" if kv_quant else "bfloat16"
            block = list(autotune.best_block(
                "decode_attention", (batch, cap, nkv, group, hd), dtype,
                8 if kv_quant else 16, "flash", backend))
        else:
            block = None               # xla-ref: one whole-cap pass
        per_layer = nkv * 2 * cap * hd * elem + cap * 4
        if kv_quant:
            per_layer += nkv * 2 * cap * 4
    return {
        "attn_backend": backend,
        "attn_block": block,
        "attn_bytes_per_token": int(_n_attn(cfg) * per_layer),
        "attn_full_cap_fp32_upcast": False,
    }


def bench_config(cfg, params, policy_name: str, kv_quant: bool, *,
                 backend: str, batch: int, max_len: int, prompt_len: int,
                 max_new: int, requests: int, temperature: float = 0.0,
                 waves: int = 3, kv_layout: str = "ring", block_size=None,
                 mesh=None, decode_ticks: int = 1, prefill_chunk=None):
    """Measure one (policy × kv_quant) serving configuration.

    Builds a fresh engine, runs one warm-up request through the same prompt
    bucket (compiles prefill, decode and the sampler), then serves the same
    ``requests``-request wave ``waves`` times (stats reset in between) and
    reports **best-of-waves** token rates — the same best-of-N treatment
    ``kernel_bench._time_call`` uses, so shared-host load spikes don't land
    in the perf trajectory.  Latency percentiles pool every wave.
    """
    policy = (None if policy_name == "none"
              else QuantPolicy(scheme=policy_name, backend=backend))
    frames = (jnp.zeros((batch, cfg.n_enc_tokens, cfg.d_model), jnp.bfloat16)
              if cfg.is_encdec else None)
    kv_quant = kv_quant and not cfg.is_encdec   # enc-dec self-KV stays bf16
    kw = {}
    if kv_layout == "paged":
        kw = dict(kv_layout="paged", block_size=block_size,
                  prefix_cache=False)           # the grid measures cold rates
    engine = Engine(params, cfg, batch, max_len, policy=policy, frames=frames,
                    kv_quant=kv_quant, mesh=mesh, decode_ticks=decode_ticks,
                    prefill_chunk=prefill_chunk, **kw)
    if kv_layout == "paged":
        block_size = engine.block_size

    engine.submit(Request(rid=-1, prompt=[1] * prompt_len, max_new=2))
    engine.run(ticks=8)
    engine.finished.clear()

    pf = dc = 0.0
    done = []
    preempt_total = hit_total = prefill_total = 0
    for wave in range(waves):
        engine.reset_stats()
        for r in range(requests):
            prompt = [(5 * r + i) % (cfg.vocab_size - 1) + 1
                      for i in range(prompt_len)]
            engine.submit(Request(
                rid=wave * requests + r, prompt=prompt,
                sampling=SamplingParams(temperature=temperature, seed=r,
                                        max_new=max_new,
                                        counter_offset=1000 * r)))
        done += list(engine.run(ticks=requests * (max_new + 4) + 20))
        engine.finished = []
        st = engine.stats
        preempt_total += st["preemptions"]
        hit_total += st["prefix_hit_tokens"]
        prefill_total += st["prefill_tokens"]
        if st["prefill_s"]:
            pf = max(pf, st["prefill_tokens"] / st["prefill_s"])
        if st["decode_s"]:
            dc = max(dc, st["decode_tokens"] / st["decode_s"])

    ttfts = [r.ttft for r in done if r.ttft is not None]
    itls = [x for r in done for x in r.itl]
    reasons = {}
    for r in done:
        reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
    attn_profile = _attn_profile(cfg, max_len, kv_quant, batch,
                                 kv_layout=kv_layout, block_size=block_size)
    mesh_profile = _mesh_profile(cfg, engine)
    return {
        "arch": cfg.name, "policy": policy_name,
        "kernel_backend": backend if policy_name != "none" else None,
        **attn_profile,
        **mesh_profile,
        "per_shard_decode_tok_s": dc / mesh_profile["data_shards"],
        "kv_layout": kv_layout,
        "block_size": int(block_size) if kv_layout == "paged" else None,
        # schema v6: the overlap knobs (DESIGN.md §11) are identity fields —
        # a tick-sweep row never gates against a single-tick row
        "decode_ticks": int(decode_ticks),
        "prefill_chunk": (int(engine.prefill_chunk)
                          if engine.prefill_chunk else None),
        "kv_quant": bool(kv_quant), "batch": batch, "max_len": max_len,
        "prompt_len": prompt_len, "max_new": max_new, "requests": requests,
        "waves": waves,
        "completed": len(done), "finish_reasons": reasons,
        "prefill_tok_s": pf, "decode_tok_s": dc,
        "prefill_to_decode_ratio": (pf / dc) if dc else 0.0,
        "ttft_ms": {"mean": 1e3 * float(np.mean(ttfts)) if ttfts else 0.0,
                    "p50": 1e3 * _pct(ttfts, 50), "p90": 1e3 * _pct(ttfts, 90),
                    "p95": 1e3 * _pct(ttfts, 95)},
        "itl_ms": {"p50": 1e3 * _pct(itls, 50), "p95": 1e3 * _pct(itls, 95),
                   "max": 1e3 * max(itls) if itls else 0.0},
        # schema v5: engine-metrics fields (DESIGN.md §10).  The grid
        # measures cold rates (prefix cache off), so prefix_hit_rate is the
        # hit share of *submitted* prompt tokens — 0.0 here by construction,
        # gated exactly so an accidentally-warm grid row can't land.
        "preemptions": int(preempt_total),
        "prefix_hit_rate": (hit_total / (hit_total + prefill_total)
                            if hit_total + prefill_total else 0.0),
        **_metrics_fields(engine),
    }


def bench_prefix_reuse(cfg, params, *, batch: int, max_len: int,
                       prefix_len: int, tail_len: int, max_new: int,
                       requests: int, block_size: int,
                       kv_quant: bool = False):
    """The prefix-reuse workload (PR 4): every request shares one
    block-aligned system prompt plus a unique tail, served twice — prefix
    caching on vs off — on the paged engine.  Reports the hit rate, TTFT
    both ways, and the pool's peak *live* HBM footprint against the dense
    ring's up-front reservation.  The caching-on engine is warmed with one
    seeding wave so the measured wave hits the already-sealed prefix (the
    steady state of a shared-system-prompt deployment)."""
    prefix_len = max(block_size, (prefix_len // block_size) * block_size)
    system = [(3 * i) % (cfg.vocab_size - 1) + 1 for i in range(prefix_len)]

    def wave(rid0):
        return [Request(rid=rid0 + r,
                        prompt=system + [(7 * r + i) % (cfg.vocab_size - 1) + 1
                                         for i in range(tail_len)],
                        sampling=SamplingParams(max_new=max_new, seed=r))
                for r in range(requests)]

    def serve(prefix_cache: bool):
        eng = Engine(params, cfg, batch, max_len, kv_quant=kv_quant,
                     kv_layout="paged", block_size=block_size,
                     prefix_cache=prefix_cache)
        for req in wave(0):              # warm-up + prefix-seeding wave
            eng.submit(req)
        eng.run(ticks=requests * (max_new + 4) + 20)
        eng.finished.clear()
        eng.reset_stats()
        for req in wave(1000):           # measured wave
            eng.submit(req)
        peak_live = 0
        for _ in range(requests * (max_new + 4) + 20):
            eng.step()
            peak_live = max(peak_live, eng.pool.live_blocks)
            if not len(eng.scheduler) and all(s is None for s in eng.slots):
                break
        done = eng.finished
        ttfts = [r.ttft for r in done if r.ttft is not None]
        return eng, done, ttfts, peak_live

    eng_hit, done_hit, ttft_hit, peak_live = serve(True)
    _, done_cold, ttft_cold, _ = serve(False)
    prompt_tokens = requests * (prefix_len + tail_len)
    live_bytes = peak_live * _kv_bytes_per_block(cfg, block_size, kv_quant)
    dense_bytes = _kv_bytes_dense_ring(cfg, batch, max_len, kv_quant)
    return {
        "workload": "prefix_reuse", "arch": cfg.name,
        **_mesh_profile(cfg),          # prefix workload runs single-device
        "kv_layout": "paged", "block_size": int(block_size),
        "kv_quant": bool(kv_quant),
        "batch": batch, "max_len": max_len, "prefix_len": prefix_len,
        "tail_len": tail_len, "max_new": max_new, "requests": requests,
        "completed": len(done_hit),
        "prefix_hit_tokens": int(eng_hit.stats["prefix_hit_tokens"]),
        "prefix_hit_rate": eng_hit.stats["prefix_hit_tokens"] / prompt_tokens,
        "ttft_ms_hit": {"mean": 1e3 * float(np.mean(ttft_hit)) if ttft_hit else 0.0,
                        "p50": 1e3 * _pct(ttft_hit, 50)},
        "ttft_ms_cold": {"mean": 1e3 * float(np.mean(ttft_cold)) if ttft_cold else 0.0,
                         "p50": 1e3 * _pct(ttft_cold, 50)},
        "kv_hbm_bytes_peak_live": int(live_bytes),
        "kv_hbm_bytes_dense_ring": int(dense_bytes),
        "kv_hbm_live_to_dense": live_bytes / dense_bytes if dense_bytes else 0.0,
        # schema v5 (measured wave of the caching-on engine)
        "preemptions": int(eng_hit.stats["preemptions"]),
        **_metrics_fields(eng_hit),
    }


def bench_trace_overhead(cfg, params, *, batch: int, max_len: int,
                         prompt_len: int, max_new: int, requests: int,
                         kv_layout: str = "ring", block_size=None,
                         mesh=None, waves: int = 6, decode_ticks: int = 4,
                         prefill_chunk=None):
    """Schema-v8 workload (DESIGN.md §13): what leaving per-request tracing
    on costs on the fused-window decode path.

    Two persistent engines — one traced (``trace='mem'``), one untraced —
    serve identical waves **interleaved** (off, on, off, on, …) so
    shared-host load drift lands on both sides of every pair equally.
    Each wave's decode rates are paired and the *max* on/off ratio across
    waves is kept: ``trace_overhead_pct = 100 · (1 − max_w on_w/off_w)``
    — the same best-of-waves treatment the grid rates get, so CPU noise
    can't masquerade as tracer cost.  The traced engine's token streams
    are also compared bitwise against the untraced engine's every wave:
    tracing is host-only by construction and must never perturb a stream.
    """
    kw = {}
    if kv_layout == "paged":
        kw = dict(kv_layout="paged", block_size=block_size,
                  prefix_cache=False)

    def make(trace):
        return Engine(params, cfg, batch, max_len, mesh=mesh,
                      decode_ticks=decode_ticks,
                      prefill_chunk=prefill_chunk, trace=trace, **kw)

    eng_on, eng_off = make("mem"), make(None)
    if kv_layout == "paged":
        block_size = eng_on.block_size

    def run_wave(eng, rid0):
        eng.reset_stats()
        for r in range(requests):
            prompt = [(5 * r + i) % (cfg.vocab_size - 1) + 1
                      for i in range(prompt_len)]
            eng.submit(Request(
                rid=rid0 + r, prompt=prompt,
                sampling=SamplingParams(max_new=max_new, seed=r,
                                        counter_offset=1000 * r)))
        done = list(eng.run(ticks=requests * (max_new + 4) + 20))
        eng.finished = []
        st = eng.stats
        dc = st["decode_tokens"] / st["decode_s"] if st["decode_s"] else 0.0
        return dc, {r.rid - rid0: list(r.out) for r in done}

    run_wave(eng_off, 0)                 # warm-up: compiles both engines
    run_wave(eng_on, 0)

    dc_on = dc_off = best_ratio = 0.0
    completed = 0
    streams_equal = True
    for w in range(waves):
        rid0 = (w + 1) * 10_000          # fresh rids: fresh trace timelines
        off_dc, off_streams = run_wave(eng_off, rid0)
        on_dc, on_streams = run_wave(eng_on, rid0)
        streams_equal = streams_equal and on_streams == off_streams
        completed += len(on_streams)
        dc_on, dc_off = max(dc_on, on_dc), max(dc_off, off_dc)
        if off_dc:
            best_ratio = max(best_ratio, on_dc / off_dc)
    overhead_pct = (max(0.0, 100.0 * (1.0 - best_ratio))
                    if best_ratio else 0.0)
    n_spans = sum(1 for rec in eng_on.trace.records()
                  if rec.get("kind") == "span" and rec.get("cat") == "phase"
                  and rec.get("rid") is not None)
    return {
        "workload": "trace_overhead", "arch": cfg.name,
        "policy": "none", "kernel_backend": None,
        **_mesh_profile(cfg, eng_on),
        "kv_layout": kv_layout,
        "block_size": int(block_size) if kv_layout == "paged" else None,
        "kv_quant": False, "batch": batch, "max_len": max_len,
        "prompt_len": prompt_len, "max_new": max_new,
        "requests": requests, "waves": waves,
        "decode_ticks": int(decode_ticks),
        "prefill_chunk": (int(eng_on.prefill_chunk)
                          if eng_on.prefill_chunk else None),
        "completed": int(completed),
        "decode_tok_s": dc_on,
        "decode_tok_s_untraced": dc_off,
        "trace_overhead_pct": overhead_pct,
        "streams_bitwise_equal": bool(streams_equal),
        # deterministic span-count pin: the tracer's per-request phase
        # spans across every measured wave (warm-up included — the traced
        # engine retains its whole run), so silent instrumentation loss
        # fails the gate as schema drift would.
        "trace_phase_spans": int(n_spans),
    }


def bench_spec_decode(cfg, params, *, batch: int, max_len: int,
                      prompt_len: int, max_new: int, requests: int,
                      draft_k: int = 4, kv_layout: str = "ring",
                      block_size=None, mesh=None, waves: int = 6):
    """Schema-v9 workload (DESIGN.md §14): draft-and-verify decode speedup
    over plain sequential decode, measured at the bulk-commit ceiling.

    Two persistent engines — spec-decode on, spec-decode off — serve
    identical waves interleaved (plain, spec, plain, spec, …) so shared-host
    load drift lands on both sides of every pair, and the *max* paired
    spec/plain decode-rate ratio across waves is kept
    (``spec_speedup_vs_plain``) — a same-machine ratio, so machine
    normalisation cancels and the gate bands it directly against the ≥1.5×
    contract.  The spec engine drafts with :class:`ReplayDrafter` seeded
    from the plain engine's own recorded streams: accept rate is 1 by
    construction, so the ratio isolates what the verify-dispatch mechanics
    buy (K tokens per dispatch) from workload-dependent draftability.  The
    workload-dependent side is reported separately:
    ``spec_accept_rate_prompt_lookup`` is the model-free
    :class:`PromptLookupDrafter`'s accept rate on the same waves — exact
    (deterministic greedy engine), so drafter-quality drift gates too.

    Every spec stream (replay *and* prompt-lookup) is compared bitwise
    against the plain stream each wave: acceptance is exact token match
    against the engine's own sampler, so speculation must never perturb a
    stream (the DESIGN.md §14 contract the test layer pins per-config)."""
    from repro.serve.draft import PromptLookupDrafter, ReplayDrafter
    kw = {}
    if kv_layout == "paged":
        kw = dict(kv_layout="paged", block_size=block_size,
                  prefix_cache=False)

    prompts = [[(5 * r + i) % (cfg.vocab_size - 1) + 1
                for i in range(prompt_len)] for r in range(requests)]

    def run_wave(eng, rid0):
        eng.reset_stats()
        for r, prompt in enumerate(prompts):
            eng.submit(Request(
                rid=rid0 + r, prompt=prompt,
                sampling=SamplingParams(max_new=max_new, seed=r,
                                        counter_offset=1000 * r)))
        done = list(eng.run(ticks=requests * (max_new + 4) + 20))
        eng.finished = []
        st = eng.stats
        dc = st["decode_tokens"] / st["decode_s"] if st["decode_s"] else 0.0
        return dc, {r.rid - rid0: list(r.out) for r in done}

    eng_plain = Engine(params, cfg, batch, max_len, mesh=mesh, **kw)
    if kv_layout == "paged":
        block_size = eng_plain.block_size
    # warm-up wave doubles as the replay oracle: record what plain decode
    # emits for each prompt, then draft exactly that through the spec engine
    _, oracle = run_wave(eng_plain, 0)
    streams = {tuple(p): oracle[r] for r, p in enumerate(prompts)}
    eng_spec = Engine(params, cfg, batch, max_len, mesh=mesh,
                      spec_decode=True, draft_k=draft_k,
                      drafter=ReplayDrafter(streams), **kw)
    run_wave(eng_spec, 0)                # warm-up: compiles verify + commit

    dc_spec = dc_plain = best_ratio = 0.0
    completed = 0
    streams_equal = True
    for w in range(waves):
        rid0 = (w + 1) * 10_000
        plain_dc, plain_streams = run_wave(eng_plain, rid0)
        spec_dc, spec_streams = run_wave(eng_spec, rid0)
        streams_equal = streams_equal and spec_streams == plain_streams
        completed += len(spec_streams)
        dc_plain, dc_spec = max(dc_plain, plain_dc), max(dc_spec, spec_dc)
        if plain_dc:
            best_ratio = max(best_ratio, spec_dc / plain_dc)
    mc = eng_spec.metrics.summary()["counters"]   # last measured wave
    drafted = int(mc.get("spec_draft_tokens", 0))
    accepted = int(mc.get("spec_accepted_tokens", 0))

    # the workload-dependent side: prompt-lookup drafting on the same wave
    # (untimed — one wave, accept rate and stream parity are what's pinned)
    eng_pl = Engine(params, cfg, batch, max_len, mesh=mesh,
                    spec_decode=True, draft_k=draft_k,
                    drafter=PromptLookupDrafter(), **kw)
    _, pl_streams = run_wave(eng_pl, 10_000)      # same rids as wave 0
    streams_equal = streams_equal and pl_streams == {
        r: list(out) for r, out in enumerate(streams.values())}
    plc = eng_pl.metrics.summary()["counters"]
    pl_drafted = int(plc.get("spec_draft_tokens", 0))
    pl_accepted = int(plc.get("spec_accepted_tokens", 0))
    return {
        "workload": "spec_decode", "arch": cfg.name,
        "policy": "none", "kernel_backend": None,
        **_mesh_profile(cfg, eng_spec),
        "kv_layout": kv_layout,
        "block_size": int(block_size) if kv_layout == "paged" else None,
        "kv_quant": False, "batch": batch, "max_len": max_len,
        "prompt_len": prompt_len, "max_new": max_new,
        "requests": requests, "waves": waves,
        "draft_k": int(draft_k),
        "completed": int(completed),
        "decode_tok_s": dc_spec,
        "decode_tok_s_plain": dc_plain,
        "spec_speedup_vs_plain": best_ratio,
        "streams_bitwise_equal": bool(streams_equal),
        # per-wave spec counters (DESIGN.md §10): deterministic host-side
        # quantities under the replay oracle — exact-gated
        "spec_windows": int(mc.get("spec_windows", 0)),
        "spec_draft_tokens": drafted,
        "spec_accepted_tokens": accepted,
        "spec_emitted_tokens": int(mc.get("spec_emitted_tokens", 0)),
        "spec_accept_rate": (accepted / drafted) if drafted else 0.0,
        "spec_accept_rate_prompt_lookup": ((pl_accepted / pl_drafted)
                                           if pl_drafted else 0.0),
    }


def sweep(arch: str = "smollm_135m", *, smoke: bool = False,
          full: bool = False, backend: str = "jnp", policies=POLICIES,
          reduced: bool = True, kv_layout: str = "ring", block_size=None,
          mesh_shape=None, tick_sweep=(1, 4), spec_decode: bool = False,
          draft_k: int = 4):
    """Run the policy × kv_quant grid; returns (rows, artifact).  The paged
    layout additionally runs the prefix-reuse workload on attention-only
    archs (others fall back to the ring grid — the paged pool requires
    per-position KV).  ``mesh_shape`` = (data, model) serves the grid on a
    sharded engine (DESIGN.md §9; needs data×model jax devices).

    Schema v6 adds the **tick-sweep workload** (DESIGN.md §11): the
    policy-free config re-served at each ``decode_ticks`` setting with
    chunked piggyback prefill on, at a decode-heavy shape (doubled
    ``max_new``) so the dispatch amortisation is what's measured.  Each
    ``decode_ticks > 1`` row carries ``tick_speedup_vs_1`` — its decode
    rate over the sweep's own single-tick row (machine-normalisation
    cancels in the ratio, so the gate can band it directly).

    Schema v8 adds the **trace-overhead workload** (DESIGN.md §13):
    tracing-on vs tracing-off engines interleaved on a decode-heavy shape,
    reporting ``trace_overhead_pct`` (gated against an absolute ≤2%
    ceiling) and ``streams_bitwise_equal`` (tracing must not perturb any
    token stream).

    Schema v9 adds the **spec-decode workload** (DESIGN.md §14) under
    ``spec_decode=True``: draft-and-verify decode vs plain decode on
    interleaved waves, reporting ``spec_speedup_vs_plain`` (gated ≥1.5×
    at the replay-oracle accept ceiling), prompt-lookup accept rate, and
    the bitwise stream-parity flag."""
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    params = registry.init_model(jax.random.PRNGKey(0), cfg)
    if kv_layout == "paged" and not registry.supports_paged_kv(cfg):
        print(f"arch {cfg.name} has no per-position KV to page; "
              f"falling back to kv_layout=ring", file=sys.stderr)
        kv_layout = "ring"
    mesh = None
    if mesh_shape is not None:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(*mesh_shape)

    if smoke:
        shape = dict(batch=2, max_len=32, prompt_len=8, max_new=4, requests=3)
        prefix_shape = dict(batch=2, max_len=32, prefix_len=16, tail_len=4,
                            max_new=4, requests=3)
    elif full:
        shape = dict(batch=8, max_len=256, prompt_len=64, max_new=32,
                     requests=16)
        prefix_shape = dict(batch=8, max_len=256, prefix_len=64, tail_len=16,
                            max_new=16, requests=16)
    else:
        shape = dict(batch=4, max_len=128, prompt_len=16, max_new=8,
                     requests=6)
        prefix_shape = dict(batch=4, max_len=128, prefix_len=32, tail_len=8,
                            max_new=8, requests=6)

    if kv_layout == "paged" and block_size is None:
        block_size = max(4, min(16, shape["max_len"] // 4))

    mesh_tag = (f"|mesh{mesh_shape[0]}x{mesh_shape[1]}"
                if mesh_shape is not None else "")
    rows, results = [], []
    for policy_name in policies:
        for kv_quant in (False, True):
            res = bench_config(cfg, params, policy_name, kv_quant,
                               backend=backend, kv_layout=kv_layout,
                               block_size=block_size, mesh=mesh, **shape)
            results.append(res)
            us_per_tok = (1e6 / res["decode_tok_s"]
                          if res["decode_tok_s"] else 0.0)
            rows.append((
                f"serve[{policy_name}|kv_quant={int(kv_quant)}"
                f"|{kv_layout}{mesh_tag}]", us_per_tok,
                f"prefill/decode={res['prefill_to_decode_ratio']:.1f}x "
                f"ttft_p50={res['ttft_ms']['p50']:.0f}ms"))

    if tick_sweep:
        # decode-heavy shape: the fused window amortises per-tick dispatch
        # overhead, so give it enough decode ticks to show up at smoke size
        tick_shape = dict(shape, max_new=2 * shape["max_new"])
        chunk = block_size if kv_layout == "paged" else shape["prompt_len"] // 2
        base_dc = None
        for n in sorted(set(int(t) for t in tick_sweep)):
            res = bench_config(cfg, params, "none", False, backend=backend,
                               kv_layout=kv_layout, block_size=block_size,
                               mesh=mesh, decode_ticks=n, prefill_chunk=chunk,
                               **tick_shape)
            res["workload"] = "tick_sweep"
            if n == 1:
                base_dc = res["decode_tok_s"]
            elif base_dc:
                res["tick_speedup_vs_1"] = res["decode_tok_s"] / base_dc
            results.append(res)
            rows.append((
                f"serve[tick_sweep|n={n}|{kv_layout}{mesh_tag}]",
                1e6 / res["decode_tok_s"] if res["decode_tok_s"] else 0.0,
                f"decode={res['decode_tok_s']:.0f}tok/s "
                + (f"x{res['tick_speedup_vs_1']:.2f}_vs_1tick "
                   if "tick_speedup_vs_1" in res else "")
                + f"ttft_p90={res['ttft_ms']['p90']:.0f}ms"))

    # schema v8: trace-overhead workload (DESIGN.md §13) — decode-heavy
    # shape like the tick sweep, fused windows + chunked prefill on, so the
    # tracer's per-window host work is measured where it matters most
    trace_shape = dict(shape, max_new=4 * shape["max_new"])
    trace_chunk = (block_size if kv_layout == "paged"
                   else shape["prompt_len"] // 2)
    res = bench_trace_overhead(cfg, params, kv_layout=kv_layout,
                               block_size=block_size, mesh=mesh,
                               decode_ticks=4, prefill_chunk=trace_chunk,
                               **trace_shape)
    results.append(res)
    rows.append((
        f"serve[trace_overhead|{kv_layout}{mesh_tag}]",
        1e6 / res["decode_tok_s"] if res["decode_tok_s"] else 0.0,
        f"overhead={res['trace_overhead_pct']:.2f}% "
        f"bitwise={int(res['streams_bitwise_equal'])} "
        f"decode={res['decode_tok_s']:.0f}tok/s"))

    if spec_decode:
        if not registry.supports_spec_decode(cfg):
            print(f"arch {cfg.name} does not support spec-decode "
                  f"(batched verify needs attention-only, non-MoE); "
                  f"skipping the spec workload", file=sys.stderr)
        else:
            # decode-heavy like the trace workload: windows need room to
            # amortise, and replay accept keeps every window at draft_k
            spec_shape = dict(shape, max_new=4 * shape["max_new"])
            res = bench_spec_decode(cfg, params, kv_layout=kv_layout,
                                    block_size=block_size, mesh=mesh,
                                    draft_k=draft_k, **spec_shape)
            results.append(res)
            rows.append((
                f"serve[spec_decode|k={draft_k}|{kv_layout}{mesh_tag}]",
                1e6 / res["decode_tok_s"] if res["decode_tok_s"] else 0.0,
                f"speedup={res['spec_speedup_vs_plain']:.2f}x "
                f"accept={res['spec_accept_rate']:.2f} "
                f"pl_accept={res['spec_accept_rate_prompt_lookup']:.2f} "
                f"bitwise={int(res['streams_bitwise_equal'])}"))

    if kv_layout == "paged":
        for kv_quant in (False, True):
            res = bench_prefix_reuse(cfg, params, block_size=block_size,
                                     kv_quant=kv_quant, **prefix_shape)
            results.append(res)
            speedup = (res["ttft_ms_cold"]["p50"] / res["ttft_ms_hit"]["p50"]
                       if res["ttft_ms_hit"]["p50"] else 0.0)
            rows.append((
                f"serve[prefix_reuse|kv_quant={int(kv_quant)}|paged]",
                res["ttft_ms_hit"]["p50"] * 1e3,
                f"hit_rate={res['prefix_hit_rate']:.2f} "
                f"ttft_cold/hit={speedup:.2f}x "
                f"live/dense_hbm={res['kv_hbm_live_to_dense']:.2f}"))

    artifact = {
        "version": ARTIFACT_VERSION,
        "generated_by": "benchmarks/serve_bench.py",
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "unix_time": time.time(),
        "smoke": smoke, "full": full, "arch": cfg.name, "shape": shape,
        "kv_layout": kv_layout,
        "mesh": list(mesh_shape) if mesh_shape is not None else None,
        "device_count": jax.device_count(),
        "attn_backend": dispatch.resolve_backend(None).name,
        "calibration": machine_calibration(),
        "results": results,
    }
    return rows, artifact


def run(full: bool = False):
    """benchmarks/run.py harness entry point: quick jnp-backend grid."""
    rows, _ = sweep(smoke=not full, full=full)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: tiny reduced config; quantised policies "
                         "run on the Pallas interpret backend")
    ap.add_argument("--full", action="store_true",
                    help="larger batch/prompt/max_new grid")
    ap.add_argument("--no-reduced", action="store_true",
                    help="use the full-size architecture config (slow off-TPU)")
    ap.add_argument("--policies", default=None,
                    help="comma list from {none,dither,stochastic,"
                         "deterministic} (default: all four; under --mesh "
                         "the default narrows to 'none' — pass the list "
                         "explicitly to override)")
    ap.add_argument("--kernel-backend", default=None,
                    help="policy matmul backend for quantised rows "
                         "(default: pallas-interpret under --smoke, else jnp)")
    ap.add_argument("--attn-backend", default=None,
                    help="decode-attention dispatcher backend (sets "
                         "$REPRO_KERNEL_BACKEND for the engine's decode "
                         "step; default: platform pick / existing env)")
    ap.add_argument("--kv-layout", default="ring", choices=["ring", "paged"],
                    help="KV cache layout: dense per-slot ring or the paged "
                         "block pool (adds the prefix-reuse workload)")
    ap.add_argument("--block-size", type=int, default=None,
                    help="paged pool block size in tokens")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="serve the grid on a (data, model)-sharded engine, "
                         "e.g. '2,2' (DESIGN.md §9; needs data×model "
                         "devices — on CPU force them with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N).  "
                         "Defaults the policy list to 'none': mesh rows "
                         "measure the sharded serve path, and only the "
                         "policy-free stream is pinned shard-invariant")
    ap.add_argument("--decode-ticks", default="1,4", metavar="N,N,...",
                    help="tick-sweep settings for the schema-v6 overlapped "
                         "workload (DESIGN.md §11); '' disables the sweep")
    ap.add_argument("--spec-decode", action="store_true",
                    help="run the schema-v9 speculative-decode workload "
                         "(DESIGN.md §14): replay-oracle speedup vs plain "
                         "decode + prompt-lookup accept rate")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="speculative window width for the spec workload")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="JSON artifact path ('' to skip writing)")
    args = ap.parse_args(argv)

    if args.attn_backend:
        os.environ[dispatch.ENV_VAR] = args.attn_backend
    backend = args.kernel_backend or ("pallas-interpret" if args.smoke
                                      else "jnp")
    mesh_shape = None
    policies = (tuple(args.policies.split(",")) if args.policies
                else POLICIES)
    if args.mesh:
        from repro.launch.mesh import parse_serve_mesh
        try:
            parsed = parse_serve_mesh(args.mesh)    # one shared parser
        except ValueError as e:
            ap.error(str(e))
        mesh_shape = tuple(int(parsed.shape[a]) for a in ("data", "model"))
        if args.policies is None:       # explicit --policies always wins
            policies = ("none",)
    tick_sweep = (tuple(int(t) for t in args.decode_ticks.split(","))
                  if args.decode_ticks else ())
    rows, artifact = sweep(args.arch, smoke=args.smoke, full=args.full,
                           backend=backend,
                           policies=policies,
                           reduced=not args.no_reduced,
                           kv_layout=args.kv_layout,
                           block_size=args.block_size,
                           mesh_shape=mesh_shape,
                           tick_sweep=tick_sweep,
                           spec_decode=args.spec_decode, draft_k=args.draft_k)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")

    ratios = [r["prefill_to_decode_ratio"] for r in artifact["results"]
              if "prefill_to_decode_ratio" in r]
    print(f"prefill/decode tokens/s ratio: min={min(ratios):.1f}x "
          f"max={max(ratios):.1f}x", file=sys.stderr)

    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"wrote {args.out} ({len(artifact['results'])} results)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
