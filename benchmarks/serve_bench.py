"""Serving benchmark: two-phase engine throughput / latency, JSON artifact.

Drives the ``serve/`` engine (batched prefill → batched decode, DESIGN.md
§6) over policy ∈ {none, dither, stochastic, deterministic} × kv_quant ∈
{off, on} and records, per configuration: prefill vs decode tokens/s,
time-to-first-token (TTFT) and inter-token latency (ITL) percentiles.  A
warm-up wave runs first so jit compile time stays out of the measured
rates.  The headline check is ``prefill_to_decode_ratio``: batched prefill
pushes prompt tokens at a multiple of the decode rate because a prompt
costs one forward pass instead of O(prompt_len) decode ticks.

Standalone CLI (emits the perf artifact future PRs diff against, alongside
``kernel_bench.json``):

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke     # CI: tiny
      # config; quantised policies run the Pallas interpret backend
  PYTHONPATH=src python benchmarks/serve_bench.py [--full] \
      [--arch smollm_135m] [--out benchmarks/artifacts/serve_bench.json]

The artifact schema is documented in benchmarks/README.md.  CPU numbers are
relative; they track the serving path's perf trajectory across PRs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ is None or __package__ == "":  # `python benchmarks/serve_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_root, os.path.join(_root, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels import autotune, dispatch
from repro.models import registry
from repro.numerics.policy import QuantPolicy
from repro.serve import Engine, Request, SamplingParams

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "artifacts", "serve_bench.json")

ARTIFACT_VERSION = 2

POLICIES = ("none", "dither", "stochastic", "deterministic")


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, float), q)) if xs else 0.0


def _attn_profile(cfg, max_len: int, kv_quant: bool, batch: int):
    """How decode attention runs for this config: the dispatcher backend the
    engine's traced decode step embeds, its cache-length block, and the
    analytic steady-state attention HBM bytes per generated token per slot
    (sum over attention layers, ring at full occupancy).  Since PR 3 the
    int8 cache is consumed as codes in-kernel — never upcast to a full-cap
    fp tensor — so there is no fp-upcast term."""
    backend = dispatch.resolve_backend(None).name
    cap = min(cfg.window, max_len) if cfg.window else max_len
    nkv, hd = cfg.n_kv_heads, cfg.hd()
    group = max(1, cfg.n_heads // max(1, nkv))
    if backend.startswith("pallas"):
        dtype = "int8" if kv_quant else "bfloat16"
        block = list(autotune.best_block(
            "decode_attention", (batch, cap, nkv, group, hd), dtype,
            8 if kv_quant else 16, "flash", backend))
    else:
        block = None                   # xla-ref: one whole-cap pass
    elem = 1 if kv_quant else 2
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn")
    per_layer = nkv * 2 * cap * hd * elem + cap * 4
    if kv_quant:
        per_layer += nkv * 2 * cap * 4
    return {
        "attn_backend": backend,
        "attn_block": block,
        "attn_bytes_per_token": int(n_attn * per_layer),
        "attn_full_cap_fp32_upcast": False,
    }


def bench_config(cfg, params, policy_name: str, kv_quant: bool, *,
                 backend: str, batch: int, max_len: int, prompt_len: int,
                 max_new: int, requests: int, temperature: float = 0.0,
                 waves: int = 3):
    """Measure one (policy × kv_quant) serving configuration.

    Builds a fresh engine, runs one warm-up request through the same prompt
    bucket (compiles prefill, decode and the sampler), then serves the same
    ``requests``-request wave ``waves`` times (stats reset in between) and
    reports **best-of-waves** token rates — the same best-of-N treatment
    ``kernel_bench._time_call`` uses, so shared-host load spikes don't land
    in the perf trajectory.  Latency percentiles pool every wave.
    """
    policy = (None if policy_name == "none"
              else QuantPolicy(scheme=policy_name, backend=backend))
    frames = (jnp.zeros((batch, cfg.n_enc_tokens, cfg.d_model), jnp.bfloat16)
              if cfg.is_encdec else None)
    kv_quant = kv_quant and not cfg.is_encdec   # enc-dec self-KV stays bf16
    engine = Engine(params, cfg, batch, max_len, policy=policy, frames=frames,
                    kv_quant=kv_quant)

    engine.submit(Request(rid=-1, prompt=[1] * prompt_len, max_new=2))
    engine.run(ticks=8)
    engine.finished.clear()

    pf = dc = 0.0
    done = []
    for wave in range(waves):
        engine.reset_stats()
        for r in range(requests):
            prompt = [(5 * r + i) % (cfg.vocab_size - 1) + 1
                      for i in range(prompt_len)]
            engine.submit(Request(
                rid=wave * requests + r, prompt=prompt,
                sampling=SamplingParams(temperature=temperature, seed=r,
                                        max_new=max_new,
                                        counter_offset=1000 * r)))
        done += list(engine.run(ticks=requests * (max_new + 4) + 20))
        engine.finished = []
        st = engine.stats
        if st["prefill_s"]:
            pf = max(pf, st["prefill_tokens"] / st["prefill_s"])
        if st["decode_s"]:
            dc = max(dc, st["decode_tokens"] / st["decode_s"])

    ttfts = [r.ttft for r in done if r.ttft is not None]
    itls = [x for r in done for x in r.itl]
    reasons = {}
    for r in done:
        reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
    attn_profile = _attn_profile(cfg, max_len, kv_quant, batch)
    return {
        "arch": cfg.name, "policy": policy_name,
        "kernel_backend": backend if policy_name != "none" else None,
        **attn_profile,
        "kv_quant": bool(kv_quant), "batch": batch, "max_len": max_len,
        "prompt_len": prompt_len, "max_new": max_new, "requests": requests,
        "waves": waves,
        "completed": len(done), "finish_reasons": reasons,
        "prefill_tok_s": pf, "decode_tok_s": dc,
        "prefill_to_decode_ratio": (pf / dc) if dc else 0.0,
        "ttft_ms": {"mean": 1e3 * float(np.mean(ttfts)) if ttfts else 0.0,
                    "p50": 1e3 * _pct(ttfts, 50), "p95": 1e3 * _pct(ttfts, 95)},
        "itl_ms": {"p50": 1e3 * _pct(itls, 50), "p95": 1e3 * _pct(itls, 95),
                   "max": 1e3 * max(itls) if itls else 0.0},
    }


def sweep(arch: str = "smollm_135m", *, smoke: bool = False,
          full: bool = False, backend: str = "jnp", policies=POLICIES,
          reduced: bool = True):
    """Run the policy × kv_quant grid; returns (rows, artifact)."""
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    params = registry.init_model(jax.random.PRNGKey(0), cfg)

    if smoke:
        shape = dict(batch=2, max_len=32, prompt_len=8, max_new=4, requests=3)
    elif full:
        shape = dict(batch=8, max_len=256, prompt_len=64, max_new=32,
                     requests=16)
    else:
        shape = dict(batch=4, max_len=128, prompt_len=16, max_new=8,
                     requests=6)

    rows, results = [], []
    for policy_name in policies:
        for kv_quant in (False, True):
            res = bench_config(cfg, params, policy_name, kv_quant,
                               backend=backend, **shape)
            results.append(res)
            us_per_tok = (1e6 / res["decode_tok_s"]
                          if res["decode_tok_s"] else 0.0)
            rows.append((
                f"serve[{policy_name}|kv_quant={int(kv_quant)}]", us_per_tok,
                f"prefill/decode={res['prefill_to_decode_ratio']:.1f}x "
                f"ttft_p50={res['ttft_ms']['p50']:.0f}ms"))

    artifact = {
        "version": ARTIFACT_VERSION,
        "generated_by": "benchmarks/serve_bench.py",
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "unix_time": time.time(),
        "smoke": smoke, "full": full, "arch": cfg.name, "shape": shape,
        "attn_backend": dispatch.resolve_backend(None).name,
        "results": results,
    }
    return rows, artifact


def run(full: bool = False):
    """benchmarks/run.py harness entry point: quick jnp-backend grid."""
    rows, _ = sweep(smoke=not full, full=full)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: tiny reduced config; quantised policies "
                         "run on the Pallas interpret backend")
    ap.add_argument("--full", action="store_true",
                    help="larger batch/prompt/max_new grid")
    ap.add_argument("--no-reduced", action="store_true",
                    help="use the full-size architecture config (slow off-TPU)")
    ap.add_argument("--policies", default=",".join(POLICIES),
                    help="comma list from {none,dither,stochastic,deterministic}")
    ap.add_argument("--kernel-backend", default=None,
                    help="policy matmul backend for quantised rows "
                         "(default: pallas-interpret under --smoke, else jnp)")
    ap.add_argument("--attn-backend", default=None,
                    help="decode-attention dispatcher backend (sets "
                         "$REPRO_KERNEL_BACKEND for the engine's decode "
                         "step; default: platform pick / existing env)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="JSON artifact path ('' to skip writing)")
    args = ap.parse_args(argv)

    if args.attn_backend:
        os.environ[dispatch.ENV_VAR] = args.attn_backend
    backend = args.kernel_backend or ("pallas-interpret" if args.smoke
                                      else "jnp")
    rows, artifact = sweep(args.arch, smoke=args.smoke, full=args.full,
                           backend=backend,
                           policies=tuple(args.policies.split(",")),
                           reduced=not args.no_reduced)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")

    ratios = [r["prefill_to_decode_ratio"] for r in artifact["results"]]
    print(f"prefill/decode tokens/s ratio: min={min(ratios):.1f}x "
          f"max={max(ratios):.1f}x", file=sys.stderr)

    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"wrote {args.out} ({len(artifact['results'])} results)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
