"""Figs 5–6: EMSE and |bias| of scaled addition u = (x+y)/2 via control mux."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import N_VALUES, loglog_slope, sample_xy, timer
from repro.core import ops


def run(full: bool = False):
    t = timer()
    n_pairs = 1000 if full else 200
    trials = 100 if full else 25
    x, y = sample_xy(n_pairs, seed=3)
    u = (x + y) / 2.0
    key = jax.random.PRNGKey(11)
    rows = []
    for scheme in ["stochastic", "deterministic", "dither"]:
        es, bs = [], []
        for n in N_VALUES:
            outs = []
            for tr in range(1 if scheme == "deterministic" else trials):
                k = jax.random.fold_in(jax.random.fold_in(key, n), tr)
                outs.append(ops.scaled_add_pulses(k, x, y, n, scheme))
            e = jnp.stack(outs)
            es.append(float(jnp.mean((e - u[None]) ** 2)))
            bs.append(float(jnp.abs(jnp.mean(e - u[None]))))
        rows.append((f"fig5_avg_emse_slope[{scheme}]", t(),
                     f"{loglog_slope(N_VALUES, es):.2f}"))
        rows.append((f"fig6_avg_bias_at_N{N_VALUES[-1]}[{scheme}]", t(),
                     f"{bs[-1]:.2e}"))
    return rows
