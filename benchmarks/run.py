"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` restores paper-scale
trial counts (slower); default is CI-sized.
"""

from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    "repr_emse",        # Figs 1-2
    "mult_emse",        # Figs 3-4
    "avg_emse",         # Figs 5-6
    "table1_asymptotics",  # Table I
    "matmul_frobenius",    # Fig 8
    "mnist_rounding",      # Figs 9-10
    "mnist_variants",      # Figs 11-14
    "fashion_mlp",         # Figs 15-16
    "kernel_bench",        # Pallas kernels
    "serve_bench",         # two-phase serving engine
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    mods = [m for m in MODULES if args.only is None or m in args.only.split(",")]
    print("name,us_per_call,derived")
    failed = []
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row_name, us, derived in mod.run(full=args.full):
                print(f"{row_name},{us:.0f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
