"""Figs 15–16: 3-layer ReLU MLP on the harder (Fashion-MNIST-like) synthetic
task; every matmul (3 weight layers) quantised separately before multiply
(the §VIII 'separate' scheme, as in the paper's Fashion-MNIST setup)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.core.matmul import quantized_matmul
from repro.data.mnist_like import make_dataset


def train_mlp(x, y, hidden=(128, 64), steps=1500, lr=0.15, seed=0):
    rs = np.random.RandomState(seed)
    dims = [x.shape[1], *hidden, 10]
    ws = [rs.normal(0, np.sqrt(2.0 / dims[i]), (dims[i], dims[i + 1])).astype(np.float32)
          for i in range(3)]
    bs = [np.zeros((d,), np.float32) for d in dims[1:]]
    n = x.shape[0]
    for s in range(steps):
        idx = rs.randint(0, n, 256)
        xb, yb = x[idx], y[idx]
        h1 = np.maximum(xb @ ws[0] + bs[0], 0)
        h2 = np.maximum(h1 @ ws[1] + bs[1], 0)
        logits = h2 @ ws[2] + bs[2]
        logits -= logits.max(1, keepdims=True)
        p = np.exp(logits); p /= p.sum(1, keepdims=True)
        p[np.arange(len(yb)), yb] -= 1.0
        p /= len(yb)
        g2 = h2.T @ p
        dh2 = (p @ ws[2].T) * (h2 > 0)
        g1 = h1.T @ dh2
        dh1 = (dh2 @ ws[1].T) * (h1 > 0)
        g0 = xb.T @ dh1
        for w_, g_ in zip(ws, (g0, g1, g2)):
            w_ -= lr * g_
        bs[2] -= lr * p.sum(0); bs[1] -= lr * dh2.sum(0); bs[0] -= lr * dh1.sum(0)
    return ws, bs


def _qmm(a, w, bits, scheme, seed):
    """Fixed [-1,1] quantizer range (paper §VII); activations are clipped to
    [0,1] between layers so the range convention holds at every layer."""
    return np.asarray(quantized_matmul(jnp.asarray(a), jnp.asarray(w), bits=bits,
                                       scheme=scheme, variant="separate",
                                       seed=seed, lo=-1.0, hi=1.0))


def quantized_mlp_acc(x, y, ws, bs, bits, scheme, trials, seed=0):
    # per-layer weight scaling to [-1,1]; ReLU is scale-equivariant so the
    # cumulative factor c keeps biases consistent and argmax unchanged.
    scales = [float(np.abs(w).max()) for w in ws]
    accs = []
    for tr in range(1 if scheme == "deterministic" else trials):
        s = seed + 31 * tr
        c = 1.0
        h = x
        for li in range(2):
            c *= scales[li]
            h = np.maximum(_qmm(h, ws[li] / scales[li], bits, scheme, s + li)
                           + bs[li] / c, 0)
            h = np.clip(h, 0.0, 1.0)  # keep activations in the quantizer range
        c *= scales[2]
        logits = _qmm(h, ws[2] / scales[2], bits, scheme, s + 2) + bs[2] / c
        accs.append(float((np.argmax(logits, 1) == y).mean()))
    return float(np.mean(accs)), float(np.var(accs))


def run(full: bool = False):
    t = timer()
    n_tr, n_te = (6000, 1000) if full else (2000, 400)
    trials = 20 if full else 6
    x_tr, y_tr, x_te, y_te = make_dataset(n_tr, n_te, hard=True, seed=9,
                                          noise=0.3, sharp=0.7)
    ws, bs = train_mlp(x_tr, y_tr)
    h1 = np.maximum(x_te @ ws[0] + bs[0], 0)
    h2 = np.maximum(h1 @ ws[1] + bs[1], 0)
    base = float((np.argmax(h2 @ ws[2] + bs[2], 1) == y_te).mean())
    rows = [("fig15_baseline_acc", t(), f"{base:.3f}")]
    for k in ([2, 3, 4, 6] if full else [2, 4]):
        accs = {}
        for scheme in ["deterministic", "stochastic", "dither"]:
            m, v = quantized_mlp_acc(x_te, y_te, ws, bs, k, scheme, trials)
            accs[scheme] = (m, v)
        rows.append((f"fig15_acc_k{k}", t(),
                     " ".join(f"{s[:5]}={m:.3f}" for s, (m, _) in accs.items())))
        rows.append((f"fig16_var_k{k}", t(),
                     f"dith={accs['dither'][1]:.2e} stoch={accs['stochastic'][1]:.2e}"))
    return rows
