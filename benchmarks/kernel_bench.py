"""Pallas kernel benchmark: backend × block-shape sweep with a JSON artifact.

Sweeps the fused dither-matmul and elementwise quantise kernels over the
dispatcher backends (pallas-interpret / xla-ref on CPU; pallas-tpu on TPU)
and a tile-size grid from the autotuner's candidate model, checking every
timed configuration against the kernels/ref.py oracle.  Numbers on CPU are
relative (interpret mode trades speed for bit-exactness with the TPU path);
they guide BlockSpec choices and catch regressions — absolute TPU perf comes
from the §Roofline dry-run terms.

Standalone CLI (emits the perf artifact future PRs diff against):

  PYTHONPATH=src python benchmarks/kernel_bench.py --backend all \
      [--full] [--autotune] [--out benchmarks/artifacts/kernel_bench.json]

The artifact schema is documented in benchmarks/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ is None or __package__ == "":  # `python benchmarks/kernel_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_root, os.path.join(_root, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import jax
import jax.numpy as jnp

from repro.kernels import autotune, dispatch, ref

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "artifacts", "kernel_bench.json")

ARTIFACT_VERSION = 1


def _cpu_backends():
    if jax.default_backend() == "tpu":
        return ["pallas-tpu", "xla-ref"]
    return ["pallas-interpret", "xla-ref"]


def _time_call(fn, repeats: int = 3) -> float:
    """Best-of-N wall time in µs (first call compiles, outside the timing)."""
    fn().block_until_ready()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _matmul_blocks(m: int, k: int, n: int, full: bool):
    cands = autotune.matmul_candidates(m, k, n)
    return cands if full else cands[:3]


def _quantize_blocks(m: int, n: int, full: bool):
    cands = autotune.quantize_candidates(m, n)
    return cands if full else cands[:2]


def sweep(full: bool = False, backends=None, do_autotune: bool = False):
    """Sweep; returns (rows, artifact).  rows = (name, us, derived) for the
    benchmarks/run.py CSV harness."""
    backends = backends or _cpu_backends()
    m = k = n = 256 if full else 128
    a = jax.random.uniform(jax.random.PRNGKey(0), (m, k))
    b = jax.random.uniform(jax.random.PRNGKey(1), (k, n))
    ref_out = ref.dither_matmul_ref(a, b, bits=8, scheme="dither")

    rows, results = [], []
    for backend in backends:
        blocks = ([None] if backend == "xla-ref"
                  else [None] + _matmul_blocks(m, k, n, full))
        for blk in blocks:
            out = dispatch.matmul(a, b, bits=8, scheme="dither", block=blk,
                                  backend=backend)
            err = float(jnp.max(jnp.abs(out - ref_out)))
            us = _time_call(lambda: dispatch.matmul(
                a, b, bits=8, scheme="dither", block=blk, backend=backend))
            label = "auto" if blk is None else "x".join(map(str, blk))
            rows.append((f"kernel_matmul[{backend}|blk={label}]", us,
                         f"max_err={err:.1e}"))
            results.append({
                "kernel": "dither_matmul", "backend": backend,
                "shape": [m, k, n], "bits": 8, "scheme": "dither",
                "block": list(blk) if blk else None, "us": us,
                "max_abs_err_vs_ref": err,
            })

    qm, qn = (512, 512) if full else (256, 256)
    x = jax.random.uniform(jax.random.PRNGKey(2), (qm, qn), minval=-1, maxval=1)
    ref_codes = ref.quantize_codes_ref(x, scale=255 / 2, zero=-1, bits=8,
                                       scheme="dither", counter=0, seed=0,
                                       n_pulses=16)
    for backend in backends:
        blocks = ([None] if backend == "xla-ref"
                  else [None] + _quantize_blocks(qm, qn, full))
        for blk in blocks:
            codes = dispatch.quantize(x, bits=8, lo=-1, hi=1, scheme="dither",
                                      block=blk, backend=backend)
            exact = bool(jnp.array_equal(codes, ref_codes))
            us = _time_call(lambda: dispatch.quantize(
                x, bits=8, lo=-1, hi=1, scheme="dither", block=blk,
                backend=backend))
            label = "auto" if blk is None else "x".join(map(str, blk))
            rows.append((f"kernel_quantize[{backend}|blk={label}]", us,
                         f"codes_exact={exact}"))
            results.append({
                "kernel": "quantize", "backend": backend, "shape": [qm, qn],
                "bits": 8, "scheme": "dither",
                "block": list(blk) if blk else None, "us": us,
                "codes_exact_vs_ref": exact,
            })

    winners = {}
    if do_autotune:
        for backend in backends:
            if backend == "xla-ref":
                continue  # no tiling concept
            winner, _sweep = autotune.autotune_matmul(
                m, k, n, bits=8, scheme="dither", backend=backend,
                repeats=1,
                run=lambda blk: dispatch.matmul(
                    a, b, bits=8, scheme="dither", block=tuple(blk),
                    backend=backend),
                candidates=_matmul_blocks(m, k, n, full))
            key = autotune.cache_key("matmul", (m, k, n), "float32", 8,
                                     "dither", backend)
            winners[key] = list(winner)
            rows.append((f"kernel_autotune_matmul[{backend}]", 0.0,
                         f"winner={'x'.join(map(str, winner))}"))
            q_winner, _qsweep = autotune.autotune_quantize(
                qm, qn, bits=8, scheme="dither", backend=backend,
                repeats=1,
                run=lambda blk: dispatch.quantize(
                    x, bits=8, lo=-1, hi=1, scheme="dither",
                    block=tuple(blk), backend=backend),
                candidates=_quantize_blocks(qm, qn, full))
            q_key = autotune.cache_key("quantize", (qm, qn), "float32", 8,
                                       "dither", backend)
            winners[q_key] = list(q_winner)
            rows.append((f"kernel_autotune_quantize[{backend}]", 0.0,
                         f"winner={'x'.join(map(str, q_winner))}"))

    artifact = {
        "version": ARTIFACT_VERSION,
        "generated_by": "benchmarks/kernel_bench.py",
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "unix_time": time.time(),
        "results": results,
        "autotune_winners": winners,
    }
    return rows, artifact


def run(full: bool = False):
    """benchmarks/run.py harness entry point: rows only (harness prints CSV)."""
    rows, _ = sweep(full=full)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="default",
                    help="'all', 'default' (platform pick + reference), or a "
                         "comma list of dispatcher backend names")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapes and the full tile grid")
    ap.add_argument("--autotune", action="store_true",
                    help="run the measured block sweep and cache winners")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="JSON artifact path ('' to skip writing)")
    args = ap.parse_args(argv)

    if args.backend == "all":
        backends = list(dispatch.available_backends())
        if jax.default_backend() != "tpu":
            backends.remove("pallas-tpu")  # uncompilable off-TPU
    elif args.backend == "default":
        backends = _cpu_backends()
    else:
        backends = [dispatch.resolve_backend(b).name
                    for b in args.backend.split(",")]

    rows, artifact = sweep(full=args.full, backends=backends,
                           do_autotune=args.autotune)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")

    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"wrote {args.out} ({len(artifact['results'])} results)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
