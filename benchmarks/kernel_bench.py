"""Pallas kernel benchmark: backend × block-shape sweep with a JSON artifact.

Sweeps the fused dither-matmul, elementwise quantise, and flash-decode
attention kernels over the dispatcher backends (pallas-interpret / xla-ref
on CPU; pallas-tpu on TPU) and a tile-size grid from the autotuner's
candidate model, checking every timed configuration against the
kernels/ref.py oracles.  The decode-attention sweep additionally times the
retired full-softmax einsum path (which upcast the whole int8 cache to fp)
as a baseline and reports analytic per-token HBM bytes for both, across
cap ∈ {256, 1024, 4096} under ``--full``.  Numbers on CPU are relative
(interpret mode trades speed for bit-exactness with the TPU path); they
guide BlockSpec choices and catch regressions — absolute TPU perf comes
from the §Roofline dry-run terms.

Standalone CLI (emits the perf artifact future PRs diff against):

  PYTHONPATH=src python benchmarks/kernel_bench.py --backend all \
      [--smoke | --full] [--autotune] \
      [--out benchmarks/artifacts/kernel_bench.json]

The artifact schema is documented in benchmarks/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ is None or __package__ == "":  # `python benchmarks/kernel_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for _p in (_root, os.path.join(_root, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import machine_calibration
from repro.kernels import autotune, dispatch, ref

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "artifacts", "kernel_bench.json")

ARTIFACT_VERSION = 3


def _cpu_backends():
    if jax.default_backend() == "tpu":
        return ["pallas-tpu", "xla-ref"]
    return ["pallas-interpret", "xla-ref"]


def _time_call(fn, repeats: int = 3) -> float:
    """Best-of-N wall time in µs (first call compiles, outside the timing)."""
    fn().block_until_ready()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _matmul_blocks(m: int, k: int, n: int, full: bool):
    cands = autotune.matmul_candidates(m, k, n)
    return cands if full else cands[:3]


def _quantize_blocks(m: int, n: int, full: bool):
    cands = autotune.quantize_candidates(m, n)
    return cands if full else cands[:2]


def _ring_cache(rng, b, cap, nkv, hd, pos_frac=0.75):
    """Synthetic int8 dither-code ring cache at 3/4 occupancy (so the
    length-aware block skipping shows up in the timings and byte counts)."""
    pos_val = max(0, int(cap * pos_frac) - 1)
    q = jnp.asarray(rng.normal(size=(b, nkv, 2, hd)), jnp.bfloat16)
    kpos = np.full((b, cap), -1, np.int64)
    for i in range(b):
        kpos[i, : pos_val + 1] = np.arange(pos_val + 1)
    k = jnp.asarray(rng.integers(-127, 128, size=(b, cap, nkv, hd)), jnp.int8)
    v = jnp.asarray(rng.integers(-127, 128, size=(b, cap, nkv, hd)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.1, 2.0, size=(b, cap, nkv)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.1, 2.0, size=(b, cap, nkv)), jnp.float32)
    return (q, k, v, jnp.asarray(kpos, jnp.int32),
            jnp.full((b,), pos_val, jnp.int32), ks, vs), pos_val


def decode_attn_bytes_per_token(cap, nkv, hd, *, pos, bk, quantized=True,
                                fp_upcast=False):
    """Analytic per-token attention HBM read bytes for one slot, one layer.

    The flash path reads ceil((pos+1)/bk) cache blocks of int8 K + V codes
    plus their f32 scales and k_pos; the einsum baseline read the whole cap
    *and* materialised an fp32 upcast of both code tensors."""
    elem = 1 if quantized else 2
    slots = cap if bk is None else min(cap, math.ceil((pos + 1) / bk) * bk)
    bytes_ = nkv * (2 * slots * hd * elem)              # K + V codes
    if quantized:
        bytes_ += nkv * 2 * slots * 4                   # k_scale + v_scale
    bytes_ += slots * 4                                 # k_pos
    if fp_upcast:
        bytes_ += nkv * 2 * cap * hd * 4                # full-cap fp32 copies
    return int(bytes_)


@jax.jit
def _einsum_decode_baseline(q, k, v, k_pos, pos, ks, vs):
    """The retired pre-PR-3 decode path: upcast the entire int8 ring cache
    to fp, full (cap,) logits + softmax, scales folded outside the kernel."""
    b, cap, nkv, hd = k.shape
    x_dtype = q.dtype
    logits = jnp.einsum("bhgd,bkhd->bhgk", q,
                        k.astype(x_dtype)).astype(jnp.float32) / math.sqrt(hd)
    logits = logits * (ks / 127.0).transpose(0, 2, 1)[:, :, None, :]
    valid = (k_pos >= 0) & (k_pos <= pos[:, None])
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x_dtype)
    pv = probs * (vs / 127.0).transpose(0, 2, 1)[:, :, None, :].astype(x_dtype)
    return jnp.einsum("bhgk,bkhd->bhgd", pv, v.astype(x_dtype))


def sweep_decode_attention(caps, backends=None, do_autotune: bool = False):
    """Flash-decode attention sweep: tok/s and bytes/token vs the einsum
    baseline across cache capacities.  Returns (rows, results, winners)."""
    backends = backends or _cpu_backends()
    rng = np.random.default_rng(7)
    b, nkv, group, hd = 2, 2, 2, 64
    rows, results, winners = [], [], {}
    for cap in caps:
        (q, k, v, k_pos, pos, ks, vs), pos_val = _ring_cache(rng, b, cap, nkv, hd)
        ref_out = ref.decode_attention_ref(q, k, v, k_pos, pos, ks, vs,
                                           block=(16,))
        base_us = _time_call(lambda: _einsum_decode_baseline(
            q, k, v, k_pos, pos, ks, vs))
        base_bytes = decode_attn_bytes_per_token(cap, nkv, hd, pos=pos_val,
                                                 bk=None, fp_upcast=True)
        for backend in backends:
            cands = autotune.decode_attention_candidates(
                cap, hd=hd, group=group, quantized=True)
            blocks = [None] if backend == "xla-ref" else [None] + cands[:3]
            for blk in blocks:
                out = dispatch.decode_attention(
                    q, k, v, k_pos, pos, k_scale=ks, v_scale=vs, block=blk,
                    backend=backend)
                err = float(jnp.max(jnp.abs(out - ref_out)))
                us = _time_call(lambda: dispatch.decode_attention(
                    q, k, v, k_pos, pos, k_scale=ks, v_scale=vs, block=blk,
                    backend=backend))
                eff_bk = (cap if blk is None and backend == "xla-ref"
                          else (blk or autotune.best_block(
                              "decode_attention", (b, cap, nkv, group, hd),
                              "int8", 8, "flash", backend))[0])
                bpt = decode_attn_bytes_per_token(cap, nkv, hd, pos=pos_val,
                                                  bk=eff_bk)
                label = "auto" if blk is None else str(blk[0])
                rows.append((
                    f"kernel_decode_attn[{backend}|cap={cap}|bk={label}]", us,
                    f"tok_s={b * 1e6 / us:.0f} bytes/tok={bpt} "
                    f"einsum_bytes/tok={base_bytes} max_err={err:.1e}"))
                results.append({
                    "kernel": "decode_attention", "backend": backend,
                    "shape": [b, cap, nkv, group, hd], "cap": cap,
                    "block": list(blk) if blk else None, "us": us,
                    "tok_s": b * 1e6 / us,
                    "us_einsum_baseline": base_us,
                    "bytes_per_token": bpt,
                    "bytes_per_token_einsum": base_bytes,
                    "max_abs_err_vs_ref": err,
                })
        if do_autotune:
            for backend in backends:
                if backend == "xla-ref":
                    continue
                winner, _ = autotune.autotune_decode_attention(
                    b, cap, nkv, group, hd, backend=backend, repeats=1,
                    run=lambda blk: dispatch.decode_attention(
                        q, k, v, k_pos, pos, k_scale=ks, v_scale=vs,
                        block=tuple(blk), backend=backend),
                    candidates=autotune.decode_attention_candidates(
                        cap, hd=hd, group=group, quantized=True)[:3])
                key = autotune.cache_key(
                    "decode_attention", (b, cap, nkv, group, hd), "int8", 8,
                    "flash", backend)
                winners[key] = list(winner)
                rows.append((f"kernel_autotune_decode_attn[{backend}|cap={cap}]",
                             0.0, f"winner={winner[0]}"))
    return rows, results, winners


def sweep(full: bool = False, backends=None, do_autotune: bool = False,
          smoke: bool = False):
    """Sweep; returns (rows, artifact).  rows = (name, us, derived) for the
    benchmarks/run.py CSV harness."""
    backends = backends or _cpu_backends()
    m = k = n = 256 if full else 128
    a = jax.random.uniform(jax.random.PRNGKey(0), (m, k))
    b = jax.random.uniform(jax.random.PRNGKey(1), (k, n))
    ref_out = ref.dither_matmul_ref(a, b, bits=8, scheme="dither")

    rows, results = [], []
    for backend in backends:
        blocks = ([None] if backend == "xla-ref"
                  else [None] + _matmul_blocks(m, k, n, full))
        for blk in blocks:
            out = dispatch.matmul(a, b, bits=8, scheme="dither", block=blk,
                                  backend=backend)
            err = float(jnp.max(jnp.abs(out - ref_out)))
            us = _time_call(lambda: dispatch.matmul(
                a, b, bits=8, scheme="dither", block=blk, backend=backend))
            label = "auto" if blk is None else "x".join(map(str, blk))
            rows.append((f"kernel_matmul[{backend}|blk={label}]", us,
                         f"max_err={err:.1e}"))
            results.append({
                "kernel": "dither_matmul", "backend": backend,
                "shape": [m, k, n], "bits": 8, "scheme": "dither",
                "block": list(blk) if blk else None, "us": us,
                "max_abs_err_vs_ref": err,
            })

    qm, qn = (512, 512) if full else (256, 256)
    x = jax.random.uniform(jax.random.PRNGKey(2), (qm, qn), minval=-1, maxval=1)
    ref_codes = ref.quantize_codes_ref(x, scale=255 / 2, zero=-1, bits=8,
                                       scheme="dither", counter=0, seed=0,
                                       n_pulses=16)
    for backend in backends:
        blocks = ([None] if backend == "xla-ref"
                  else [None] + _quantize_blocks(qm, qn, full))
        for blk in blocks:
            codes = dispatch.quantize(x, bits=8, lo=-1, hi=1, scheme="dither",
                                      block=blk, backend=backend)
            exact = bool(jnp.array_equal(codes, ref_codes))
            us = _time_call(lambda: dispatch.quantize(
                x, bits=8, lo=-1, hi=1, scheme="dither", block=blk,
                backend=backend))
            label = "auto" if blk is None else "x".join(map(str, blk))
            rows.append((f"kernel_quantize[{backend}|blk={label}]", us,
                         f"codes_exact={exact}"))
            results.append({
                "kernel": "quantize", "backend": backend, "shape": [qm, qn],
                "bits": 8, "scheme": "dither",
                "block": list(blk) if blk else None, "us": us,
                "codes_exact_vs_ref": exact,
            })

    winners = {}
    if do_autotune:
        for backend in backends:
            if backend == "xla-ref":
                continue  # no tiling concept
            winner, _sweep = autotune.autotune_matmul(
                m, k, n, bits=8, scheme="dither", backend=backend,
                repeats=1,
                run=lambda blk: dispatch.matmul(
                    a, b, bits=8, scheme="dither", block=tuple(blk),
                    backend=backend),
                candidates=_matmul_blocks(m, k, n, full))
            key = autotune.cache_key("matmul", (m, k, n), "float32", 8,
                                     "dither", backend)
            winners[key] = list(winner)
            rows.append((f"kernel_autotune_matmul[{backend}]", 0.0,
                         f"winner={'x'.join(map(str, winner))}"))
            q_winner, _qsweep = autotune.autotune_quantize(
                qm, qn, bits=8, scheme="dither", backend=backend,
                repeats=1,
                run=lambda blk: dispatch.quantize(
                    x, bits=8, lo=-1, hi=1, scheme="dither",
                    block=tuple(blk), backend=backend),
                candidates=_quantize_blocks(qm, qn, full))
            q_key = autotune.cache_key("quantize", (qm, qn), "float32", 8,
                                       "dither", backend)
            winners[q_key] = list(q_winner)
            rows.append((f"kernel_autotune_quantize[{backend}]", 0.0,
                         f"winner={'x'.join(map(str, q_winner))}"))

    # flash-decode attention: cap grid scales with the mode (--smoke keeps
    # CI to one small cap; --full covers the ISSUE's 256/1024/4096 sweep)
    caps = [256] if smoke else ([256, 1024, 4096] if full else [256, 1024])
    da_rows, da_results, da_winners = sweep_decode_attention(
        caps, backends=backends, do_autotune=do_autotune)
    rows += da_rows
    results += da_results
    winners.update(da_winners)

    artifact = {
        "version": ARTIFACT_VERSION,
        "generated_by": "benchmarks/kernel_bench.py",
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "unix_time": time.time(),
        "calibration": machine_calibration(),
        "results": results,
        "autotune_winners": winners,
    }
    return rows, artifact


def run(full: bool = False):
    """benchmarks/run.py harness entry point: rows only (harness prints CSV)."""
    rows, _ = sweep(full=full, smoke=not full)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="default",
                    help="'all', 'default' (platform pick + reference), or a "
                         "comma list of dispatcher backend names")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale shapes, the full tile grid, and the "
                         "cap ∈ {256,1024,4096} decode-attention sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: small matmul/quantize shapes and a "
                         "single-cap decode-attention sweep")
    ap.add_argument("--autotune", action="store_true",
                    help="run the measured block sweep and cache winners")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="JSON artifact path ('' to skip writing)")
    args = ap.parse_args(argv)

    if args.backend == "all":
        backends = list(dispatch.available_backends())
        if jax.default_backend() != "tpu":
            backends.remove("pallas-tpu")  # uncompilable off-TPU
    elif args.backend == "default":
        backends = _cpu_backends()
    else:
        backends = [dispatch.resolve_backend(b).name
                    for b in args.backend.split(",")]

    rows, artifact = sweep(full=args.full, backends=backends,
                           do_autotune=args.autotune, smoke=args.smoke)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")

    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"wrote {args.out} ({len(artifact['results'])} results)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
