"""Pallas kernel benchmark: block-shape sweep for the fused dither matmul
(interpret mode on CPU — relative numbers guide BlockSpec choices; absolute
TPU perf comes from the §Roofline dry-run terms)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import timer
from repro.kernels import ops as kops, ref


def run(full: bool = False):
    t = timer()
    m = k = n = 256 if full else 128
    a = jax.random.uniform(jax.random.PRNGKey(0), (m, k))
    b = jax.random.uniform(jax.random.PRNGKey(1), (k, n))
    rows = []
    ref_out = ref.dither_matmul_ref(a, b, bits=8, scheme="dither")
    for blk in [(64, 64, 64), (128, 128, 128), (128, 128, 64)]:
        t0 = time.time()
        out = kops.dither_matmul(a, b, bits=8, scheme="dither", block=blk)
        out.block_until_ready()
        dt = (time.time() - t0) * 1e6
        err = float(jnp.max(jnp.abs(out - ref_out)))
        rows.append((f"kernel_dither_matmul_blk{blk}", dt, f"max_err={err:.1e}"))
    # elementwise quantize kernel
    x = jax.random.uniform(jax.random.PRNGKey(2), (512, 512), minval=-1, maxval=1)
    for blk in [(128, 128), (256, 256)]:
        t0 = time.time()
        codes = kops.quantize_2d(x, bits=8, lo=-1, hi=1, scheme="dither", block=blk)
        codes.block_until_ready()
        dt = (time.time() - t0) * 1e6
        rows.append((f"kernel_quantize_blk{blk}", dt, f"mean_code={float(codes.mean()):.1f}"))
    return rows
