"""Fig 8: Frobenius error ‖AB − Ĉ‖_F of k-bit rounded matmul, entries in
[0, 0.5) (narrow range vs quantizer), per rounding scheme and k.

Also exercises the Pallas fused kernel ('separate' variant) so the bench
covers both the reference path and the production kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.core.matmul import matmul_error, quantized_matmul
from repro.kernels import ops as kops


def run(full: bool = False):
    t = timer()
    size = 100
    n_mats = 20 if full else 5
    ks = [1, 2, 3, 4, 6, 8]
    rows = []
    errs = {s: {k: [] for k in ks} for s in
            ["deterministic", "stochastic", "dither", "dither_pallas"]}
    for m in range(n_mats):
        rs = np.random.RandomState(m)
        a = jnp.asarray(rs.rand(size, size).astype(np.float32) * 0.5)
        b = jnp.asarray(rs.rand(size, size).astype(np.float32) * 0.5)
        for k in ks:
            for scheme in ["deterministic", "stochastic", "dither"]:
                c = quantized_matmul(a, b, bits=k, scheme=scheme,
                                     variant="per_partial", seed=m)
                errs[scheme][k].append(float(matmul_error(a, b, c)))
            ck = kops.dither_matmul(a, b, bits=k, scheme="dither", counter=m,
                                    block=(64, 64, 64))
            errs["dither_pallas"][k].append(float(matmul_error(a, b, ck)))
    for k in ks:
        vals = {s: float(np.mean(errs[s][k])) for s in errs}
        rows.append((f"fig8_ef_k{k}", t(),
                     " ".join(f"{s[:6]}={v:.3f}" for s, v in vals.items())))
    # the paper's qualitative claims
    small_k_win = np.mean(errs["dither"][1]) < np.mean(errs["deterministic"][1])
    dither_le_stoch = np.mean(errs["dither"][2]) <= np.mean(errs["stochastic"][2]) * 1.1
    rows.append(("fig8_dither_beats_det_at_k1", t(), str(bool(small_k_win))))
    rows.append(("fig8_dither_le_stoch_at_k2", t(), str(bool(dither_le_stoch))))
    return rows
