"""Figs 1–2: EMSE L and |bias| of representing x, per scheme and N.

Validates: stochastic L ≈ 1/(6N); deterministic L ≈ 1/(12N²);
dither L ≤ 2/N² with ~zero bias; bias SEM slope dither ≈ -1 vs
stochastic ≈ -1/2 (paper's Fig 2 discussion).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import N_VALUES, loglog_slope, sample_xy, timer
from repro.core import representations as rep
from repro.core import theory


def _estimate(scheme: str, x, n: int, trials: int, key):
    outs = []
    for t in range(trials):
        k = jax.random.fold_in(key, t)
        if scheme == "stochastic":
            p = rep.stochastic_encode(k, x, n)
        elif scheme == "deterministic":
            p = rep.deterministic_encode(x, n)
        else:
            p = rep.dither_encode(k, x, n)
        outs.append(rep.decode(p))
        if scheme == "deterministic":
            break  # deterministic: single trial (paper footnote 2)
    e = jnp.stack(outs)
    emse = float(jnp.mean((e - x[None]) ** 2))
    bias = float(jnp.abs(jnp.mean(e - x[None])))
    return emse, bias


def run(full: bool = False):
    t = timer()
    n_pairs = 1000 if full else 200
    trials = 200 if full else 40
    x, _ = sample_xy(n_pairs)
    key = jax.random.PRNGKey(42)
    rows = []
    curves = {}
    for scheme in ["stochastic", "deterministic", "dither"]:
        es, bs = [], []
        for n in N_VALUES:
            emse, bias = _estimate(scheme, x, n, trials, jax.random.fold_in(key, n))
            es.append(emse)
            bs.append(bias)
        curves[scheme] = (es, bs)
        rows.append((f"fig1_emse_slope[{scheme}]", t(), f"{loglog_slope(N_VALUES, es):.2f}"))
    # paper checks
    n0 = N_VALUES[-1]
    checks = {
        "stoch_vs_1/(6N)": curves["stochastic"][0][-1] * 6 * n0,
        "det_vs_1/(12N^2)": curves["deterministic"][0][-1] * 12 * n0 * n0,
        "dither_under_2/N^2": curves["dither"][0][-1] * n0 * n0 / 2.0,
    }
    for k, v in checks.items():
        rows.append((f"fig1_{k}", t(), f"{v:.2f}"))
    rows.append(("fig2_bias_dither_lt_stoch",
                 t(), f"{curves['dither'][1][-1] < curves['stochastic'][1][-1]}"))
    return rows
