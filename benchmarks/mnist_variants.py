"""Figs 11–14: the §VIII rounding-placement variants on the MNIST-like task.

Fig 11/12: 'round_a_once' (input quantised once, pq(r+1) roundings).
Fig 13/14: 'separate' (both matrices quantised once, (p+r)q roundings).
"""

from __future__ import annotations

from benchmarks.common import timer
from benchmarks.mnist_rounding import run as run_base


def run(full: bool = False):
    t = timer()
    rows = []
    for fig, variant in (("fig11_12", "round_a_once"), ("fig13_14", "separate")):
        for name, us, derived in run_base(full, variant=variant):
            rows.append((name.replace("fig9", f"{fig}_acc")
                             .replace("fig10", f"{fig}_var"), t(), derived))
    return rows
