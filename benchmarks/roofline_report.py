"""Render the dry-run JSON into the roofline markdown table (the DESIGN.md
§5 scaling cells; rows = arch × cell, columns = compute/memory/collective
roofline terms)."""

from __future__ import annotations

import json
import sys


def fmt(x, pct=False):
    if pct:
        return f"{100*x:.0f}%"
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def step_time_bound(rt):
    """Optimistic step time = max of the three terms (perfect overlap)."""
    return max(rt["compute"], rt["memory"], rt["collective"])


def roofline_fraction(x):
    """compute_term / max(all terms): 1.0 = compute-bound at peak."""
    rt = x["roofline_seconds"]
    t = step_time_bound(rt)
    return rt["compute"] / t if t > 0 else 0.0


def render(path, mesh_filter="single"):
    rows = json.load(open(path))
    out = []
    out.append("| arch | shape | mesh | compute | memory | collective | dominant "
               "| roofline frac | useful FLOPs (6ND/HLO) | one-line fix |")
    out.append("|---|---|---|---|---|---|---|---|---|---|"[:-4])
    fixes = {
        "compute": "reduce remat recompute / quantized (int8) matmul path",
        "memory": "int8 KV cache + wider decode batch per chip",
        "collective": "overlap DP reduce w/ backward; dither-compress grads; "
                      "localise MoE dispatch",
    }
    for x in rows:
        if x.get("mesh") != mesh_filter and mesh_filter != "all":
            continue
        if x["status"] == "skip":
            out.append(f"| {x['arch']} | {x['shape']} | {x['mesh']} | — | — | — | "
                       f"skip | — | — | {x['reason'][:60]}… |")
            continue
        if x["status"] != "ok":
            continue
        rt = x["roofline_seconds"]
        out.append(
            f"| {x['arch']} | {x['shape']} | {x['mesh']} "
            f"| {fmt(rt['compute'])} | {fmt(rt['memory'])} "
            f"| {fmt(rt['collective'])} | {x['dominant']} "
            f"| {fmt(roofline_fraction(x), pct=True)} "
            f"| {x['useful_flops_ratio']:.2f} | {fixes[x['dominant']][:58]} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun_baseline.json"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "all"
    print(render(path, mesh))
