"""Table I: asymptotic orders of bias/variance/EMSE for all 3 schemes × 3 ops.

Fits log-log slopes of sample estimates against N and compares with the
paper's claimed exponents (None = exactly-zero bias → checked as 'small').
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import N_VALUES, loglog_slope, sample_xy, timer
from repro.core import ops, representations as rep, theory


def _samples(scheme, op, x, y, n, trials, key):
    outs = []
    for tr in range(1 if scheme == "deterministic" else trials):
        k = jax.random.fold_in(key, tr)
        if op == "repr":
            if scheme == "stochastic":
                outs.append(rep.decode(rep.stochastic_encode(k, x, n)))
            elif scheme == "deterministic":
                outs.append(rep.decode(rep.deterministic_encode(x, n)))
            else:
                outs.append(rep.decode(rep.dither_encode(k, x, n)))
        elif op == "mult":
            outs.append(ops.multiply_estimate(k, x, y, n, scheme))
        else:
            outs.append(ops.scaled_add_pulses(k, x, y, n, scheme))
    return jnp.stack(outs)


def run(full: bool = False):
    t = timer()
    n_pairs = 600 if full else 150
    trials = 60 if full else 20
    x, y = sample_xy(n_pairs, seed=5)
    target = {"repr": x, "mult": x * y, "avg": (x + y) / 2}
    rows = []
    for (scheme, op), want in theory.TABLE_I.items():
        vs = []
        for n in N_VALUES:
            e = _samples(scheme, op, x, y, n, trials,
                         jax.random.fold_in(jax.random.PRNGKey(13), n))
            var = float(jnp.mean(jnp.var(e, axis=0)))
            vs.append(max(var, 1e-18))
        slope = loglog_slope(N_VALUES, vs)
        claim = want["var"]
        if claim is None:
            verdict = "var~0" if vs[-1] < 1e-6 else f"var={vs[-1]:.1e}"
        else:
            verdict = f"slope={slope:.2f} (claim -{claim})"
        rows.append((f"table1_var[{scheme},{op}]", t(), verdict))
    return rows
