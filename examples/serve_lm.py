"""Serving demo: the two-phase engine end to end (DESIGN.md §6).

Requests go through the scheduler into decode slots; prompts prefill in one
batched forward (KV written per-slot); decode runs under per-request
sampling with streaming callbacks.

  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax

from repro.configs import get_config
from repro.models import registry
from repro.numerics.policy import QuantPolicy
from repro.serve import Engine, Request, SamplingParams

cfg = get_config("smollm_135m").reduced()
params = registry.init_model(jax.random.PRNGKey(0), cfg)

engine = Engine(params, cfg, batch=4, max_len=128,
                policy=QuantPolicy(scheme="dither", bits=8),
                scheduler="priority")


def on_token(req, tok):
    if len(req.out) == 1:
        print(f"  [stream] req {req.rid} first token: {tok}")


for rid in range(8):
    engine.submit(Request(
        rid=rid,
        prompt=[1 + rid, 2, 3],
        priority=1 if rid >= 6 else 0,        # late VIPs overtake the queue
        stream=on_token if rid == 0 else None,
        sampling=SamplingParams(
            temperature=0.7 if rid % 2 else 0.0,   # mix greedy + sampled
            top_k=16, seed=rid, max_new=12,
            counter_offset=1000 * rid),            # independent dither walks
    ))

t0 = time.time()
done = engine.run(ticks=400)
dt = time.time() - t0
for r in sorted(done, key=lambda r: r.rid):
    print(f"request {r.rid} [{r.finish_reason}]: {r.out}")
st = engine.stats
print(f"{len(done)} requests, {sum(len(r.out) for r in done)} tokens in {dt:.1f}s "
      f"(prefill {st['prefill_tokens']}tok/{st['prefill_s']:.2f}s, "
      f"decode {st['decode_tokens']}tok/{st['decode_s']:.2f}s)")
