"""Serving demo: continuous batching over the ring-buffer KV cache engine.

  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax

from repro.configs import get_config
from repro.models import registry
from repro.numerics.policy import QuantPolicy
from repro.serve.engine import Engine, Request

cfg = get_config("smollm_135m").reduced()
params = registry.init_model(jax.random.PRNGKey(0), cfg)

engine = Engine(params, cfg, batch=4, max_len=128,
                policy=QuantPolicy(scheme="dither", bits=8))
for rid in range(8):
    engine.submit(Request(rid=rid, prompt=[1 + rid, 2, 3], max_new=12))

t0 = time.time()
done = engine.run(ticks=400)
dt = time.time() - t0
for r in sorted(done, key=lambda r: r.rid):
    print(f"request {r.rid}: {r.out}")
print(f"{len(done)} requests, {sum(len(r.out) for r in done)} tokens "
      f"in {dt:.1f}s")
