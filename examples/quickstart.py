"""Quickstart: the dither-computing core API in 2 minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import representations as rep, rounding, theory
from repro.core.matmul import matmul_error, quantized_matmul
from repro.kernels import ops as kops

key = jax.random.PRNGKey(0)

# --- 1. Represent reals as pulse sequences (paper §II) ----------------------
x = jax.random.uniform(key, (5,))
N = 64
pulses = rep.dither_encode(key, x, N)          # N pulses, unbiased, Var ≤ 2/N²
print("x        =", [f"{v:.3f}" for v in x])
print("dither   =", [f"{v:.3f}" for v in rep.decode(pulses)])
print("EMSE bound 2/N² =", theory.emse_repr_dither_bound(N))

# --- 2. Dither rounding: stochastic rounding with a counter (§VII) ----------
vals = jnp.array([1.3, 2.7, 0.5])
for i in range(4):
    print(f"dither_round(counter={i}) ->", rounding.dither_round(vals, i, seed=7, n_pulses=8))

# --- 3. k-bit quantised matmul, three rounding placements (§VII–VIII) -------
a = jax.random.uniform(jax.random.PRNGKey(1), (64, 64)) * 0.5
b = jax.random.uniform(jax.random.PRNGKey(2), (64, 64)) * 0.5
for scheme in ["deterministic", "stochastic", "dither"]:
    c = quantized_matmul(a, b, bits=2, scheme=scheme, variant="per_partial")
    print(f"k=2 {scheme:14s} ‖AB−Ĉ‖_F = {float(matmul_error(a, b, c)):.3f}")

# --- 4. The fused Pallas TPU kernel (interpret mode on CPU) ------------------
c = kops.dither_matmul(a, b, bits=8, scheme="dither", block=(64, 64, 64))
print("pallas dither_matmul err:", float(matmul_error(a, b, c)))
