"""End-to-end training driver: train an LM with dither-rounded int8 matmuls,
checkpointing, WSD schedule, and gradient compression.

CPU demo (reduced config, a few hundred steps):
  PYTHONPATH=src python examples/train_lm.py
Full-scale (same code path on a TPU mesh):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_1_5b --steps 1000 ...
"""

import numpy as np

from repro.configs import get_config
from repro.launch.train import run_training
from repro.numerics.policy import QuantPolicy

cfg = get_config("smollm_135m").reduced()
steps, losses = run_training(
    cfg,
    steps=200,
    batch=8,
    seq=64,
    policy=QuantPolicy(scheme="dither", bits=8),      # the paper's numerics
    grad_policy=QuantPolicy(scheme="dither", bits=8),  # compressed DP grads
    ckpt_dir="/tmp/repro_train_demo",
    schedule="wsd",
    peak_lr=3e-3,
)
print(f"trained {steps} steps: loss {np.mean(losses[:10]):.3f} -> "
      f"{np.mean(losses[-10:]):.3f}")
