"""Mini reproduction of the paper's figures on your laptop:

  * Figs 1–2 (representation EMSE/bias vs N),
  * Fig 8 (k-bit matmul Frobenius error),
  * the MNIST-style accuracy ordering (Fig 9).

  PYTHONPATH=src python examples/rounding_study.py
"""

from benchmarks import matmul_frobenius, mnist_rounding, repr_emse

for mod, name in [(repr_emse, "Figs 1-2"), (matmul_frobenius, "Fig 8"),
                  (mnist_rounding, "Figs 9-10")]:
    print(f"== {name} ==")
    for row, _, derived in mod.run(full=False):
        print(f"  {row:42s} {derived}")
